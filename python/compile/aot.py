"""AOT compile path: lower every L2 function to HLO *text* artifacts.

Runs once at build time (`make artifacts`); the rust runtime loads the
emitted `artifacts/*.hlo.txt` via `HloModuleProto::from_text_file` and
executes them on the PJRT CPU client. HLO text — NOT `.serialize()` —
is the interchange format: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also emits:
  * MANIFEST.json — artifact index (shapes/dtypes) the rust runtime parses;
  * transformer_init.bin — flat f32 initial parameters for the E2E example.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, transformer


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "s32", "float64": "f64", "bfloat16": "bf16"}[
        jnp.dtype(dt).name
    ]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_and_write(fn, args, name: str, out_dir: str) -> dict:
    """jit+lower `fn` at the arg specs, write HLO text, return manifest row."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    row = {
        "name": name,
        "file": fname,
        "inputs": [{"shape": list(a.shape), "dtype": _dtype_tag(a.dtype)} for a in args],
        "outputs": [{"shape": list(o.shape), "dtype": _dtype_tag(o.dtype)} for o in outs],
    }
    print(f"  wrote {fname}: {len(text)} chars, "
          f"{len(row['inputs'])} in / {len(row['outputs'])} out")
    return row


# (n, b, k) shapes for the least-squares pipeline. See DESIGN.md §4.
#   quickstart: tiny; fig4: scaled cluster regime (m=24, d=3, n=16);
#   fig5: full simulated regime (m=6552, d=6, n=2184, LPS(5,13)).
LSTSQ_SHAPES = [
    ("qs", 16, 8, 32),
    ("fig4", 16, 375, 2000),
    ("fig5", 2184, 3, 200),
]
# Per-worker shapes: a graph-scheme machine holds exactly 2 blocks.
WORKER_SHAPES = [("qs", 2, 8, 32), ("fig4", 2, 375, 2000), ("fig5", 2, 3, 200)]


def export_lstsq(out_dir: str) -> list:
    rows = []
    for tag, n, b, k in LSTSQ_SHAPES:
        rows.append(lower_and_write(
            model.batched_block_grad,
            (_spec((k,)), _spec((n, b, k)), _spec((n, b))),
            f"block_grad_{tag}_{n}x{b}x{k}", out_dir))
        rows.append(lower_and_write(
            model.decode_combine, (_spec((n, k)), _spec((n,))),
            f"decode_combine_{tag}_{n}x{k}", out_dir))
        rows.append(lower_and_write(
            model.lstsq_loss,
            (_spec((k,)), _spec((n, b, k)), _spec((n, b))),
            f"lstsq_loss_{tag}_{n}x{b}x{k}", out_dir))
    for tag, n, b, k in WORKER_SHAPES:
        rows.append(lower_and_write(
            model.worker_block_grad,
            (_spec((k,)), _spec((n, b, k)), _spec((n, b))),
            f"worker_grad_{tag}_{n}x{b}x{k}", out_dir))
    return rows


def export_transformer(out_dir: str, cfg: transformer.GptConfig,
                       n_blocks: int, batch: int) -> tuple:
    p = transformer.n_params(cfg)
    loss_scale = 1.0 / (n_blocks * batch * cfg.seq_len)
    tok = _spec((batch, cfg.seq_len + 1), jnp.int32)
    tok_all = _spec((n_blocks, batch, cfg.seq_len + 1), jnp.int32)
    flat = _spec((p,))
    rows = [
        lower_and_write(transformer.block_grad_fn(cfg, loss_scale),
                        (flat, tok), "tfm_block_grad", out_dir),
        lower_and_write(transformer.block_grad_all_fn(cfg, loss_scale),
                        (flat, tok_all), "tfm_block_grad_all", out_dir),
        lower_and_write(transformer.eval_loss_fn(cfg),
                        (flat, tok), "tfm_eval_loss", out_dir),
    ]
    init = transformer.init_params(cfg, seed=0)
    init.tofile(os.path.join(out_dir, "transformer_init.bin"))
    print(f"  wrote transformer_init.bin: {p} params")
    meta = {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_head": cfg.n_head,
        "n_layer": cfg.n_layer, "seq_len": cfg.seq_len, "n_params": p,
        "n_blocks": n_blocks, "batch": batch, "loss_scale": loss_scale,
        "init_file": "transformer_init.bin",
    }
    return rows, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", choices=["lstsq", "transformer", "all"], default="all")
    ap.add_argument("--tfm-blocks", type=int, default=16)
    ap.add_argument("--tfm-batch", type=int, default=8)
    ap.add_argument("--tfm-d-model", type=int, default=128)
    ap.add_argument("--tfm-layers", type=int, default=2)
    ap.add_argument("--tfm-seq", type=int, default=64)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    rows, tfm_meta = [], None
    if args.only in ("lstsq", "all"):
        print("exporting least-squares pipeline artifacts:")
        rows += export_lstsq(args.out_dir)
    if args.only in ("transformer", "all"):
        print("exporting transformer artifacts:")
        cfg = transformer.GptConfig(
            d_model=args.tfm_d_model, n_layer=args.tfm_layers, seq_len=args.tfm_seq)
        trows, tfm_meta = export_transformer(
            args.out_dir, cfg, args.tfm_blocks, args.tfm_batch)
        rows += trows

    manifest_path = os.path.join(args.out_dir, "MANIFEST.json")
    # merge with any existing manifest so --only partial runs don't drop rows
    existing = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        existing = {r["name"]: r for r in old.get("artifacts", [])}
        if tfm_meta is None:
            tfm_meta = old.get("transformer")
    for r in rows:
        existing[r["name"]] = r
    manifest = {"artifacts": sorted(existing.values(), key=lambda r: r["name"]),
                "transformer": tfm_meta}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path} ({len(existing)} artifacts)")


if __name__ == "__main__":
    main()
