"""Layer-2: a small GPT-style decoder LM for the end-to-end example.

The end-to-end driver (examples/transformer_e2e.rs) trains this model
with coded gradient descent: data blocks are shards of token sequences,
workers compute per-block gradients of the LM loss via the AOT-lowered
`block_grad_fn`, and the rust leader decodes + applies SGD on a *flat*
f32 parameter vector. Keeping params flat means the rust side never
needs to know the pytree structure — the HLO unflattens internally from
the static spec below.

The MLP projections go through the Layer-1 Pallas matmul kernel
(kernels/matmul.py, custom VJP) so the transformer exercises the full
L1 -> L2 -> L3 stack; attention/layernorm stay plain jnp (they lower to
fused HLO anyway and are not the FLOP hot-spot at these sizes).
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.matmul import matmul


@dataclass(frozen=True)
class GptConfig:
    vocab: int = 256      # byte-level
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    seq_len: int = 64

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def param_spec(cfg: GptConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat parameter layout."""
    d, f = cfg.d_model, cfg.d_ff
    spec = [("tok_emb", (cfg.vocab, d)), ("pos_emb", (cfg.seq_len, d))]
    for l in range(cfg.n_layer):
        p = f"l{l}."
        spec += [
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "qkv_w", (d, 3 * d)), (p + "qkv_b", (3 * d,)),
            (p + "proj_w", (d, d)), (p + "proj_b", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
            (p + "mlp_in_w", (d, f)), (p + "mlp_in_b", (f,)),
            (p + "mlp_out_w", (f, d)), (p + "mlp_out_b", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return spec


def n_params(cfg: GptConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_spec(cfg))


def unflatten(cfg: GptConfig, flat: jnp.ndarray) -> dict:
    """Slice the flat vector back into named tensors (static shapes)."""
    out, off = {}, 0
    for name, shape in param_spec(cfg):
        size = int(np.prod(shape))
        out[name] = flat[off : off + size].reshape(shape)
        off += size
    return out


def init_params(cfg: GptConfig, seed: int = 0) -> np.ndarray:
    """GPT-2-style init, returned flat (numpy, build-time only)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        base = name.split(".")[-1]
        if base.endswith("_b"):
            w = np.zeros(shape, np.float32)
        elif base in ("ln1_g", "ln2_g", "lnf_g"):
            w = np.ones(shape, np.float32)
        elif base == "proj_w" or base == "mlp_out_w":
            # scaled residual-branch init
            w = rng.normal(0.0, 0.02 / np.sqrt(2 * cfg.n_layer), shape).astype(np.float32)
        else:
            w = rng.normal(0.0, 0.02, shape).astype(np.float32)
        chunks.append(w.reshape(-1))
    return np.concatenate(chunks)


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dense(x, w, b):
    """(..., D) @ (D, F) + b through the Pallas matmul kernel."""
    lead = x.shape[:-1]
    y = matmul(x.reshape(-1, x.shape[-1]), w) + b
    return y.reshape(*lead, w.shape[-1])


def _attention(cfg: GptConfig, x, p, prefix):
    b, t, d = x.shape
    h, dh = cfg.n_head, cfg.d_model // cfg.n_head
    qkv = _dense(x, p[prefix + "qkv_w"], p[prefix + "qkv_b"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return _dense(out, p[prefix + "proj_w"], p[prefix + "proj_b"])


def forward(cfg: GptConfig, flat: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B,T) int32 -> logits (B,T,V). LM head tied to tok_emb."""
    p = unflatten(cfg, flat)
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][:t]
    for l in range(cfg.n_layer):
        pre = f"l{l}."
        x = x + _attention(cfg, _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"]), p, pre)
        hmid = _dense(_layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"]),
                      p[pre + "mlp_in_w"], p[pre + "mlp_in_b"])
        x = x + _dense(jax.nn.gelu(hmid), p[pre + "mlp_out_w"], p[pre + "mlp_out_b"])
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    return matmul(x.reshape(b * t, -1), p["tok_emb"].T).reshape(b, t, cfg.vocab)


def block_loss(cfg: GptConfig, flat, tokens, loss_scale: float):
    """f_i(theta): scaled summed next-token CE over one data block.

    tokens: (B, T+1) int32 — inputs tokens[:, :-1], targets tokens[:, 1:].
    With loss_scale = 1/(n_blocks*B*T), sum_i f_i is the global mean CE,
    so the coded update matches uncoded full-batch GD on mean loss.
    """
    logits = forward(cfg, flat, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll) * loss_scale


def block_grad_fn(cfg: GptConfig, loss_scale: float):
    """(flat (P,), tokens (B,T+1)) -> (grad (P,), loss) — the worker HLO."""
    def fn(flat, tokens):
        loss, grad = jax.value_and_grad(
            lambda f: block_loss(cfg, f, tokens, loss_scale)
        )(flat)
        return grad, loss
    return fn


def block_grad_all_fn(cfg: GptConfig, loss_scale: float):
    """(flat (P,), tokens (n,B,T+1)) -> (grads (n,P), losses (n,)).

    vmapped over blocks — the simulated GCOD engine's single dispatch.
    """
    single = block_grad_fn(cfg, loss_scale)
    def fn(flat, tokens_all):
        return jax.vmap(lambda tk: single(flat, tk))(tokens_all)
    return fn


def eval_loss_fn(cfg: GptConfig):
    """(flat, tokens (B,T+1)) -> mean CE, for held-out eval curves."""
    def fn(flat, tokens):
        return (block_loss(cfg, flat, tokens, 1.0 / (tokens.shape[0] * (tokens.shape[1] - 1))),)
    return fn
