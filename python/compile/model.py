"""Layer-2 JAX model: the compute graphs the rust coordinator executes.

Everything here is *build-time only*: `aot.py` lowers these jitted
functions to HLO text once, and the rust runtime (rust/src/runtime/)
loads + executes the artifacts on the PJRT CPU client. Python never
runs on the request path.

The functions call the Layer-1 Pallas kernels (kernels/*.py); their
pure-jnp oracles live in kernels/ref.py and pytest pins them together.
"""

import jax
import jax.numpy as jnp

from .kernels.block_grad import block_grad as _block_grad_kernel
from .kernels.decode_combine import decode_combine as _decode_combine_kernel


def batched_block_grad(theta, x, y):
    """All-blocks least-squares gradients, (k,),(n,b,k),(n,b) -> (n,k).

    Used by the simulated GCOD engine (Algorithm 3): one PJRT dispatch
    computes every block gradient; the rust side then samples stragglers,
    decodes, and combines.
    """
    return (_block_grad_kernel(theta, x, y),)


def worker_block_grad(theta, x, y):
    """A single worker's view: its own blocks only (graph schemes: n=2).

    Same computation as `batched_block_grad` but lowered for the
    per-machine shapes the distributed coordinator feeds each worker.
    Returns the per-block gradients; the worker sums them into its
    message g_j = sum_i A_ij grad_i in rust (cheap axpy) or the leader
    decodes per-block directly.
    """
    return (_block_grad_kernel(theta, x, y),)


def decode_combine(g, w):
    """Parameter-server combine u = G^T w, (n,k),(n,) -> (k,)."""
    return (_decode_combine_kernel(g, w),)


def sgd_step(theta, update, gamma):
    """theta' = theta - gamma * update  (gamma as a scalar input)."""
    return (theta - gamma * update,)


def lstsq_loss(theta, x, y):
    """Full objective |X theta - y|^2 over stacked blocks, for eval curves."""
    r = jnp.einsum("nbk,k->nb", x, theta) - y
    return (jnp.sum(r * r),)
