"""Tiled Pallas matmul with a custom VJP.

This is the generic MXU-shaped building block the L2 transformer uses
for its dense projections. The forward pass is a (TM, TN) output-tiled
kernel with the full K dimension staged through VMEM per tile; the
backward pass reuses the same kernel for dA = dC @ B^T and dB = A^T @ dC
so that gradients also flow through Pallas (jax cannot differentiate a
raw ``pallas_call``).

TPU notes (this session lowers with ``interpret=True`` so the kernel
becomes plain HLO runnable on the CPU PJRT client — see DESIGN.md
§Hardware-Adaptation):

* default tiles are 128x128, the MXU systolic-array shape;
* per-program VMEM footprint is TM*K + K*TN + TM*TN f32 words; the
  default tiles keep this under ~2 MiB for K <= 2048, inside a 16 MiB
  VMEM budget with double buffering headroom;
* K is not tiled: for the shapes this repo lowers (K <= 4096) a full-K
  stripe is the better schedule because it avoids a VMEM accumulator
  revisit per K-tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output tile: the MXU shape.
TILE_M = 128
TILE_N = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (TM, TN) output tile: full-K stripe product."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _ceil_to(x: int, t: int) -> int:
    return ((x + t - 1) // t) * t


def _matmul_padded(a, b, tile_m, tile_n, out_dtype):
    """Pad operands to tile multiples, run the grid, slice the result."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims mismatch: {a.shape} @ {b.shape}"
    mp, np_ = _ceil_to(m, tile_m), _ceil_to(n, tile_n)
    if mp != m:
        a = jnp.pad(a, ((0, mp - m), (0, 0)))
    if np_ != n:
        b = jnp.pad(b, ((0, 0), (0, np_ - n)))
    grid = (mp // tile_m, np_ // tile_n)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,
    )(a, b)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul(a: jnp.ndarray, b: jnp.ndarray, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """Pallas tiled matmul: (M,K) @ (K,N) -> (M,N), differentiable."""
    return _matmul_padded(a, b, tile_m, tile_n, jnp.result_type(a, b))


def _matmul_fwd(a, b, tile_m, tile_n):
    return matmul(a, b, tile_m, tile_n), (a, b)


def _matmul_bwd(tile_m, tile_n, res, dc):
    a, b = res
    # dA = dC @ B^T ; dB = A^T @ dC — both through the same Pallas kernel.
    da = _matmul_padded(dc, b.T, tile_m, tile_n, a.dtype)
    db = _matmul_padded(a.T, dc, tile_m, tile_n, b.dtype)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
