"""Pallas kernel for the parameter server's decode-combine step.

After the optimal decoder picks coefficients w (w_j = 0 for stragglers,
component-wise values elsewhere — paper Section III), the update
direction is

    u = G^T w = sum_i w[i] * G[i]            G: (n,k), w: (n,)

The kernel tiles the feature dimension; each program reduces the full
n dimension for one k-tile (a (TK, n) @ (n,) matvec on the MXU's vector
path). VMEM per program: n*TK + n + TK f32 words — with TK=512 and the
repo's largest n (6552 machines) that is ~12.8 MiB, inside budget; use
tile_k=256 beyond that.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_K = 512


def _combine_kernel(g_ref, w_ref, o_ref):
    """o[tile] = G[:, tile]^T @ w."""
    o_ref[...] = jnp.dot(g_ref[...].T, w_ref[...], preferred_element_type=o_ref.dtype)


def _ceil_to(x: int, t: int) -> int:
    return ((x + t - 1) // t) * t


def decode_combine(g: jnp.ndarray, w: jnp.ndarray, tile_k: int = TILE_K) -> jnp.ndarray:
    """Combined update u (k,) = sum_i w[i] * G[i] via the Pallas kernel."""
    n, k = g.shape
    tk = min(tile_k, k)
    kp = _ceil_to(k, tk)
    gp = jnp.pad(g, ((0, 0), (0, kp - k))) if kp != k else g
    u = pl.pallas_call(
        _combine_kernel,
        grid=(kp // tk,),
        in_specs=[
            pl.BlockSpec((n, tk), lambda j: (0, j)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((tk,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((kp,), g.dtype),
        interpret=True,
    )(gp, w)
    return u[:k] if kp != k else u
