"""Pallas kernels for coded least-squares block gradients.

The workers' compute hot-spot in gradient coding (Glasgow & Wootters
2021, Section I): for each data block i, the contribution to a worker's
message is the block gradient

    G[i] = X[i]^T (X[i] @ theta - y[i])          X[i]: (b,k), y[i]: (b,)

We stage this as two Pallas kernels so each is a clean MXU-shaped
matmul (see DESIGN.md §Hardware-Adaptation):

  1. residual kernel   — grid over blocks:      r[i] = X[i] @ theta - y[i]
  2. gradient kernel   — grid (blocks, k-tiles): G[i, jT] = X[i][:, jT]^T r[i]

VMEM accounting per program (f32 words): kernel 1 holds b*k + k + b;
kernel 2 holds b*TK + b + TK. With the default feature tile TK=512 and
the repo's block sizes (b <= 512) both stay well under a 16 MiB VMEM
budget; TK is the knob to shrink if b grows.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-dimension tile for the gradient kernel.
TILE_K = 512
# Block-dimension tile: how many data blocks one grid step processes.
# §Perf note (EXPERIMENTS.md §Perf L1): a grid of n single-block
# programs costs one lowered-loop iteration of overhead per block —
# measured 3.6s/dispatch at n=2184. Tiling blocks per program:
# TN=168 -> 54ms, TN=546 -> 26ms, TN=2184 (one fused program) -> 4.5ms
# on the CPU PJRT client. On a real TPU pick TN so TN*b*TILE_K*4B stays
# ~1-4 MiB for double-buffered HBM->VMEM pipelining; on CPU the fully
# fused variant wins, so that is the default.
TILE_N = 2184


def _residual_kernel(theta_ref, x_ref, y_ref, r_ref):
    """r[i] = X[i] @ theta - y[i] for a tile of TN blocks."""
    x = x_ref[...]  # (tn, b, k)
    r_ref[...] = (
        jax.lax.dot_general(
            x, theta_ref[...],
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=r_ref.dtype,
        )
        - y_ref[...]
    )


def _grad_kernel(x_ref, r_ref, g_ref):
    """G[i, tile] = X[i][:, tile]^T @ r[i] for a (block-tile, k-tile)."""
    x = x_ref[...]  # (tn, b, tk)
    r = r_ref[...]  # (tn, b)
    # batched per-block X^T r: contract b, batch over the block tile
    g_ref[...] = jax.lax.dot_general(
        x, r,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=g_ref.dtype,
    )


def _ceil_to(x: int, t: int) -> int:
    return ((x + t - 1) // t) * t


def _pick_tile_n(n: int, tile_n: int) -> int:
    """Largest divisor of n that is <= tile_n (grid must divide evenly)."""
    tn = min(tile_n, n)
    while n % tn != 0:
        tn -= 1
    return tn


def block_residual(
    theta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, tile_n: int = TILE_N
) -> jnp.ndarray:
    """Per-block residuals r (n,b) via the Pallas residual kernel."""
    n, b, k = x.shape
    tn = _pick_tile_n(n, tile_n)
    return pl.pallas_call(
        _residual_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((tn, b, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((tn, b), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), x.dtype),
        interpret=True,
    )(theta, x, y)


def block_grad(
    theta: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    tile_k: int = TILE_K,
    tile_n: int = TILE_N,
) -> jnp.ndarray:
    """Batched block gradients G (n,k): G[i] = X[i]^T (X[i] theta - y[i]).

    Args:
      theta: (k,) current iterate.
      x:     (n,b,k) stacked block design matrices.
      y:     (n,b) stacked block observations.
      tile_k: feature tile for the second kernel (padded if k % tile_k).
      tile_n: blocks per grid step (rounded down to a divisor of n).
    """
    n, b, k = x.shape
    r = block_residual(theta, x, y, tile_n)

    tn = _pick_tile_n(n, tile_n)
    tk = min(tile_k, k)
    kp = _ceil_to(k, tk)
    xg = jnp.pad(x, ((0, 0), (0, 0), (0, kp - k))) if kp != k else x
    g = pl.pallas_call(
        _grad_kernel,
        grid=(n // tn, kp // tk),
        in_specs=[
            pl.BlockSpec((tn, b, tk), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tn, b), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn, tk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, kp), x.dtype),
        interpret=True,
    )(xg, r)
    return g[:, :k] if kp != k else g
