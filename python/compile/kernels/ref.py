"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks every kernel against
(see python/tests/test_kernels.py). They are also what a "no-Pallas"
build of the L2 model would use, so they must be numerically identical
up to float reassociation.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul oracle: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(a, b)


def block_grad_ref(theta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Batched least-squares block gradients.

    For every data block i (of n):  G[i] = X[i]^T (X[i] @ theta - y[i]).

    Args:
      theta: (k,)   current iterate.
      x:     (n,b,k) stacked block design matrices.
      y:     (n,b)  stacked block observations.
    Returns:
      (n,k) per-block gradients.
    """
    r = jnp.einsum("nbk,k->nb", x, theta) - y
    return jnp.einsum("nbk,nb->nk", x, r)


def block_residual_ref(theta: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-block residuals r[i] = X[i] @ theta - y[i], shape (n,b)."""
    return jnp.einsum("nbk,k->nb", x, theta) - y


def decode_combine_ref(g: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Decoded gradient combine: u = G^T w = sum_i w[i] * G[i].

    Args:
      g: (n,k) per-block (or per-machine) gradients.
      w: (n,)  decoding coefficients (alpha* or w*; zeros for stragglers).
    Returns:
      (k,) combined update direction.
    """
    return g.T @ w
