"""L2 transformer: shapes, flat-param round trip, gradient correctness,
and a short training sanity check (loss decreases under SGD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import transformer as T

CFG = T.GptConfig(vocab=61, d_model=32, n_head=2, n_layer=2, seq_len=12)


def _tokens(rng, b, t1, vocab=CFG.vocab):
    return jnp.asarray(rng.integers(0, vocab, (b, t1)), jnp.int32)


def test_param_spec_layout_consistent():
    p = T.n_params(CFG)
    flat = jnp.arange(p, dtype=jnp.float32)
    tree = T.unflatten(CFG, flat)
    # every spec entry present, shapes correct, slices disjoint + exhaustive
    off = 0
    for name, shape in T.param_spec(CFG):
        assert tree[name].shape == shape
        size = int(np.prod(shape))
        np.testing.assert_array_equal(
            np.asarray(tree[name]).ravel(), np.arange(off, off + size, dtype=np.float32))
        off += size
    assert off == p


def test_init_params_stats():
    flat = T.init_params(CFG, seed=1)
    assert flat.dtype == np.float32 and flat.shape == (T.n_params(CFG),)
    tree = T.unflatten(CFG, jnp.asarray(flat))
    np.testing.assert_array_equal(tree["lnf_g"], np.ones(CFG.d_model, np.float32))
    np.testing.assert_array_equal(tree["l0.qkv_b"], np.zeros(3 * CFG.d_model, np.float32))
    assert 0.01 < float(np.std(np.asarray(tree["tok_emb"]))) < 0.03


def test_forward_shapes_and_finite():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(T.init_params(CFG, 0))
    logits = T.forward(CFG, flat, _tokens(rng, 3, CFG.seq_len))
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_is_causal():
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(1)
    flat = jnp.asarray(T.init_params(CFG, 0))
    tok = _tokens(rng, 1, CFG.seq_len)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % CFG.vocab)
    l1 = T.forward(CFG, flat, tok)
    l2 = T.forward(CFG, flat, tok2)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


def test_block_grad_matches_autodiff_of_loss():
    rng = np.random.default_rng(2)
    flat = jnp.asarray(T.init_params(CFG, 0))
    tok = _tokens(rng, 2, CFG.seq_len + 1)
    scale = 1.0 / (4 * 2 * CFG.seq_len)
    g, loss = T.block_grad_fn(CFG, scale)(flat, tok)
    want = jax.grad(lambda f: T.block_loss(CFG, f, tok, scale))(flat)
    np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-6)
    assert g.shape == flat.shape and float(loss) > 0


def test_block_grad_all_matches_singles():
    rng = np.random.default_rng(3)
    flat = jnp.asarray(T.init_params(CFG, 0))
    toks = jnp.stack([_tokens(rng, 2, CFG.seq_len + 1) for _ in range(3)])
    scale = 1.0 / (3 * 2 * CFG.seq_len)
    gall, lall = jax.jit(T.block_grad_all_fn(CFG, scale))(flat, toks)
    single = T.block_grad_fn(CFG, scale)
    for i in range(3):
        gi, li = single(flat, toks[i])
        np.testing.assert_allclose(gall[i], gi, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(lall[i], li, rtol=1e-5)


def test_sum_of_block_losses_is_mean_ce():
    """With loss_scale = 1/(n*B*T), sum_i f_i equals the global mean CE."""
    rng = np.random.default_rng(4)
    flat = jnp.asarray(T.init_params(CFG, 0))
    n, b = 3, 2
    toks = jnp.stack([_tokens(rng, b, CFG.seq_len + 1) for _ in range(n)])
    scale = 1.0 / (n * b * CFG.seq_len)
    total = sum(float(T.block_loss(CFG, flat, toks[i], scale)) for i in range(n))
    (mean_ce,) = T.eval_loss_fn(CFG)(
        flat, toks.reshape(n * b, CFG.seq_len + 1))
    assert abs(total - float(mean_ce)) < 1e-4


def test_short_training_decreases_loss():
    rng = np.random.default_rng(5)
    flat = jnp.asarray(T.init_params(CFG, 0))
    tok = _tokens(rng, 4, CFG.seq_len + 1)
    scale = 1.0 / (4 * CFG.seq_len)
    step = jax.jit(T.block_grad_fn(CFG, scale))
    losses = []
    for _ in range(8):
        g, loss = step(flat, tok)
        losses.append(float(loss))
        flat = flat - 0.5 * g
    assert losses[-1] < losses[0] * 0.9, losses
