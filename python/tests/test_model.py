"""L2 model functions: shapes and agreement with oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@given(n=st.integers(1, 10), b=st.integers(1, 6), k=st.integers(1, 50),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_batched_block_grad(n, b, k, seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=k).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, b, k)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, b)).astype(np.float32))
    (g,) = model.batched_block_grad(theta, x, y)
    np.testing.assert_allclose(g, ref.block_grad_ref(theta, x, y), rtol=1e-5, atol=1e-5)
    (g2,) = model.worker_block_grad(theta, x, y)
    np.testing.assert_allclose(g2, g, rtol=1e-6)


def test_decode_combine_and_sgd_step():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 12)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=8).astype(np.float32))
    (u,) = model.decode_combine(g, w)
    np.testing.assert_allclose(u, g.T @ w, rtol=1e-5, atol=1e-5)
    theta = jnp.asarray(rng.normal(size=12).astype(np.float32))
    (t2,) = model.sgd_step(theta, u, jnp.float32(0.1))
    np.testing.assert_allclose(t2, theta - 0.1 * u, rtol=1e-6)


def test_lstsq_loss_value():
    rng = np.random.default_rng(1)
    n, b, k = 3, 4, 5
    theta = jnp.asarray(rng.normal(size=k).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, b, k)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, b)).astype(np.float32))
    (loss,) = model.lstsq_loss(theta, x, y)
    r = np.einsum("nbk,k->nb", x, theta) - np.asarray(y)
    assert abs(float(loss) - float((r * r).sum())) < 1e-3
