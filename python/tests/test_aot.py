"""AOT path: HLO-text lowering round trip and manifest integrity.

Executing the HLO from rust is covered by rust/tests/runtime_integration.rs;
here we check the python half: lowering produces parseable HLO text with
the right entry signature, and MANIFEST.json (if present) is consistent.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_contains_entry(tmp_path):
    spec = jax.ShapeDtypeStruct((4, 3, 5), jnp.float32)
    theta = jax.ShapeDtypeStruct((5,), jnp.float32)
    y = jax.ShapeDtypeStruct((4, 3), jnp.float32)
    row = aot.lower_and_write(
        model.batched_block_grad, (theta, spec, y), "t_block_grad", str(tmp_path))
    text = (tmp_path / "t_block_grad.hlo.txt").read_text()
    assert "HloModule" in text and "ENTRY" in text
    assert row["inputs"][0]["shape"] == [5]
    assert row["outputs"][0]["shape"] == [4, 5]
    # HLO text must mention the parameter shapes
    assert "f32[4,3,5]" in text


def test_hlo_text_has_no_serialized_proto_markers(tmp_path):
    """Interchange must be text (xla_extension 0.5.1 rejects 64-bit-id protos)."""
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    row = aot.lower_and_write(
        lambda a, b: (a @ b,), (spec, spec), "t_mm", str(tmp_path))
    raw = (tmp_path / "t_mm.hlo.txt").read_bytes()
    assert raw.isascii()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "MANIFEST.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "MANIFEST.json")) as f:
        man = json.load(f)
    assert man["artifacts"], "manifest has no artifacts"
    names = set()
    for row in man["artifacts"]:
        assert row["name"] not in names, f"duplicate {row['name']}"
        names.add(row["name"])
        path = os.path.join(ART, row["file"])
        assert os.path.exists(path), f"missing {row['file']}"
        head = open(path).read(2000)
        assert "HloModule" in head
        for io in row["inputs"] + row["outputs"]:
            assert io["dtype"] in ("f32", "s32", "f64", "bf16")
            assert all(isinstance(d, int) and d > 0 for d in io["shape"])
    tfm = man.get("transformer")
    if tfm:
        init = os.path.join(ART, tfm["init_file"])
        assert os.path.getsize(init) == 4 * tfm["n_params"]
        assert {"tfm_block_grad", "tfm_block_grad_all", "tfm_eval_loss"} <= names


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "MANIFEST.json")),
                    reason="artifacts not built")
def test_manifest_worker_shapes_are_two_blocks():
    """Graph schemes put exactly 2 blocks on each machine (Def. II.2)."""
    with open(os.path.join(ART, "MANIFEST.json")) as f:
        man = json.load(f)
    workers = [r for r in man["artifacts"] if r["name"].startswith("worker_grad_")]
    assert workers
    for row in workers:
        assert row["inputs"][1]["shape"][0] == 2
