"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and dtypes for matmul); every kernel must
match ref.py to float tolerance across the sweep, including the padded
(non-tile-multiple) paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_grad import block_grad, block_residual
from compile.kernels.decode_combine import decode_combine
from compile.kernels.matmul import matmul

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


@given(
    n=st.integers(1, 12), b=st.integers(1, 9), k=st.integers(1, 80),
    tile_k=st.sampled_from([8, 32, 512]), seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_block_grad_matches_ref(n, b, k, tile_k, seed):
    rng = np.random.default_rng(seed)
    theta, x, y = _rand(rng, (k,)), _rand(rng, (n, b, k)), _rand(rng, (n, b))
    got = block_grad(theta, x, y, tile_k=tile_k)
    want = ref.block_grad_ref(theta, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(n=st.integers(1, 12), b=st.integers(1, 9), k=st.integers(1, 80),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_block_residual_matches_ref(n, b, k, seed):
    rng = np.random.default_rng(seed)
    theta, x, y = _rand(rng, (k,)), _rand(rng, (n, b, k)), _rand(rng, (n, b))
    np.testing.assert_allclose(
        block_residual(theta, x, y), ref.block_residual_ref(theta, x, y),
        rtol=1e-5, atol=1e-5)


@given(n=st.integers(1, 40), k=st.integers(1, 90),
       tile_k=st.sampled_from([16, 64, 512]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_decode_combine_matches_ref(n, k, tile_k, seed):
    rng = np.random.default_rng(seed)
    g, w = _rand(rng, (n, k)), _rand(rng, (n,))
    np.testing.assert_allclose(
        decode_combine(g, w, tile_k=tile_k), ref.decode_combine_ref(g, w),
        rtol=1e-4, atol=1e-4)


def test_decode_combine_zero_weights_gives_zero():
    g = jnp.ones((7, 13), jnp.float32)
    u = decode_combine(g, jnp.zeros((7,), jnp.float32))
    assert float(jnp.abs(u).max()) == 0.0


def test_decode_combine_straggler_zeroing_matches_subset_sum():
    """w_j = 0 for stragglers means their gradients never contribute."""
    rng = np.random.default_rng(3)
    g, w = _rand(rng, (10, 20)), np.ones(10, np.float32)
    w[[2, 5, 6]] = 0.0
    got = decode_combine(g, jnp.asarray(w))
    want = jnp.sum(g[np.array([0, 1, 3, 4, 7, 8, 9])], axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       dtype=st.sampled_from([np.float32, np.dtype("bfloat16")]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    got = matmul(a, b, 16, 16)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@given(m=st.integers(1, 30), k=st.integers(1, 30), n=st.integers(1, 30),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_matmul_custom_vjp_matches_jax_grad(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, (m, k)), _rand(rng, (k, n))
    da, db = jax.grad(lambda a, b: jnp.sum(matmul(a, b, 16, 16) ** 2), (0, 1))(a, b)
    da_r, db_r = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2), (0, 1))(a, b)
    np.testing.assert_allclose(da, da_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(db, db_r, rtol=1e-4, atol=1e-4)


def test_block_grad_is_true_lstsq_gradient():
    """G[i] must equal the analytic gradient of 0.5|X_i theta - y_i|^2."""
    rng = np.random.default_rng(7)
    n, b, k = 4, 5, 11
    theta, x, y = _rand(rng, (k,)), _rand(rng, (n, b, k)), _rand(rng, (n, b))
    def fi(th, i):
        r = x[i] @ th - y[i]
        return 0.5 * jnp.sum(r * r)
    got = block_grad(theta, x, y)
    for i in range(n):
        want = jax.grad(fi)(theta, i)
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)
