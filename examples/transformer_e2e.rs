//! END-TO-END driver: coded training of a real transformer LM.
//!
//! Proves all three layers compose on a real workload: the GPT-style
//! decoder defined in python/compile/transformer.py (L2, with its MLP
//! matmuls as Pallas kernels, L1) is AOT-lowered to HLO; this rust
//! driver (L3) generates a synthetic corpus, builds the paper's graph
//! assignment over 16 token blocks on 24 machines, and trains with
//! coded gradient descent under random stragglers — comparing optimal
//! decoding, fixed-coefficient decoding and an uncoded baseline.
//! Loss curves are written to transformer_e2e_loss.csv and summarized
//! in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example transformer_e2e --
//! [--iters 300] [--p 0.2]`

use gcod::bench_util::BenchArgs;
use gcod::codes::{GradientCode, GraphCode};
use gcod::data::TokenCorpus;
use gcod::decode::{Decoder, FixedDecoder, IgnoreStragglersDecoder, OptimalGraphDecoder};
use gcod::gd::pjrt::PjrtTransformerTrainer;
use gcod::metrics::CsvWriter;
use gcod::prng::Rng;
use gcod::runtime::Runtime;
use gcod::straggler::BernoulliStragglers;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let iters = args.usize_or("--iters", 300);
    let p = args.f64_or("--p", 0.2);
    let gamma = args.f64_or("--gamma", 0.5);

    let rt = Runtime::open_default()?;
    let tfm = rt
        .manifest
        .transformer
        .clone()
        .expect("run `make artifacts` first (transformer artifacts missing)");
    println!(
        "model: GPT d_model={} layers={} seq={} vocab={} -> {} params",
        tfm.d_model, tfm.n_layer, tfm.seq_len, tfm.vocab, tfm.n_params
    );

    let mut rng = Rng::new(1);
    let code = GraphCode::random_regular(tfm.n_blocks, 3, &mut rng);
    println!(
        "assignment: {} ({} blocks on {} machines, d=3), stragglers p={p}",
        code.name(), tfm.n_blocks, code.n_machines()
    );

    let corpus = TokenCorpus::generate(200_000, tfm.vocab, &mut rng);
    let tokens = corpus.blocks(tfm.n_blocks, tfm.batch, tfm.seq_len + 1, &mut rng);
    let eval_tokens = corpus.blocks(1, tfm.batch, tfm.seq_len + 1, &mut rng);
    let rho = rng.permutation(tfm.n_blocks);

    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    let opt = OptimalGraphDecoder::new(&code.graph);
    let fix = FixedDecoder::new(code.assignment(), p);
    let unc = IgnoreStragglersDecoder { a: code.assignment(), weight: 1.0 / (3.0 * (1.0 - p)) };
    let arms: [(&str, &dyn Decoder); 3] =
        [("optimal", &opt), ("fixed", &fix), ("uncoded-style", &unc)];
    for (label, decoder) in arms {
        let mut strag = BernoulliStragglers::new(p, 77);
        let mut trainer = PjrtTransformerTrainer {
            rt: &rt,
            decoder,
            stragglers: &mut strag,
            m: code.n_machines(),
            gamma,
        };
        let t0 = std::time::Instant::now();
        let run = trainer.run(&tokens, &eval_tokens, iters, (iters / 10).max(1), Some(&rho))?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{label:>14}: train CE {:.4} -> {:.4} | eval CE {:.4} -> {:.4} | \
             {:.1}s ({:.0} ms/iter)",
            run.train_loss[0],
            run.train_loss.last().unwrap(),
            run.eval_loss[0].1,
            run.eval_loss.last().unwrap().1,
            dt,
            dt * 1e3 / iters as f64
        );
        curves.push((label.to_string(), run.train_loss));
    }

    // CSV for EXPERIMENTS.md
    let path = std::path::Path::new("transformer_e2e_loss.csv");
    let mut w = CsvWriter::to_file(path, &["iter", "optimal", "fixed", "uncoded_style"])?;
    for i in 0..iters {
        w.write_row(&[i as f64, curves[0].1[i], curves[1].1[i], curves[2].1[i]])?;
    }
    w.flush()?;
    println!("loss curves -> {}", path.display());

    // sanity: the model must actually learn
    let first = curves[0].1[0];
    let last = *curves[0].1.last().unwrap();
    anyhow::ensure!(last < first * 0.8, "optimal-decoding run failed to learn: {first} -> {last}");
    println!("E2E OK: loss decreased under coded training with stragglers.");
    Ok(())
}
