//! Quickstart: the full three-layer pipeline in one page.
//!
//! 1. Build the paper's assignment: a random 3-regular graph on 16 data
//!    blocks = 24 machines, each machine holding 2 blocks (Def. II.2).
//! 2. Straggle machines at p = 0.2 and decode optimally in linear time
//!    (Section III component rules).
//! 3. Run coded gradient descent where the gradients and the combine
//!    execute the AOT Pallas artifacts on the PJRT CPU client.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use gcod::codes::{GradientCode, GraphCode};
use gcod::data::LstsqData;
use gcod::decode::{Decoder, FixedDecoder, OptimalGraphDecoder};
use gcod::gd::{pjrt::PjrtGcod, StepSize};
use gcod::metrics::sci;
use gcod::prng::Rng;
use gcod::runtime::Runtime;
use gcod::straggler::{BernoulliStragglers, StragglerModel};

fn main() -> anyhow::Result<()> {
    let p = 0.2;
    let mut rng = Rng::new(7);

    // -- the assignment scheme ------------------------------------------------
    let code = GraphCode::random_regular(16, 3, &mut rng);
    println!("scheme: {} — n={} blocks, m={} machines, d={}",
             code.name(), code.n_blocks(), code.n_machines(), code.replication());

    // -- one decode, by hand --------------------------------------------------
    let mut strag = BernoulliStragglers::new(p, 42);
    let mask = strag.sample(code.n_machines());
    let dec = OptimalGraphDecoder::new(&code.graph).decode(&mask);
    println!(
        "one round: {} stragglers -> |alpha*-1|^2 = {} (per block {})",
        mask.iter().filter(|&&s| s).count(),
        sci(dec.error_sq()),
        sci(dec.error_sq() / 16.0)
    );

    // -- coded GD on the PJRT artifacts ---------------------------------------
    // data shape must match the lowered `qs` artifacts: n=16, b=8, k=32
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let rt = Runtime::open_default()?;
    let e0 = data.dist_to_opt(&vec![0.0; 32]);

    for (label, optimal) in [("optimal decoding", true), ("fixed decoding", false)] {
        let opt_dec = OptimalGraphDecoder::new(&code.graph);
        let fix_dec = FixedDecoder::new(code.assignment(), p);
        let decoder: &dyn Decoder = if optimal { &opt_dec } else { &fix_dec };
        let mut strag = BernoulliStragglers::new(p, 1234);
        let mut engine = PjrtGcod {
            rt: &rt,
            decoder,
            stragglers: &mut strag,
            m: code.n_machines(),
            step: StepSize::Const(0.08),
            rho: Some(Rng::new(5).permutation(16)),
        };
        let hist = engine.run(&data, &vec![0.0; 32], 40)?;
        println!(
            "{label:>17}: |theta-theta*|^2  {} -> {}  (40 iters, all FLOPs via Pallas/PJRT)",
            sci(e0),
            sci(hist.final_progress())
        );
    }
    println!("done. see examples/least_squares_cluster.rs for the distributed version.");
    Ok(())
}
