//! Adversarial stragglers: the paper's motivating comparison.
//!
//! Reproduces the Section V story: with adversarially chosen stragglers
//! the FRC of [4] loses a p fraction of all blocks (error/n ≈ p) while
//! the expander graph scheme loses only ≈ p/2 (Corollary V.3) — and
//! coded GD still converges, down to the Corollary VII.2 noise floor.
//!
//! Run: `cargo run --release --example adversarial_robustness`

use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::data::LstsqData;
use gcod::gd::{analysis::theory, bounds, SimulatedGcod, StepSize};
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use gcod::straggler::{frc_group_attack, graph_isolation_attack, StragglerModel};

/// Straggler "model" that replays a fixed adversarial mask every round.
struct FixedMask(Vec<bool>);

impl StragglerModel for FixedMask {
    fn sample(&mut self, m: usize) -> Vec<bool> {
        assert_eq!(m, self.0.len());
        self.0.clone()
    }
    fn name(&self) -> String {
        "adversarial-fixed".into()
    }
}

fn main() {
    let mut rng = Rng::new(21);
    let n = 16;
    let d = 3;
    let graph = build(&SchemeSpec::GraphRandomRegular { n, d }, &mut rng);
    let frc = build(&SchemeSpec::Frc { n, m: n * d / 2 * 2 / 2, d }, &mut rng); // n=16, m=24, d=3
    let m = graph.n_machines();
    assert_eq!(frc.n_machines(), m);

    // ---- Table: adversarial decoding error vs p (Cor V.2/V.3, Rmk V.4) ----
    println!("== adversarial decoding error |alpha*-1|^2 / n ==");
    let mut table = Table::new(&[
        "p", "graph (attack)", "frc (attack)", "lower p/2", "frc theory p", "Cor V.2 bound",
    ]);
    let lambda = {
        let g = graph.graph.as_ref().unwrap();
        gcod::graphs::spectral::spectral_gap(g, 4000, &mut rng)
    };
    for &p in &gcod::bench_util::P_GRID {
        let budget = (p * m as f64).floor() as usize;
        let gmask = graph_isolation_attack(graph.graph.as_ref().unwrap(), budget);
        let gdec = make_decoder(&graph, DecoderSpec::Optimal, p);
        let gerr = gdec.decode(&gmask).error_sq() / n as f64;
        let fmask = frc_group_attack(frc.frc.as_ref().unwrap(), budget);
        let fdec = make_decoder(&frc, DecoderSpec::Optimal, p);
        let ferr = fdec.decode(&fmask).error_sq() / n as f64;
        table.row(vec![
            format!("{p:.2}"),
            sci(gerr),
            sci(ferr),
            sci(theory::graph_adversarial_lower(p)),
            sci(p),
            sci(theory::graph_adversarial_bound(p, d as f64, lambda)),
        ]);
    }
    table.print();

    // ---- Convergence under a fixed adversarial pattern (Cor VII.2) ----
    println!("\n== coded GD under adversarial stragglers (p=0.25) ==");
    let p = 0.25;
    let budget = (p * m as f64).floor() as usize;
    let data = LstsqData::generate(256, 16, n, 1.0, &mut rng);
    let consts = bounds::estimate_lstsq_constants(&data, &mut rng);
    let mut t2 = Table::new(&["scheme", "final |theta-theta*|^2", "VII.2 floor (theory)"]);
    for (label, scheme, mask) in [
        ("graph+optimal", &graph, graph_isolation_attack(graph.graph.as_ref().unwrap(), budget)),
        ("frc+optimal", &frc, frc_group_attack(frc.frc.as_ref().unwrap(), budget)),
    ] {
        let dec = make_decoder(scheme, DecoderSpec::Optimal, p);
        let r_sq = dec.decode(&mask).error_sq();
        let mut strag = FixedMask(mask);
        let mut engine = SimulatedGcod {
            decoder: dec.as_ref(),
            stragglers: &mut strag,
            step: StepSize::Const(0.02),
            rho: None,
            m,
            alpha_scale: 1.0,
        };
        let mut src = &data;
        let hist = engine.run(&mut src, &vec![0.0; 16], 400);
        let floor = bounds::cor_vii2(&consts, r_sq, data.dist_to_opt(&vec![0.0; 16]))
            .map(|(_, f)| sci(f))
            .unwrap_or_else(|| "n/a (mu <= sqrt(r) L')".into());
        t2.row(vec![label.into(), sci(hist.final_progress()), floor]);
    }
    t2.print();
    println!("\nexpected shape: graph error ~ p/2, FRC error ~ p (2x worse),");
    println!("and both converge to a floor scaling with their |alpha*-1|^2.");
}
