//! Distributed coded least squares — the paper's Figure-4 setting.
//!
//! m = 24 worker threads (the paper's Sherlock allocation), each owning
//! the 2 data blocks of its graph edge and computing its gradient by
//! executing the AOT `worker_grad` artifact on its own PJRT client.
//! The leader waits for the first ceil(m(1-p)) gradients (Waitany
//! semantics), optimally decodes, and steps.
//!
//! Default scale is the DESIGN.md §3 substitution (N=6000, k=2000 vs
//! the paper's 60000 x 20000 — same code path, laptop-sized); pass
//! --n-points/--dim to grow it (requires re-lowering artifacts).
//!
//! Run: `cargo run --release --example least_squares_cluster --
//! [--p 0.2] [--iters 30] [--backend pjrt]`

use gcod::bench_util::BenchArgs;
use gcod::codes::{GradientCode, GraphCode};
use gcod::coordinator::{Cluster, ClusterConfig, ComputeBackend, StragglerInjection};
use gcod::data::LstsqData;
use gcod::decode::OptimalGraphDecoder;
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let p = args.f64_or("--p", 0.2);
    let iters = args.usize_or("--iters", 30);
    let n_points = args.usize_or("--n-points", 6000);
    let k = args.usize_or("--dim", 2000);
    let backend = args.str_or("--backend", "pjrt");

    let mut rng = Rng::new(11);
    let code = GraphCode::random_regular(16, 3, &mut rng); // m = 24
    println!("generating N={n_points}, k={k} least-squares data (+ exact theta*)...");
    let data = LstsqData::generate(n_points, k, 16, 1.0, &mut rng);

    let backend = match backend.as_str() {
        "native" => ComputeBackend::Native,
        _ => ComputeBackend::Pjrt {
            artifacts_dir: std::env::var("GCOD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
            artifact: format!("worker_grad_fig4_2x{}x{}", data.b, k),
        },
    };
    let cfg = ClusterConfig {
        wait_fraction: 1.0 - p,
        backend,
        injection: StragglerInjection::Stagnant {
            p,
            churn: 0.1,
            delay: Duration::from_millis(250),
            seed: 3,
        },
        step_size: 2e-5,
        iters,
        max_duration: None,
    };
    println!("spawning {} workers...", code.n_machines());
    let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg)?;
    cluster.wait_ready(Duration::from_secs(300))?;
    println!("cluster ready; running {iters} iterations at p={p}");

    let dec = OptimalGraphDecoder::new(&code.graph);
    let report = cluster.run(&cfg, &dec, &vec![0.0; k], |t| data.dist_to_opt(t))?;
    cluster.shutdown();

    let mut table =
        Table::new(&["iter", "wall(ms)", "stragglers", "decode err^2", "|theta-theta*|^2"]);
    for s in report.iters.iter().step_by((iters / 10).max(1)) {
        table.row(vec![
            s.iter.to_string(),
            format!("{:.1}", s.wall.as_secs_f64() * 1e3),
            s.stragglers.to_string(),
            sci(s.decode_error_sq),
            sci(s.progress),
        ]);
    }
    table.print();
    println!(
        "total {:.2}s, mean iter {:.1}ms, final |theta-theta*|^2 = {}",
        report.total.as_secs_f64(),
        report.total.as_secs_f64() * 1e3 / report.iters.len().max(1) as f64,
        sci(report.final_progress)
    );
    Ok(())
}
