//! GD hot-path kernels — streaming vs Gram-cached vs the PR3-era
//! allocating loop, at Fig-4/5-style simulated-GD sizes.
//!
//! For least-squares gradients `∇f_i(θ) = X_iᵀ(X_i θ − y_i)`, caching
//! per-block `(G_i = X_iᵀX_i, c_i = X_iᵀy_i)` once per run turns each
//! iteration's gradient set into n small d×d gemvs (~n·d² flops)
//! instead of a full pass over the data matrix (~2·N·d flops) — a
//! k/(2b) per-iteration ratio, so the Gram path wins when blocks are
//! tall (b ≫ d) and loses in the paper's regime-2 shape (b = 3 ≪ d).
//!
//! Measures, and **fails loudly** (non-zero exit, for CI) unless:
//! * the GD iteration loop performs zero heap allocations after setup
//!   (verified with a counting global allocator: per-trial allocation
//!   counts must not depend on the iteration count);
//! * streaming `_into` is bit-identical to the allocating baseline and
//!   the Gram path agrees with streaming to 1e-6 relative;
//! * no timing record regresses past the tracked baseline under the
//!   statistical gate: per-trial samples feed a bootstrap CI, and a
//!   record fails only when its interval separates above the
//!   baseline's ([`gcod::bench_util::compare_against_baseline`]) —
//!   fixed speedup thresholds were retired with schema 2 because a
//!   noisy CI box can miss 5x on a good day.
//!
//! Flags: --quick, --iters N, --trials N, --json PATH (default
//! BENCH_gd.json; "none" disables), --baseline (write the tracked
//! rust/benches/baselines/ file instead; also skips the gate, since a
//! refresh run defines the new reference).

use gcod::bench_util::{
    black_box, compare_against_baseline, read_baseline, record_from_samples, BenchArgs,
    JsonReport, BENCH_SLACK,
};
use gcod::codes::{GradientCode, GraphCode};
use gcod::data::LstsqData;
use gcod::decode::{Decoder, OptimalGraphDecoder};
use gcod::gd::{GdScratch, GradSource, GramCache, SimulatedGcod, StepSize};
use gcod::linalg::Mat;
use gcod::metrics::{Stopwatch, Table};
use gcod::prng::Rng;
use gcod::straggler::BernoulliStragglers;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting allocator: the zero-allocation claim is measured, not
/// asserted on faith.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// The PR3-era gradient path: a freshly allocated gradient matrix
/// every iteration (what `GradSource::block_grads` used to feed the
/// loop). Values are bit-identical to the `_into` streaming path.
struct AllocStreaming<'a>(&'a LstsqData);

impl GradSource for AllocStreaming<'_> {
    fn n_blocks(&self) -> usize {
        self.0.n_blocks
    }
    fn dim(&self) -> usize {
        self.0.k
    }
    fn block_grads_into(&mut self, theta: &[f64], out: &mut Mat) {
        *out = self.0.block_grads(theta);
    }
    fn progress(&mut self, theta: &[f64]) -> f64 {
        self.0.dist_to_opt(theta)
    }
}

/// One simulated-GD trial (fixed straggler seed per trial index, like
/// the `gd-final` sweep) on a caller-owned scratch.
fn run_trial<S: GradSource>(
    src: &mut S,
    dec: &dyn Decoder,
    m: usize,
    theta0: &[f64],
    iters: usize,
    seed: u64,
    scratch: &mut GdScratch,
) -> f64 {
    let mut strag = BernoulliStragglers::new(0.2, seed);
    let mut gd = SimulatedGcod {
        decoder: dec,
        stragglers: &mut strag,
        step: StepSize::simulated_grid(9),
        rho: None,
        m,
        alpha_scale: 1.0,
    };
    gd.run_with(src, theta0, iters, scratch).final_progress()
}

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick();
    let mut report = JsonReport::new("bench_gd_perf");
    let mut failures = Vec::new();

    // ---- tall-block configuration (b >> d: the Gram regime) ----
    let (n_points, dim, n_blocks, deg) =
        if quick { (4096usize, 16usize, 16usize, 4usize) } else { (32768, 32, 32, 6) };
    let b = n_points / n_blocks;
    let iters = args.usize_or("--iters", if quick { 10 } else { 30 });
    let trials = args.usize_or("--trials", if quick { 6 } else { 20 });
    println!(
        "== gd-final trial kernels: N={n_points} d={dim} n={n_blocks} (b={b} rows/block), \
         {iters} iters/trial, {trials} trials =="
    );
    let mut rng = Rng::new(0);
    let code = GraphCode::random_regular(n_blocks, deg, &mut rng);
    let m = code.n_machines();
    let gdec = OptimalGraphDecoder::new(&code.graph);
    let data = LstsqData::generate(n_points, dim, n_blocks, 1.0, &mut rng);
    let theta0 = vec![0.0; dim];

    let sw = Stopwatch::new();
    let cache = GramCache::new(&data);
    let build_s = sw.elapsed_secs();
    println!("GramCache build: {:.3} ms (amortized across the run's trials)", build_s * 1e3);

    let mut scratch = GdScratch::new();
    let mean_s = |s: &[f64]| s.iter().sum::<f64>() / s.len().max(1) as f64;
    // per-trial samples (not one aggregate stopwatch) so every arm gets
    // a bootstrap CI in the schema-2 report
    let time_arm = |label: &str, f: &mut dyn FnMut(u64) -> f64| -> (Vec<f64>, f64) {
        let mut last = 0.0;
        // warmup: one trial to size scratch and decoder state
        black_box(f(0));
        let mut samples = Vec::with_capacity(trials);
        for t in 0..trials {
            let sw = Stopwatch::new();
            last = f(t as u64);
            samples.push(sw.elapsed_secs());
            black_box(last);
        }
        println!("  {label:<34} {:>9.3} ms/trial", mean_s(&samples) * 1e3);
        (samples, last)
    };

    let (alloc_t, alloc_v) = time_arm("alloc-streaming (PR3-era loop)", &mut |t| {
        let mut src = AllocStreaming(&data);
        run_trial(&mut src, &gdec, m, &theta0, iters, 100 + t, &mut scratch)
    });
    let (stream_t, stream_v) = time_arm("streaming block_grads_into", &mut |t| {
        let mut src = &data;
        run_trial(&mut src, &gdec, m, &theta0, iters, 100 + t, &mut scratch)
    });
    let (gram_t, gram_v) = time_arm("gram-cached (G_i theta - c_i)", &mut |t| {
        let mut src = &cache;
        run_trial(&mut src, &gdec, m, &theta0, iters, 100 + t, &mut scratch)
    });
    let (alloc_s, stream_s, gram_s) = (mean_s(&alloc_t), mean_s(&stream_t), mean_s(&gram_t));

    // correctness cross-checks between the arms (same final trial)
    if stream_v.to_bits() != alloc_v.to_bits() {
        failures.push(format!(
            "streaming _into is not bit-identical to the allocating path: {stream_v} vs {alloc_v}"
        ));
    }
    let rel = (gram_v - stream_v).abs() / (1.0 + stream_v.abs().max(gram_v.abs()));
    if rel > 1e-6 {
        failures.push(format!(
            "gram path diverged from streaming: {gram_v} vs {stream_v} (rel {rel:.2e})"
        ));
    }

    let mut t = Table::new(&["path", "ms/trial", "speedup vs alloc-streaming"]);
    for (name, samples) in [
        ("alloc-streaming", &alloc_t),
        ("streaming _into", &stream_t),
        ("gram-cached", &gram_t),
    ] {
        let secs = mean_s(samples);
        t.row(vec![name.into(), format!("{:.3}", secs * 1e3), format!("{:.2}x", alloc_s / secs)]);
        report.push(record_from_samples(
            &format!("gd-trial N={n_points} d={dim} n={n_blocks} {name}"),
            samples,
            Some(n_points * dim),
            1,
        ));
    }
    t.print();
    // informational only — the pass/fail call on timing is the
    // CI-separation gate against the tracked baseline, not a fixed
    // multiplier that flakes with the machine's mood
    let speedup = stream_s / gram_s;
    println!(
        "gram speedup over streaming: {speedup:.2}x (flop ratio ~ 2b/d = {:.0}x; timing is \
         gated statistically against the tracked baseline)",
        2.0 * b as f64 / dim as f64
    );

    // ---- zero per-iteration allocation (counting allocator) ----
    // With warm scratch + decoder, a trial's allocation count must not
    // depend on its iteration count: everything per-iteration lives in
    // GdScratch, only per-trial setup (the history vectors) allocates.
    println!("\n== allocation audit (counting global allocator) ==");
    let mut audit = |gram: bool| -> (u64, u64) {
        let mut go = |it: usize| {
            if gram {
                let mut src = &cache;
                run_trial(&mut src, &gdec, m, &theta0, it, 7, &mut scratch)
            } else {
                let mut src = &data;
                run_trial(&mut src, &gdec, m, &theta0, it, 7, &mut scratch)
            }
        };
        black_box(go(4)); // warm scratch + decoder at this shape
        let a0 = allocs();
        black_box(go(4));
        let per_short = allocs() - a0;
        let a1 = allocs();
        black_box(go(32));
        let per_long = allocs() - a1;
        (per_short, per_long)
    };
    for label in ["streaming", "gram"] {
        let (per_short, per_long) = audit(label == "gram");
        println!(
            "  {label:<10} {per_short} allocs @ 4 iters, {per_long} allocs @ 32 iters \
             (both are per-trial setup)"
        );
        if per_long != per_short {
            failures.push(format!(
                "{label} GD loop allocates per iteration: {per_short} allocs at 4 iters vs \
                 {per_long} at 32"
            ));
        }
    }

    // ---- the crossover: the paper's regime-2 shape (b << d) ----
    // Short blocks flip the trade: this is why the gd-final sweep's
    // `grad=auto` applies the k <= b cut instead of always using Gram.
    let (n2, d2, nb2) = if quick { (384usize, 48usize, 128usize) } else { (768, 96, 256) };
    let b2 = n2 / nb2;
    println!("\n== regime-2 shape: N={n2} d={d2} n={nb2} (b={b2} rows/block) ==");
    let code2 = GraphCode::random_regular(nb2, 4, &mut rng);
    let m2 = code2.n_machines();
    let gdec2 = OptimalGraphDecoder::new(&code2.graph);
    let data2 = LstsqData::generate(n2, d2, nb2, 1.0, &mut rng);
    let cache2 = GramCache::new(&data2);
    let theta0_2 = vec![0.0; d2];
    let mut scratch2 = GdScratch::new();
    let trials2 = trials.min(8);
    let time2 = |gram: bool, scratch2: &mut GdScratch| -> Vec<f64> {
        let mut go = |t: u64| {
            if gram {
                let mut src = &cache2;
                run_trial(&mut src, &gdec2, m2, &theta0_2, iters, 300 + t, &mut *scratch2)
            } else {
                let mut src = &data2;
                run_trial(&mut src, &gdec2, m2, &theta0_2, iters, 300 + t, &mut *scratch2)
            }
        };
        black_box(go(0));
        let mut samples = Vec::with_capacity(trials2);
        for t in 0..trials2 {
            let sw = Stopwatch::new();
            black_box(go(t as u64));
            samples.push(sw.elapsed_secs());
        }
        samples
    };
    let s2t = time2(false, &mut scratch2);
    let g2t = time2(true, &mut scratch2);
    let (s2, g2) = (mean_s(&s2t), mean_s(&g2t));
    println!(
        "  streaming {:.3} ms/trial vs gram {:.3} ms/trial -> auto picks {}",
        s2 * 1e3,
        g2 * 1e3,
        if GramCache::pays_off(n2, d2, nb2) { "gram" } else { "streaming" }
    );
    if GramCache::pays_off(n2, d2, nb2) {
        failures.push(format!(
            "pays_off misclassifies the regime-2 shape N={n2} d={d2} n={nb2} as Gram-friendly"
        ));
    }
    for (name, samples) in [("streaming", &s2t), ("gram", &g2t)] {
        report.push(record_from_samples(
            &format!("gd-trial N={n2} d={d2} n={nb2} {name} (regime-2)"),
            samples,
            Some(n2 * d2),
            1,
        ));
    }

    // --baseline writes the tracked baseline; explicit --json wins.
    let json = match args.get("--json") {
        Some(path) => path.to_string(),
        None if args.has("--baseline") => {
            format!("{}/benches/baselines/BENCH_gd.json", env!("CARGO_MANIFEST_DIR"))
        }
        None => "BENCH_gd.json".to_string(),
    };
    if json != "none" {
        match report.write(std::path::Path::new(&json)) {
            Ok(()) => println!("\nwrote {json}"),
            Err(e) => eprintln!("\ncould not write {json}: {e}"),
        }
    }

    // statistical regression gate against the tracked baseline; a
    // --baseline run is defining the new reference, so it never gates
    // against itself
    let tracked = format!("{}/benches/baselines/BENCH_gd.json", env!("CARGO_MANIFEST_DIR"));
    if !args.has("--baseline") {
        match read_baseline(std::path::Path::new(&tracked)) {
            Some(base) if !base.is_empty() => {
                let regressions = compare_against_baseline(report.records(), &base, BENCH_SLACK);
                println!(
                    "regression gate: {} record(s) vs tracked baseline, {} regression(s)",
                    report.records().len(),
                    regressions.len()
                );
                failures.extend(regressions);
            }
            _ => println!(
                "regression gate: no usable baseline at {tracked} (missing or placeholder) — \
                 skipped; run with --baseline on a quiet machine to pin one"
            ),
        }
    }

    if failures.is_empty() {
        println!("\nclaim check: Gram caching turns each gd-final iteration into n d×d gemvs,");
        println!("the loop allocates nothing per iteration, and auto-selection respects the");
        println!("k <= b crossover. All checks passed.");
    } else {
        eprintln!("\nBENCH FAILURES:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
