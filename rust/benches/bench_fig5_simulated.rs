//! Figure 5 — simulated coded GD, the paper's regime 2.
//!
//! m = 6552 machines, N = 6552 data points, k = 200, sigma = 1,
//! d = 6 via the LPS(5,13) graph; blocks of 3 points (n = 2184).
//! (a) convergence at p = 0.2 over 50 iterations (uncoded runs 6x);
//! (b) |theta_t - theta*|^2 after 50 iterations across the p grid.
//!
//! Schemes: A2 optimal, A2 fixed, expander[6] fixed, FRC optimal,
//! uncoded (ignore stragglers, 6x iterations per Remark VIII.1).
//!
//! The repetition axis (independent GD runs per arm, and the step-size
//! grid search) fans across the sweep::TrialEngine — each engine trial
//! is one full trajectory with its own deterministic seed, so results
//! are identical for any --threads value.
//!
//! Flags: --runs (default 5; paper uses 20 — pass --runs 20 for the
//! full error bars), --iters (default 50), --threads N, --quick (runs=2).
//!
//! Sharded mode: --shard i/k [--out-dir DIR] [--trials N] ports the
//! repetition axis onto standard `gd-final` sweep configs — one
//! manifest per (arm, p) covering this process's slice of the
//! repetitions, mergeable with `gcod sweep-merge` (or runnable whole
//! under `gcod sweep-launch`) bit-identically to a single-process run.
//! The sharded arms use the standard gd-final runner (grid step sizes
//! via --set step-c, no per-arm gamma tuning or uncoded 6x iteration
//! compensation — those remain interactive-mode features); --quick (or
//! --small) swaps in regime-1-sized schemes for CI smoke runs.

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::data::LstsqData;
use gcod::gd::{SimulatedGcod, StepSize};
use gcod::metrics::{sci, Stats, Table};
use gcod::prng::Rng;
use gcod::straggler::BernoulliStragglers;
use gcod::sweep::shard::{self, ShardSpec, SweepConfig, SweepKind};
use gcod::sweep::TrialEngine;
use std::collections::BTreeMap;
use std::path::PathBuf;

const N: usize = 6552;
const K: usize = 200;
const NBLOCKS: usize = 2184;

struct Arm {
    label: &'static str,
    scheme: SchemeSpec,
    decoder: DecoderSpec,
    /// iteration multiplier (uncoded compensation, Remark VIII.1)
    iter_mult: usize,
    /// best grid c, tuned per arm by `tune_step` (Appendix G method);
    /// this is a *constant* step gamma = gamma0 * 1.05^c scaled to the
    /// workload's curvature (our X scaling differs from the paper's
    /// cluster, so absolute c values are not comparable to Table IV)
    step_c: u32,
}

fn arms() -> Vec<Arm> {
    vec![
        Arm { label: "A2 optimal", scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
              decoder: DecoderSpec::Optimal, iter_mult: 1, step_c: 0 },
        Arm { label: "A2 fixed", scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
              decoder: DecoderSpec::Fixed, iter_mult: 1, step_c: 0 },
        Arm { label: "expander[6] fixed", scheme: SchemeSpec::ExpanderAdj { n: 6552, d: 6 },
              decoder: DecoderSpec::Fixed, iter_mult: 1, step_c: 0 },
        Arm { label: "frc optimal", scheme: SchemeSpec::Frc { n: NBLOCKS, m: 6552, d: 6 },
              decoder: DecoderSpec::Optimal, iter_mult: 1, step_c: 0 },
        Arm { label: "uncoded 6x", scheme: SchemeSpec::Uncoded { n: NBLOCKS },
              decoder: DecoderSpec::Ignore, iter_mult: 6, step_c: 0 },
    ]
}

/// Base step: 1/(2L) with L ~ (N/k)(1+sqrt(k/N))^2 for our X scaling.
fn gamma_at(c: u32) -> f64 {
    let l = (N as f64 / K as f64) * (1.0 + (K as f64 / N as f64).sqrt()).powi(2);
    0.5 / l * 1.05f64.powi(c as i32)
}

/// One full GD trajectory (self-contained per seed: rebuilds the scheme
/// so it can run as an engine trial on any thread).
fn run_arm(arm: &Arm, base: &LstsqData, gamma: f64, p: f64, iters: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let scheme = build(&arm.scheme, &mut rng);
    // schemes disagree on block granularity: the graph scheme uses
    // n = 2m/d = 2184 blocks, the expander code of [6] one block per
    // machine (6552); re-slice the same data points accordingly
    let data = if scheme.n_blocks() == base.n_blocks {
        base.reblock(base.n_blocks)
    } else {
        base.reblock(scheme.n_blocks())
    };
    let data = &data;
    let dec = make_decoder(&scheme, arm.decoder, p);
    let mut strag = BernoulliStragglers::new(p, seed ^ 0xABCD);
    let rho = rng.permutation(scheme.n_blocks());
    let mut engine = SimulatedGcod {
        decoder: &dec,
        stragglers: &mut strag,
        step: StepSize::Const(gamma),
        rho: Some(rho),
        m: scheme.n_machines(),
        alpha_scale: if arm.decoder == DecoderSpec::Ignore { 1.0 / (1.0 - p) } else { 1.0 },
    };
    let mut src = data;
    engine.run(&mut src, &vec![0.0; K], iters * arm.iter_mult).progress
}

/// Appendix-G-style tuning: grid search at p=0.2 per arm, all grid
/// points evaluated as parallel engine trials.
fn tune_step(engine: &TrialEngine, arm: &Arm, data: &LstsqData) -> u32 {
    let grid: Vec<u32> = (0..=24).step_by(4).map(|c| c as u32).collect();
    let finals = engine.run_map(
        grid.len(),
        |_chunk| (),
        |_ctx, i, _rng| {
            let prog = run_arm(arm, data, gamma_at(grid[i]), 0.2, 20, 1234);
            *prog.last().unwrap()
        },
    );
    let mut best = (f64::INFINITY, 0u32);
    for (i, &fin) in finals.iter().enumerate() {
        if fin.is_finite() && fin < best.0 {
            best = (fin, grid[i]);
        }
    }
    best.1
}

/// Sharded manifest mode: the Figure-5 arms as standard `gd-final`
/// sweeps (one full deterministic GD trajectory per trial), one shard
/// manifest per (arm, p) — the last ROADMAP "port" item, making every
/// figure sweep dispatchable.
fn run_shard_mode(args: &BenchArgs, spec: ShardSpec) {
    let small = args.quick() || args.has("--small");
    let trials = args.usize_or("--trials", if small { 4 } else { 20 });
    let threads = args.threads();
    let out_dir = PathBuf::from(args.str_or("--out-dir", "fig5_shards"));
    if let Err(e) = std::fs::create_dir_all(&out_dir) {
        eprintln!("cannot create --out-dir {}: {e}", out_dir.display());
        std::process::exit(2);
    }
    // (label, scheme, decoder): regime-2 paper arms, or regime-1-sized
    // stand-ins for smoke runs
    let arms: Vec<(&str, String, &str)> = if small {
        vec![
            ("a1_optimal", "graph-rr:16,3".into(), "optimal"),
            ("a1_fixed", "graph-rr:16,3".into(), "fixed"),
            ("frc_optimal", "frc:16,24,3".into(), "optimal"),
        ]
    } else {
        vec![
            ("a2_optimal", "lps:5,13".into(), "optimal"),
            ("a2_fixed", "lps:5,13".into(), "fixed"),
            ("expander_fixed", format!("expander:{N},6"), "fixed"),
            ("frc_optimal", format!("frc:{NBLOCKS},{N},6"), "optimal"),
            ("uncoded_ignore", format!("uncoded:{NBLOCKS}"), "ignore"),
        ]
    };
    let mut params = BTreeMap::new();
    if small {
        params.insert("n-points".into(), "96".into());
        params.insert("dim".into(), "12".into());
        params.insert("iters".into(), "10".into());
    } else {
        params.insert("n-points".into(), N.to_string());
        params.insert("dim".into(), K.to_string());
        params.insert("iters".into(), args.usize_or("--iters", 50).to_string());
    }
    params.insert("step-c".into(), "9".into());
    println!(
        "== Figure 5 sharded mode: shard {spec}, {trials} repetitions/combo, {threads} threads =="
    );
    let mut write_failures = 0usize;
    for (name, scheme, decoder) in arms {
        for &p in &P_GRID {
            let cfg = SweepConfig {
                sweep: SweepKind::GdFinal,
                scheme: scheme.clone(),
                decoder: decoder.into(),
                p,
                seed: 5000 + (p * 1000.0).round() as u64,
                trials,
                chunk: 1, // trajectories are heavyweight: lease per run
                params: params.clone(),
            };
            let res = shard::run_shard(&cfg, threads, spec).expect("gd-final sweep");
            let path = out_dir.join(format!(
                "fig5_{name}_p{:03}_shard{}of{}.json",
                (p * 100.0).round() as u32,
                spec.index,
                spec.count
            ));
            match res.write(&path) {
                Ok(()) => println!(
                    "  {name} p={p:.2}: reps [{}, {}) mean={} -> {}",
                    res.lo,
                    res.hi,
                    sci(res.stats.mean()),
                    path.display()
                ),
                Err(e) => {
                    eprintln!("  {e}");
                    write_failures += 1;
                }
            }
        }
    }
    if write_failures > 0 {
        eprintln!("{write_failures} shard manifest(s) could not be written");
        std::process::exit(1);
    }
    println!("merge each combo's {} shard(s) with `gcod sweep-merge`.", spec.count);
}

fn main() {
    let args = BenchArgs::from_env();
    if let Some(s) = args.get("--shard") {
        let spec = match ShardSpec::parse(s) {
            Ok(spec) => spec,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        };
        run_shard_mode(&args, spec);
        return;
    }
    let runs = if args.quick() { 2 } else { args.usize_or("--runs", 5) };
    let iters = args.usize_or("--iters", 50);
    let threads = args.threads();
    // one run per chunk: trajectories are heavyweight, so load-balance
    // at run granularity
    let engine = TrialEngine::new(threads, 0xF195).with_chunk(1);

    println!("generating regime-2 data: N={N}, k={K}, sigma=1, n={NBLOCKS} blocks...");
    let mut rng = Rng::new(0);
    let data = LstsqData::generate(N, K, NBLOCKS, 1.0, &mut rng);
    let e0 = data.dist_to_opt(&vec![0.0; K]);
    println!("|theta_0 - theta*|^2 = {}", sci(e0));

    // tune step sizes per arm (Appendix G grid-search methodology)
    let mut arm_list = arms();
    for arm in &mut arm_list {
        arm.step_c = tune_step(&engine, arm, &data);
        println!("tuned {}: c={} (gamma={:.2e})", arm.label, arm.step_c, gamma_at(arm.step_c));
    }
    let arm_list = arm_list;

    // ---- (a) convergence curves at p = 0.2 ----
    println!("\n== Figure 5(a): convergence at p=0.2 ({runs} runs, {threads} threads) ==");
    let p = 0.2;
    let mut table = Table::new(&{
        let mut h = vec!["iter"];
        h.extend(arm_list.iter().map(|x| x.label));
        h
    });
    let mut curves: Vec<Vec<f64>> = Vec::new();
    for arm in &arm_list {
        let gamma = gamma_at(arm.step_c);
        let progs = engine.run_map(
            runs,
            |_chunk| (),
            |_ctx, r, _rng| run_arm(arm, &data, gamma, p, iters, 500 + r as u64),
        );
        let mut acc: Vec<Stats> = (0..=iters).map(|_| Stats::new()).collect();
        for prog in &progs {
            // sample the curve at coded-iteration granularity
            for (i, a) in acc.iter_mut().enumerate() {
                let idx = (i * arm.iter_mult).min(prog.len() - 1);
                a.push(prog[idx]);
            }
        }
        curves.push(acc.iter().map(|s| s.mean()).collect());
    }
    for i in (0..=iters).step_by((iters / 10).max(1)) {
        let mut row = vec![i.to_string()];
        for c in &curves {
            row.push(sci(c[i]));
        }
        table.row(row);
    }
    table.print();

    // ---- (b) final error across the p grid ----
    println!("\n== Figure 5(b): |theta-theta*|^2 after {iters} iters ==");
    let mut t2 = Table::new(&{
        let mut h = vec!["p"];
        h.extend(arm_list.iter().map(|x| x.label));
        h
    });
    for &p in &P_GRID {
        let mut row = vec![format!("{p:.2}")];
        for arm in &arm_list {
            let gamma = gamma_at(arm.step_c);
            let finals = engine.run_map(
                runs,
                |_chunk| (),
                |_ctx, r, _rng| {
                    *run_arm(arm, &data, gamma, p, iters, 900 + r as u64).last().unwrap()
                },
            );
            let mut st = Stats::new();
            for f in finals {
                st.push(f);
            }
            row.push(format!("{}±{}", sci(st.mean()), sci(st.std())));
        }
        t2.row(row);
    }
    t2.print();
    println!("\nexpected shape (paper Fig. 5): optimal ~ FRC << fixed < expander << uncoded.");
}
