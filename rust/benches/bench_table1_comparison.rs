//! Table I — the scheme-comparison table: expected random-straggler
//! error and worst-case (adversarial) error for every scheme the paper
//! lists, at matched replication.
//!
//! Measured columns (n=16..31 blocks, d~3..4, p=0.2):
//!   E|alpha_bar-1|^2/n   — Monte Carlo over Bernoulli stragglers
//!   worst |alpha-1|^2/n  — best attack available for the scheme
//! plus the paper's theory column for reference.

use gcod::bench_util::BenchArgs;
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::theory;
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use gcod::straggler::{frc_group_attack, graph_isolation_attack, greedy_decode_attack_on};
use gcod::sweep::{bernoulli_masks, decoding_stats_par, TrialEngine};

fn main() {
    let args = BenchArgs::from_env();
    let p = args.f64_or("--p", 0.2);
    let runs = if args.quick() { 400 } else { args.usize_or("--runs", 2000) };
    let threads = args.threads();

    struct Row {
        label: &'static str,
        spec: SchemeSpec,
        dec: DecoderSpec,
        theory_note: String,
    }
    let d = 3.0;
    let rows = vec![
        Row { label: "expander code [6] (fixed)", spec: SchemeSpec::ExpanderAdj { n: 24, d: 3 },
              dec: DecoderSpec::Fixed,
              theory_note: format!("worst < 4p/(d(1-p)) = {}", sci(4.0 * p / (d * (1.0 - p)))) },
        Row { label: "pairwise balanced [5] (fixed)",
              spec: SchemeSpec::Pairwise { n: 16, m: 24, d: 3 },
              dec: DecoderSpec::Fixed,
              theory_note: format!("E >= p/(d(1-p)) = {}", sci(theory::fixed_lower_bound(p, d))) },
        Row { label: "BIBD [7] (optimal=fixed)", spec: SchemeSpec::Bibd { s: 3 },
              dec: DecoderSpec::Optimal,
              theory_note: "worst O(1/sqrt(m))".into() },
        Row { label: "BRC [9] (optimal)", spec: SchemeSpec::Brc { n: 16, m: 24, batch: 4 },
              dec: DecoderSpec::Optimal,
              theory_note: "E ~ e^{-Theta(d)}".into() },
        Row { label: "rBGC [8] (fixed)", spec: SchemeSpec::Rbgc { n: 16, m: 24, d: 3 },
              dec: DecoderSpec::Fixed,
              theory_note: format!("E < 1/((1-p)d) = {}", sci(1.0 / ((1.0 - p) * d))) },
        Row { label: "FRC [4] (optimal)", spec: SchemeSpec::Frc { n: 16, m: 24, d: 3 },
              dec: DecoderSpec::Optimal,
              theory_note: format!("E = p^d = {}; worst = p = {}", sci(p.powf(d)), sci(p)) },
        Row { label: "THIS PAPER graph (optimal)",
              spec: SchemeSpec::GraphRandomRegular { n: 16, d: 3 },
              dec: DecoderSpec::Optimal,
              theory_note: format!("E = p^(d-o(d)) = {}; worst ~ p/(2(1-p)) = {}",
                                   sci(theory::optimal_lower_bound(p, d)),
                                   sci(p / (2.0 * (1.0 - p)))) },
    ];

    println!("== Table I at p={p}, d~3, m=24 (measured vs theory, {threads} threads) ==");
    let engine = TrialEngine::new(threads, 5);
    let mut t = Table::new(&["scheme", "E err/n (measured)", "worst err/n (attack)", "theory"]);
    for row in rows {
        let mut rng = Rng::new(17);
        let scheme = build(&row.spec, &mut rng);
        let m = scheme.n_machines();
        let dec = make_decoder(&scheme, row.dec, p);
        let stats = decoding_stats_par(
            &engine,
            |_chunk| make_decoder(&scheme, row.dec, p),
            bernoulli_masks(m, p),
            runs,
            &mut rng,
        );
        let n = scheme.n_blocks();
        // worst case: scheme-appropriate attack
        let budget = (p * m as f64).floor() as usize;
        let mask = if let Some(g) = &scheme.graph {
            graph_isolation_attack(g, budget)
        } else if let Some(frc) = &scheme.frc {
            frc_group_attack(frc, budget)
        } else {
            greedy_decode_attack_on(
                &engine,
                |_chunk| make_decoder(&scheme, row.dec, p),
                &scheme.a,
                budget,
            )
        };
        // worst-case column uses alpha (normalized for fixed decoders by
        // their own calibration, matching the paper's alpha-bar)
        let adv = dec.decode(&mask).error_sq() / n as f64;
        t.row(vec![
            row.label.to_string(),
            sci(stats.mean_err_per_block),
            sci(adv),
            row.theory_note,
        ]);
    }
    t.print();
    println!("\nexpected shape: graph-optimal matches FRC on E (both ~ p^d),");
    println!("but its worst-case is ~half the FRC's; fixed-coefficient rows sit ~p/(d(1-p)).");
}
