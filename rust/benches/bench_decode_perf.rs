//! Decoder performance — the paper's complexity claim (Section III):
//! optimal graph decoding costs c*m operations, "the same order as
//! computing the update in Equation (1)".
//!
//! Measures:
//! * linear-time graph decoder vs the generic LSQR decoder on the same
//!   assignments; scaling in m; per-edge cost stability;
//! * the batched/parallel trial loop: serial allocating `decode()` vs
//!   allocation-free `decode_into` vs the multi-thread `TrialEngine`
//!   at n=32768 (target: engine >= 5x serial throughput);
//! * LSQR warm-starting on the generic decoder.
//!
//! Every record carries per-iteration samples and a bootstrap CI
//! (schema 2); the trial-loop arms are repeated several times so even
//! one-shot sweeps get an interval. Exit status is the statistical
//! gate: non-zero only when a record's CI separates above the tracked
//! baseline's CI (plus slack) — see
//! [`gcod::bench_util::compare_against_baseline`].
//!
//! Flags: --quick, --threads N (default: all cores), --trials N,
//! --json PATH (default BENCH_decode.json; "none" disables),
//! --baseline (write the tracked rust/benches/baselines/ file instead;
//! also skips the gate, since a refresh run defines the reference).

use gcod::bench_util::{
    bench, black_box, compare_against_baseline, fmt_dur, read_baseline, record_from_samples,
    BenchArgs, JsonReport, BENCH_SLACK,
};
use gcod::codes::zoo::{self, SchemeSpec};
use gcod::codes::{GradientCode, GraphCode};
use gcod::decode::{Decoder, Decoding, GenericOptimalDecoder, OptimalGraphDecoder};
use gcod::linalg::dist2_sq;
use gcod::metrics::{Stopwatch, Table};
use gcod::prng::Rng;
use gcod::sweep::{bernoulli_masks, decoding_error_sweep, TrialEngine};
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let budget = Duration::from_millis(if args.quick() { 300 } else { 1500 });
    let threads = args.threads();
    let mut report = JsonReport::new("bench_decode_perf");

    // ---- linear-time claim: ns/edge roughly constant across m ----
    println!("== graph decoder scaling (d=6 random regular, decode_into) ==");
    let mut t = Table::new(&["n", "m", "mean/decode", "ns/edge"]);
    let mut rng = Rng::new(0);
    for n in [512usize, 2048, 8192, 32768] {
        let code = GraphCode::random_regular(n, 6, &mut rng);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let m = code.n_machines();
        let mut masks = Vec::new();
        for i in 0..16 {
            masks.push(Rng::new(i).bernoulli_mask(m, 0.2));
        }
        let mut out = Decoding::empty();
        let mut i = 0;
        let r = bench(&format!("graph-decode n={n}"), 2, budget, 4000, || {
            dec.decode_into(&masks[i % 16], &mut out);
            black_box(out.alpha[0]);
            i += 1;
        });
        report.push_result(&r, Some(m), 1);
        t.row(vec![
            n.to_string(),
            m.to_string(),
            fmt_dur(r.mean),
            format!("{:.1}", r.mean.as_nanos() as f64 / m as f64),
        ]);
    }
    t.print();

    // ---- batched + parallel Monte-Carlo trial loop at full scale ----
    let n_big = if args.quick() { 8192 } else { 32768 };
    let trials = args.usize_or("--trials", if args.quick() { 200 } else { 600 });
    println!("\n== trial-loop throughput (n={n_big}, d=6, p=0.2, {trials} trials) ==");
    let code = GraphCode::random_regular(n_big, 6, &mut rng);
    let g = &code.graph;
    let m = code.n_machines();

    // each arm is repeated so the one-shot sweep totals still yield a
    // bootstrap interval; samples are per-trial seconds
    let reps = if args.quick() { 3 } else { 5 };
    let time_reps = |f: &mut dyn FnMut() -> f64| -> (Vec<f64>, f64) {
        let mut samples = Vec::with_capacity(reps);
        let mut metric = 0.0;
        for _ in 0..reps {
            let sw = Stopwatch::new();
            metric = f();
            black_box(metric);
            samples.push(sw.elapsed_secs() / trials as f64);
        }
        (samples, metric)
    };
    let mean_s = |s: &[f64]| s.iter().sum::<f64>() / s.len().max(1) as f64;

    // serial baseline: one allocating decode() per trial (fresh mask +
    // w/alpha vectors every time — the pre-engine code path)
    let engine1 = TrialEngine::new(1, 42);
    let serial_dec = OptimalGraphDecoder::new(g);
    let (serial_t, _) = time_reps(&mut || {
        let mut acc = 0.0f64;
        for ti in 0..trials {
            let mask = engine1.trial_rng(ti).bernoulli_mask(m, 0.2);
            acc += serial_dec.decode(&mask).error_sq();
        }
        acc
    });

    // batched: allocation-free decode_into on one engine thread
    let (batched_t, s1_mean) = time_reps(&mut || {
        let dec = |_c: usize| OptimalGraphDecoder::new(g);
        decoding_error_sweep(&engine1, dec, bernoulli_masks(m, 0.2), trials).mean()
    });

    // parallel: same trials fanned across the engine
    let engine_n = TrialEngine::new(threads, 42);
    let (parallel_t, sn_mean) = time_reps(&mut || {
        let dec = |_c: usize| OptimalGraphDecoder::new(g);
        decoding_error_sweep(&engine_n, dec, bernoulli_masks(m, 0.2), trials).mean()
    });

    // the engine paths must agree on the accumulated metric (the
    // engine determinism contract: 1 thread == N threads, bit for bit)
    assert_eq!(
        s1_mean.to_bits(),
        sn_mean.to_bits(),
        "engine determinism violated: 1-thread vs {threads}-thread means differ"
    );

    let serial_s = mean_s(&serial_t) * trials as f64;
    let parallel_s = mean_s(&parallel_t) * trials as f64;
    let tput = |secs: f64| trials as f64 / secs;
    let mut t2 = Table::new(&["path", "total", "trials/s", "speedup vs serial"]);
    for (name, samples) in [
        ("serial decode()", &serial_t),
        ("batched decode_into (1 thread)", &batched_t),
        (&format!("TrialEngine ({threads} threads)")[..], &parallel_t),
    ] {
        let secs = mean_s(samples) * trials as f64;
        t2.row(vec![
            name.to_string(),
            format!("{:.3}s", secs),
            format!("{:.1}", tput(secs)),
            format!("{:.2}x", serial_s / secs),
        ]);
        let arm_threads = if name.starts_with("TrialEngine") { threads } else { 1 };
        report.push(record_from_samples(
            &format!("trial-loop n={n_big} {name}"),
            samples,
            Some(m),
            arm_threads,
        ));
    }
    t2.print();
    let speedup = serial_s / parallel_s;
    println!(
        "engine speedup {speedup:.2}x over serial decode() (target >= 5x with >= 6 cores; \
         mean err/n = {:.3e})",
        sn_mean / n_big as f64
    );

    // ---- graph decoder vs LSQR on the paper's two regimes ----
    println!("\n== optimal decoders on the paper's graphs (p=0.2) ==");
    let mut t3 = Table::new(&["graph", "decoder", "mean/decode", "speedup"]);
    for (label, code) in [
        ("A1 rr(16,3)", GraphCode::random_regular(16, 3, &mut rng)),
        ("A2 lps(5,13)", GraphCode::lps(5, 13)),
    ] {
        let m = code.n_machines();
        let masks: Vec<Vec<bool>> = (0..16).map(|i| Rng::new(i).bernoulli_mask(m, 0.2)).collect();
        let gdec = OptimalGraphDecoder::new(&code.graph);
        let ldec = GenericOptimalDecoder::new(code.assignment());
        let mut out = Decoding::empty();
        let mut i = 0;
        let rg = bench(&format!("{label} graph-decode"), 2, budget, 100_000, || {
            gdec.decode_into(&masks[i % 16], &mut out);
            black_box(out.alpha[0]);
            i += 1;
        });
        // p=0.2 flips ~32% of machines between independent masks, past
        // the 25% restart guard — this measures the (mostly cold) LSQR
        // path; the dedicated warm-start section below uses p=0.1
        let mut j = 0;
        let rl = bench(&format!("{label} lsqr-decode"), 1, budget, 10_000, || {
            ldec.decode_into(&masks[j % 16], &mut out);
            black_box(out.alpha[0]);
            j += 1;
        });
        report.push_result(&rg, Some(m), 1);
        report.push_result(&rl, Some(m), 1);
        let speedup = rl.mean.as_secs_f64() / rg.mean.as_secs_f64();
        t3.row(vec![
            label.into(),
            "graph O(m)".into(),
            fmt_dur(rg.mean),
            format!("{speedup:.0}x vs lsqr"),
        ]);
        t3.row(vec![label.into(), "lsqr".into(), fmt_dur(rl.mean), "1x".into()]);
    }
    t3.print();

    // ---- LSQR warm start: repeated similar masks vs cold restarts ----
    println!("\n== generic decoder warm start (expander n=2048 d=6, p=0.1) ==");
    let ecode = GraphCode::random_regular(2048, 6, &mut rng);
    let a = ecode.assignment();
    let wmasks: Vec<Vec<bool>> =
        (0..16).map(|i| Rng::new(100 + i).bernoulli_mask(a.cols, 0.1)).collect();
    let warm_dec = GenericOptimalDecoder::new(a);
    let mut out = Decoding::empty();
    let mut i = 0;
    let r_warm = bench("lsqr warm-start", 2, budget, 10_000, || {
        warm_dec.decode_into(&wmasks[i % 16], &mut out);
        black_box(out.alpha[0]);
        i += 1;
    });
    // force cold restarts on a long-lived decoder so only the solver
    // path differs (CSR mirror + scratch are built once on both sides)
    let cold_dec = GenericOptimalDecoder::new(a).with_restart_fraction(-1.0);
    let mut j = 0;
    let r_cold = bench("lsqr cold-start", 2, budget, 10_000, || {
        cold_dec.decode_into(&wmasks[j % 16], &mut out);
        black_box(out.alpha[0]);
        j += 1;
    });
    report.push_result(&r_warm, Some(a.cols), 1);
    report.push_result(&r_cold, Some(a.cols), 1);
    println!(
        "warm/cold = {:.2}x ({} vs {})",
        r_cold.mean.as_secs_f64() / r_warm.mean.as_secs_f64(),
        fmt_dur(r_warm.mean),
        fmt_dur(r_cold.mean)
    );

    // ---- restart-fraction tuning sweep (the named tunable) ----
    // Independent Bernoulli(p) masks flip ~2p(1-p) of the machines, so
    // sweeping p exercises guards on both sides of the default
    // DEFAULT_RESTART_FRACTION = 0.25: the tuned value is the smallest
    // fraction whose timing matches "always warm" on the workloads that
    // benefit, without regressing the high-churn ones.
    println!("\n== restart-fraction sweep (expander n=2048 d=6) ==");
    let mut t4 = Table::new(&["p", "restart-fraction", "mean/decode"]);
    for p in [0.05, 0.1, 0.2] {
        let pmasks: Vec<Vec<bool>> =
            (0..16).map(|i| Rng::new(700 + i).bernoulli_mask(a.cols, p)).collect();
        for f in [-1.0, 0.1, 0.25, 0.5, 1.0] {
            let dec = GenericOptimalDecoder::new(a).with_restart_fraction(f);
            let mut i = 0;
            let r = bench(&format!("lsqr p={p} restart-fraction={f}"), 2, budget, 10_000, || {
                dec.decode_into(&pmasks[i % 16], &mut out);
                black_box(out.alpha[0]);
                i += 1;
            });
            report.push_result(&r, Some(a.cols), 1);
            t4.row(vec![
                format!("{p:.2}"),
                if f < 0.0 { "always-cold".into() } else { format!("{f:.2}") },
                fmt_dur(r.mean),
            ]);
        }
    }
    t4.print();

    // ---- degree-diagonal preconditioning (ROADMAP PR 1 follow-up) ----
    // LSQR on A_S D with D = diag(1/|a_j|_2) equalizes the column
    // norms that slow Golub-Kahan on heterogeneous-degree codes (rBGC
    // columns are binomial). Gated off by default (`with_precond` /
    // the sweeps' `precond` param) so existing manifests stay
    // bit-exact; this arm measures what turning it on buys — iteration
    // counts and wall time — next to a regular graph scheme whose
    // columns are already uniform (expected: no win there).
    println!("\n== LSQR degree-diagonal preconditioning (cold starts, p=0.2) ==");
    let mut t5 = Table::new(&["scheme", "precond", "GK iters (16 masks)", "mean/decode"]);
    for spec in ["rbgc:256,384,6", "graph-rr:256,6"] {
        let scheme = zoo::build(&SchemeSpec::parse(spec).unwrap(), &mut rng);
        let a = &scheme.a;
        let pmasks: Vec<Vec<bool>> =
            (0..16).map(|i| Rng::new(900 + i).bernoulli_mask(a.cols, 0.2)).collect();
        let mut alphas: Vec<Vec<f64>> = Vec::new();
        for precond in [false, true] {
            // cold restarts isolate the solver path, so the iteration
            // totals compare decode for decode
            let dec = GenericOptimalDecoder::new(a)
                .with_restart_fraction(-1.0)
                .with_precond(precond);
            let mut gk_iters = 0usize;
            for mask in &pmasks {
                dec.decode_into(mask, &mut out);
                gk_iters += dec.last_lsqr_iterations();
            }
            alphas.push(out.alpha.clone());
            let mut i = 0;
            let r = bench(&format!("{spec} lsqr precond={precond}"), 1, budget, 10_000, || {
                dec.decode_into(&pmasks[i % 16], &mut out);
                black_box(out.alpha[0]);
                i += 1;
            });
            // iters carries the GK iteration total for this arm (the
            // tuning signal), not the sample count
            let mut rec = record_from_samples(
                &format!("{spec} lsqr precond={precond}"),
                &r.samples,
                Some(a.cols),
                1,
            );
            rec.iters = gk_iters as u64;
            report.push(rec);
            t5.row(vec![
                spec.into(),
                if precond { "on" } else { "off" }.into(),
                gk_iters.to_string(),
                fmt_dur(r.mean),
            ]);
        }
        // preconditioning must not move the optimum: the last mask's
        // alpha agrees across the two solvers to LSQR tolerance
        let d = dist2_sq(&alphas[0], &alphas[1]);
        assert!(d < 1e-8, "{spec}: precond changed the optimum, |dalpha|^2 = {d:e}");
    }
    t5.print();

    // --baseline writes the tracked baseline (diffed by CI and across
    // commits) instead of the working directory; an explicit --json
    // PATH always wins.
    let json = match args.get("--json") {
        Some(path) => path.to_string(),
        None if args.has("--baseline") => {
            format!("{}/benches/baselines/BENCH_decode.json", env!("CARGO_MANIFEST_DIR"))
        }
        None => "BENCH_decode.json".to_string(),
    };
    if json != "none" {
        match report.write(std::path::Path::new(&json)) {
            Ok(()) => println!("\nwrote {json}"),
            Err(e) => eprintln!("\ncould not write {json}: {e}"),
        }
    }

    // statistical regression gate against the tracked baseline; a
    // --baseline refresh run never gates against itself
    let tracked = format!("{}/benches/baselines/BENCH_decode.json", env!("CARGO_MANIFEST_DIR"));
    let mut failures = Vec::new();
    if !args.has("--baseline") {
        match read_baseline(std::path::Path::new(&tracked)) {
            Some(base) if !base.is_empty() => {
                failures = compare_against_baseline(report.records(), &base, BENCH_SLACK);
                println!(
                    "\nregression gate: {} record(s) vs tracked baseline, {} regression(s)",
                    report.records().len(),
                    failures.len()
                );
            }
            _ => println!(
                "\nregression gate: no usable baseline at {tracked} (missing or placeholder) — \
                 skipped; run with --baseline on a quiet machine to pin one"
            ),
        }
    }

    println!("\nclaim check: ns/edge flat across n (linear time), the component");
    println!("decoder orders faster than generic least squares, and the trial");
    println!("engine turns cores into throughput without changing the metrics.");
    if !failures.is_empty() {
        eprintln!("\nBENCH FAILURES:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
