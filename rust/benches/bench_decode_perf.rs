//! Decoder performance — the paper's complexity claim (Section III):
//! optimal graph decoding costs c*m operations, "the same order as
//! computing the update in Equation (1)".
//!
//! Measures: linear-time graph decoder vs the generic LSQR decoder on
//! the same assignments; scaling in m; per-edge cost stability.

use gcod::bench_util::{bench, black_box, BenchArgs};
use gcod::codes::{GradientCode, GraphCode};
use gcod::decode::{Decoder, GenericOptimalDecoder, OptimalGraphDecoder};
use gcod::metrics::Table;
use gcod::prng::Rng;
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let budget = Duration::from_millis(if args.quick() { 300 } else { 1500 });

    // ---- linear-time claim: ns/edge roughly constant across m ----
    println!("== graph decoder scaling (d=6 random regular) ==");
    let mut t = Table::new(&["n", "m", "mean/decode", "ns/edge"]);
    let mut rng = Rng::new(0);
    for n in [512usize, 2048, 8192, 32768] {
        let code = GraphCode::random_regular(n, 6, &mut rng);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let m = code.n_machines();
        let mut masks = Vec::new();
        for i in 0..16 {
            masks.push(Rng::new(i).bernoulli_mask(m, 0.2));
        }
        let mut i = 0;
        let r = bench(&format!("graph-decode n={n}"), 2, budget, 4000, || {
            let d = dec.decode(&masks[i % 16]);
            black_box(d.alpha[0]);
            i += 1;
        });
        t.row(vec![
            n.to_string(),
            m.to_string(),
            gcod::bench_util::fmt_dur(r.mean),
            format!("{:.1}", r.mean.as_nanos() as f64 / m as f64),
        ]);
    }
    t.print();

    // ---- graph decoder vs LSQR on the paper's two regimes ----
    println!("\n== optimal decoders on the paper's graphs (p=0.2) ==");
    let mut t2 = Table::new(&["graph", "decoder", "mean/decode", "speedup"]);
    for (label, code) in [
        ("A1 rr(16,3)", GraphCode::random_regular(16, 3, &mut rng)),
        ("A2 lps(5,13)", GraphCode::lps(5, 13)),
    ] {
        let m = code.n_machines();
        let masks: Vec<Vec<bool>> = (0..16).map(|i| Rng::new(i).bernoulli_mask(m, 0.2)).collect();
        let gdec = OptimalGraphDecoder::new(&code.graph);
        let ldec = GenericOptimalDecoder::new(code.assignment());
        let mut i = 0;
        let rg = bench(&format!("{label} graph-decode"), 2, budget, 100_000, || {
            black_box(gdec.decode(&masks[i % 16]).alpha[0]);
            i += 1;
        });
        let mut j = 0;
        let rl = bench(&format!("{label} lsqr-decode"), 1, budget, 10_000, || {
            black_box(ldec.decode(&masks[j % 16]).alpha[0]);
            j += 1;
        });
        let speedup = rl.mean.as_secs_f64() / rg.mean.as_secs_f64();
        t2.row(vec![label.into(), "graph O(m)".into(), gcod::bench_util::fmt_dur(rg.mean), format!("{speedup:.0}x vs lsqr")]);
        t2.row(vec![label.into(), "lsqr".into(), gcod::bench_util::fmt_dur(rl.mean), "1x".into()]);
    }
    t2.print();
    println!("\nclaim check: ns/edge flat across n (linear time), and the");
    println!("component decoder is orders faster than generic least squares.");
}
