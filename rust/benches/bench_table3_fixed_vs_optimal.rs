//! Table III — fixed vs optimal decoding for expander-graph schemes:
//! the error/covariance bounds the paper tabulates, against measurement.
//!
//!   fixed (lower bound):   E ~ p/(d(1-p)),  |cov| ~ 2p/(d(1-p))
//!   optimal (upper bound): E ~ p^{d-o(d)},  |cov| ~ log^2(n) p^{2d-o(d)}
//!
//! Measured on the paper's two graphs: A1 = random 3-regular (n=16)
//! and A2 = LPS(5,13) (n=2184, d=6).

use gcod::bench_util::BenchArgs;
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::{decoding_stats, theory};
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use gcod::straggler::BernoulliStragglers;

fn main() {
    let args = BenchArgs::from_env();
    let p = args.f64_or("--p", 0.15);
    let runs = if args.quick() { 200 } else { args.usize_or("--runs", 1500) };

    println!("== Table III at p={p} ({runs} Monte-Carlo draws) ==");
    let mut t = Table::new(&[
        "graph", "decoding", "E err/n meas", "E err/n theory", "|cov| meas", "|cov| theory",
    ]);
    for (gname, spec, d) in [
        ("A1 rr(16,3)", SchemeSpec::GraphRandomRegular { n: 16, d: 3 }, 3.0),
        ("A2 lps(5,13)", SchemeSpec::GraphLps { p: 5, q: 13 }, 6.0),
    ] {
        let mut rng = Rng::new(23);
        let scheme = build(&spec, &mut rng);
        let n = scheme.n_blocks();
        let logn = (n as f64).ln();
        let runs_here = if n > 1000 { runs.min(400) } else { runs };
        for (dname, dspec) in [("fixed", DecoderSpec::Fixed), ("optimal", DecoderSpec::Optimal)] {
            let dec = make_decoder(&scheme, dspec, p);
            let stats = decoding_stats(
                dec.as_ref(),
                &mut BernoulliStragglers::new(p, 31),
                scheme.n_machines(),
                n,
                runs_here,
                &mut rng,
            );
            let (e_th, c_th) = match dspec {
                DecoderSpec::Fixed => (
                    theory::fixed_lower_bound(p, d),
                    2.0 * p / (d * (1.0 - p)),
                ),
                _ => (
                    theory::optimal_lower_bound(p, d),
                    logn * logn * p.powf(2.0 * d),
                ),
            };
            t.row(vec![
                gname.to_string(),
                dname.to_string(),
                sci(stats.mean_err_per_block),
                sci(e_th),
                sci(stats.cov_norm),
                sci(c_th),
            ]);
        }
    }
    t.print();
    println!("\nexpected shape: optimal rows orders of magnitude below fixed rows,");
    println!("measured E within a small factor of its theory column.");
}
