//! Dispatcher overhead and elasticity bench.
//!
//! Measures the cost of running a standard sweep through the elastic
//! dispatch subsystem (`dispatch::Dispatcher` + `LocalProcess` worker
//! subprocesses) against the in-process single-run baseline, across
//! worker-pool sizes and lease grains, and with simulated Bernoulli
//! stragglers (the paper's random-straggler model applied to the sweep
//! infrastructure itself). Every dispatched variant's merged JSON is
//! asserted byte-identical to the baseline — perf runs double as
//! conformance runs.
//!
//! Flags: --trials N (default 2000; 400 under --quick), --workers
//! k1,k2,... (default 2,4), --grain g (default 0 = auto), --sim-p p
//! (straggler sim probability, default 0.3), --sim-delay-ms (default
//! 30), --quick.

use gcod::bench_util::{bench, BenchArgs};
use gcod::dispatch::{DispatchConfig, Dispatcher, LocalProcess, StragglerSimCfg};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::time::Duration;

fn sweep_cfg(trials: usize) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 23,
        trials,
        chunk: 32,
        params: BTreeMap::new(),
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let trials = args.usize_or("--trials", if args.quick() { 400 } else { 2000 });
    let workers = args.usize_list_or("--workers", &[2, 4]);
    let grain = args.usize_or("--grain", 0);
    let sim_p = args.f64_or("--sim-p", 0.3);
    let sim_delay = args.usize_or("--sim-delay-ms", 30) as u64;
    let cfg = sweep_cfg(trials);

    println!("== dispatch overhead: decode-error, {trials} trials ==");
    let single = shard::run_full(&cfg, 1).expect("single run");
    let reference = single.render();
    bench("in-process single run (1 thread)", 1, Duration::from_secs(2), 20, || {
        let m = shard::run_full(&cfg, 1).expect("single run");
        assert_eq!(m.render(), reference);
    });

    let dispatch_once = |k: usize, sim: Option<StragglerSimCfg>, label: &str| {
        let dcfg = DispatchConfig {
            grain,
            poll_interval: Duration::from_millis(2),
            straggler_sim: sim,
            out_dir: std::env::temp_dir().join(format!(
                "gcod_bench_dispatch_{}_{k}",
                std::process::id()
            )),
            ..DispatchConfig::default()
        };
        let mut transport = LocalProcess::new(env!("CARGO_BIN_EXE_gcod"), k);
        let out = Dispatcher::new(dcfg).run(&cfg, &mut transport).expect("dispatch");
        assert_eq!(out.merged.render(), reference, "{label}: merged bits diverged");
        out.report
    };

    for &k in &workers {
        let r = bench(&format!("dispatched, {k} workers"), 1, Duration::from_secs(4), 8, || {
            dispatch_once(k, None, "healthy");
        });
        let per_trial_ns = r.mean.as_nanos() as f64 / trials as f64;
        println!("  -> {per_trial_ns:.0} ns/trial amortized (incl. spawn + manifest I/O)");
    }

    println!("\n== elasticity under simulated stragglers (p={sim_p}, {sim_delay}ms delay) ==");
    for &k in &workers {
        let sim = StragglerSimCfg {
            p: sim_p,
            delay: Duration::from_millis(sim_delay),
            seed: 0xD15B,
        };
        bench(
            &format!("dispatched, {k} workers, Bernoulli({sim_p}) stragglers"),
            0,
            Duration::from_secs(4),
            5,
            || {
                let report = dispatch_once(k, Some(sim.clone()), "straggler-sim");
                gcod::bench_util::black_box(report);
            },
        );
    }
    println!("\nall dispatched merges byte-identical to the single-process run.");
}
