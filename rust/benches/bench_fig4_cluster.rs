//! Figure 4 — distributed coded GD on the worker-thread cluster.
//!
//! The paper: m=24 MPI ranks on Sherlock, N=60000, k=20000; waits for
//! the first ceil(m(1-p)) gradients, decodes, steps; Fig 4(a) plots
//! convergence at p=0.2, Fig 4(b) the error after a fixed time budget
//! across p. Here: 24 worker *threads* (DESIGN.md §3), scaled default
//! N=6000, k=500 (native backend) — pass --pjrt to run the AOT
//! worker_grad artifacts at the lowered shape k=2000.
//!
//! Flags: --iters (default 25), --budget-ms (default 4000, Fig 4b),
//! --runs (default 2), --pjrt, --quick, and the sharded-sweep pair
//! --shard i/k + --out-dir DIR: the Fig 4(b) repetition axis runs on
//! the shard layer (`sweep::shard`), so `k` processes can each take a
//! contiguous slice of the runs and write `fig4-cluster` manifests that
//! `gcod sweep-merge` validates and folds. (Unlike the simulated
//! sweeps, cluster values depend on real scheduling, so merges check
//! coverage/config, not bit-reproducibility — for the deterministic
//! Figure-4 stand-in use `gcod sweep-shard --sweep gd-final`.)

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::{GradientCode, GraphCode};
use gcod::coordinator::{Cluster, ClusterConfig, ComputeBackend, StragglerInjection};
use gcod::data::LstsqData;
use gcod::decode::{Decoder, FixedDecoder, IgnoreStragglersDecoder, OptimalGraphDecoder};
use gcod::metrics::{sci, Stats, Table};
use gcod::prng::Rng;
use gcod::sweep::shard::{ShardResult, ShardSpec, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let iters = args.usize_or("--iters", 25);
    let runs = if args.quick() { 1 } else { args.usize_or("--runs", 2) };
    let budget = Duration::from_millis(args.usize_or("--budget-ms", 4000) as u64);
    let shard_spec = match ShardSpec::parse(&args.str_or("--shard", "0/1")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let out_dir: Option<PathBuf> = args.get("--out-dir").map(PathBuf::from);
    if let Some(dir) = &out_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("cannot create --out-dir {}: {e}", dir.display());
            std::process::exit(2);
        }
    }
    let pjrt = args.has("--pjrt");
    if pjrt && !cfg!(pjrt_runtime) {
        eprintln!("--pjrt requires building with --features pjrt; falling back to native");
    }
    let pjrt = pjrt && cfg!(pjrt_runtime);
    let k = if pjrt { 2000 } else { args.usize_or("--dim", 500) };
    let n_points = 6000;

    let mut rng = Rng::new(3);
    let code = GraphCode::random_regular(16, 3, &mut rng); // m=24 like the paper
    println!("generating N={n_points}, k={k} data + exact theta* ...");
    let data = LstsqData::generate(n_points, k, 16, 1.0, &mut rng);
    let e0 = data.dist_to_opt(&vec![0.0; k]);
    println!("m=24 workers, backend={}", if pjrt { "pjrt" } else { "native" });

    let backend = || {
        #[cfg(pjrt_runtime)]
        if pjrt {
            return ComputeBackend::Pjrt {
                artifacts_dir: "artifacts".into(),
                artifact: format!("worker_grad_fig4_2x{}x{}", data.b, k),
            };
        }
        ComputeBackend::Native
    };
    let gamma = 2e-5 * (2000.0 / k as f64); // scale with 1/L ~ k/N

    type RunOut = (f64, Vec<f64>, f64);
    let run_one = |p: f64, which: &str, seed: u64, max_dur: Option<Duration>| -> RunOut {
        let cfg = ClusterConfig {
            wait_fraction: 1.0 - p,
            backend: backend(),
            injection: StragglerInjection::Stagnant {
                p,
                churn: 0.1,
                delay: Duration::from_millis(80),
                seed,
            },
            step_size: gamma,
            iters: if max_dur.is_some() { 100_000 } else { iters },
            max_duration: max_dur,
        };
        let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
        cluster.wait_ready(Duration::from_secs(300)).unwrap();
        let opt = OptimalGraphDecoder::new(&code.graph);
        let fix = FixedDecoder::new(code.assignment(), p);
        let ign = IgnoreStragglersDecoder { a: code.assignment(), weight: 1.0 / (3.0 * (1.0 - p)) };
        let dec: &dyn Decoder = match which {
            "optimal" => &opt,
            "fixed" => &fix,
            _ => &ign,
        };
        let report = cluster.run(&cfg, dec, &vec![0.0; k], |t| data.dist_to_opt(t)).unwrap();
        cluster.shutdown();
        let curve: Vec<f64> = report.iters.iter().map(|s| s.progress).collect();
        let mean_iter_ms = report.total.as_secs_f64() * 1e3 / report.iters.len().max(1) as f64;
        (report.final_progress, curve, mean_iter_ms)
    };

    // ---- Fig 4(a): convergence curves at p = 0.2 ----
    // the curve section is not trial-indexed; only the primary shard
    // runs it when the repetition axis is split across processes
    if shard_spec.index == 0 {
        println!("\n== Figure 4(a): convergence at p=0.2, |theta_0-theta*|^2 = {} ==", sci(e0));
        let mut table = Table::new(&["iter", "optimal", "fixed", "ignore"]);
        let mut curves = Vec::new();
        for which in ["optimal", "fixed", "ignore"] {
            let (_, curve, ms) = run_one(0.2, which, 42, None);
            println!("  {which}: {:.1} ms/iter", ms);
            curves.push(curve);
        }
        let len = curves.iter().map(|c| c.len()).min().unwrap_or(0);
        for i in (0..len).step_by((len / 10).max(1)) {
            table.row(vec![
                i.to_string(),
                sci(curves[0][i]),
                sci(curves[1][i]),
                sci(curves[2][i]),
            ]);
        }
        table.print();
    } else {
        println!("\n(shard {shard_spec}: skipping Figure 4(a), it is not trial-indexed)");
    }

    // ---- Fig 4(b): error after a fixed time budget across p ----
    // the repetition axis rides the shard layer: this process runs runs
    // [lo, hi) of [0, runs) and can emit a manifest per (p, decoder)
    let (run_lo, run_hi) = shard_spec.range(runs);
    println!(
        "\n== Figure 4(b): |theta-theta*|^2 after {budget:?} budget \
         (runs [{run_lo}, {run_hi}) of {runs}) =="
    );
    let ps: Vec<f64> = if args.quick() { vec![0.1, 0.2, 0.3] } else { P_GRID.to_vec() };
    let mut t2 = Table::new(&["p", "optimal", "fixed", "ignore"]);
    for &p in &ps {
        let mut row = vec![format!("{p:.2}")];
        for which in ["optimal", "fixed", "ignore"] {
            let mut values = Vec::with_capacity(run_hi - run_lo);
            for r in run_lo..run_hi {
                let (fin, _, _) = run_one(p, which, 100 + r as u64, Some(budget));
                values.push(fin);
            }
            if let Some(dir) = &out_dir {
                let mut params = BTreeMap::new();
                params.insert("iters".into(), iters.to_string());
                params.insert("budget-ms".into(), budget.as_millis().to_string());
                params.insert("dim".into(), k.to_string());
                params.insert("backend".into(), if pjrt { "pjrt" } else { "native" }.into());
                let cfg = SweepConfig {
                    sweep: SweepKind::Fig4Cluster,
                    scheme: "graph-rr:16,3".into(),
                    decoder: which.into(),
                    p,
                    seed: 100,
                    trials: runs,
                    chunk: 1,
                    params,
                };
                let res = ShardResult::from_values(cfg, run_lo, run_hi, values.clone());
                let path = dir.join(format!(
                    "fig4b_p{:03}_{which}_shard{}of{}.json",
                    (p * 100.0).round() as u32,
                    shard_spec.index,
                    shard_spec.count
                ));
                match res.write(&path) {
                    Ok(()) => println!("  wrote {}", path.display()),
                    Err(e) => eprintln!("  {e}"),
                }
            }
            let st = Stats::from_values(&values);
            row.push(format!("{}±{}", sci(st.mean()), sci(st.std())));
        }
        t2.row(row);
    }
    if shard_spec.count > 1 {
        println!(
            "(partial table: shard {shard_spec} ran {} of {runs} runs per cell —",
            run_hi - run_lo
        );
        println!(" merge the manifests with `gcod sweep-merge` for the full statistics)");
    }
    t2.print();
    println!("\nexpected shape (paper Fig. 4): optimal reaches machine-precision-ish");
    println!("error while fixed plateaus ~1e-2..1e-3 and ignore-stragglers higher.");
}
