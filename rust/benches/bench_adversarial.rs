//! Adversarial-straggler bench (Section V: Cor. V.2/V.3, Rmk V.4).
//!
//! For each p: attack the graph scheme (vertex isolation), the FRC
//! (group kill), and — on small m — every scheme with the generic
//! greedy attack; compare against the spectral upper bound and the p/2
//! lower bound. Also verifies the error never exceeds Cor. V.2.
//!
//! The greedy search evaluates its per-step candidates as parallel
//! trials on the sweep::TrialEngine (--threads N, default all cores);
//! the selected attack mask is thread-count-independent.

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::theory;
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use gcod::straggler::{frc_group_attack, graph_isolation_attack, greedy_decode_attack_on};
use gcod::sweep::TrialEngine;

fn main() {
    let args = BenchArgs::from_env();
    let include_lps = !args.quick();
    let engine = TrialEngine::new(args.threads(), 0xADA);

    println!("== adversarial error |alpha*-1|^2/n vs theory ==");
    let mut rng = Rng::new(9);
    let graph = build(&SchemeSpec::GraphRandomRegular { n: 64, d: 4 }, &mut rng);
    let frc = build(&SchemeSpec::Frc { n: 64, m: 128, d: 4 }, &mut rng);
    let bibd = build(&SchemeSpec::Bibd { s: 5 }, &mut rng); // 31 pts, d=6
    let lambda = gcod::graphs::spectral::spectral_gap(graph.graph.as_ref().unwrap(), 4000, &mut rng);
    println!("graph rr(64,4): spectral gap lambda = {lambda:.3}");

    let mut t = Table::new(&[
        "p", "graph attack", "lower p/2", "CorV.2 bound", "frc attack", "frc theory p", "bibd greedy",
    ]);
    for &p in &P_GRID {
        let gb = (p * graph.n_machines() as f64).floor() as usize;
        let gmask = graph_isolation_attack(graph.graph.as_ref().unwrap(), gb);
        let gdec = make_decoder(&graph, DecoderSpec::Optimal, p);
        let gerr = gdec.decode(&gmask).error_sq() / graph.n_blocks() as f64;
        let bound = theory::graph_adversarial_bound(p, 4.0, lambda);
        assert!(gerr <= bound + 1e-9, "Cor V.2 violated: {gerr} > {bound}");

        let fb = (p * frc.n_machines() as f64).floor() as usize;
        let fmask = frc_group_attack(frc.frc.as_ref().unwrap(), fb);
        let fdec = make_decoder(&frc, DecoderSpec::Optimal, p);
        let ferr = fdec.decode(&fmask).error_sq() / frc.n_blocks() as f64;

        let bb = (p * bibd.n_machines() as f64).floor() as usize;
        let bdec = make_decoder(&bibd, DecoderSpec::Optimal, p);
        let bmask = greedy_decode_attack_on(
            &engine,
            |_chunk| make_decoder(&bibd, DecoderSpec::Optimal, p),
            &bibd.a,
            bb,
        );
        let berr = bdec.decode(&bmask).error_sq() / bibd.n_blocks() as f64;

        t.row(vec![
            format!("{p:.2}"),
            sci(gerr),
            sci(theory::graph_adversarial_lower(p)),
            sci(bound),
            sci(ferr),
            sci(p),
            sci(berr),
        ]);
    }
    t.print();

    if include_lps {
        println!("\n== LPS(5,13) full scale (Cor V.3: (1+o(1))/2 * p/(1-p)) ==");
        let lps = build(&SchemeSpec::GraphLps { p: 5, q: 13 }, &mut rng);
        let lam = gcod::graphs::spectral::spectral_gap(lps.graph.as_ref().unwrap(), 2000, &mut rng);
        let mut t2 = Table::new(&["p", "attack err/n", "lower p/2", "CorV.3 ~ p/(2(1-p))", "CorV.2 bound"]);
        for &p in &[0.1, 0.2, 0.3] {
            let b = (p * 6552.0) as usize;
            let mask = graph_isolation_attack(lps.graph.as_ref().unwrap(), b);
            let dec = make_decoder(&lps, DecoderSpec::Optimal, p);
            let err = dec.decode(&mask).error_sq() / 2184.0;
            t2.row(vec![
                format!("{p:.2}"),
                sci(err),
                sci(p / 2.0),
                sci(p / (2.0 * (1.0 - p))),
                sci(theory::graph_adversarial_bound(p, 6.0, lam)),
            ]);
        }
        t2.print();
    }
    println!("\nexpected shape: graph ~ p/2 (half the FRC's p); everything under Cor V.2.");
}
