//! Adversarial-straggler bench (Section V: Cor. V.2/V.3, Rmk V.4).
//!
//! For each p: attack the graph scheme (vertex isolation), the FRC
//! (group kill), and — on small m — every scheme with the generic
//! greedy attack; compare against the spectral upper bound and the p/2
//! lower bound. Also verifies the error never exceeds Cor. V.2.
//!
//! The greedy search runs on the sweep::shard attack path: the nested
//! greedy trace gives the whole error-vs-budget curve in one pass and
//! the trial axis *is* the attack budget. --shard i/k + --out PATH
//! record only this process's budget slice in a merge-ready manifest
//! (`gcod sweep-merge` folds the slices bit-exactly) — note the greedy
//! search is sequential, so each shard still recomputes the trace
//! prefix up to its own hi; sharding trims the trailing budgets only.

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::theory;
use gcod::metrics::{sci, Table};
use gcod::prng::Rng;
use gcod::straggler::{frc_group_attack, graph_isolation_attack};
use gcod::sweep::shard::{self, ShardSpec, SweepConfig, SweepKind};
use std::collections::BTreeMap;

fn main() {
    let args = BenchArgs::from_env();
    let include_lps = !args.quick();
    let shard_spec = match ShardSpec::parse(&args.str_or("--shard", "0/1")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    println!("== adversarial error |alpha*-1|^2/n vs theory ==");
    let mut rng = Rng::new(9);
    let graph = build(&SchemeSpec::GraphRandomRegular { n: 64, d: 4 }, &mut rng);
    let frc = build(&SchemeSpec::Frc { n: 64, m: 128, d: 4 }, &mut rng);
    let bibd = build(&SchemeSpec::Bibd { s: 5 }, &mut rng); // 31 pts, d=6
    let lambda =
        gcod::graphs::spectral::spectral_gap(graph.graph.as_ref().unwrap(), 4000, &mut rng);
    println!("graph rr(64,4): spectral gap lambda = {lambda:.3}");

    // the BIBD greedy search as a standard attack sweep: one nested
    // trace to the largest budget on the grid covers every p
    let max_budget = (P_GRID[P_GRID.len() - 1] * bibd.n_machines() as f64).floor() as usize;
    let attack_cfg = SweepConfig {
        sweep: SweepKind::Attack,
        scheme: "bibd:5".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 0xADA,
        trials: max_budget,
        chunk: 1,
        params: BTreeMap::new(),
    };
    let attack = shard::run_shard(&attack_cfg, 1, shard_spec).expect("attack sweep");
    if let Some(out) = args.get("--out") {
        match attack.write(std::path::Path::new(out)) {
            Ok(()) => println!("wrote attack-shard manifest {out}"),
            Err(e) => eprintln!("{e}"),
        }
    }
    // err/n after budget b = attack value at trial b-1 (when this
    // process's shard covers it)
    let bibd_err_at = |budget: usize| -> Option<f64> {
        if budget == 0 {
            return Some(0.0);
        }
        let t = budget - 1;
        (attack.lo..attack.hi).contains(&t).then(|| attack.values[t - attack.lo])
    };

    let mut t = Table::new(&[
        "p",
        "graph attack",
        "lower p/2",
        "CorV.2 bound",
        "frc attack",
        "frc theory p",
        "bibd greedy",
    ]);
    for &p in &P_GRID {
        let gb = (p * graph.n_machines() as f64).floor() as usize;
        let gmask = graph_isolation_attack(graph.graph.as_ref().unwrap(), gb);
        let gdec = make_decoder(&graph, DecoderSpec::Optimal, p);
        let gerr = gdec.decode(&gmask).error_sq() / graph.n_blocks() as f64;
        let bound = theory::graph_adversarial_bound(p, 4.0, lambda);
        assert!(gerr <= bound + 1e-9, "Cor V.2 violated: {gerr} > {bound}");

        let fb = (p * frc.n_machines() as f64).floor() as usize;
        let fmask = frc_group_attack(frc.frc.as_ref().unwrap(), fb);
        let fdec = make_decoder(&frc, DecoderSpec::Optimal, p);
        let ferr = fdec.decode(&fmask).error_sq() / frc.n_blocks() as f64;

        let bb = (p * bibd.n_machines() as f64).floor() as usize;
        let berr = bibd_err_at(bb);

        t.row(vec![
            format!("{p:.2}"),
            sci(gerr),
            sci(theory::graph_adversarial_lower(p)),
            sci(bound),
            sci(ferr),
            sci(p),
            berr.map(sci).unwrap_or_else(|| format!("(shard {shard_spec})")),
        ]);
    }
    t.print();

    if include_lps {
        println!("\n== LPS(5,13) full scale (Cor V.3: (1+o(1))/2 * p/(1-p)) ==");
        let lps = build(&SchemeSpec::GraphLps { p: 5, q: 13 }, &mut rng);
        let lam = gcod::graphs::spectral::spectral_gap(lps.graph.as_ref().unwrap(), 2000, &mut rng);
        let mut t2 =
            Table::new(&["p", "attack err/n", "lower p/2", "CorV.3 ~ p/(2(1-p))", "CorV.2 bound"]);
        for &p in &[0.1, 0.2, 0.3] {
            let b = (p * 6552.0) as usize;
            let mask = graph_isolation_attack(lps.graph.as_ref().unwrap(), b);
            let dec = make_decoder(&lps, DecoderSpec::Optimal, p);
            let err = dec.decode(&mask).error_sq() / 2184.0;
            t2.row(vec![
                format!("{p:.2}"),
                sci(err),
                sci(p / 2.0),
                sci(p / (2.0 * (1.0 - p))),
                sci(theory::graph_adversarial_bound(p, 6.0, lam)),
            ]);
        }
        t2.print();
    }
    println!("\nexpected shape: graph ~ p/2 (half the FRC's p); everything under Cor V.2.");
}
