//! Table IV — the step sizes chosen by grid search (Appendix G).
//!
//! For each (scheme, decoder) arm and each p, sweep the paper's grid
//! (simulated regime: gamma_t = min(0.6, 0.3*1.3^c/(t+1)), c in 0..=20)
//! and report the best c — the reproduction of the paper's Table IV
//! bottom half, at the scaled simulation size.
//!
//! Flags: --iters (default 50), --quick (coarser grid).

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::data::LstsqData;
use gcod::gd::grid::{grid_search, GridKind};
use gcod::gd::SimulatedGcod;
use gcod::metrics::Table;
use gcod::prng::Rng;
use gcod::straggler::BernoulliStragglers;

fn main() {
    let args = BenchArgs::from_env();
    let iters = args.usize_or("--iters", 50);
    let step = if args.quick() { 4 } else { 1 };

    // scaled simulation workload (structure matches regime 2)
    let mut rng = Rng::new(0);
    let n_blocks = 64;
    let data = LstsqData::generate(512, 48, n_blocks, 1.0, &mut rng);

    let arms: Vec<(&str, SchemeSpec, DecoderSpec, usize)> = vec![
        (
            "A (graph) optimal",
            SchemeSpec::GraphRandomRegular { n: n_blocks, d: 6 },
            DecoderSpec::Optimal,
            1,
        ),
        (
            "A (graph) fixed",
            SchemeSpec::GraphRandomRegular { n: n_blocks, d: 6 },
            DecoderSpec::Fixed,
            1,
        ),
        ("uncoded (6x iters)", SchemeSpec::Uncoded { n: n_blocks }, DecoderSpec::Ignore, 6),
        ("expander [6] fixed", SchemeSpec::ExpanderAdj { n: 128, d: 6 }, DecoderSpec::Fixed, 1),
        ("FRC [4] optimal", SchemeSpec::Frc { n: n_blocks, m: 192, d: 6 }, DecoderSpec::Optimal, 1),
    ];

    println!("== Table IV (simulated regime grid, c in 0..=20{}) ==",
             if step > 1 { " step 4 (--quick)" } else { "" });
    let mut t =
        Table::new(&["assignment/decoder", "p=0.05", "0.10", "0.15", "0.20", "0.25", "0.30"]);
    for (label, spec, dspec, mult) in arms {
        let mut row = vec![label.to_string()];
        for &p in &P_GRID {
            let mut best_c = 0;
            let mut best_e = f64::INFINITY;
            let mut c = 0u32;
            while c <= 20 {
                let r = grid_search(GridKind::Simulated, c, c, |stepsize| {
                    let mut rng2 = Rng::new(77);
                    let scheme = build(&spec, &mut rng2);
                    // schemes disagree on block granularity (expander
                    // code: one block per machine) — re-slice the data
                    let data = data.reblock(scheme.n_blocks());
                    let dec = make_decoder(&scheme, dspec, p);
                    let mut strag = BernoulliStragglers::new(p, 1000 + (p * 100.0) as u64);
                    let mut eng = SimulatedGcod {
                        decoder: dec.as_ref(),
                        stragglers: &mut strag,
                        step: stepsize,
                        rho: Some(rng2.permutation(scheme.n_blocks())),
                        m: scheme.n_machines(),
                        alpha_scale: if dspec == DecoderSpec::Ignore {
                            1.0 / (1.0 - p)
                        } else {
                            1.0
                        },
                    };
                    let mut src = &data;
                    eng.run(&mut src, &vec![0.0; 48], iters * mult).final_progress()
                });
                if r.best_error < best_e {
                    best_e = r.best_error;
                    best_c = c;
                }
                c += step;
            }
            row.push(best_c.to_string());
        }
        t.row(row);
    }
    t.print();
    println!("\nexpected shape (paper Table IV): optimal decoders tolerate larger c");
    println!("(bigger steps) than fixed; uncoded needs the smallest steps.");
}
