//! Figure 3 — decoding error and covariance norm under random stragglers.
//!
//! (a)(b): regime 1 — m=24 machines, d=3, A_1 = random 3-regular graph
//!         on n=16 vertices.
//! (c)(d): regime 2 — m=6552, d=6, A_2 = LPS(5,13) on n=2184 vertices.
//!
//! Series per panel: graph scheme w/ optimal + fixed decoding, the
//! expander code of [6] (optimal in regime 1, fixed in regime 2 — as
//! the paper does, for decode cost), and the FRC theory line
//! p^d/(1-p^d), which the FRC achieves exactly.
//!
//! Flags: --runs N (default 50, as the paper), --reps R (error bars,
//! default 5; 2 under --quick), --regime 1|2|both, --threads N.
//!
//! The Monte-Carlo draws run on the sweep::TrialEngine: per-trial PRNG
//! substreams + ordered reduction, so the numbers are identical for any
//! --threads value.
//!
//! Sharded mode: --shard i/k [--out-dir DIR] [--trials N] switches to
//! the sweep::shard decode-error path — one manifest per (regime-1 arm,
//! p), covering this process's slice of the trials; merge the k
//! processes' manifests per combo with `gcod sweep-merge` for results
//! bit-identical to a single-process run.

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::theory;
use gcod::metrics::{sci, Stats, Table};
use gcod::prng::Rng;
use gcod::sweep::shard::{self, ShardSpec, SweepConfig, SweepKind};
use gcod::sweep::{bernoulli_masks, decoding_stats_par, TrialEngine};
use std::collections::BTreeMap;
use std::path::PathBuf;

struct Arm {
    label: &'static str,
    scheme: SchemeSpec,
    decoder: DecoderSpec,
}

fn sweep(regime: &str, arms: &[Arm], d: f64, runs: usize, reps: usize, threads: usize) {
    println!(
        "\n== Figure 3 {regime}: E|alpha_bar-1|^2/n over p \
         ({runs} runs x {reps} reps, {threads} threads) =="
    );
    let mut err_table = Table::new(&{
        let mut h = vec!["p"];
        h.extend(arms.iter().map(|a| a.label));
        h.push("frc/theory p^d/(1-p^d)");
        h
    });
    let mut cov_table = Table::new(&{
        let mut h = vec!["p"];
        h.extend(arms.iter().map(|a| a.label));
        h.push("frc/theory ell*opt");
        h
    });
    for &p in &P_GRID {
        let mut err_row = vec![format!("{p:.2}")];
        let mut cov_row = vec![format!("{p:.2}")];
        for arm in arms {
            let mut errs = Stats::new();
            let mut covs = Stats::new();
            for rep in 0..reps {
                let mut rng = Rng::new(1000 + rep as u64);
                let scheme = build(&arm.scheme, &mut rng);
                let m = scheme.n_machines();
                let engine =
                    TrialEngine::new(threads, 77 + rep as u64 * 13 + (p * 1000.0) as u64);
                let s = decoding_stats_par(
                    &engine,
                    |_chunk| make_decoder(&scheme, arm.decoder, p),
                    bernoulli_masks(m, p),
                    runs,
                    &mut rng,
                );
                errs.push(s.mean_err_per_block);
                covs.push(s.cov_norm);
            }
            err_row.push(format!("{}±{}", sci(errs.mean()), sci(errs.std())));
            cov_row.push(format!("{}±{}", sci(covs.mean()), sci(covs.std())));
        }
        err_row.push(sci(theory::optimal_lower_bound(p, d)));
        // ell=2 blocks/machine at n=N... see Fig 3 text
        cov_row.push(sci(2.0 * theory::optimal_lower_bound(p, d)));
        err_table.row(err_row);
        cov_table.row(cov_row);
    }
    println!("-- (a/c) mean decoding error --");
    err_table.print();
    println!("-- (b/d) covariance spectral norm --");
    cov_table.print();
}

/// Sharded manifest mode: the regime-1 arms as standard decode-error
/// sweeps, one shard manifest per (arm, p).
fn run_shard_mode(args: &BenchArgs, spec: ShardSpec) {
    let trials = args.usize_or("--trials", 10_000);
    let threads = args.threads();
    let out_dir = PathBuf::from(args.str_or("--out-dir", "fig3_shards"));
    if let Err(e) = std::fs::create_dir_all(&out_dir) {
        eprintln!("cannot create --out-dir {}: {e}", out_dir.display());
        std::process::exit(2);
    }
    let arms: [(&str, &str, &str); 4] = [
        ("a1_optimal", "graph-rr:16,3", "optimal"),
        ("a1_fixed", "graph-rr:16,3", "fixed"),
        ("expander_optimal", "expander:24,3", "optimal"),
        ("frc_optimal", "frc:16,24,3", "optimal"),
    ];
    println!(
        "== Figure 3 sharded mode: shard {spec}, {trials} trials/combo, {threads} threads =="
    );
    for (name, scheme, decoder) in arms {
        for &p in &P_GRID {
            let cfg = SweepConfig {
                sweep: SweepKind::DecodeError,
                scheme: scheme.into(),
                decoder: decoder.into(),
                p,
                seed: 1000 + (p * 1000.0).round() as u64,
                trials,
                chunk: 32,
                params: BTreeMap::new(),
            };
            let res = shard::run_shard(&cfg, threads, spec).expect("decode-error sweep");
            let path = out_dir.join(format!(
                "fig3_{name}_p{:03}_shard{}of{}.json",
                (p * 100.0).round() as u32,
                spec.index,
                spec.count
            ));
            match res.write(&path) {
                Ok(()) => println!(
                    "  {name} p={p:.2}: trials [{}, {}) mean={} -> {}",
                    res.lo,
                    res.hi,
                    sci(res.stats.mean()),
                    path.display()
                ),
                Err(e) => eprintln!("  {e}"),
            }
        }
    }
    println!("merge each combo's {} shard(s) with `gcod sweep-merge`.", spec.count);
}

fn main() {
    let args = BenchArgs::from_env();
    if let Some(s) = args.get("--shard") {
        let spec = match ShardSpec::parse(s) {
            Ok(spec) => spec,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        };
        run_shard_mode(&args, spec);
        return;
    }
    let runs = args.usize_or("--runs", 50);
    let reps = if args.quick() { 2 } else { args.usize_or("--reps", 5) };
    let regime = args.str_or("--regime", "both");
    let threads = args.threads();

    if regime == "1" || regime == "both" {
        let arms = [
            Arm {
                label: "A1 optimal",
                scheme: SchemeSpec::GraphRandomRegular { n: 16, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "A1 fixed",
                scheme: SchemeSpec::GraphRandomRegular { n: 16, d: 3 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "expander[6] optimal",
                scheme: SchemeSpec::ExpanderAdj { n: 24, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "frc optimal",
                scheme: SchemeSpec::Frc { n: 16, m: 24, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
        ];
        sweep("regime 1 (m=24, d=3)", &arms, 3.0, runs, reps, threads);
    }
    if regime == "2" || regime == "both" {
        let runs2 = if args.quick() { 20 } else { runs };
        let arms = [
            Arm {
                label: "A2=LPS optimal",
                scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "A2=LPS fixed",
                scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "expander[6] fixed",
                scheme: SchemeSpec::ExpanderAdj { n: 6552, d: 6 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "frc optimal",
                scheme: SchemeSpec::Frc { n: 2184, m: 6552, d: 6 },
                decoder: DecoderSpec::Optimal,
            },
        ];
        sweep("regime 2 (m=6552, d=6, LPS(5,13))", &arms, 6.0, runs2, reps.min(3), threads);
    }
    println!("\nexpected shape (paper Fig. 3): optimal tracks the p^d/(1-p^d)");
    println!("floor at small p; fixed ~ p/(d(1-p)); expander[6] worst.");
}
