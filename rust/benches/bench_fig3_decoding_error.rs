//! Figure 3 — decoding error and covariance norm under random stragglers.
//!
//! (a)(b): regime 1 — m=24 machines, d=3, A_1 = random 3-regular graph
//!         on n=16 vertices.
//! (c)(d): regime 2 — m=6552, d=6, A_2 = LPS(5,13) on n=2184 vertices.
//!
//! Series per panel: graph scheme w/ optimal + fixed decoding, the
//! expander code of [6] (optimal in regime 1, fixed in regime 2 — as
//! the paper does, for decode cost), and the FRC theory line
//! p^d/(1-p^d), which the FRC achieves exactly.
//!
//! Flags: --runs N (default 50, as the paper), --reps R (error bars,
//! default 5; 2 under --quick), --regime 1|2|both, --threads N.
//!
//! The Monte-Carlo draws run on the sweep::TrialEngine: per-trial PRNG
//! substreams + ordered reduction, so the numbers are identical for any
//! --threads value.

use gcod::bench_util::{BenchArgs, P_GRID};
use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::gd::analysis::theory;
use gcod::metrics::{sci, Stats, Table};
use gcod::prng::Rng;
use gcod::sweep::{bernoulli_masks, decoding_stats_par, TrialEngine};

struct Arm {
    label: &'static str,
    scheme: SchemeSpec,
    decoder: DecoderSpec,
}

fn sweep(regime: &str, arms: &[Arm], d: f64, runs: usize, reps: usize, threads: usize) {
    println!(
        "\n== Figure 3 {regime}: E|alpha_bar-1|^2/n over p ({runs} runs x {reps} reps, {threads} threads) =="
    );
    let mut err_table = Table::new(&{
        let mut h = vec!["p"];
        h.extend(arms.iter().map(|a| a.label));
        h.push("frc/theory p^d/(1-p^d)");
        h
    });
    let mut cov_table = Table::new(&{
        let mut h = vec!["p"];
        h.extend(arms.iter().map(|a| a.label));
        h.push("frc/theory ell*opt");
        h
    });
    for &p in &P_GRID {
        let mut err_row = vec![format!("{p:.2}")];
        let mut cov_row = vec![format!("{p:.2}")];
        for arm in arms {
            let mut errs = Stats::new();
            let mut covs = Stats::new();
            for rep in 0..reps {
                let mut rng = Rng::new(1000 + rep as u64);
                let scheme = build(&arm.scheme, &mut rng);
                let m = scheme.n_machines();
                let engine =
                    TrialEngine::new(threads, 77 + rep as u64 * 13 + (p * 1000.0) as u64);
                let s = decoding_stats_par(
                    &engine,
                    |_chunk| make_decoder(&scheme, arm.decoder, p),
                    bernoulli_masks(m, p),
                    runs,
                    &mut rng,
                );
                errs.push(s.mean_err_per_block);
                covs.push(s.cov_norm);
            }
            err_row.push(format!("{}±{}", sci(errs.mean()), sci(errs.std())));
            cov_row.push(format!("{}±{}", sci(covs.mean()), sci(covs.std())));
        }
        err_row.push(sci(theory::optimal_lower_bound(p, d)));
        cov_row.push(sci(2.0 * theory::optimal_lower_bound(p, d))); // ell=2 blocks/machine at n=N... see Fig 3 text
        err_table.row(err_row);
        cov_table.row(cov_row);
    }
    println!("-- (a/c) mean decoding error --");
    err_table.print();
    println!("-- (b/d) covariance spectral norm --");
    cov_table.print();
}

fn main() {
    let args = BenchArgs::from_env();
    let runs = args.usize_or("--runs", 50);
    let reps = if args.quick() { 2 } else { args.usize_or("--reps", 5) };
    let regime = args.str_or("--regime", "both");
    let threads = args.threads();

    if regime == "1" || regime == "both" {
        let arms = [
            Arm {
                label: "A1 optimal",
                scheme: SchemeSpec::GraphRandomRegular { n: 16, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "A1 fixed",
                scheme: SchemeSpec::GraphRandomRegular { n: 16, d: 3 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "expander[6] optimal",
                scheme: SchemeSpec::ExpanderAdj { n: 24, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "frc optimal",
                scheme: SchemeSpec::Frc { n: 16, m: 24, d: 3 },
                decoder: DecoderSpec::Optimal,
            },
        ];
        sweep("regime 1 (m=24, d=3)", &arms, 3.0, runs, reps, threads);
    }
    if regime == "2" || regime == "both" {
        let runs2 = if args.quick() { 20 } else { runs };
        let arms = [
            Arm {
                label: "A2=LPS optimal",
                scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
                decoder: DecoderSpec::Optimal,
            },
            Arm {
                label: "A2=LPS fixed",
                scheme: SchemeSpec::GraphLps { p: 5, q: 13 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "expander[6] fixed",
                scheme: SchemeSpec::ExpanderAdj { n: 6552, d: 6 },
                decoder: DecoderSpec::Fixed,
            },
            Arm {
                label: "frc optimal",
                scheme: SchemeSpec::Frc { n: 2184, m: 6552, d: 6 },
                decoder: DecoderSpec::Optimal,
            },
        ];
        sweep("regime 2 (m=6552, d=6, LPS(5,13))", &arms, 6.0, runs2, reps.min(3), threads);
    }
    println!("\nexpected shape (paper Fig. 3): optimal tracks the p^d/(1-p^d)");
    println!("floor at small p; fixed ~ p/(d(1-p)); expander[6] worst.");
}
