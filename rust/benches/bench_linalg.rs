//! Raw-speed linalg tier — the exact (pinned 4-wide reference) kernels
//! against the fast tier (8-wide, fixed reduction order) on the
//! reductions that dominate sweep time: dot, the GramCache gemv serve
//! path, the cache-blocked SYRK, and the full GramCache build behind
//! gd-final.
//!
//! Compile with `--features simd` to point the fast tier at the AVX2
//! kernels on x86-64; without the feature the portable 8-wide path is
//! measured, so this bench runs (and gates) everywhere.
//!
//! Fails loudly (non-zero exit, for CI) when:
//! * the fast tier disagrees with exact beyond `FAST_REL_TOL` on any
//!   measured shape — the exact|fast contract checked at real sizes,
//!   not just the unit-test toys;
//! * a record's bootstrap CI separates above the tracked baseline's
//!   interval (statistical gate, see
//!   [`gcod::bench_util::compare_against_baseline`]).
//!
//! Flags: --quick, --json PATH (default BENCH_linalg.json; "none"
//! disables), --baseline (write the tracked
//! rust/benches/baselines/BENCH_linalg.json instead and skip the gate).

use gcod::bench_util::{
    bench, black_box, compare_against_baseline, read_baseline, BenchArgs, JsonReport, BENCH_SLACK,
};
use gcod::data::LstsqData;
use gcod::gd::GramCache;
use gcod::linalg::simd::FAST_REL_TOL;
use gcod::linalg::{LinalgBackend, Mat};
use gcod::prng::Rng;
use std::time::Duration;

fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

fn max_rel_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| rel_err(*x, *y)).fold(0.0, f64::max)
}

const BACKENDS: [LinalgBackend; 2] = [LinalgBackend::Exact, LinalgBackend::Fast];

fn main() {
    let args = BenchArgs::from_env();
    let quick = args.quick();
    let budget = Duration::from_millis(if quick { 200 } else { 1000 });
    let mut report = JsonReport::new("bench_linalg");
    let mut failures: Vec<String> = Vec::new();
    let mut rng = Rng::new(42);

    let fast_impl = if cfg!(feature = "simd") {
        "simd feature: AVX2 where available"
    } else {
        "portable 8-wide"
    };
    println!("== linalg tiers: exact (pinned reference) vs fast ({fast_impl}) ==");

    // ---- dot: the reduction under every residual and every norm ----
    // odd lengths exercise the 8-wide main loop plus a 1..=7 tail
    let dot_lens: &[usize] = if quick { &[1021, 8191] } else { &[1021, 65_531, 1_048_573] };
    for &n in dot_lens {
        let x = rng.gaussian_vec(n, 1.0);
        let y = rng.gaussian_vec(n, 1.0);
        let exact = LinalgBackend::Exact.dot(&x, &y);
        let fast = LinalgBackend::Fast.dot(&x, &y);
        let err = rel_err(exact, fast);
        if err > FAST_REL_TOL {
            failures.push(format!("dot n={n}: fast vs exact rel err {err:.2e} > {FAST_REL_TOL:e}"));
        }
        for be in BACKENDS {
            let r = bench(&format!("dot n={n} {}", be.as_str()), 2, budget, 100_000, || {
                black_box(be.dot(&x, &y));
            });
            report.push_result(&r, Some(n), 1);
        }
    }

    // ---- gemv over a packed block: the GramCache serve path ----
    let gemv_shapes: &[(usize, usize)] =
        if quick { &[(256, 31)] } else { &[(256, 31), (1024, 96)] };
    for &(rows, cols) in gemv_shapes {
        let a = rng.gaussian_vec(rows * cols, 1.0);
        let x = rng.gaussian_vec(cols, 1.0);
        let mut y_exact = vec![0.0; rows];
        let mut y_fast = vec![0.0; rows];
        LinalgBackend::Exact.gemv_slice_into(1.0, &a, cols, &x, 0.0, &mut y_exact);
        LinalgBackend::Fast.gemv_slice_into(1.0, &a, cols, &x, 0.0, &mut y_fast);
        let err = max_rel_err(&y_exact, &y_fast);
        if err > FAST_REL_TOL {
            failures.push(format!(
                "gemv {rows}x{cols}: fast vs exact rel err {err:.2e} > {FAST_REL_TOL:e}"
            ));
        }
        let mut y = vec![0.0; rows];
        for be in BACKENDS {
            let r = bench(&format!("gemv {rows}x{cols} {}", be.as_str()), 2, budget, 100_000, || {
                be.gemv_slice_into(1.0, &a, cols, &x, 0.0, &mut y);
                black_box(y[0]);
            });
            report.push_result(&r, Some(rows * cols), 1);
        }
    }

    // ---- SYRK G = AᵀA: the GramCache build kernel, cache-blocked on
    // the fast tier ----
    let syrk_shapes: &[(usize, usize)] =
        if quick { &[(1024, 16)] } else { &[(1024, 16), (4096, 32)] };
    for &(rows, cols) in syrk_shapes {
        let a = rng.gaussian_vec(rows * cols, 1.0);
        let mut g_exact = Mat::zeros(cols, cols);
        let mut g_fast = Mat::zeros(cols, cols);
        LinalgBackend::Exact.syrk_into(&a, cols, &mut g_exact);
        LinalgBackend::Fast.syrk_into(&a, cols, &mut g_fast);
        let err = max_rel_err(&g_exact.data, &g_fast.data);
        if err > FAST_REL_TOL {
            failures.push(format!(
                "syrk {rows}x{cols}: fast vs exact rel err {err:.2e} > {FAST_REL_TOL:e}"
            ));
        }
        let mut g = Mat::zeros(cols, cols);
        for be in BACKENDS {
            let r = bench(&format!("syrk {rows}x{cols} {}", be.as_str()), 1, budget, 20_000, || {
                be.syrk_into(&a, cols, &mut g);
                black_box(g.data[0]);
            });
            report.push_result(&r, Some(rows * cols), 1);
        }
    }

    // ---- the full GramCache build (n blocks of SYRK + the shared
    // elementwise gather, which is tier-independent by construction) ----
    let (n_pts, dim, n_blocks) = if quick { (4096, 16, 16) } else { (32768, 32, 32) };
    let data = LstsqData::generate(n_pts, dim, n_blocks, 1.0, &mut rng);
    let exact_cache = GramCache::new_backend(&data, LinalgBackend::Exact);
    let fast_cache = GramCache::new_backend(&data, LinalgBackend::Fast);
    let mut worst = 0.0f64;
    for i in 0..n_blocks {
        worst = worst.max(max_rel_err(exact_cache.block_gram(i), fast_cache.block_gram(i)));
        // the c_i gather has no reduction order: bit-equal across tiers
        for (e, f) in exact_cache.block_c(i).iter().zip(fast_cache.block_c(i)) {
            if e.to_bits() != f.to_bits() {
                failures.push(format!("gram-build block {i}: c_i differs across tiers"));
                break;
            }
        }
    }
    println!("gram blocks: worst fast-vs-exact rel err {worst:.2e} (tol {FAST_REL_TOL:e})");
    if worst > FAST_REL_TOL {
        failures.push(format!(
            "gram-build N={n_pts} d={dim}: fast vs exact rel err {worst:.2e} > {FAST_REL_TOL:e}"
        ));
    }
    for be in BACKENDS {
        let name = format!("gram-build N={n_pts} d={dim} n={n_blocks} {}", be.as_str());
        let r = bench(&name, 1, budget, 200, || {
            black_box(GramCache::new_backend(&data, be).backend());
        });
        report.push_result(&r, Some(n_pts * dim), 1);
    }

    // ---- JSON + the statistical regression gate ----
    let json = match args.get("--json") {
        Some(path) => path.to_string(),
        None if args.has("--baseline") => {
            format!("{}/benches/baselines/BENCH_linalg.json", env!("CARGO_MANIFEST_DIR"))
        }
        None => "BENCH_linalg.json".to_string(),
    };
    if json != "none" {
        match report.write(std::path::Path::new(&json)) {
            Ok(()) => println!("\nwrote {json}"),
            Err(e) => eprintln!("\ncould not write {json}: {e}"),
        }
    }
    let tracked = format!("{}/benches/baselines/BENCH_linalg.json", env!("CARGO_MANIFEST_DIR"));
    if !args.has("--baseline") {
        match read_baseline(std::path::Path::new(&tracked)) {
            Some(base) if !base.is_empty() => {
                let regressions = compare_against_baseline(report.records(), &base, BENCH_SLACK);
                println!(
                    "regression gate: {} record(s) vs tracked baseline, {} regression(s)",
                    report.records().len(),
                    regressions.len()
                );
                failures.extend(regressions);
            }
            _ => println!(
                "regression gate: no usable baseline at {tracked} (missing or placeholder) — \
                 skipped; run with --baseline on a quiet machine to pin one"
            ),
        }
    }

    if failures.is_empty() {
        println!("\nclaim check: the fast tier matches exact to FAST_REL_TOL on every measured");
        println!("shape, the c_i gather is bit-identical across tiers, and no record regressed");
        println!("past the tracked baseline. All checks passed.");
    } else {
        eprintln!("\nBENCH FAILURES:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
