//! PJRT runtime performance: artifact dispatch latency and the cost
//! split of one coded-GD iteration on the request path (L3 overhead vs
//! L1/L2 compute), supporting the "L3 is not the bottleneck" target in
//! DESIGN.md §Perf.

use gcod::bench_util::{bench, black_box, BenchArgs};
use gcod::codes::GraphCode;
use gcod::data::LstsqData;
use gcod::decode::{Decoder, OptimalGraphDecoder};
use gcod::metrics::Table;
use gcod::prng::Rng;
use gcod::runtime::{Runtime, Tensor};
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let budget = Duration::from_millis(if args.quick() { 400 } else { 2000 });
    let rt = Runtime::open_default().expect("run `make artifacts` first");
    let mut rng = Rng::new(0);

    println!("== artifact dispatch latency (host-literal path) ==");
    let mut t = Table::new(&["artifact", "mean", "min"]);
    // qs block grad: 16x8x32
    {
        let exe = rt.load("block_grad_qs_16x8x32").unwrap();
        let theta =
            Tensor::f32(&[32], rng.gaussian_vec(32, 1.0).iter().map(|&v| v as f32).collect());
        let x = Tensor::f32(&[16, 8, 32], (0..4096).map(|_| rng.gaussian() as f32).collect());
        let y = Tensor::f32(&[16, 8], (0..128).map(|_| rng.gaussian() as f32).collect());
        let r = bench("block_grad_qs", 3, budget, 100_000, || {
            black_box(exe.run(&[theta.clone(), x.clone(), y.clone()]).unwrap());
        });
        t.row(vec![
            "block_grad_qs_16x8x32".into(),
            gcod::bench_util::fmt_dur(r.mean),
            gcod::bench_util::fmt_dur(r.min),
        ]);
    }
    // fig5 block grad: 2184x3x200 — the simulated-regime hot dispatch
    {
        let exe = rt.load("block_grad_fig5_2184x3x200").unwrap();
        let theta = Tensor::f32(&[200], vec![0.1; 200]);
        let x = Tensor::f32(&[2184, 3, 200], vec![0.01; 2184 * 3 * 200]);
        let y = Tensor::f32(&[2184, 3], vec![0.2; 2184 * 3]);
        let xb = exe.upload(&x, &rt.client).unwrap();
        let yb = exe.upload(&y, &rt.client).unwrap();
        let r = bench("block_grad_fig5 (host)", 2, budget, 10_000, || {
            black_box(exe.run(&[theta.clone(), x.clone(), y.clone()]).unwrap());
        });
        t.row(vec![
            "block_grad_fig5 host-inputs".into(),
            gcod::bench_util::fmt_dur(r.mean),
            gcod::bench_util::fmt_dur(r.min),
        ]);
        let r2 = bench("block_grad_fig5 (device)", 2, budget, 10_000, || {
            let tb = exe.upload(&theta, &rt.client).unwrap();
            black_box(exe.run_b(&[&tb, &xb, &yb]).unwrap());
        });
        t.row(vec![
            "block_grad_fig5 device-resident".into(),
            gcod::bench_util::fmt_dur(r2.mean),
            gcod::bench_util::fmt_dur(r2.min),
        ]);
    }
    // combine
    {
        let exe = rt.load("decode_combine_fig5_2184x200").unwrap();
        let g = Tensor::f32(&[2184, 200], vec![0.5; 2184 * 200]);
        let w = Tensor::f32(&[2184], vec![1.0; 2184]);
        let r = bench("decode_combine_fig5", 3, budget, 100_000, || {
            black_box(exe.run(&[g.clone(), w.clone()]).unwrap());
        });
        t.row(vec![
            "decode_combine_fig5".into(),
            gcod::bench_util::fmt_dur(r.mean),
            gcod::bench_util::fmt_dur(r.min),
        ]);
    }
    t.print();

    // ---- one full coded-GD iteration: where does the time go? ----
    println!("\n== request-path cost split (fig5 shapes, p=0.2) ==");
    let code = GraphCode::lps(5, 13);
    let data = LstsqData::generate(6552, 200, 2184, 1.0, &mut rng);
    let dec = OptimalGraphDecoder::new(&code.graph);
    let masks: Vec<Vec<bool>> = (0..16).map(|i| Rng::new(i).bernoulli_mask(6552, 0.2)).collect();
    let mut i = 0;
    let r_decode = bench("decode (L3)", 2, budget, 100_000, || {
        black_box(dec.decode(&masks[i % 16]).alpha[0]);
        i += 1;
    });
    let exe = rt.load("block_grad_fig5_2184x3x200").unwrap();
    let combine = rt.load("decode_combine_fig5_2184x200").unwrap();
    let (xb32, yb32) = data.to_f32_buffers();
    let xbuf = exe.upload(&Tensor::f32(&[2184, 3, 200], xb32), &rt.client).unwrap();
    let ybuf = exe.upload(&Tensor::f32(&[2184, 3], yb32), &rt.client).unwrap();
    let theta = Tensor::f32(&[200], vec![0.0; 200]);
    let alpha = Tensor::f32(&[2184], vec![1.0; 2184]);
    let r_grad = bench("block grads (L1/L2)", 2, budget, 10_000, || {
        let tb = exe.upload(&theta, &rt.client).unwrap();
        black_box(exe.run_b(&[&tb, &xbuf, &ybuf]).unwrap());
    });
    let g_host = exe
        .run(&[
            theta.clone(),
            Tensor::f32(&[2184, 3, 200], data.to_f32_buffers().0),
            Tensor::f32(&[2184, 3], data.to_f32_buffers().1),
        ])
        .unwrap()
        .into_iter()
        .next()
        .unwrap();
    let r_combine = bench("combine (L1)", 2, budget, 100_000, || {
        black_box(combine.run(&[g_host.clone(), alpha.clone()]).unwrap());
    });
    let total = r_decode.mean + r_grad.mean + r_combine.mean;
    println!(
        "\nsplit: decode {:.1}% | grads {:.1}% | combine {:.1}%  (iter ~ {})",
        100.0 * r_decode.mean.as_secs_f64() / total.as_secs_f64(),
        100.0 * r_grad.mean.as_secs_f64() / total.as_secs_f64(),
        100.0 * r_combine.mean.as_secs_f64() / total.as_secs_f64(),
        gcod::bench_util::fmt_dur(total)
    );
    println!("target: L3 decode a small fraction of the gradient compute.");
}
