//! Feature-gate plumbing for the PJRT runtime.
//!
//! The crate is dependency-free by default (offline CI), but the PJRT
//! request path needs the vendored `xla` + `anyhow` crates. Gating that
//! code on `feature = "pjrt"` alone made `cargo check --features pjrt`
//! explode into hundreds of unresolved-import errors in a tree without
//! the vendored deps. Instead, the code is gated on the `pjrt_runtime`
//! cfg emitted here, which is set only when the feature is on AND the
//! deps are actually declared: the dep-free wiring in Cargo.toml makes
//! `pjrt` expand to the `pjrt-unvendored` marker feature, which
//! suppresses the cfg and lets `lib.rs` raise one clear
//! `compile_error!` pointing at the vendoring instructions. Vendoring
//! (switching the feature to `pjrt = ["dep:xla", "dep:anyhow"]`) drops
//! the marker and the runtime compiles for real.

fn main() {
    // declared unconditionally so `-D warnings` builds never trip the
    // unexpected-cfg lint on targets that mention pjrt_runtime
    println!("cargo:rustc-check-cfg=cfg(pjrt_runtime)");
    let pjrt = std::env::var_os("CARGO_FEATURE_PJRT").is_some();
    let unvendored = std::env::var_os("CARGO_FEATURE_PJRT_UNVENDORED").is_some();
    if pjrt && !unvendored {
        println!("cargo:rustc-cfg=pjrt_runtime");
    }
}
