//! Cross-shard determinism + conformance suite for the sharded sweep
//! subsystem (`sweep::shard`). The contracts pinned here:
//!
//! * running a standard sweep as 1, 4 or 8 shards (any thread counts)
//!   and merging produces a result **bit-identical** to the
//!   single-process run — including byte-identical merged JSON — for
//!   the Fig-3 (`decode-error`), Fig-4 (`gd-final`, the deterministic
//!   substream port of the cluster experiment) and greedy-attack
//!   (`attack`) sweeps, with both stateless and warm-started (LSQR)
//!   decoders;
//! * a property test: *any* random contiguous split of `[0, N)` merges
//!   to the single-run bits, for random chunk sizes (partial leading
//!   chunks exercise the warm-state replay path);
//! * the `gcod sweep-shard` / `gcod sweep-merge` CLI round-trip over
//!   real separate OS processes is byte-identical to the in-process
//!   single run, and the merge CLI rejects bad shard sets.

use gcod::prop_assert;
use gcod::sweep::shard::{self, MergedSweep, ShardSpec, SweepConfig, SweepKind};
use gcod::testing::check;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::Command;

fn cfg(
    kind: SweepKind,
    scheme: &str,
    decoder: &str,
    trials: usize,
    seed: u64,
    chunk: usize,
) -> SweepConfig {
    SweepConfig {
        sweep: kind,
        scheme: scheme.into(),
        decoder: decoder.into(),
        p: 0.25,
        seed,
        trials,
        chunk,
        params: BTreeMap::new(),
    }
}

/// Run the sweep as `k` balanced shards (each with its own thread
/// count, to compose shard- and thread-invariance) and merge.
fn run_split(cfg: &SweepConfig, k: usize) -> MergedSweep {
    let shards: Vec<_> = (0..k)
        .map(|i| {
            let threads = 1 + (i % 3);
            shard::run_shard(cfg, threads, ShardSpec::new(i, k).unwrap()).unwrap()
        })
        .collect();
    shard::merge(shards).unwrap()
}

fn assert_merged_identical(a: &MergedSweep, b: &MergedSweep, ctx: &str) {
    assert_eq!(a.values.len(), b.values.len(), "{ctx}: value count");
    for (i, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: trial {i}: {x} vs {y}");
    }
    assert_eq!(a.stats.mean().to_bits(), b.stats.mean().to_bits(), "{ctx}: mean");
    assert_eq!(a.stats.m2().to_bits(), b.stats.m2().to_bits(), "{ctx}: m2");
    // the headline acceptance contract: byte-identical merged JSON
    assert_eq!(a.render(), b.render(), "{ctx}: merged JSON bytes");
}

/// Fig-3 sweep: stateless linear-time graph decoder.
#[test]
fn decode_error_1_vs_4_vs_8_shards_bit_exact() {
    let c = cfg(SweepKind::DecodeError, "graph-rr:16,3", "optimal", 200, 7, 8);
    let single = shard::run_full(&c, 2).unwrap();
    for k in [4usize, 8] {
        let merged = run_split(&c, k);
        assert_merged_identical(&single, &merged, &format!("decode-error {k} shards"));
    }
}

/// Fig-3 sweep through the *warm-started* LSQR decoder: shard
/// boundaries that cut chunks mid-way exercise the replay path, which
/// must rebuild the warm-start history exactly.
#[test]
fn decode_error_lsqr_warm_replay_bit_exact() {
    // 90 trials, chunk 8: balanced 4-way shards are 23/23/22/22 wide,
    // so shard starts (23, 46, 68) all land mid-chunk
    let c = cfg(SweepKind::DecodeError, "graph-rr:12,3", "optimal-lsqr", 90, 13, 8);
    let single = shard::run_full(&c, 1).unwrap();
    let merged = run_split(&c, 4);
    assert_merged_identical(&single, &merged, "lsqr warm replay 4 shards");
    // explicit ragged split (empty shard included)
    let ranges = [(0usize, 5usize), (5, 37), (37, 37), (37, 90)];
    let shards: Vec<_> =
        ranges.iter().map(|&(lo, hi)| shard::run_range(&c, 2, lo, hi).unwrap()).collect();
    let ragged = shard::merge(shards).unwrap();
    assert_merged_identical(&single, &ragged, "lsqr warm replay ragged split");
}

/// Fig-4 on deterministic substreams (`gd-final`): each trial is one
/// full simulated coded-GD trajectory.
#[test]
fn gd_final_1_vs_4_vs_8_shards_bit_exact() {
    let mut c = cfg(SweepKind::GdFinal, "graph-rr:8,3", "optimal", 12, 3, 4);
    c.params.insert("n-points".into(), "64".into());
    c.params.insert("dim".into(), "8".into());
    c.params.insert("iters".into(), "10".into());
    let single = shard::run_full(&c, 2).unwrap();
    for k in [4usize, 8] {
        let merged = run_split(&c, k);
        assert_merged_identical(&single, &merged, &format!("gd-final {k} shards"));
    }
}

/// Greedy adversarial sweep: the trial axis is the attack budget, and
/// shards recompute the nested greedy trace up to their own `hi` — the
/// prefix property must make every slice agree with the full run.
#[test]
fn attack_1_vs_3_shards_bit_exact() {
    let c = cfg(SweepKind::Attack, "graph-rr:12,3", "optimal", 10, 0, 4);
    let single = shard::run_full(&c, 1).unwrap();
    let merged = run_split(&c, 3);
    assert_merged_identical(&single, &merged, "attack 3 shards");
    // and through the warm-started generic decoder
    let c2 = cfg(SweepKind::Attack, "graph-rr:12,3", "optimal-lsqr", 8, 0, 4);
    let single2 = shard::run_full(&c2, 1).unwrap();
    let merged2 = run_split(&c2, 4);
    assert_merged_identical(&single2, &merged2, "attack lsqr 4 shards");
}

/// Property: ANY random contiguous split of [0, N) merges to the
/// single-run bits, for random chunk sizes, seeds and both decoder
/// families (the stateless graph decoder and the warm-started LSQR
/// decoder whose replay path depends on the chunk grid).
#[test]
fn prop_random_shard_splits_merge_to_single_bits() {
    check("shard-random-splits", 12, |g| {
        let trials = g.size(20, 60);
        let chunk = g.size(1, 16);
        let seed = g.rng.next_u64();
        let decoder = *g.choice(&["optimal", "optimal-lsqr"]);
        let c = cfg(SweepKind::DecodeError, "graph-rr:12,3", decoder, trials, seed, chunk);
        let single = shard::run_full(&c, 2).map_err(|e| format!("full run: {e}"))?;
        // random cut points -> contiguous ranges covering [0, trials)
        let n_cuts = g.size(0, 4);
        let mut cuts: Vec<usize> = (0..n_cuts).map(|_| g.rng.below(trials + 1)).collect();
        cuts.push(0);
        cuts.push(trials);
        cuts.sort_unstable();
        cuts.dedup();
        let mut shards = Vec::new();
        for w in cuts.windows(2) {
            let threads = 1 + g.rng.below(3);
            shards.push(
                shard::run_range(&c, threads, w[0], w[1]).map_err(|e| format!("range: {e}"))?,
            );
        }
        let merged = shard::merge(shards).map_err(|e| format!("merge: {e}"))?;
        prop_assert!(
            merged.render() == single.render(),
            "split {cuts:?} chunk {chunk} decoder {decoder} diverged from single run"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// CLI round-trip: real separate OS processes
// ---------------------------------------------------------------------

fn gcod_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gcod"))
}

fn run_ok(cmd: &mut Command) {
    let out = cmd.output().expect("spawn gcod");
    assert!(
        out.status.success(),
        "gcod failed: {:?}\nstdout: {}\nstderr: {}",
        cmd,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gcod_shard_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The acceptance contract: `gcod sweep-shard` + `gcod sweep-merge`
/// across separate OS processes produce byte-identical merged metric
/// JSON to the equivalent single-process run, for at least two shard
/// counts (here 1, 2 and 3).
#[test]
fn cli_shard_merge_round_trip_byte_identical() {
    let dir = tmp_dir("cli");
    let sweep_args: &[&str] = &[
        "sweep-shard",
        "--sweep",
        "decode-error",
        "--scheme",
        "graph-rr:16,3",
        "--decoder",
        "optimal",
        "--p",
        "0.2",
        "--trials",
        "120",
        "--seed",
        "9",
        "--chunk",
        "8",
        "--threads",
        "2",
    ];
    let shard_path = |i: usize, k: usize| dir.join(format!("s{i}of{k}.json"));
    for k in [1usize, 2, 3] {
        for i in 0..k {
            run_ok(gcod_bin().args(sweep_args).args([
                "--shard",
                &format!("{i}/{k}"),
                "--out",
                shard_path(i, k).to_str().unwrap(),
            ]));
        }
        let merged = dir.join(format!("merged_{k}.json"));
        let mut merge_cmd = gcod_bin();
        merge_cmd.arg("sweep-merge");
        for i in 0..k {
            merge_cmd.args(["--input", shard_path(i, k).to_str().unwrap()]);
        }
        merge_cmd.args(["--out", merged.to_str().unwrap()]);
        run_ok(&mut merge_cmd);
    }
    let m1 = std::fs::read_to_string(dir.join("merged_1.json")).unwrap();
    let m2 = std::fs::read_to_string(dir.join("merged_2.json")).unwrap();
    let m3 = std::fs::read_to_string(dir.join("merged_3.json")).unwrap();
    assert_eq!(m1, m2, "1-shard vs 2-shard merged JSON");
    assert_eq!(m1, m3, "1-shard vs 3-shard merged JSON");

    // and both equal the in-process single run of the same config
    let c = SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 9,
        trials: 120,
        chunk: 8,
        params: BTreeMap::new(),
    };
    let single = shard::run_full(&c, 4).unwrap();
    assert_eq!(m1, single.render(), "CLI merge vs in-process run");
    let _ = std::fs::remove_dir_all(&dir);
}

/// sweep-merge must reject incomplete and mismatched shard sets.
#[test]
fn cli_merge_rejects_bad_shard_sets() {
    let dir = tmp_dir("cli_bad");
    let base: &[&str] =
        &["sweep-shard", "--trials", "60", "--seed", "4", "--threads", "1"];
    let s0 = dir.join("s0.json");
    let s2 = dir.join("s2.json");
    run_ok(gcod_bin().args(base).args(["--shard", "0/3", "--out", s0.to_str().unwrap()]));
    run_ok(gcod_bin().args(base).args(["--shard", "2/3", "--out", s2.to_str().unwrap()]));

    // gap: shard 1/3 missing
    let out = gcod_bin()
        .args(["sweep-merge", "--input", s0.to_str().unwrap(), "--input", s2.to_str().unwrap()])
        .args(["--out", dir.join("m.json").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "merge of gapped shards must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("gap"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // seed mismatch
    let s1_other = dir.join("s1_other.json");
    run_ok(gcod_bin().args([
        "sweep-shard",
        "--trials",
        "60",
        "--seed",
        "5",
        "--threads",
        "1",
        "--shard",
        "1/3",
        "--out",
        s1_other.to_str().unwrap(),
    ]));
    let out = gcod_bin()
        .args([
            "sweep-merge",
            "--input",
            s0.to_str().unwrap(),
            "--input",
            s1_other.to_str().unwrap(),
            "--input",
            s2.to_str().unwrap(),
        ])
        .args(["--out", dir.join("m2.json").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "merge of mismatched-seed shards must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("config mismatch"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // schema mismatch: doctor one manifest's schema version
    let doctored = std::fs::read_to_string(&s0)
        .unwrap()
        .replace("\"schema\": 2", "\"schema\": 99");
    let s0_bad = dir.join("s0_bad.json");
    std::fs::write(&s0_bad, doctored).unwrap();
    let out = gcod_bin()
        .args(["sweep-merge", "--input", s0_bad.to_str().unwrap()])
        .args(["--out", dir.join("m3.json").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "merge of wrong-schema manifest must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("schema version 99"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
