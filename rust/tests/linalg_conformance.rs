//! Conformance suite for the raw-speed linalg tier (`linalg::simd`).
//!
//! The exact|fast contract, pinned property-style:
//!
//! * the fast tier agrees with the exact (pinned-bits reference) tier
//!   to `FAST_REL_TOL` on random shapes, including every 8-wide
//!   remainder length 0..=7 (the tails that SIMD kernels classically
//!   get wrong);
//! * the fast tier is *deterministic*: same input, same bits, every
//!   call — on any hardware, with or without `--features simd` (the
//!   AVX2 kernels mirror the portable 8-wide kernels op for op);
//! * `LinalgBackend::Exact` dispatch is bit-identical to the plain
//!   scalar kernels, so `linalg=exact` sweeps cannot drift;
//! * at the sweep layer: a `linalg=exact` run renders byte-identical
//!   manifests to a run with no `linalg` param at all (the param is
//!   canonicalized away), and a `linalg=fast` sweep is shard-split
//!   invariant (1 shard == 4 shards, bit for bit) while recording its
//!   tier in the manifest.

use gcod::linalg::simd::{dot_fast, gemv_slice_into_fast, syrk_into_fast, FAST_REL_TOL};
use gcod::linalg::{dot, gemv_slice_into, syrk_into, LinalgBackend, Mat};
use gcod::prop_assert;
use gcod::sweep::shard::{self, ShardSpec, SweepConfig, SweepKind};
use gcod::testing::check;
use std::collections::BTreeMap;

fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

/// Random length hitting every `8q + r` tail class: the property
/// harness picks the remainder explicitly so r = 0..=7 all occur.
fn len_with_tail(g: &mut gcod::testing::Gen) -> usize {
    let blocks = g.size(0, 24);
    let rem = g.size(0, 7);
    (8 * blocks + rem).max(1)
}

#[test]
fn prop_fast_dot_matches_exact_and_is_deterministic() {
    check("linalg-dot-conformance", 120, |g| {
        let n = len_with_tail(g);
        // mixed scales stress the reduction-order difference
        let scale = *g.choice(&[1.0, 1e-6, 1e6]);
        let x: Vec<f64> = (0..n).map(|_| g.rng.gaussian() * scale).collect();
        let y: Vec<f64> = (0..n).map(|_| g.rng.gaussian()).collect();
        let exact = dot(&x, &y);
        let fast = dot_fast(&x, &y);
        prop_assert!(
            rel_err(exact, fast) <= FAST_REL_TOL,
            "n={n}: fast {fast} vs exact {exact} (rel {:.2e})",
            rel_err(exact, fast)
        );
        // determinism: bit-equal on every call
        prop_assert!(fast.to_bits() == dot_fast(&x, &y).to_bits(), "fast dot not deterministic");
        // dispatch: Exact is the scalar kernel, bit for bit
        prop_assert!(
            LinalgBackend::Exact.dot(&x, &y).to_bits() == exact.to_bits(),
            "Exact dispatch drifted from the scalar reference"
        );
        prop_assert!(
            LinalgBackend::Fast.dot(&x, &y).to_bits() == fast.to_bits(),
            "Fast dispatch drifted from dot_fast"
        );
        Ok(())
    });
}

#[test]
fn prop_fast_gemv_matches_exact() {
    check("linalg-gemv-conformance", 80, |g| {
        let rows = g.size(1, 40);
        let cols = len_with_tail(g).min(96);
        let a: Vec<f64> = (0..rows * cols).map(|_| g.rng.gaussian()).collect();
        let x: Vec<f64> = (0..cols).map(|_| g.rng.gaussian()).collect();
        let alpha = *g.choice(&[1.0, -0.5, 2.25]);
        let beta = *g.choice(&[0.0, 1.0, -1.5]);
        let y0: Vec<f64> = (0..rows).map(|_| g.rng.gaussian()).collect();
        let mut y_exact = y0.clone();
        let mut y_fast = y0.clone();
        gemv_slice_into(alpha, &a, cols, &x, beta, &mut y_exact);
        gemv_slice_into_fast(alpha, &a, cols, &x, beta, &mut y_fast);
        for (i, (e, f)) in y_exact.iter().zip(&y_fast).enumerate() {
            prop_assert!(
                rel_err(*e, *f) <= FAST_REL_TOL,
                "rows={rows} cols={cols} row {i}: fast {f} vs exact {e}"
            );
        }
        // beta == 0.0 must overwrite, never read: poison survives iff
        // the kernel wrongly consumed the old y
        let mut y_poison = vec![f64::NAN; rows];
        gemv_slice_into_fast(alpha, &a, cols, &x, 0.0, &mut y_poison);
        prop_assert!(
            y_poison.iter().all(|v| v.is_finite()),
            "fast gemv with beta=0 read the poisoned y"
        );
        Ok(())
    });
}

#[test]
fn prop_fast_syrk_matches_exact() {
    check("linalg-syrk-conformance", 60, |g| {
        // rows cross the SYRK_PANEL_ROWS = 64 panel boundary; cols
        // cover sub-8 widths and 8-wide remainders
        let rows = g.size(1, 150);
        let cols = g.size(1, 20);
        let density = g.f64_in(0.3, 1.0);
        let a: Vec<f64> = (0..rows * cols)
            .map(|_| if g.rng.bernoulli(density) { g.rng.gaussian() } else { 0.0 })
            .collect();
        let mut g_exact = Mat::zeros(cols, cols);
        let mut g_fast = Mat::zeros(cols, cols);
        syrk_into(&a, cols, &mut g_exact);
        syrk_into_fast(&a, cols, &mut g_fast);
        for i in 0..cols {
            for j in 0..cols {
                let (e, f) = (g_exact.data[i * cols + j], g_fast.data[i * cols + j]);
                prop_assert!(
                    rel_err(e, f) <= FAST_REL_TOL,
                    "rows={rows} cols={cols} G[{i}][{j}]: fast {f} vs exact {e}"
                );
                // both tiers mirror the upper triangle: exact symmetry
                let ft = g_fast.data[j * cols + i];
                prop_assert!(f.to_bits() == ft.to_bits(), "fast G not bitwise symmetric");
            }
        }
        // determinism: a second fast build reproduces the bits
        let mut g_fast2 = Mat::zeros(cols, cols);
        syrk_into_fast(&a, cols, &mut g_fast2);
        prop_assert!(
            g_fast.data.iter().zip(&g_fast2.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "fast syrk not deterministic"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Sweep-layer contract: exact is byte-identical, fast is recorded and
// shard-invariant
// ---------------------------------------------------------------------

fn sweep_cfg(params: BTreeMap<String, String>) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal-lsqr".into(),
        p: 0.25,
        seed: 11,
        trials: 48,
        chunk: 8,
        params,
    }
}

#[test]
fn exact_tier_manifest_bytes_match_param_free_run() {
    // a user passing `--set linalg=exact` goes through canonicalization
    let mut params = BTreeMap::new();
    params.insert("linalg".to_string(), "exact".to_string());
    shard::canonicalize_linalg(&mut params);
    let explicit = shard::run_full(&sweep_cfg(params), 2).unwrap();
    let absent = shard::run_full(&sweep_cfg(BTreeMap::new()), 2).unwrap();
    assert_eq!(explicit.render(), absent.render(), "linalg=exact changed manifest bytes");
}

#[test]
fn fast_tier_sweep_is_shard_invariant_and_recorded() {
    let mut params = BTreeMap::new();
    params.insert("linalg".to_string(), "fast".to_string());
    let cfg = sweep_cfg(params);
    let single = shard::run_full(&cfg, 2).unwrap();
    let shards: Vec<_> = (0..4)
        .map(|i| shard::run_shard(&cfg, 1 + (i % 3), ShardSpec::new(i, 4).unwrap()).unwrap())
        .collect();
    let merged = shard::merge(shards).unwrap();
    for (i, (x, y)) in single.values.iter().zip(&merged.values).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "fast tier trial {i} not shard-invariant");
    }
    assert_eq!(single.render(), merged.render(), "fast tier merged JSON bytes differ");
    // the tier rides in the manifest params
    assert!(
        single.render().contains("\"linalg\": \"fast\""),
        "manifest does not record the fast tier:\n{}",
        single.render()
    );
    // and the fast run really differs from exact somewhere (it is a
    // different reduction order feeding LSQR), while staying close
    let exact = shard::run_full(&sweep_cfg(BTreeMap::new()), 2).unwrap();
    for (x, y) in exact.values.iter().zip(&single.values) {
        assert!((x - y).abs() <= 1e-6 * (1.0 + x.abs()), "fast tier far from exact: {x} vs {y}");
    }
}
