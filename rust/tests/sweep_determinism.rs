//! Determinism contracts of the batched decoding + trial engine stack:
//!
//! * `decode_into` is bit-exactly the same computation as `decode` for
//!   all five decoders (decode() is a thin wrapper; for the stateful
//!   warm-started LSQR decoder, two instances fed the same mask history
//!   must agree bit for bit);
//! * `TrialEngine` reductions are identical for 1 vs 8 threads;
//! * the engine-parallel greedy adversarial attack returns the serial
//!   attack's mask.

use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::codes::{FrcCode, GradientCode, GraphCode};
use gcod::decode::{
    Decoder, Decoding, FixedDecoder, FrcOptimalDecoder, GenericOptimalDecoder,
    IgnoreStragglersDecoder, OptimalGraphDecoder,
};
use gcod::prng::Rng;
use gcod::straggler::{greedy_decode_attack, greedy_decode_attack_on};
use gcod::sweep::{bernoulli_masks, decoding_error_sweep, decoding_stats_par, TrialEngine};

fn assert_bit_equal(a: &Decoding, b: &Decoding, ctx: &str) {
    assert_eq!(a.w.len(), b.w.len(), "{ctx}: w length");
    assert_eq!(a.alpha.len(), b.alpha.len(), "{ctx}: alpha length");
    for j in 0..a.w.len() {
        assert_eq!(a.w[j].to_bits(), b.w[j].to_bits(), "{ctx}: w[{j}]");
    }
    for i in 0..a.alpha.len() {
        assert_eq!(a.alpha[i].to_bits(), b.alpha[i].to_bits(), "{ctx}: alpha[{i}]");
    }
}

/// Feed two independently-constructed decoder instances the same mask
/// sequence: one through `decode()`, one through `decode_into` with a
/// reused buffer. Results must agree bit for bit on every trial.
fn check_decode_into_equiv<A: Decoder, B: Decoder>(
    via_decode: &A,
    via_into: &B,
    m: usize,
    masks: usize,
    p: f64,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let mut out = Decoding { w: vec![f64::NAN; 1], alpha: vec![f64::NAN; 3] }; // stale junk
    for trial in 0..masks {
        let mask = rng.bernoulli_mask(m, p);
        let d = via_decode.decode(&mask);
        via_into.decode_into(&mask, &mut out);
        assert_bit_equal(&d, &out, &format!("{} trial {trial}", via_decode.name()));
    }
}

#[test]
fn decode_into_matches_decode_graph() {
    let mut rng = Rng::new(1);
    let code = GraphCode::random_regular(20, 4, &mut rng);
    check_decode_into_equiv(
        &OptimalGraphDecoder::new(&code.graph),
        &OptimalGraphDecoder::new(&code.graph),
        code.n_machines(),
        50,
        0.3,
        7,
    );
}

#[test]
fn decode_into_matches_decode_lsqr_warm() {
    let mut rng = Rng::new(2);
    let code = GraphCode::random_regular(16, 4, &mut rng);
    let a = code.assignment();
    // identical construction => identical warm-start history => bits
    check_decode_into_equiv(
        &GenericOptimalDecoder::new(a),
        &GenericOptimalDecoder::new(a),
        a.cols,
        50,
        0.2,
        8,
    );
}

#[test]
fn decode_into_matches_decode_fixed() {
    let mut rng = Rng::new(3);
    let code = GraphCode::random_regular(18, 3, &mut rng);
    let a = code.assignment();
    check_decode_into_equiv(
        &FixedDecoder::new(a, 0.25),
        &FixedDecoder::new(a, 0.25),
        a.cols,
        50,
        0.25,
        9,
    );
}

#[test]
fn decode_into_matches_decode_frc() {
    let code = FrcCode::new(16, 24, 3);
    check_decode_into_equiv(
        &FrcOptimalDecoder::new(&code),
        &FrcOptimalDecoder::new(&code),
        24,
        50,
        0.4,
        10,
    );
}

#[test]
fn decode_into_matches_decode_ignore() {
    let code = FrcCode::new(12, 12, 3);
    let a = code.assignment();
    check_decode_into_equiv(
        &IgnoreStragglersDecoder { a, weight: 1.25 },
        &IgnoreStragglersDecoder { a, weight: 1.25 },
        12,
        50,
        0.35,
        11,
    );
}

/// decode_into == decode at the degenerate sizes every decoder must
/// survive: n=1 and n=2 blocks, zero machines, the no-straggler mask
/// and the all-straggler mask — across all five decoder families.
#[test]
fn decode_into_matches_decode_edge_sizes() {
    use gcod::graphs::Graph;

    /// no stragglers, all stragglers, and (when m > 0) the two
    /// single-flip boundary masks
    fn edge_masks(m: usize) -> Vec<Vec<bool>> {
        let mut v = vec![vec![false; m], vec![true; m]];
        if m >= 1 {
            let mut one = vec![false; m];
            one[0] = true;
            v.push(one);
            let mut all_but_one = vec![true; m];
            all_but_one[m - 1] = false;
            v.push(all_but_one);
        }
        v
    }

    fn check_masks<A: Decoder, B: Decoder>(
        via_decode: &A,
        via_into: &B,
        masks: &[Vec<bool>],
        ctx: &str,
    ) {
        let mut out = Decoding { w: vec![f64::NAN; 2], alpha: vec![f64::NAN; 1] }; // stale junk
        for (i, mask) in masks.iter().enumerate() {
            let d = via_decode.decode(mask);
            via_into.decode_into(mask, &mut out);
            assert_bit_equal(&d, &out, &format!("{ctx}, mask {i}"));
        }
    }

    // n = 1 block, zero machines: the literally-empty mask
    let g1 = Graph::new(1, vec![]);
    let a1 = g1.assignment_matrix();
    check_masks(
        &OptimalGraphDecoder::new(&g1),
        &OptimalGraphDecoder::new(&g1),
        &edge_masks(0),
        "graph n=1 m=0",
    );
    check_masks(
        &GenericOptimalDecoder::new(&a1),
        &GenericOptimalDecoder::new(&a1),
        &edge_masks(0),
        "lsqr n=1 m=0",
    );
    check_masks(
        &FixedDecoder::new(&a1, 0.2),
        &FixedDecoder::new(&a1, 0.2),
        &edge_masks(0),
        "fixed n=1 m=0",
    );
    check_masks(
        &IgnoreStragglersDecoder { a: &a1, weight: 1.0 },
        &IgnoreStragglersDecoder { a: &a1, weight: 1.0 },
        &edge_masks(0),
        "ignore n=1 m=0",
    );

    // n = 2 blocks, one machine (a single graph edge)
    let g2 = Graph::new(2, vec![(0, 1)]);
    let a2 = g2.assignment_matrix();
    check_masks(
        &OptimalGraphDecoder::new(&g2),
        &OptimalGraphDecoder::new(&g2),
        &edge_masks(1),
        "graph n=2 m=1",
    );
    check_masks(
        &GenericOptimalDecoder::new(&a2),
        &GenericOptimalDecoder::new(&a2),
        &edge_masks(1),
        "lsqr n=2 m=1",
    );
    check_masks(
        &FixedDecoder::new(&a2, 0.2),
        &FixedDecoder::new(&a2, 0.2),
        &edge_masks(1),
        "fixed n=2 m=1",
    );
    check_masks(
        &IgnoreStragglersDecoder { a: &a2, weight: 0.5 },
        &IgnoreStragglersDecoder { a: &a2, weight: 0.5 },
        &edge_masks(1),
        "ignore n=2 m=1",
    );

    // FRC at its smallest shapes: 1 block / 1 machine and 2 / 2
    let f1 = FrcCode::new(1, 1, 1);
    check_masks(
        &FrcOptimalDecoder::new(&f1),
        &FrcOptimalDecoder::new(&f1),
        &edge_masks(1),
        "frc n=1 m=1",
    );
    let f2 = FrcCode::new(2, 2, 1);
    check_masks(
        &FrcOptimalDecoder::new(&f2),
        &FrcOptimalDecoder::new(&f2),
        &edge_masks(2),
        "frc n=2 m=2",
    );
    check_masks(
        &FixedDecoder::new(f2.assignment(), 0.3),
        &FixedDecoder::new(f2.assignment(), 0.3),
        &edge_masks(2),
        "fixed frc n=2",
    );
    check_masks(
        &GenericOptimalDecoder::new(f2.assignment()),
        &GenericOptimalDecoder::new(f2.assignment()),
        &edge_masks(2),
        "lsqr frc n=2",
    );
}

/// The headline contract: a Monte-Carlo sweep accumulates identical
/// metrics on 1 thread and on 8, for both a stateless decoder and the
/// stateful warm-started LSQR decoder (chunk-scoped state).
#[test]
fn engine_one_thread_equals_eight_threads() {
    let mut rng = Rng::new(4);
    let code = GraphCode::random_regular(32, 4, &mut rng);
    let g = &code.graph;
    let a = code.assignment();
    let m = code.n_machines();

    let graph_sweep = |threads: usize| {
        let engine = TrialEngine::new(threads, 0xD15C).with_chunk(8);
        decoding_error_sweep(
            &engine,
            |_c| OptimalGraphDecoder::new(g),
            bernoulli_masks(m, 0.25),
            256,
        )
    };
    let s1 = graph_sweep(1);
    let s8 = graph_sweep(8);
    assert_eq!(s1.count(), s8.count());
    assert_eq!(s1.mean().to_bits(), s8.mean().to_bits(), "graph mean");
    assert_eq!(s1.var().to_bits(), s8.var().to_bits(), "graph var");
    assert_eq!(s1.min().to_bits(), s8.min().to_bits(), "graph min");
    assert_eq!(s1.max().to_bits(), s8.max().to_bits(), "graph max");

    let lsqr_sweep = |threads: usize| {
        let engine = TrialEngine::new(threads, 0xD15C).with_chunk(8);
        decoding_error_sweep(
            &engine,
            |_c| GenericOptimalDecoder::new(a),
            bernoulli_masks(m, 0.2),
            96,
        )
    };
    let l1 = lsqr_sweep(1);
    let l8 = lsqr_sweep(8);
    assert_eq!(l1.mean().to_bits(), l8.mean().to_bits(), "lsqr mean (warm-start chunking)");
    assert_eq!(l1.var().to_bits(), l8.var().to_bits(), "lsqr var");
}

/// Same for the full Figure-3 statistics (normalized error + covariance
/// norm): the parallel collection and shared reduction must not depend
/// on the thread count.
#[test]
fn decoding_stats_par_thread_invariant() {
    let mut rng = Rng::new(5);
    let scheme = build(&SchemeSpec::GraphRandomRegular { n: 16, d: 3 }, &mut rng);
    let m = scheme.n_machines();
    let run = |threads: usize| {
        let engine = TrialEngine::new(threads, 99).with_chunk(16);
        // power iteration consumes the caller rng: give each run an
        // identical fresh stream
        let mut prng = Rng::new(1234);
        decoding_stats_par(
            &engine,
            |_c| make_decoder(&scheme, DecoderSpec::Optimal, 0.2),
            bernoulli_masks(m, 0.2),
            200,
            &mut prng,
        )
    };
    let a = run(1);
    let b = run(8);
    assert_eq!(a.mean_err_per_block.to_bits(), b.mean_err_per_block.to_bits());
    assert_eq!(a.cov_norm.to_bits(), b.cov_norm.to_bits());
    assert_eq!(a.mean_alpha_scale.to_bits(), b.mean_alpha_scale.to_bits());
    assert_eq!(a.raw_err_per_block.to_bits(), b.raw_err_per_block.to_bits());
}

/// The engine-parallel greedy attack selects exactly the serial greedy
/// attack's machines (deterministic decoder, shared tie-break), and is
/// itself thread-count-invariant.
#[test]
fn parallel_greedy_attack_matches_serial() {
    let mut rng = Rng::new(6);
    let code = GraphCode::random_regular(14, 3, &mut rng);
    let a = code.assignment();
    let budget = 5;
    let serial = greedy_decode_attack(&OptimalGraphDecoder::new(&code.graph), a, budget);
    let par1 = greedy_decode_attack_on(
        &TrialEngine::new(1, 0),
        |_c| OptimalGraphDecoder::new(&code.graph),
        a,
        budget,
    );
    let par8 = greedy_decode_attack_on(
        &TrialEngine::new(8, 0),
        |_c| OptimalGraphDecoder::new(&code.graph),
        a,
        budget,
    );
    assert_eq!(serial, par1, "serial vs 1-thread engine");
    assert_eq!(par1, par8, "1-thread vs 8-thread engine");
    assert_eq!(serial.iter().filter(|&&s| s).count(), budget);
}
