//! Property tests (testing:: harness) on the paper's invariants.

use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::codes::{GradientCode, GraphCode};
use gcod::decode::{Decoder, GenericOptimalDecoder, OptimalGraphDecoder};
use gcod::graphs::components::{analyze_components, optimal_alpha};
use gcod::graphs::random_regular_graph;
use gcod::linalg::{dist2_sq, dist_to_ones_sq};
use gcod::metrics::Stats;
use gcod::prop_assert;
use gcod::testing::check;

/// Eq. (4): on every surviving edge, alpha*_u + alpha*_v = 2 — unless
/// the component is a single edge-less vertex (alpha 0).
#[test]
fn prop_eq4_on_surviving_edges() {
    check("eq4", 60, |g| {
        let n = g.size(4, 24);
        let d = *g.choice(&[2usize, 3, 4]);
        let n = if n * d % 2 == 1 { n + 1 } else { n };
        let graph = random_regular_graph(n, d, g.rng);
        let p = g.f64_in(0.0, 0.6);
        let alive: Vec<bool> = (0..graph.m()).map(|_| !g.rng.bernoulli(p)).collect();
        let alpha = optimal_alpha(&graph, &alive);
        for (e, &(u, v)) in graph.edges.iter().enumerate() {
            if alive[e] {
                prop_assert!(
                    (alpha[u] + alpha[v] - 2.0).abs() < 1e-9,
                    "edge {e}=({u},{v}): {} + {} != 2",
                    alpha[u],
                    alpha[v]
                );
            }
        }
        Ok(())
    });
}

/// The graph decoder's w reproduces alpha exactly (A w = alpha) and its
/// alpha agrees with the LSQR characterization (Eq. 9) on every random
/// graph and straggler pattern.
#[test]
fn prop_graph_decoder_is_optimal() {
    check("graph-decoder-optimal", 40, |g| {
        let half = g.size(3, 12);
        let graph = random_regular_graph(2 * half, 3, g.rng);
        let code = GraphCode::new("t", graph);
        let p = g.f64_in(0.0, 0.7);
        let mask: Vec<bool> = (0..code.n_machines()).map(|_| g.rng.bernoulli(p)).collect();
        let gd = OptimalGraphDecoder::new(&code.graph).decode(&mask);
        let aw = code.assignment().mul_vec(&gd.w);
        prop_assert!(dist2_sq(&aw, &gd.alpha) < 1e-14, "A w != alpha");
        let ld = GenericOptimalDecoder::new(code.assignment()).decode(&mask);
        prop_assert!(
            dist2_sq(&gd.alpha, &ld.alpha) < 1e-9,
            "graph vs lsqr alpha mismatch: {}",
            dist2_sq(&gd.alpha, &ld.alpha)
        );
        // optimality within the machine's w-space: error no worse than lsqr
        prop_assert!(
            gd.error_sq() <= ld.error_sq() + 1e-9,
            "{} > {}",
            gd.error_sq(),
            ld.error_sq()
        );
        Ok(())
    });
}

/// decode_into == decode (bit for bit) on freshly built decoders, for
/// every scheme/decoder pair in the zoo and any mask (the allocation-free
/// path must be the same computation as the allocating wrapper).
#[test]
fn prop_decode_into_equals_decode() {
    check("decode-into-equals-decode", 60, |g| {
        let specs = [
            SchemeSpec::GraphRandomRegular { n: 12, d: 3 },
            SchemeSpec::Frc { n: 12, m: 12, d: 4 },
            SchemeSpec::ExpanderAdj { n: 12, d: 3 },
            SchemeSpec::Brc { n: 12, m: 12, batch: 4 },
        ];
        let spec = g.choice(&specs).clone();
        let s = build(&spec, g.rng);
        let dspec = *g.choice(&[DecoderSpec::Optimal, DecoderSpec::Fixed, DecoderSpec::Ignore]);
        let p = g.f64_in(0.0, 1.0);
        let mask: Vec<bool> = (0..s.n_machines()).map(|_| g.rng.bernoulli(p)).collect();
        // two independently-built decoders with identical (empty) history
        let a = make_decoder(&s, dspec, 0.25).decode(&mask);
        let mut b = gcod::decode::Decoding::empty();
        make_decoder(&s, dspec, 0.25).decode_into(&mask, &mut b);
        prop_assert!(a.w.len() == b.w.len() && a.alpha.len() == b.alpha.len(), "shape");
        for j in 0..a.w.len() {
            prop_assert!(a.w[j].to_bits() == b.w[j].to_bits(), "w[{j}]: {} vs {}", a.w[j], b.w[j]);
        }
        for i in 0..a.alpha.len() {
            prop_assert!(
                a.alpha[i].to_bits() == b.alpha[i].to_bits(),
                "alpha[{i}]: {} vs {}",
                a.alpha[i],
                b.alpha[i]
            );
        }
        Ok(())
    });
}

/// Stragglers never get weight; all-straggle decodes to alpha = 0.
#[test]
fn prop_straggler_weights_zero() {
    check("straggler-weights-zero", 40, |g| {
        let specs = [
            SchemeSpec::GraphRandomRegular { n: 10, d: 3 },
            SchemeSpec::Frc { n: 12, m: 12, d: 4 },
            SchemeSpec::ExpanderAdj { n: 12, d: 3 },
            SchemeSpec::Rbgc { n: 12, m: 12, d: 3 },
        ];
        let spec = g.choice(&specs).clone();
        let s = build(&spec, g.rng);
        let dspec = *g.choice(&[DecoderSpec::Optimal, DecoderSpec::Fixed, DecoderSpec::Ignore]);
        let dec = make_decoder(&s, dspec, 0.25);
        let p = g.f64_in(0.0, 1.0);
        let mask: Vec<bool> = (0..s.n_machines()).map(|_| g.rng.bernoulli(p)).collect();
        let d = dec.decode(&mask);
        for j in 0..s.n_machines() {
            if mask[j] {
                prop_assert!(d.w[j] == 0.0, "straggler {j} got weight {}", d.w[j]);
            }
        }
        let all = dec.decode(&vec![true; s.n_machines()]);
        prop_assert!(
            all.alpha.iter().all(|&a| a.abs() < 1e-12),
            "all-straggle alpha nonzero"
        );
        Ok(())
    });
}

/// Component analysis is a partition, and the alpha error decomposes
/// exactly as the sum of per-component bipartite imbalances
/// (Section III observations 1-3).
#[test]
fn prop_component_error_decomposition() {
    check("component-decomposition", 50, |g| {
        let half = g.size(3, 14);
        let graph = random_regular_graph(2 * half, 4, g.rng);
        let p = g.f64_in(0.1, 0.8);
        let alive: Vec<bool> = (0..graph.m()).map(|_| !g.rng.bernoulli(p)).collect();
        let analysis = analyze_components(&graph, &alive);
        // partition check
        let mut seen = vec![false; graph.n];
        for c in &analysis.components {
            for &v in &c.vertices {
                prop_assert!(!seen[v], "vertex {v} in two components");
                seen[v] = true;
            }
        }
        prop_assert!(seen.iter().all(|&s| s), "missing vertex");
        // error decomposition
        let alpha = optimal_alpha(&graph, &alive);
        let total = dist_to_ones_sq(&alpha);
        let mut sum = 0.0;
        for c in &analysis.components {
            match &c.sides {
                None => {}
                Some((l, r)) => {
                    let (l, r) = (l.len() as f64, r.len() as f64);
                    // each side deviates by (l-r)/(l+r) in opposite signs
                    let imb = (l - r) / (l + r);
                    sum += (l + r) * imb * imb;
                }
            }
        }
        prop_assert!((total - sum).abs() < 1e-9, "decomposition {total} vs {sum}");
        Ok(())
    });
}

/// Spectral sanity on random regular graphs: estimated lambda_2 is below
/// d and above the Alon-Boppana-ish floor, and the assignment matrix
/// identity sigma_2^2 = 2d - lambda holds (Corollary V.2's proof step).
#[test]
fn prop_spectral_identities() {
    check("spectral-identities", 10, |g| {
        let half = g.size(6, 16);
        let d = *g.choice(&[3usize, 4]);
        let graph = random_regular_graph(2 * half, d, g.rng);
        let l2 = gcod::graphs::spectral::lambda2(&graph, 3000, g.rng);
        prop_assert!(l2 < d as f64 - 1e-6, "lambda2 {l2} >= d");
        prop_assert!(l2 > -(d as f64) - 1e-9, "lambda2 {l2} < -d");
        Ok(())
    });
}

/// LSQR matches the dense Cholesky least-squares solution on random
/// well-conditioned systems.
#[test]
fn prop_lsqr_matches_cholesky() {
    check("lsqr-vs-cholesky", 30, |g| {
        let m = g.size(3, 10);
        let n = g.size(2, m.min(8));
        let mut a = gcod::linalg::Mat::zeros(m, n);
        for v in a.data.iter_mut() {
            *v = g.rng.gaussian();
        }
        // make it well-conditioned: add identity-ish structure
        for i in 0..n.min(m) {
            a[(i, i)] += 3.0;
        }
        let b: Vec<f64> = (0..m).map(|_| g.rng.gaussian()).collect();
        let exact = gcod::linalg::chol::lstsq_normal(&a, &b, 0.0)
            .map_err(|e| format!("chol: {e}"))?;
        let got = gcod::sparse::lsqr(&a, &b, 1e-13, 500);
        prop_assert!(
            dist2_sq(&got.x, &exact) < 1e-8,
            "lsqr {:?} vs chol {:?}",
            got.x,
            exact
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Stats::merge algebra (the shard-merge cross-check relies on these)
// ---------------------------------------------------------------------

fn random_stats(g: &mut gcod::testing::Gen<'_>, len: usize) -> (Vec<f64>, Stats) {
    let xs: Vec<f64> = (0..len).map(|_| g.rng.gaussian() * 10.0).collect();
    let s = Stats::from_values(&xs);
    (xs, s)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-12 * a.abs().max(b.abs()).max(1.0)
}

/// merge is associative: count/min/max bitwise, mean/m2 to rounding.
#[test]
fn prop_stats_merge_associative() {
    check("stats-merge-associative", 60, |g| {
        let (la, lb, lc) = (g.size(0, 20), g.size(0, 20), g.size(0, 20));
        let (_, a) = random_stats(g, la);
        let (_, b) = random_stats(g, lb);
        let (_, c) = random_stats(g, lc);
        // (a + b) + c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        prop_assert!(left.count() == right.count(), "count");
        prop_assert!(left.min().to_bits() == right.min().to_bits(), "min");
        prop_assert!(left.max().to_bits() == right.max().to_bits(), "max");
        prop_assert!(close(left.mean(), right.mean()), "mean {} vs {}", left.mean(), right.mean());
        prop_assert!(close(left.m2(), right.m2()), "m2 {} vs {}", left.m2(), right.m2());
        Ok(())
    });
}

/// The empty accumulator is a two-sided identity, bit for bit.
#[test]
fn prop_stats_merge_identity() {
    check("stats-merge-identity", 40, |g| {
        let len = g.size(0, 30);
        let (_, s) = random_stats(g, len);
        let mut right = s.clone();
        right.merge(&Stats::new());
        let mut left = Stats::new();
        left.merge(&s);
        for t in [&right, &left] {
            prop_assert!(t.count() == s.count(), "count");
            prop_assert!(t.mean().to_bits() == s.mean().to_bits(), "mean");
            prop_assert!(t.m2().to_bits() == s.m2().to_bits(), "m2");
            prop_assert!(t.min().to_bits() == s.min().to_bits(), "min");
            prop_assert!(t.max().to_bits() == s.max().to_bits(), "max");
        }
        Ok(())
    });
}

/// Merging singletons reproduces the sequential fold (count/min/max
/// bitwise, float moments to rounding) — and chunked partial merges
/// agree with both.
#[test]
fn prop_stats_merge_of_singletons_matches_fold() {
    check("stats-merge-singletons", 40, |g| {
        let len = g.size(1, 40);
        let (xs, folded) = random_stats(g, len);
        let mut singles = Stats::new();
        for &x in &xs {
            let mut one = Stats::new();
            one.push(x);
            singles.merge(&one);
        }
        let chunk = 1 + g.rng.below(7);
        let mut chunked = Stats::new();
        for c in xs.chunks(chunk) {
            chunked.merge(&Stats::from_values(c));
        }
        for t in [&singles, &chunked] {
            prop_assert!(t.count() == folded.count(), "count");
            prop_assert!(t.min().to_bits() == folded.min().to_bits(), "min");
            prop_assert!(t.max().to_bits() == folded.max().to_bits(), "max");
            prop_assert!(close(t.mean(), folded.mean()), "mean {} vs {}", t.mean(), folded.mean());
            prop_assert!(close(t.m2(), folded.m2()), "m2 {} vs {}", t.m2(), folded.m2());
        }
        Ok(())
    });
}

/// Fixed decoding is unbiased for every regular scheme: empirical
/// E[alpha] = 1 within Monte-Carlo tolerance.
#[test]
fn prop_fixed_decoder_unbiased() {
    check("fixed-unbiased", 6, |g| {
        let half = g.size(5, 10);
        let n = 2 * half;
        let scheme = build(&SchemeSpec::GraphRandomRegular { n, d: 4 }, g.rng);
        let p = g.f64_in(0.05, 0.4);
        let dec = make_decoder(&scheme, DecoderSpec::Fixed, p);
        let trials = 6000;
        let mut mean = vec![0.0; n];
        for _ in 0..trials {
            let mask: Vec<bool> = (0..scheme.n_machines()).map(|_| g.rng.bernoulli(p)).collect();
            let d = dec.decode(&mask);
            for i in 0..n {
                mean[i] += d.alpha[i] / trials as f64;
            }
        }
        for (i, &m) in mean.iter().enumerate() {
            prop_assert!((m - 1.0).abs() < 0.08, "E[alpha_{i}] = {m} at p={p}");
        }
        Ok(())
    });
}
