//! Refactor-safety suite for the pluggable sweep-kernel architecture.
//!
//! The `SweepKind` enum + match-driven runner became an open kernel
//! registry; the contracts pinned here:
//!
//! * **Legacy oracle byte-identity.** For each legacy kind
//!   (`decode-error`, `gd-final`, `attack`) an *inline replica of the
//!   pre-refactor closed-form runner* — written against the public
//!   engine/zoo/gd/straggler APIs, with no sweep-kernel involvement —
//!   must produce manifests byte-identical to `shard::run_range`
//!   through the registry, on full ranges and mid-chunk subranges.
//!   This is the strongest check available in-tree: the old code path,
//!   resurrected independently, arbitrates the new one.
//! * **Golden fixtures.** Rendered manifests are pinned under
//!   `tests/fixtures/golden/`; once blessed (first run, or
//!   `GCOD_BLESS_GOLDEN=1`), any byte drift across commits fails — the
//!   cross-commit complement to the in-commit oracles. `SHARD_SCHEMA`
//!   is asserted unbumped.
//! * **Registry hygiene.** Unknown kinds are rejected at parse;
//!   duplicate registrations are refused; a custom kernel registered at
//!   runtime shards and merges bit-exactly with zero changes to any
//!   other layer. (Dispatching a custom kernel over subprocesses
//!   additionally requires it to be registered in the worker binary —
//!   see the README's worked example.)
//! * **`adv-gd` determinism + physics.** 1 ≡ 8 threads and 1 ≡ 4
//!   shards to the merged JSON byte (including the warm-started LSQR
//!   decoder), and the empirical noise floor grows with the adversarial
//!   budget (the paper's adversarial-regime claim).

use gcod::codes::zoo::{build, make_decoder, BuiltScheme, DecoderSpec, SchemeSpec};
use gcod::data::LstsqData;
use gcod::error::Result;
use gcod::gd::{GdScratch, GramCache, SimulatedGcod, StepSize};
use gcod::prng::Rng;
use gcod::straggler::{
    greedy_decode_attack, greedy_decode_attack_trace, BernoulliStragglers, FixedMaskStragglers,
};
use gcod::sweep::kernels::{register_kernel, SweepKernel, DATA_SALT};
use gcod::sweep::shard::{
    self, ShardResult, ShardSpec, SweepConfig, SweepKind, SCHEME_SALT, SHARD_SCHEMA,
};
use gcod::sweep::{bernoulli_masks, decoding_error_values, TrialEngine};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn cfg(kind: SweepKind, scheme: &str, decoder: &str, trials: usize, chunk: usize) -> SweepConfig {
    SweepConfig {
        sweep: kind,
        scheme: scheme.into(),
        decoder: decoder.into(),
        p: 0.25,
        seed: 7,
        trials,
        chunk,
        params: BTreeMap::new(),
    }
}

/// Rebuild the scheme + engine exactly as the runner does (public
/// salts: the sweep-identity contract).
fn setup(cfg: &SweepConfig, threads: usize) -> (BuiltScheme, DecoderSpec, TrialEngine) {
    let spec = SchemeSpec::parse(&cfg.scheme).unwrap();
    let dspec = DecoderSpec::parse(&cfg.decoder).unwrap();
    let scheme = build(&spec, &mut Rng::new(cfg.seed ^ SCHEME_SALT));
    let engine = TrialEngine::new(threads, cfg.seed).with_chunk(cfg.chunk);
    (scheme, dspec, engine)
}

// ---------------------------------------------------------------------
// Inline replicas of the pre-refactor `shard::run_range` match arms
// ---------------------------------------------------------------------

fn oracle_decode_error(cfg: &SweepConfig, threads: usize, lo: usize, hi: usize) -> Vec<f64> {
    let (scheme, dspec, engine) = setup(cfg, threads);
    let m = scheme.n_machines();
    decoding_error_values(
        &engine,
        |_chunk| make_decoder(&scheme, dspec, cfg.p),
        bernoulli_masks(m, cfg.p),
        lo,
        hi,
    )
}

fn oracle_gd_final(cfg: &SweepConfig, threads: usize, lo: usize, hi: usize) -> Vec<f64> {
    let (scheme, dspec, engine) = setup(cfg, threads);
    let n_points = cfg
        .param_usize("n-points", 512)
        .max(cfg.param_usize("dim", 32) + 1)
        .div_ceil(scheme.n_blocks())
        * scheme.n_blocks();
    let dim = cfg.param_usize("dim", 32);
    let iters = cfg.param_usize("iters", 30);
    let sigma = cfg.param_f64("sigma", 1.0);
    let step_c = cfg.param_usize("step-c", 9) as u32;
    let data = LstsqData::generate(
        n_points,
        dim,
        scheme.n_blocks(),
        sigma,
        &mut Rng::new(cfg.seed ^ DATA_SALT),
    );
    let use_gram = match cfg.params.get("grad").map(String::as_str) {
        Some("gram") => true,
        Some("streaming") => false,
        _ => GramCache::pays_off(n_points, dim, scheme.n_blocks()),
    };
    // the pre-refactor build was serial; the kernel now builds in
    // parallel, so this doubles as a serial ≡ parallel cross-check
    let cache = use_gram.then(|| GramCache::new(&data));
    struct Ctx<'a> {
        dec: Box<dyn gcod::decode::Decoder + 'a>,
        scratch: GdScratch,
        theta0: Vec<f64>,
    }
    engine.run_range_map(
        lo,
        hi,
        |_chunk| Ctx {
            dec: make_decoder(&scheme, dspec, cfg.p),
            scratch: GdScratch::new(),
            theta0: vec![0.0; dim],
        },
        |ctx, _t, rng| {
            let Ctx { dec, scratch, theta0 } = ctx;
            let mut strag = BernoulliStragglers::new(cfg.p, rng.next_u64());
            let rho = rng.permutation(scheme.n_blocks());
            let mut gd = SimulatedGcod {
                decoder: dec.as_ref(),
                stragglers: &mut strag,
                step: StepSize::simulated_grid(step_c),
                rho: Some(rho),
                m: scheme.n_machines(),
                alpha_scale: 1.0,
            };
            match &cache {
                Some(c) => {
                    let mut src = c;
                    gd.run_with(&mut src, theta0, iters, scratch)
                }
                None => {
                    let mut src = &data;
                    gd.run_with(&mut src, theta0, iters, scratch)
                }
            }
            .final_progress()
        },
    )
}

/// Independent inline replica of the `adv-gd` kernel, written against
/// the public engine/zoo/gd/straggler APIs with no sweep-kernel (or
/// `GdProblem`) involvement: commit one greedy adversarial mask — a
/// pure function of (scheme, decoder, budget) — then run one full
/// deterministic GD trajectory per trial with the mask replayed every
/// iteration and only the block shuffle drawn from the substream.
fn oracle_adv_gd(cfg: &SweepConfig, threads: usize, lo: usize, hi: usize) -> Vec<f64> {
    let (scheme, dspec, engine) = setup(cfg, threads);
    let m = scheme.n_machines();
    let dim = cfg.param_usize("dim", 32);
    let n_points = cfg
        .param_usize("n-points", 512)
        .max(dim + 1)
        .div_ceil(scheme.n_blocks())
        * scheme.n_blocks();
    let iters = cfg.param_usize("iters", 30);
    let sigma = cfg.param_f64("sigma", 1.0);
    let step_c = cfg.param_usize("step-c", 9) as u32;
    let budget = cfg
        .param_usize("budget", (cfg.p * m as f64).floor() as usize)
        .min(m);
    let data = LstsqData::generate(
        n_points,
        dim,
        scheme.n_blocks(),
        sigma,
        &mut Rng::new(cfg.seed ^ DATA_SALT),
    );
    let atk_dec = make_decoder(&scheme, dspec, cfg.p);
    let mask = greedy_decode_attack(atk_dec.as_ref(), &scheme.a, budget);
    drop(atk_dec);
    let use_gram = match cfg.params.get("grad").map(String::as_str) {
        Some("gram") => true,
        Some("streaming") => false,
        _ => GramCache::pays_off(n_points, dim, scheme.n_blocks()),
    };
    // serial build; the kernel builds in parallel, so this doubles as a
    // serial ≡ parallel cross-check (as in oracle_gd_final)
    let cache = use_gram.then(|| GramCache::new(&data));
    struct Ctx<'a> {
        dec: Box<dyn gcod::decode::Decoder + 'a>,
        scratch: GdScratch,
        theta0: Vec<f64>,
    }
    engine.run_range_map(
        lo,
        hi,
        |_chunk| Ctx {
            dec: make_decoder(&scheme, dspec, cfg.p),
            scratch: GdScratch::new(),
            theta0: vec![0.0; dim],
        },
        |ctx, _t, rng| {
            let Ctx { dec, scratch, theta0 } = ctx;
            let mut strag = FixedMaskStragglers::new(&mask);
            let rho = rng.permutation(scheme.n_blocks());
            let mut gd = SimulatedGcod {
                decoder: dec.as_ref(),
                stragglers: &mut strag,
                step: StepSize::simulated_grid(step_c),
                rho: Some(rho),
                m,
                alpha_scale: 1.0,
            };
            match &cache {
                Some(c) => {
                    let mut src = c;
                    gd.run_with(&mut src, theta0, iters, scratch)
                }
                None => {
                    let mut src = &data;
                    gd.run_with(&mut src, theta0, iters, scratch)
                }
            }
            .final_progress()
        },
    )
}

fn oracle_attack(cfg: &SweepConfig, threads: usize, lo: usize, hi: usize) -> Vec<f64> {
    let (scheme, dspec, _engine) = setup(cfg, threads);
    let dec = make_decoder(&scheme, dspec, cfg.p);
    let (_, trace) = greedy_decode_attack_trace(dec.as_ref(), &scheme.a, hi);
    let n = scheme.n_blocks() as f64;
    trace[lo..hi].iter().map(|e| e / n).collect()
}

fn assert_oracle_matches(
    cfg: &SweepConfig,
    oracle: impl Fn(&SweepConfig, usize, usize, usize) -> Vec<f64>,
    label: &str,
) {
    // full range and a mid-chunk subrange, serial and threaded
    let mid = (cfg.chunk / 2).max(1);
    for (threads, lo, hi) in
        [(1usize, 0usize, cfg.trials), (4, 0, cfg.trials), (2, mid, cfg.trials - 1)]
    {
        let via_registry = shard::run_range(cfg, threads, lo, hi).unwrap();
        let via_oracle =
            ShardResult::from_values(cfg.clone(), lo, hi, oracle(cfg, threads, lo, hi));
        assert_eq!(
            via_registry.render(),
            via_oracle.render(),
            "{label}: registry kernel diverged from the pre-refactor oracle \
             (threads={threads}, range [{lo}, {hi}))"
        );
    }
}

#[test]
fn decode_error_kernel_matches_legacy_oracle() {
    // stateless linear-time graph decoder
    assert_oracle_matches(
        &cfg(SweepKind::DecodeError, "graph-rr:16,3", "optimal", 40, 8),
        oracle_decode_error,
        "decode-error/optimal",
    );
    // stateful warm-started LSQR decoder (chunk-scoped warm state)
    assert_oracle_matches(
        &cfg(SweepKind::DecodeError, "expander:12,3", "optimal-lsqr", 30, 8),
        oracle_decode_error,
        "decode-error/optimal-lsqr",
    );
}

#[test]
fn gd_final_kernel_matches_legacy_oracle() {
    let mut gram = cfg(SweepKind::GdFinal, "graph-rr:8,3", "optimal", 12, 4);
    gram.params.insert("n-points".into(), "64".into());
    gram.params.insert("dim".into(), "8".into());
    gram.params.insert("iters".into(), "8".into());
    assert_oracle_matches(&gram, oracle_gd_final, "gd-final/gram(auto)");

    let mut streaming = gram.clone();
    streaming.params.insert("grad".into(), "streaming".into());
    streaming.decoder = "optimal-lsqr".into();
    assert_oracle_matches(&streaming, oracle_gd_final, "gd-final/streaming+lsqr");
}

#[test]
fn adv_gd_kernel_matches_inline_oracle() {
    // default budget floor(p*m), graph decoder, gram-auto gradients
    let mut adv = cfg(SweepKind::AdvGd, "graph-rr:8,3", "optimal", 12, 4);
    adv.params.insert("n-points".into(), "64".into());
    adv.params.insert("dim".into(), "8".into());
    adv.params.insert("iters".into(), "8".into());
    adv.params.insert("step-c".into(), "0".into());
    assert_oracle_matches(&adv, oracle_adv_gd, "adv-gd/optimal");

    // explicit budget, warm-started LSQR decoder (chunk-scoped state
    // exercises the replay contract), streaming gradients
    let mut lsqr = adv.clone();
    lsqr.decoder = "optimal-lsqr".into();
    lsqr.params.insert("budget".into(), "4".into());
    lsqr.params.insert("grad".into(), "streaming".into());
    assert_oracle_matches(&lsqr, oracle_adv_gd, "adv-gd/streaming+lsqr");
}

#[test]
fn attack_kernel_matches_legacy_oracle() {
    assert_oracle_matches(
        &cfg(SweepKind::Attack, "graph-rr:12,3", "optimal", 8, 4),
        oracle_attack,
        "attack/optimal",
    );
}

// ---------------------------------------------------------------------
// Golden fixtures
// ---------------------------------------------------------------------

/// Compare `rendered` against the committed fixture, blessing it on
/// first run (or under `GCOD_BLESS_GOLDEN=1`). See
/// `tests/fixtures/golden/README.md`.
fn assert_golden(name: &str, rendered: &str) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    if std::env::var("GCOD_BLESS_GOLDEN").is_ok() || !path.is_file() {
        std::fs::write(&path, rendered).unwrap();
        eprintln!("blessed golden fixture {} ({} bytes)", path.display(), rendered.len());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want,
        rendered,
        "golden fixture {} diverged — per-trial sweep bytes are a cross-commit \
         contract; if the change is intentional, bump SHARD_SCHEMA and re-bless \
         with GCOD_BLESS_GOLDEN=1",
        path.display()
    );
}

#[test]
fn schema_version_is_frozen() {
    // the refactor must not bump the manifest schema: all four legacy
    // kinds render schema-3 manifests through the registry
    assert_eq!(SHARD_SCHEMA, 3, "SHARD_SCHEMA changed — golden fixtures are now stale");
}

#[test]
fn golden_manifests_for_all_legacy_kinds() {
    // decode-error
    let de = cfg(SweepKind::DecodeError, "graph-rr:16,3", "optimal", 40, 8);
    // gd-final (gram-auto shape)
    let mut gd = cfg(SweepKind::GdFinal, "graph-rr:8,3", "optimal", 12, 4);
    gd.params.insert("n-points".into(), "64".into());
    gd.params.insert("dim".into(), "8".into());
    gd.params.insert("iters".into(), "8".into());
    // attack
    let atk = cfg(SweepKind::Attack, "graph-rr:12,3", "optimal", 8, 4);
    for (c, name) in [
        (&de, "sweep_decode_error.json"),
        (&gd, "sweep_gd_final.json"),
        (&atk, "sweep_attack.json"),
    ] {
        let full = shard::run_range(c, 2, 0, c.trials).unwrap();
        assert_golden(name, &full.render());
        // the merged rendering of a 3-shard split re-merges to the
        // same golden bytes as the single-shard merge
        let shards: Vec<_> = (0..3)
            .map(|i| shard::run_shard(c, 2, ShardSpec::new(i, 3).unwrap()).unwrap())
            .collect();
        let merged = shard::merge(shards).unwrap();
        let single = shard::merge(vec![full]).unwrap();
        assert_eq!(merged.render(), single.render(), "{name}: 3-shard merge bytes");
        assert_golden(&name.replace("sweep_", "merged_"), &merged.render());
    }

    // fig4-cluster manifests come from the bench; pin the rendering on
    // a synthetic (deterministic) result so the format is golden too
    let f4 = cfg(SweepKind::Fig4Cluster, "graph-rr:16,3", "optimal", 4, 2);
    let synth = ShardResult::from_values(f4, 0, 4, vec![0.5, 0.25, 0.125, 1.0 / 3.0]);
    assert_golden("sweep_fig4_cluster.json", &synth.render());

    // adv-gd: new in this schema, golden from birth
    let mut adv = cfg(SweepKind::AdvGd, "graph-rr:8,3", "optimal", 8, 4);
    adv.params.insert("n-points".into(), "64".into());
    adv.params.insert("dim".into(), "8".into());
    adv.params.insert("iters".into(), "8".into());
    let full = shard::run_range(&adv, 2, 0, 8).unwrap();
    assert_golden("sweep_adv_gd.json", &full.render());
}

// ---------------------------------------------------------------------
// Registry hygiene + the "add your own sweep kind" contract
// ---------------------------------------------------------------------

/// The README's worked example, verbatim in spirit: a custom kernel
/// whose chunk-scoped state is a running checksum (so warm-state replay
/// is load-bearing), registered at runtime, sharded and merged
/// bit-exactly with no changes to any other layer.
struct ParityKernel;

impl SweepKernel for ParityKernel {
    fn name(&self) -> &'static str {
        "golden-parity"
    }

    fn run_range(
        &self,
        _cfg: &SweepConfig,
        scheme: &BuiltScheme,
        _dspec: DecoderSpec,
        engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let m = scheme.n_machines() as f64;
        Ok(engine.run_range_map(
            lo,
            hi,
            // chunk-scoped state: a checksum that carries across the
            // chunk's trials — split-invariance requires the engine's
            // partial-chunk replay
            |_chunk| 0u64,
            |acc, t, rng| {
                *acc = acc.wrapping_add(rng.next_u64()).wrapping_add(t as u64);
                (*acc % 4096) as f64 / m
            },
        ))
    }
}

#[test]
fn registered_kernel_shards_and_merges_bit_exact() {
    let kind = register_kernel(Box::new(ParityKernel)).unwrap();
    assert_eq!(kind, SweepKind::parse("golden-parity").unwrap());
    // duplicate registration is refused
    assert!(register_kernel(Box::new(ParityKernel)).is_err());

    let c = cfg(kind, "graph-rr:12,3", "optimal", 50, 8);
    let single = shard::run_full(&c, 1).unwrap();
    // thread count is free
    assert_eq!(shard::run_full(&c, 8).unwrap().render(), single.render());
    // mid-chunk shard splits replay warm state and merge to the byte
    let shards: Vec<_> = (0..4)
        .map(|i| shard::run_shard(&c, 2, ShardSpec::new(i, 4).unwrap()).unwrap())
        .collect();
    assert_eq!(shard::merge(shards).unwrap().render(), single.render());
    // manifests of the custom kind round-trip
    let rt = ShardResult::parse(&shard::run_range(&c, 1, 3, 17).unwrap().render()).unwrap();
    assert_eq!((rt.lo, rt.hi), (3, 17));
    assert_eq!(rt.config.sweep, kind);
}

#[test]
fn unknown_kind_is_rejected_everywhere() {
    assert!(SweepKind::parse("no-such-kernel").is_err());
    // a manifest naming an unregistered kernel fails to parse
    let c = cfg(SweepKind::DecodeError, "graph-rr:12,3", "optimal", 4, 2);
    let text = shard::run_range(&c, 1, 0, 4).unwrap().render();
    let forged = text.replace("\"sweep\": \"decode-error\"", "\"sweep\": \"no-such-kernel\"");
    let err = ShardResult::parse(&forged).unwrap_err();
    assert!(format!("{err}").contains("unknown sweep kind"), "{err}");
}

// ---------------------------------------------------------------------
// adv-gd: determinism + the noise-floor claim
// ---------------------------------------------------------------------

fn adv_cfg(decoder: &str, budget: Option<usize>) -> SweepConfig {
    let mut c = cfg(SweepKind::AdvGd, "graph-rr:8,3", decoder, 24, 4);
    c.params.insert("n-points".into(), "64".into());
    c.params.insert("dim".into(), "8".into());
    c.params.insert("iters".into(), "10".into());
    // conservative step grid: lambda_max(X^T X) ~ N/k = 8 here, so the
    // default c = 9 can overshoot; c = 0 keeps every trajectory stable
    // (bit-exactness tests don't care, the noise-floor physics does)
    c.params.insert("step-c".into(), "0".into());
    if let Some(b) = budget {
        c.params.insert("budget".into(), b.to_string());
    }
    c
}

/// 1 ≡ 8 threads and 1 ≡ 4 shards to the merged JSON byte, on both the
/// stateless graph decoder and the warm-started LSQR decoder (whose
/// chunk-scoped state exercises the replay contract), with the 24/4/4
/// split landing mid-chunk.
#[test]
fn adv_gd_threads_and_shards_bit_exact() {
    for decoder in ["optimal", "optimal-lsqr"] {
        let c = adv_cfg(decoder, None);
        let t1 = shard::run_full(&c, 1).unwrap();
        let t8 = shard::run_full(&c, 8).unwrap();
        assert_eq!(t1.render(), t8.render(), "adv-gd threads 1 vs 8 ({decoder})");
        let shards: Vec<_> = (0..4)
            .map(|i| shard::run_shard(&c, 2, ShardSpec::new(i, 4).unwrap()).unwrap())
            .collect();
        let merged = shard::merge(shards).unwrap();
        assert_eq!(t1.render(), merged.render(), "adv-gd 1 vs 4 shards ({decoder})");
    }
}

/// The paper's adversarial-regime claim, empirically: GD under a
/// committed greedy adversarial mask converges down to a noise floor
/// that grows with the adversarial budget. Budget 0 is plain coded GD
/// (no stragglers — near-exact convergence); a large budget leaves a
/// markedly higher floor.
#[test]
fn adv_gd_noise_floor_grows_with_budget() {
    let run = |budget: usize| {
        let mut c = adv_cfg("optimal", Some(budget));
        c.params.insert("iters".into(), "40".into());
        let merged = shard::run_full(&c, 2).unwrap();
        assert!(
            merged.values.iter().all(|v| v.is_finite() && *v >= 0.0),
            "budget {budget}: non-finite optimality gap"
        );
        merged.stats.mean()
    };
    let none = run(0); // plain coded GD: converges toward theta*
    let mild = run(3); // the default floor(p*m) = floor(0.25 * 12)
    let heavy = run(6); // half the machines
    assert!(
        heavy > none * 10.0,
        "adversarial floor did not rise: none={none:e} mild={mild:e} heavy={heavy:e}"
    );
    assert!(mild > none, "budget 3 left no floor: none={none:e} mild={mild:e}");
    assert!(
        heavy >= mild * 0.5,
        "floor collapsed with budget: mild={mild:e} heavy={heavy:e}"
    );
}

/// adv-gd param validation: garbage budgets and grad spellings are
/// rejected before any work happens.
#[test]
fn adv_gd_validates_params() {
    let mut c = adv_cfg("optimal", None);
    c.params.insert("budget".into(), "many".into());
    let err = shard::run_range(&c, 1, 0, 4).unwrap_err();
    assert!(format!("{err}").contains("bad budget"), "{err}");
    let mut c = adv_cfg("optimal", Some(3));
    c.params.insert("grad".into(), "graam".into());
    let err = shard::run_range(&c, 1, 0, 4).unwrap_err();
    assert!(format!("{err}").contains("grad kernel"), "{err}");
    c.params.insert("grad".into(), "streaming".into());
    c.params.insert("precond".into(), "maybe".into());
    let err = shard::run_range(&c, 1, 0, 4).unwrap_err();
    assert!(format!("{err}").contains("precond"), "{err}");
}
