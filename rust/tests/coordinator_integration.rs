//! Coordinator integration: the distributed Algorithm-2 cluster over
//! the real PJRT worker path, plus failure-injection behaviours.
//! Environment-bound behind the `pjrt` feature (needs the vendored
//! xla/anyhow dependencies and `make artifacts`); the native-backend
//! coordinator is covered by the unit tests in src/coordinator/.
#![cfg(pjrt_runtime)]

use gcod::codes::{GradientCode, GraphCode};
use gcod::coordinator::{Cluster, ClusterConfig, ComputeBackend, StragglerInjection};
use gcod::data::LstsqData;
use gcod::decode::{FixedDecoder, OptimalGraphDecoder};
use gcod::prng::Rng;
use std::time::Duration;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

/// Full PJRT worker path: m=24 threads, each with its own PJRT client
/// executing the qs worker artifact; optimal decoding at the leader.
#[test]
fn pjrt_cluster_converges_with_stragglers() {
    let mut rng = Rng::new(0);
    let code = GraphCode::random_regular(16, 3, &mut rng); // m = 24
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let p = 0.2;
    let cfg = ClusterConfig {
        wait_fraction: 1.0 - p,
        backend: ComputeBackend::Pjrt {
            artifacts_dir: artifacts_dir(),
            artifact: "worker_grad_qs_2x8x32".to_string(),
        },
        injection: StragglerInjection::Random {
            p,
            delay: Duration::from_millis(40),
            seed: 3,
        },
        step_size: 0.06,
        iters: 25,
        max_duration: None,
    };
    let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
    cluster.wait_ready(Duration::from_secs(300)).unwrap();
    let dec = OptimalGraphDecoder::new(&code.graph);
    let report = cluster
        .run(&cfg, &dec, &vec![0.0; 32], |t| data.dist_to_opt(t))
        .unwrap();
    cluster.shutdown();
    let e0 = data.dist_to_opt(&vec![0.0; 32]);
    assert!(
        report.final_progress < e0 * 0.05,
        "no convergence: {e0} -> {}",
        report.final_progress
    );
    // waitany semantics: exactly m - ceil(m(1-p)) stragglers per iter
    let expect = 24 - ((24.0 * (1.0 - p)).ceil() as usize);
    assert!(report.iters.iter().all(|s| s.stragglers == expect));
}

/// The time-budget cutoff (Figure 4b's "error after 60 seconds") stops
/// the run early.
#[test]
fn cluster_respects_time_budget() {
    let mut rng = Rng::new(1);
    let code = GraphCode::random_regular(8, 3, &mut rng);
    let data = LstsqData::generate(32, 6, 8, 0.2, &mut rng);
    let cfg = ClusterConfig {
        wait_fraction: 1.0,
        backend: ComputeBackend::Native,
        injection: StragglerInjection::Random {
            p: 0.5,
            delay: Duration::from_millis(50),
            seed: 2,
        },
        step_size: 0.05,
        iters: 100_000,
        max_duration: Some(Duration::from_millis(400)),
    };
    let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
    cluster.wait_ready(Duration::from_secs(30)).unwrap();
    let dec = OptimalGraphDecoder::new(&code.graph);
    let t0 = std::time::Instant::now();
    let report = cluster
        .run(&cfg, &dec, &vec![0.0; 6], |t| data.dist_to_opt(t))
        .unwrap();
    cluster.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10));
    assert!(report.iters.len() < 100_000);
    assert!(!report.iters.is_empty());
}

/// Stagnant injection: the same machines straggle across consecutive
/// iterations (the §VIII cluster behaviour), unlike Random.
#[test]
fn stagnant_injection_is_sticky_across_iters() {
    let mut rng = Rng::new(2);
    let code = GraphCode::random_regular(8, 3, &mut rng); // m = 12
    let data = LstsqData::generate(32, 6, 8, 0.2, &mut rng);
    let p = 0.3;
    let cfg = ClusterConfig {
        wait_fraction: 1.0 - p,
        backend: ComputeBackend::Native,
        injection: StragglerInjection::Stagnant {
            p,
            churn: 0.02,
            delay: Duration::from_millis(60),
            seed: 5,
        },
        step_size: 0.04,
        iters: 12,
        max_duration: None,
    };
    let mut cluster = Cluster::spawn(code.assignment(), &data, &cfg).unwrap();
    cluster.wait_ready(Duration::from_secs(30)).unwrap();
    // fixed decoding here: exercises the non-optimal leader path too
    let dec = FixedDecoder::new(code.assignment(), p);
    let report = cluster
        .run(&cfg, &dec, &vec![0.0; 6], |t| data.dist_to_opt(t))
        .unwrap();
    cluster.shutdown();
    // stickiness: consecutive straggler masks overlap far more than iid
    // Bernoulli sets would (mean Jaccard of iid 3-of-12 subsets ~ 0.14)
    let masks: Vec<&Vec<bool>> = report.iters.iter().map(|s| &s.straggler_mask).collect();
    let mut jac_sum = 0.0;
    for w in masks.windows(2) {
        let inter = w[0].iter().zip(w[1].iter()).filter(|(a, b)| **a && **b).count() as f64;
        let union = w[0].iter().zip(w[1].iter()).filter(|(a, b)| **a || **b).count() as f64;
        jac_sum += if union == 0.0 { 1.0 } else { inter / union };
    }
    let mean_jaccard = jac_sum / (masks.len() - 1) as f64;
    assert!(mean_jaccard > 0.35, "stagnant not sticky: mean jaccard {mean_jaccard}");
}
