//! Chaos-harness integration suite: the seeded fault injector
//! (`dispatch::chaos`), the result audit and the health/quarantine
//! policy, end to end over real `gcod sweep-shard` subprocess
//! boundaries.
//!
//! * a profile-drawn storm of kills and delays (crash-class only) is
//!   absorbed by the retry/reap machinery: merged bytes identical to
//!   the single-process run;
//! * a pinned byzantine worker forging self-consistent manifests is
//!   caught by the re-execution audit, quarantined, and every range it
//!   banked is invalidated and recomputed — bytes still identical;
//! * the `gcod sweep-launch --chaos-*` CLI round trip mirrors the CI
//!   chaos-soak step: fault plan logged, adversary quarantined, merged
//!   file byte-identical to the `sweep-shard 0/1` + `sweep-merge` path.
//!
//! (Fault-plan replay determinism — same seed, same decision sequence —
//! is pinned by the unit tests in `src/dispatch/chaos.rs`; audit
//! attribution corner cases by the scripted tests in
//! `src/dispatch/mod.rs`.)

use gcod::dispatch::{ChaosProfile, ChaosTransport, DispatchConfig, Dispatcher, LocalProcess};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gcod_chaos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sweep_cfg(trials: usize) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 9,
        trials,
        chunk: 8,
        params: BTreeMap::new(),
    }
}

fn dcfg(tag: &str) -> DispatchConfig {
    DispatchConfig {
        grain: 16,
        poll_interval: Duration::from_millis(2),
        out_dir: tmp_dir(tag),
        ..DispatchConfig::default()
    }
}

/// Crash-class storm: seeded kills and delays across the pool. Retry +
/// reap machinery absorbs everything and the bits never move.
#[test]
fn seeded_fault_storm_stays_bit_exact() {
    let c = sweep_cfg(160);
    let single = shard::run_full(&c, 2).unwrap();
    let profile = ChaosProfile::parse("kill=0.25,delay=0.45").unwrap();
    let mut t = ChaosTransport::new(LocalProcess::new(gcod_bin(), 3), 1234, profile);
    let mut d = dcfg("storm");
    d.max_retries = 10;
    let out = Dispatcher::new(d).run(&c, &mut t).unwrap();
    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
    assert!(!t.plan.log.is_empty(), "seeded profile never drew a fault");
}

/// The acceptance contract over real process boundaries: a pinned
/// byzantine worker whose forged manifests pass structural validation
/// is condemned by the re-execution audit, quarantined, and all of its
/// banked ranges recomputed by the honest pool — merged bytes exact.
/// `grain == chunk` makes the audit window the whole lease, so every
/// forgery is deterministically caught.
#[test]
fn byzantine_worker_quarantined_over_subprocesses() {
    let c = sweep_cfg(96);
    let single = shard::run_full(&c, 2).unwrap();
    let profile = ChaosProfile::parse("byz-worker=1").unwrap();
    let mut t = ChaosTransport::new(LocalProcess::new(gcod_bin(), 3), 7, profile);
    let mut d = dcfg("byz");
    d.grain = 8;
    d.audit_fraction = 1.0;
    let out = Dispatcher::new(d).run(&c, &mut t).unwrap();
    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
    assert!(
        out.report.quarantined.iter().any(|(w, why)| *w == 1 && why == "byzantine"),
        "adversary not quarantined: {}",
        out.report.summary()
    );
    assert!(out.report.audit_mismatches >= 1, "{}", out.report.summary());
    assert!(out.report.invalidated_ranges >= 1, "{}", out.report.summary());
}

// ---------------------------------------------------------------------
// CLI end-to-end
// ---------------------------------------------------------------------

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawn gcod");
    assert!(
        out.status.success(),
        "gcod failed: {:?}\nstdout: {}\nstderr: {}",
        cmd,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

const CLI_SWEEP_ARGS: &[&str] = &[
    "--sweep",
    "decode-error",
    "--scheme",
    "graph-rr:16,3",
    "--decoder",
    "optimal",
    "--p",
    "0.2",
    "--trials",
    "120",
    "--seed",
    "9",
    "--chunk",
    "8",
];

/// The CI chaos-soak step in miniature: `sweep-launch` under a seeded
/// byzantine fault plan must log the plan, quarantine the adversary and
/// produce a merged file byte-identical to the single-process path.
#[test]
fn cli_chaos_byzantine_round_trip() {
    let dir = tmp_dir("cli");
    let shard_path = dir.join("single_shard.json");
    let single_path = dir.join("single_merged.json");
    let launched_path = dir.join("launched.json");

    run_ok(Command::new(gcod_bin()).arg("sweep-shard").args(CLI_SWEEP_ARGS).args([
        "--threads",
        "2",
        "--shard",
        "0/1",
        "--out",
        shard_path.to_str().unwrap(),
    ]));
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        shard_path.to_str().unwrap(),
        "--out",
        single_path.to_str().unwrap(),
    ]));
    let stdout = run_ok(Command::new(gcod_bin()).arg("sweep-launch").args(CLI_SWEEP_ARGS).args([
        "--workers",
        "3",
        "--grain",
        "8",
        "--max-retries",
        "10",
        "--chaos-seed",
        "42",
        "--chaos-profile",
        "byz-worker=1",
        "--audit-fraction",
        "1",
        "--quarantine-after",
        "2",
        "--out",
        launched_path.to_str().unwrap(),
    ]));
    assert!(stdout.contains("[chaos]"), "missing fault-plan log: {stdout}");
    assert!(
        stdout.contains("worker 1 (byzantine)"),
        "adversary not quarantined in report: {stdout}"
    );

    let single = std::fs::read_to_string(&single_path).unwrap();
    let launched = std::fs::read_to_string(&launched_path).unwrap();
    assert_eq!(single, launched, "chaos sweep-launch output != single-process merge");
    let merged = shard::MergedSweep::parse(&launched).unwrap();
    assert_eq!(merged.values.len(), 120);
    let _ = std::fs::remove_dir_all(&dir);
}
