//! Cross-module integration tests: codes x decoders x stragglers x GD,
//! pinned against the paper's analytic results.

use gcod::codes::zoo::{build, make_decoder, DecoderSpec, SchemeSpec};
use gcod::codes::{FrcCode, GraphCode};
use gcod::data::LstsqData;
use gcod::decode::{Decoder, FrcOptimalDecoder, GenericOptimalDecoder, OptimalGraphDecoder};
use gcod::gd::analysis::{decoding_stats, theory};
use gcod::gd::{SimulatedGcod, StepSize};
use gcod::prng::Rng;
use gcod::straggler::{
    frc_group_attack, graph_isolation_attack, BernoulliStragglers, StragglerModel,
};

/// Figure 3(a) shape at one grid point: optimal tracks the p^d/(1-p^d)
/// lower bound; fixed tracks p/(d(1-p)); expander code sits in between
/// or worse.
#[test]
fn fig3_shape_at_p02() {
    let p = 0.2;
    let mut rng = Rng::new(0);
    let scheme = build(&SchemeSpec::GraphRandomRegular { n: 16, d: 3 }, &mut rng);
    let m = scheme.n_machines();
    let runs = 4000;

    let opt = make_decoder(&scheme, DecoderSpec::Optimal, p);
    let s_opt = decoding_stats(
        opt.as_ref(), &mut BernoulliStragglers::new(p, 1), m, 16, runs, &mut rng);
    let fix = make_decoder(&scheme, DecoderSpec::Fixed, p);
    let s_fix = decoding_stats(
        fix.as_ref(), &mut BernoulliStragglers::new(p, 1), m, 16, runs, &mut rng);

    let lb_opt = theory::optimal_lower_bound(p, 3.0);
    let lb_fix = theory::fixed_lower_bound(p, 3.0);
    // optimal is within 4x of its lower bound (expander on 16 vertices
    // is not perfect; the paper's Fig 3a shows the same small gap)
    assert!(s_opt.mean_err_per_block >= lb_opt * 0.5, "{} vs {}", s_opt.mean_err_per_block, lb_opt);
    assert!(s_opt.mean_err_per_block <= lb_opt * 4.0, "{} vs {}", s_opt.mean_err_per_block, lb_opt);
    // fixed is near its own (much larger) bound
    assert!(s_fix.mean_err_per_block >= lb_fix * 0.8);
    assert!(s_fix.mean_err_per_block <= lb_fix * 2.0);
    // the headline gap: optimal beats fixed by ~10x at p=0.2, d=3
    assert!(s_opt.mean_err_per_block * 5.0 < s_fix.mean_err_per_block);
}

/// Table I worst-case column: adversarial error ~ p/2 for graph codes,
/// ~ p for FRC — the factor-2 separation that motivates the paper.
#[test]
fn table1_adversarial_factor_two() {
    let mut rng = Rng::new(1);
    // larger n so floor(pm/d) isolation is granular enough
    let g = GraphCode::random_regular(64, 4, &mut rng); // m = 128
    let frc = FrcCode::new(64, 128, 4);
    let p = 0.25;
    let budget = (p * 128.0) as usize;

    let gmask = graph_isolation_attack(&g.graph, budget);
    let gerr = OptimalGraphDecoder::new(&g.graph).decode(&gmask).error_sq() / 64.0;
    let fmask = frc_group_attack(&frc, budget);
    let ferr = FrcOptimalDecoder::new(&frc).decode(&fmask).error_sq() / 64.0;

    // frc: exactly p (kills pm/d whole groups)
    assert!((ferr - p).abs() < 0.05, "frc adversarial {ferr} vs p {p}");
    // graph: at least the Rmk V.4 floor p/2, but the greedy attack can
    // beat naive isolation (neighbors of isolated vertices get cheaper),
    // so only require it stays below the FRC's loss and the Cor V.2 cap
    assert!(gerr >= p / 2.0 - 0.03, "graph attack too weak: {gerr}");
    let bound = theory::graph_adversarial_bound(p, 4.0, 4.0 - 2.0 * 3.0f64.sqrt());
    assert!(gerr <= bound + 1e-9, "graph attack {gerr} above Cor V.2 bound {bound}");
    assert!(ferr > 1.3 * gerr, "FRC should lose clearly more: {ferr} vs {gerr}");
}

/// Corollary V.2: the spectral bound holds for the LPS graph under the
/// isolation attack (and the attack achieves at least p/2 - slack).
#[test]
fn lps_adversarial_within_spectral_bound() {
    let code = GraphCode::lps(5, 13);
    let mut rng = Rng::new(2);
    let lambda = gcod::graphs::spectral::spectral_gap(&code.graph, 2000, &mut rng);
    // Ramanujan: lambda >= d - 2 sqrt(d-1)
    assert!(lambda >= 6.0 - 2.0 * 5.0f64.sqrt() - 0.05, "lambda={lambda}");
    let p = 0.2;
    let budget = (p * 6552.0) as usize;
    let mask = graph_isolation_attack(&code.graph, budget);
    let err = OptimalGraphDecoder::new(&code.graph).decode(&mask).error_sq() / 2184.0;
    let bound = theory::graph_adversarial_bound(p, 6.0, lambda);
    assert!(err <= bound, "attack error {err} exceeds Cor V.2 bound {bound}");
    assert!(err >= 0.5 * theory::graph_adversarial_lower(p), "attack too weak: {err}");
}

/// The decoders agree on alpha for every scheme in the zoo.
#[test]
fn all_schemes_specialized_equals_lsqr() {
    let mut rng = Rng::new(3);
    for spec in [
        SchemeSpec::GraphRandomRegular { n: 14, d: 3 },
        SchemeSpec::Frc { n: 12, m: 12, d: 3 },
    ] {
        let s = build(&spec, &mut rng);
        let opt = make_decoder(&s, DecoderSpec::Optimal, 0.2);
        let lsqr = GenericOptimalDecoder::new(&s.a);
        for trial in 0..25 {
            let mask = rng.bernoulli_mask(s.n_machines(), 0.3);
            let a1 = opt.decode(&mask).alpha;
            let a2 = lsqr.decode(&mask).alpha;
            let d2 = gcod::linalg::dist2_sq(&a1, &a2);
            assert!(d2 < 1e-10, "{spec:?} trial {trial}: dist {d2}");
        }
    }
}

/// Figure 5 shape (scaled down): after the same number of iterations,
/// optimal < fixed < uncoded-style error, and optimal with d=6 LPS-like
/// replication is near batch GD.
#[test]
fn fig5_shape_scaled() {
    let mut rng = Rng::new(4);
    let p = 0.2;
    let scheme = build(&SchemeSpec::GraphRandomRegular { n: 32, d: 4 }, &mut rng);
    let data = LstsqData::generate(320, 24, 32, 1.0, &mut rng);
    let run = |dspec: DecoderSpec, seed: u64| {
        let dec = make_decoder(&scheme, dspec, p);
        let mut strag = BernoulliStragglers::new(p, seed);
        let mut eng = SimulatedGcod {
            decoder: dec.as_ref(),
            stragglers: &mut strag,
            step: StepSize::Const(0.02),
            rho: Some(Rng::new(9).permutation(32)),
            m: scheme.n_machines(),
            alpha_scale: 1.0,
        };
        let mut src = &data;
        eng.run(&mut src, &vec![0.0; 24], 80).final_progress()
    };
    let (mut e_opt, mut e_fix, mut e_unc) = (0.0, 0.0, 0.0);
    for s in 0..3 {
        e_opt += run(DecoderSpec::Optimal, 40 + s);
        e_fix += run(DecoderSpec::Fixed, 40 + s);
        e_unc += run(DecoderSpec::Ignore, 40 + s);
    }
    assert!(e_opt < e_fix, "optimal {e_opt} !< fixed {e_fix}");
    // ignore-stragglers without rescaling has a bias floor; fixed beats it
    assert!(e_fix < e_unc, "fixed {e_fix} !< ignore {e_unc}");
}

/// Debias (Prop B.1) turns the biased ignore-stragglers scheme into an
/// unbiased one with E[alpha-hat] = 1.
#[test]
fn debias_produces_unbiased_alpha() {
    let mut rng = Rng::new(5);
    let scheme = build(&SchemeSpec::GraphRandomRegular { n: 16, d: 4 }, &mut rng);
    let p = 0.3;
    let dec = gcod::decode::IgnoreStragglersDecoder { a: &scheme.a, weight: 1.0 };
    // estimate E[alpha] by Monte Carlo
    let mut mean = vec![0.0; 16];
    let trials = 8000;
    let mut strag = BernoulliStragglers::new(p, 6);
    for _ in 0..trials {
        let mask = strag.sample(scheme.n_machines());
        let d = dec.decode(&mask);
        for i in 0..16 {
            mean[i] += d.alpha[i] / trials as f64;
        }
    }
    let deb = gcod::codes::debias(&scheme.a, &mean, 0.5);
    // the debiased assignment decoded the same way has mean ~ 1
    let dec2 = gcod::decode::IgnoreStragglersDecoder { a: &deb.a, weight: 1.0 };
    let mut mean2 = vec![0.0; deb.a.rows];
    let mut strag2 = BernoulliStragglers::new(p, 6);
    for _ in 0..trials {
        let mask = strag2.sample(scheme.n_machines());
        let d = dec2.decode(&mask);
        for i in 0..deb.a.rows {
            mean2[i] += d.alpha[i] / trials as f64;
        }
    }
    for (i, &m) in mean2.iter().enumerate() {
        assert!((m - 1.0).abs() < 0.05, "E[alpha-hat_{i}] = {m}");
    }
}

/// The linear-time decoder handles the paper's full-scale regime-2
/// graph (n=2184, m=6552) fast enough to be "the same order as the
/// update itself" — and the giant-component theory (Thm IV.3) shows:
/// at p=0.2 almost all blocks decode to exactly 1.
#[test]
fn lps_full_scale_decode() {
    let code = GraphCode::lps(5, 13);
    let dec = OptimalGraphDecoder::new(&code.graph);
    let mut strag = BernoulliStragglers::new(0.2, 7);
    let t0 = std::time::Instant::now();
    let mut total_err = 0.0;
    let runs = 50;
    for _ in 0..runs {
        let mask = strag.sample(6552);
        total_err += dec.decode(&mask).error_sq();
    }
    let per_decode = t0.elapsed().as_secs_f64() / runs as f64;
    let err_per_block = total_err / (runs as f64 * 2184.0);
    // p^d = 0.2^6 = 6.4e-5; allow an order of magnitude of slack above
    // the bound (bipartite LPS giant components contribute small error)
    assert!(err_per_block < 6.4e-4, "err/block {err_per_block}");
    assert!(per_decode < 0.05, "decode too slow: {per_decode}s");
}
