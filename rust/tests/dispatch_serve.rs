//! Multi-host dispatch integration suite: the TCP transport and the
//! `gcod serve` job coordinator, end to end over real sockets and real
//! `gcod sweep-shard` subprocess boundaries.
//!
//! * `TcpTransport` behind the unchanged `Dispatcher`, with a chaos
//!   kill tearing a remote worker's lease down mid-range: the retry
//!   machinery absorbs it and the merged bytes are identical to the
//!   single-process run — the acceptance invariant of the serve stack;
//! * the full daemon path: `serve_on` + three registered `worker_loop`s
//!   + `submit_job` with a server-side chaos kill, asserting the
//!   streamed manifest is byte-identical to `shard::run_full`;
//! * `query_status` returns the registry/metrics snapshot.
//!
//! (Wire-format round trips, framing splits and protocol-violation
//! rejection are pinned by the unit tests in `src/dispatch/protocol.rs`.)

use gcod::dispatch::{
    query_status, serve_on, submit_job, worker_loop, ChaosProfile, ChaosTransport,
    DispatchConfig, Dispatcher, JobSpec, ServeConfig, TcpTransport, WorkerOpts,
};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::net::TcpListener;
use std::thread;
use std::time::Duration;

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn sweep_cfg(trials: usize) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 11,
        trials,
        chunk: 8,
        params: BTreeMap::new(),
    }
}

fn spawn_worker(addr: &str, class: &str) -> thread::JoinHandle<gcod::error::Result<u64>> {
    let mut opts = WorkerOpts::new(addr, gcod_bin());
    opts.class = class.into();
    thread::spawn(move || worker_loop(&opts))
}

/// The dispatcher over TCP workers, with a chaos kill mid-lease. The
/// kill frame really tears down the remote shard subprocess, the range
/// is retried elsewhere, and the merged result never moves a bit from
/// the single-process run.
#[test]
fn tcp_transport_chaos_kill_stays_bit_exact() {
    let c = sweep_cfg(96);
    let single = shard::run_full(&c, 2).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..3).map(|_| spawn_worker(&addr, "")).collect();
    let tcp = TcpTransport::accept(&listener, 3, Duration::from_secs(20)).unwrap();
    assert_eq!(tcp.alive(), 3);

    let mut t = ChaosTransport::new(tcp, 0, ChaosProfile::parse("none").unwrap());
    t.preset_kill(1, Duration::from_millis(30));
    let out_dir =
        std::env::temp_dir().join(format!("gcod_serve_test_tcp_{}", std::process::id()));
    let d = DispatchConfig {
        grain: 16,
        max_retries: 10,
        poll_interval: Duration::from_millis(2),
        out_dir: out_dir.clone(),
        ..DispatchConfig::default()
    };
    let out = Dispatcher::new(d).run(&c, &mut t).unwrap();
    let _ = std::fs::remove_dir_all(&out_dir);

    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
    assert!(out.report.retried >= 1, "chaos kill never forced a retry: {}", out.report.summary());
    assert!(!t.plan.log.is_empty(), "kill preset left no fault-plan log");

    // orderly shutdown: every worker (including the one whose lease was
    // killed — only its subprocess died) gets a goodbye and exits Ok
    t.inner().shutdown();
    for w in workers {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }
}

/// The daemon path end to end: workers register with a capability
/// class, a status probe answers, and a submitted job — with a chaos
/// kill taking out one worker slot mid-lease — streams back a manifest
/// byte-identical to the single-process run. `once` terminates the
/// daemon after the job so the test (and the CI smoke) can join it.
#[test]
fn serve_submit_kill_mid_lease_matches_single_process() {
    let c = sweep_cfg(96);
    let single = shard::run_full(&c, 2).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 3;
    scfg.once = true;
    scfg.poll = Duration::from_millis(2);
    let server = thread::spawn(move || serve_on(listener, &scfg));
    let workers: Vec<_> = (0..3).map(|_| spawn_worker(&addr, "cpu")).collect();

    let status = query_status(&addr, Duration::from_secs(10)).unwrap();
    assert!(status.contains("workers registered"), "not a status table: {status}");
    assert!(status.contains("jobs done"), "not a status table: {status}");

    let mut spec = JobSpec::new(c.clone());
    spec.class = "cpu".into();
    spec.grain = 16;
    spec.max_retries = 10;
    spec.kill_worker = Some(2);
    spec.kill_after_ms = 30;
    let out = submit_job(&addr, spec, Duration::from_secs(120)).unwrap();

    assert_eq!(out.manifest, single.render(), "served manifest != single-process run");
    let merged = shard::MergedSweep::parse(&out.manifest).unwrap();
    assert_eq!(merged.values.len(), 96);

    server.join().unwrap().expect("serve_on should exit cleanly in once mode");
    for w in workers {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }
}

/// A worker of the wrong capability class never runs a lease: the job
/// waits for an eligible worker, and classes are matched exactly.
#[test]
fn submit_requires_matching_capability_class() {
    let c = sweep_cfg(16);
    let single = shard::run_full(&c, 1).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 1;
    scfg.once = true;
    scfg.poll = Duration::from_millis(2);
    let server = thread::spawn(move || serve_on(listener, &scfg));

    // a generic worker registers first, but the job demands class "gpu"
    // — it must queue until the eligible worker shows up
    let generic = spawn_worker(&addr, "");
    let submitter = {
        let addr = addr.clone();
        let c = c.clone();
        thread::spawn(move || {
            let mut spec = JobSpec::new(c);
            spec.class = "gpu".into();
            submit_job(&addr, spec, Duration::from_secs(120))
        })
    };
    thread::sleep(Duration::from_millis(300));
    let gpu = spawn_worker(&addr, "gpu");

    let out = submitter.join().unwrap().expect("job should run once a gpu worker joins");
    assert_eq!(out.manifest, single.render());

    server.join().unwrap().unwrap();
    for w in [generic, gpu] {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }
}
