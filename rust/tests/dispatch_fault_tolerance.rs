//! Fault-tolerance suite for the elastic dispatch subsystem
//! (`dispatch::Dispatcher` + `LocalProcess` over real `gcod` worker
//! subprocesses). The contracts pinned here:
//!
//! * killing a worker mid-shard loses nothing: the lease is
//!   re-dispatched and the merged JSON is **byte-identical** to the
//!   single-process run — for all three standard sweep kinds
//!   (`decode-error`, `gd-final`, `attack`);
//! * a worker that never heartbeats (hangs before doing any work) is
//!   reaped by the lease deadline and its range re-dispatched, with the
//!   same byte-identity guarantee;
//! * the `gcod sweep-launch` CLI end-to-end — 3 local workers, one
//!   injected kill — produces a merged file byte-identical to the
//!   `sweep-shard 0/1` + `sweep-merge` single-process path (the CI
//!   smoke step mirrors this);
//! * stats-only manifests round-trip through the CLI and refuse to mix
//!   with full manifests.
//!
//! (Duplicate-cover dedup and retry exhaustion are pinned
//! deterministically by the in-crate scripted-transport tests in
//! `src/dispatch/mod.rs`; here everything crosses real process
//! boundaries.)

use gcod::dispatch::{
    ChaosProfile, ChaosTransport, DispatchConfig, Dispatcher, LocalProcess, WorkerId,
};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gcod_dispatch_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn decode_error_cfg() -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 9,
        trials: 120,
        chunk: 8,
        params: BTreeMap::new(),
    }
}

fn dcfg(tag: &str) -> DispatchConfig {
    DispatchConfig {
        grain: 16,
        poll_interval: Duration::from_millis(5),
        out_dir: tmp_dir(tag),
        ..DispatchConfig::default()
    }
}

/// Dispatch `cfg` over 2 subprocesses with one worker chaos-killed
/// mid-range; assert the merged JSON is byte-identical to the
/// in-process single run.
fn assert_faulted_dispatch_bit_exact(cfg: &SweepConfig, tag: &str, kill: Option<WorkerId>) {
    let single = shard::run_full(cfg, 2).unwrap();
    let d = dcfg(tag);
    let mut transport =
        ChaosTransport::new(LocalProcess::new(gcod_bin(), 2), 0, ChaosProfile::none());
    if let Some(w) = kill {
        // the chaos kill hides any early inner completion, so it lands
        // mid-range no matter how fast the worker finishes
        transport.preset_kill(w, Duration::from_millis(30));
    }
    let out = Dispatcher::new(d).run(cfg, &mut transport).unwrap();
    assert_eq!(
        out.merged.render(),
        single.render(),
        "{tag}: merged JSON bytes diverged from the single-process run \
         ({})",
        out.report.summary()
    );
    if kill.is_some() {
        assert!(out.report.retried >= 1, "{tag}: kill never re-dispatched a lease: {}",
                out.report.summary());
        assert!(!out.report.failure_log.is_empty(), "{tag}: empty failure log");
    }
}

/// The headline acceptance contract: a worker killed mid-range, lease
/// re-dispatched, merged bits identical — for every standard sweep kind.
#[test]
fn kill_mid_shard_is_bit_exact_for_all_sweep_kinds() {
    // decode-error (Fig. 3)
    assert_faulted_dispatch_bit_exact(&decode_error_cfg(), "kill_decode", Some(0));

    // gd-final (Fig. 4/5 on deterministic substreams)
    let mut gd = SweepConfig {
        sweep: SweepKind::GdFinal,
        scheme: "graph-rr:8,3".into(),
        decoder: "optimal".into(),
        p: 0.25,
        seed: 3,
        trials: 12,
        chunk: 4,
        params: BTreeMap::new(),
    };
    gd.params.insert("n-points".into(), "64".into());
    gd.params.insert("dim".into(), "8".into());
    gd.params.insert("iters".into(), "10".into());
    assert_faulted_dispatch_bit_exact(&gd, "kill_gd", Some(0));

    // attack (budget axis, nested greedy trace)
    let attack = SweepConfig {
        sweep: SweepKind::Attack,
        scheme: "graph-rr:12,3".into(),
        decoder: "optimal".into(),
        p: 0.25,
        seed: 0,
        trials: 10,
        chunk: 4,
        params: BTreeMap::new(),
    };
    assert_faulted_dispatch_bit_exact(&attack, "kill_attack", Some(0));

    // adv-gd (greedy adversarial noise floor; new kernel, same contract)
    let mut adv = SweepConfig {
        sweep: SweepKind::AdvGd,
        scheme: "graph-rr:8,3".into(),
        decoder: "optimal".into(),
        p: 0.25,
        seed: 3,
        trials: 12,
        chunk: 4,
        params: BTreeMap::new(),
    };
    adv.params.insert("n-points".into(), "64".into());
    adv.params.insert("dim".into(), "8".into());
    adv.params.insert("iters".into(), "8".into());
    assert_faulted_dispatch_bit_exact(&adv, "kill_adv_gd", Some(0));
}

/// A worker that never heartbeats: its first job sleeps far past the
/// lease deadline, the dispatcher reaps the lease and re-dispatches.
#[test]
fn hung_worker_is_reaped_by_lease_deadline() {
    let cfg = decode_error_cfg();
    let single = shard::run_full(&cfg, 2).unwrap();
    let mut d = dcfg("hang");
    d.lease_timeout = Duration::from_millis(400);
    d.lease_timeout_per_trial = Duration::ZERO; // flat deadline on purpose
    d.speculate = false; // force the rescue through the timeout path
    let mut transport =
        ChaosTransport::new(LocalProcess::new(gcod_bin(), 2), 0, ChaosProfile::none());
    transport.preset_delay(0, 60_000); // effectively never heartbeats
    let out = Dispatcher::new(d).run(&cfg, &mut transport).unwrap();
    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
    assert!(out.report.timeouts >= 1, "no lease timed out: {}", out.report.summary());
}

/// Straggler simulation end-to-end: Bernoulli-delayed workers change
/// wall-clock behavior only, never the merged bits.
#[test]
fn simulated_stragglers_do_not_change_bits() {
    let cfg = decode_error_cfg();
    let single = shard::run_full(&cfg, 2).unwrap();
    let mut d = dcfg("sim");
    d.straggler_sim = Some(gcod::dispatch::StragglerSimCfg {
        p: 0.4,
        delay: Duration::from_millis(40),
        seed: 77,
    });
    let mut transport = LocalProcess::new(gcod_bin(), 3);
    let out = Dispatcher::new(d).run(&cfg, &mut transport).unwrap();
    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
}

// ---------------------------------------------------------------------
// CLI end-to-end
// ---------------------------------------------------------------------

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawn gcod");
    assert!(
        out.status.success(),
        "gcod failed: {:?}\nstdout: {}\nstderr: {}",
        cmd,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

const CLI_SWEEP_ARGS: &[&str] = &[
    "--sweep",
    "decode-error",
    "--scheme",
    "graph-rr:16,3",
    "--decoder",
    "optimal",
    "--p",
    "0.2",
    "--trials",
    "200",
    "--seed",
    "7",
    "--chunk",
    "16",
];

/// `gcod sweep-launch` with 3 workers and one injected kill produces a
/// merged file byte-identical to the `sweep-shard 0/1` + `sweep-merge`
/// single-process path (mirrors the CI smoke step).
#[test]
fn cli_sweep_launch_with_kill_matches_single_process_file() {
    let dir = tmp_dir("cli_launch");
    let shard_path = dir.join("single_shard.json");
    let single_path = dir.join("single_merged.json");
    let launched_path = dir.join("launched.json");

    run_ok(Command::new(gcod_bin()).arg("sweep-shard").args(CLI_SWEEP_ARGS).args([
        "--threads",
        "2",
        "--shard",
        "0/1",
        "--out",
        shard_path.to_str().unwrap(),
    ]));
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        shard_path.to_str().unwrap(),
        "--out",
        single_path.to_str().unwrap(),
    ]));
    let stdout = run_ok(Command::new(gcod_bin()).arg("sweep-launch").args(CLI_SWEEP_ARGS).args([
        "--workers",
        "3",
        "--grain",
        "32",
        "--hang-worker",
        "0",
        "--hang-ms",
        "150",
        "--kill-worker",
        "0",
        "--kill-after-ms",
        "30",
        "--out",
        launched_path.to_str().unwrap(),
    ]));
    assert!(stdout.contains("dispatched"), "missing report summary: {stdout}");

    let single = std::fs::read_to_string(&single_path).unwrap();
    let launched = std::fs::read_to_string(&launched_path).unwrap();
    assert_eq!(single, launched, "sweep-launch output != single-process merge");
    // sanity: it is a merged manifest of the full sweep
    let merged = shard::MergedSweep::parse(&launched).unwrap();
    assert_eq!(merged.values.len(), 200);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint/resume through the CLI: a launch that dies of retry
/// exhaustion (injected kill, zero retry budget) leaves its journal
/// behind; `--resume` recomputes only the uncovered ranges and the
/// merged file is byte-identical to the single-process path.
#[test]
fn cli_journal_resume_after_failed_launch() {
    let dir = tmp_dir("cli_resume");
    let shard_path = dir.join("single_shard.json");
    let single_path = dir.join("single_merged.json");
    let resumed_path = dir.join("resumed.json");
    let journal = dir.join("launch.journal");

    run_ok(Command::new(gcod_bin()).arg("sweep-shard").args(CLI_SWEEP_ARGS).args([
        "--threads",
        "2",
        "--shard",
        "0/1",
        "--out",
        shard_path.to_str().unwrap(),
    ]));
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        shard_path.to_str().unwrap(),
        "--out",
        single_path.to_str().unwrap(),
    ]));

    // first launch: worker 0 slowed then killed mid-range with a zero
    // retry budget — the launch must fail, banking whatever the healthy
    // worker completed in the journal
    let out = Command::new(gcod_bin())
        .arg("sweep-launch")
        .args(CLI_SWEEP_ARGS)
        .args([
            "--workers",
            "2",
            "--grain",
            "32",
            "--hang-worker",
            "0",
            "--hang-ms",
            "300",
            "--kill-worker",
            "0",
            "--kill-after-ms",
            "50",
            "--max-retries",
            "0",
            "--journal",
            journal.to_str().unwrap(),
            "--out",
            dir.join("failed.json").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        !out.status.success(),
        "launch with max-retries 0 and an injected kill must fail\nstdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--resume"), "missing resume hint in stderr: {stderr}");
    assert!(journal.is_file(), "journal must survive the failed launch");

    // resume with a healthy pool: completes and matches the
    // single-process bytes; the journal is consumed
    run_ok(Command::new(gcod_bin()).arg("sweep-launch").args(CLI_SWEEP_ARGS).args([
        "--workers",
        "2",
        "--grain",
        "32",
        "--resume",
        journal.to_str().unwrap(),
        "--out",
        resumed_path.to_str().unwrap(),
    ]));
    assert_eq!(
        std::fs::read_to_string(&single_path).unwrap(),
        std::fs::read_to_string(&resumed_path).unwrap(),
        "resumed launch output != single-process merge"
    );
    assert!(!journal.is_file(), "journal must be cleaned up after a successful resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--range` shards merge exactly like `--shard` splits, and
/// stats-only manifests work through the CLI but refuse to mix with
/// full ones.
#[test]
fn cli_range_and_stats_only_modes() {
    let dir = tmp_dir("cli_range");
    let mk = |extra: &[&str], name: &str| {
        let p = dir.join(name);
        run_ok(
            Command::new(gcod_bin())
                .arg("sweep-shard")
                .args(CLI_SWEEP_ARGS)
                .args(["--threads", "1", "--out", p.to_str().unwrap()])
                .args(extra),
        );
        p
    };
    // ragged --range split == --shard 0/1 after merge
    let a = mk(&["--range", "0..37"], "r0.json");
    let b = mk(&["--range", "37..200"], "r1.json");
    let full = mk(&["--shard", "0/1"], "full.json");
    let merged_ranges = dir.join("m_ranges.json");
    let merged_full = dir.join("m_full.json");
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        a.to_str().unwrap(),
        "--input",
        b.to_str().unwrap(),
        "--out",
        merged_ranges.to_str().unwrap(),
    ]));
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        full.to_str().unwrap(),
        "--out",
        merged_full.to_str().unwrap(),
    ]));
    assert_eq!(
        std::fs::read_to_string(&merged_ranges).unwrap(),
        std::fs::read_to_string(&merged_full).unwrap(),
        "ragged --range merge != single-shard merge"
    );

    // stats-only: small manifests, Chan-merged result
    let so0 = mk(&["--range", "0..100", "--stats-only"], "so0.json");
    let so1 = mk(&["--range", "100..200", "--stats-only"], "so1.json");
    assert!(
        std::fs::metadata(&so0).unwrap().len() < std::fs::metadata(&full).unwrap().len() / 4,
        "stats-only manifest is not materially smaller"
    );
    let merged_so = dir.join("m_so.json");
    run_ok(Command::new(gcod_bin()).args([
        "sweep-merge",
        "--input",
        so0.to_str().unwrap(),
        "--input",
        so1.to_str().unwrap(),
        "--out",
        merged_so.to_str().unwrap(),
    ]));
    let so = shard::MergedSweep::parse(&std::fs::read_to_string(&merged_so).unwrap()).unwrap();
    let full_merged =
        shard::MergedSweep::parse(&std::fs::read_to_string(&merged_full).unwrap()).unwrap();
    assert!(so.stats_only && so.values.is_empty());
    assert_eq!(so.stats.count(), 200);
    assert_eq!(so.stats.min().to_bits(), full_merged.stats.min().to_bits());
    assert_eq!(so.stats.max().to_bits(), full_merged.stats.max().to_bits());
    assert!((so.stats.mean() - full_merged.stats.mean()).abs() < 1e-12);

    // mixing stats-only and full manifests is rejected
    let out = Command::new(gcod_bin())
        .args([
            "sweep-merge",
            "--input",
            so0.to_str().unwrap(),
            "--input",
            b.to_str().unwrap(),
            "--out",
            dir.join("m_mixed.json").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "mixed stats-only/full merge must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("stats-only"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
