//! Runtime integration: execute the real AOT artifacts and pin their
//! numerics against the pure-rust oracles. Requires `make artifacts`
//! and the `pjrt` feature (environment-bound: needs the vendored
//! xla/anyhow dependencies and the PJRT CPU client).
#![cfg(pjrt_runtime)]

use gcod::data::LstsqData;
use gcod::prng::Rng;
use gcod::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("run `make artifacts` before cargo test")
}

#[test]
fn manifest_covers_required_artifacts() {
    let rt = runtime();
    for name in [
        "block_grad_qs_16x8x32",
        "decode_combine_qs_16x32",
        "worker_grad_qs_2x8x32",
        "lstsq_loss_qs_16x8x32",
        "block_grad_fig5_2184x3x200",
        "decode_combine_fig5_2184x200",
        "worker_grad_fig4_2x375x2000",
        "tfm_block_grad",
        "tfm_block_grad_all",
        "tfm_eval_loss",
    ] {
        assert!(rt.manifest.artifact(name).is_some(), "missing artifact {name}");
    }
}

/// The Pallas block_grad artifact agrees with the rust oracle.
#[test]
fn block_grad_artifact_matches_rust_oracle() {
    let rt = runtime();
    let mut rng = Rng::new(0);
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let theta = rng.gaussian_vec(32, 1.0);
    let want = data.block_grads(&theta);

    let (xb, yb) = data.to_f32_buffers();
    let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
    let out = rt
        .run(
            "block_grad_qs_16x8x32",
            &[
                Tensor::f32(&[32], theta32),
                Tensor::f32(&[16, 8, 32], xb),
                Tensor::f32(&[16, 8], yb),
            ],
        )
        .unwrap();
    let g = out[0].as_f32().unwrap();
    assert_eq!(out[0].shape(), &[16, 32]);
    let mut max_err = 0.0f64;
    for i in 0..16 {
        for c in 0..32 {
            max_err = max_err.max((g[i * 32 + c] as f64 - want[(i, c)]).abs());
        }
    }
    assert!(max_err < 1e-3, "max err {max_err}");
}

/// decode_combine artifact == G^T alpha in rust.
#[test]
fn decode_combine_artifact_matches_rust() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let g: Vec<f32> = (0..16 * 32).map(|_| rng.gaussian() as f32).collect();
    let w: Vec<f32> = (0..16).map(|_| rng.gaussian() as f32).collect();
    let out = rt
        .run(
            "decode_combine_qs_16x32",
            &[Tensor::f32(&[16, 32], g.clone()), Tensor::f32(&[16], w.clone())],
        )
        .unwrap();
    let u = out[0].as_f32().unwrap();
    for c in 0..32 {
        let want: f32 = (0..16).map(|i| g[i * 32 + c] * w[i]).sum();
        assert!((u[c] - want).abs() < 1e-3, "{} vs {}", u[c], want);
    }
}

/// worker artifact (2 blocks) slices consistently with the full one.
#[test]
fn worker_grad_artifact_is_block_grad_slice() {
    let rt = runtime();
    let mut rng = Rng::new(2);
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let theta = rng.gaussian_vec(32, 1.0);
    let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
    let (mx, my) = data.machine_f32_buffers(&[3, 11]);
    let out = rt
        .run(
            "worker_grad_qs_2x8x32",
            &[
                Tensor::f32(&[32], theta32),
                Tensor::f32(&[2, 8, 32], mx),
                Tensor::f32(&[2, 8], my),
            ],
        )
        .unwrap();
    let g = out[0].as_f32().unwrap();
    let want = data.block_grads(&theta);
    for (slot, blk) in [(0usize, 3usize), (1, 11)] {
        for c in 0..32 {
            assert!(
                (g[slot * 32 + c] as f64 - want[(blk, c)]).abs() < 1e-3,
                "block {blk} col {c}"
            );
        }
    }
}

/// lstsq_loss artifact equals |X theta - y|^2.
#[test]
fn loss_artifact_matches() {
    let rt = runtime();
    let mut rng = Rng::new(3);
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let theta = rng.gaussian_vec(32, 1.0);
    let want = data.loss(&theta);
    let (xb, yb) = data.to_f32_buffers();
    let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
    let out = rt
        .run(
            "lstsq_loss_qs_16x8x32",
            &[
                Tensor::f32(&[32], theta32),
                Tensor::f32(&[16, 8, 32], xb),
                Tensor::f32(&[16, 8], yb),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap()[0] as f64;
    assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
}

/// PJRT-backed coded GD (the full L1+L2+L3 request path) converges and
/// with p=0 matches batch GD run natively.
#[test]
fn pjrt_gcod_matches_native_when_exact() {
    use gcod::codes::GraphCode;
    use gcod::decode::OptimalGraphDecoder;
    use gcod::gd::pjrt::PjrtGcod;
    use gcod::gd::{SimulatedGcod, StepSize};
    use gcod::straggler::BernoulliStragglers;

    let rt = runtime();
    let mut rng = Rng::new(4);
    let code = GraphCode::random_regular(16, 3, &mut rng);
    let data = LstsqData::generate(128, 32, 16, 0.5, &mut rng);
    let dec = OptimalGraphDecoder::new(&code.graph);

    let mut s1 = BernoulliStragglers::new(0.0, 9);
    let mut pjrt_engine = PjrtGcod {
        rt: &rt,
        decoder: &dec,
        stragglers: &mut s1,
        m: 24,
        step: StepSize::Const(0.08),
        rho: None,
    };
    let h_pjrt = pjrt_engine.run(&data, &vec![0.0; 32], 15).unwrap();

    let mut s2 = BernoulliStragglers::new(0.0, 9);
    let mut native = SimulatedGcod {
        decoder: &dec,
        stragglers: &mut s2,
        step: StepSize::Const(0.08),
        rho: None,
        m: 24,
        alpha_scale: 1.0,
    };
    let mut src = &data;
    let h_native = native.run(&mut src, &vec![0.0; 32], 15);

    for (a, b) in h_pjrt.progress.iter().zip(&h_native.progress) {
        assert!((a - b).abs() < 1e-2 * (1.0 + b), "pjrt {a} vs native {b}");
    }
}

/// Transformer artifacts: one coded step decreases training loss given
/// a large enough step, and grads have the right shape.
#[test]
fn transformer_artifact_grad_step() {
    let rt = runtime();
    let tfm = rt.manifest.transformer.clone().expect("transformer meta");
    let mut rng = Rng::new(5);
    let corpus = gcod::data::TokenCorpus::generate(50_000, tfm.vocab, &mut rng);
    let tokens = corpus.blocks(tfm.n_blocks, tfm.batch, tfm.seq_len + 1, &mut rng);
    let mut params = rt.read_transformer_init().unwrap();
    assert_eq!(params.len(), tfm.n_params);

    let exe = rt.load("tfm_block_grad_all").unwrap();
    let run_once = |params: &Vec<f32>| {
        let out = exe
            .run(&[
                Tensor::f32(&[tfm.n_params], params.clone()),
                Tensor::i32(&[tfm.n_blocks, tfm.batch, tfm.seq_len + 1], tokens.clone()),
            ])
            .unwrap();
        let grads = out[0].as_f32().unwrap().to_vec();
        let losses = out[1].as_f32().unwrap().to_vec();
        (grads, losses)
    };
    let (grads, losses) = run_once(&params);
    assert_eq!(grads.len(), tfm.n_blocks * tfm.n_params);
    assert_eq!(losses.len(), tfm.n_blocks);
    let loss0: f64 = losses.iter().map(|&l| l as f64).sum();
    // full-gradient step (all alpha = 1)
    for i in 0..tfm.n_blocks {
        for c in 0..tfm.n_params {
            params[c] -= 1.0 * grads[i * tfm.n_params + c];
        }
    }
    let (_, losses1) = run_once(&params);
    let loss1: f64 = losses1.iter().map(|&l| l as f64).sum();
    assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
}
