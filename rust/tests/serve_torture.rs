//! Protocol torture suite: hostile and broken peers against a live
//! `serve_on` coordinator. None of these may panic the daemon, wedge
//! its event loop, or poison later clients — each attack is followed by
//! a status probe, and the suite ends with a real job running to a
//! byte-identical result while a slow-loris connection is still
//! half-open.
//!
//! Attack inventory: truncated frames, non-UTF8 garbage payloads,
//! oversize length prefixes, mid-frame disconnects, a never-completing
//! HTTP request line (slow loris), and an honest HTTP request for a
//! bogus path (404, not a dropped connection).

use gcod::dispatch::{
    query_status, serve_on, submit_job, worker_loop, JobSpec, ServeConfig, WorkerOpts,
};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn sweep_cfg(trials: usize) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 11,
        trials,
        chunk: 8,
        params: BTreeMap::new(),
    }
}

/// Open a raw socket, write `bytes`, drop the connection immediately.
fn hit_and_run(addr: &str, bytes: &[u8]) {
    let mut s = TcpStream::connect(addr).unwrap();
    let _ = s.write_all(bytes);
    // dropped here: the peer sees a mid-frame EOF
}

fn frame_prefix(len: u32) -> [u8; 4] {
    len.to_be_bytes()
}

#[test]
fn hostile_peers_never_take_the_coordinator_down() {
    let c = sweep_cfg(32);
    let single = shard::run_full(&c, 1).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let drain = Arc::new(AtomicBool::new(false));
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 1;
    scfg.poll = Duration::from_millis(2);
    scfg.drain = Some(drain.clone());
    let server = thread::spawn(move || serve_on(listener, &scfg));
    let probe = |attack: &str| {
        let status = query_status(&addr, Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("status probe failed after {attack}: {e}"));
        assert!(status.contains("workers registered"), "not a status table after {attack}");
    };
    probe("nothing (baseline)");

    // 1. truncated frame: announce 100 bytes, deliver 10, vanish
    let mut attack = frame_prefix(100).to_vec();
    attack.extend_from_slice(b"{\"msg\": \"");
    hit_and_run(&addr, &attack);
    probe("a truncated frame");

    // 2. non-UTF8 garbage payload in a well-formed frame
    let mut attack = frame_prefix(4).to_vec();
    attack.extend_from_slice(&[0xFF, 0xFE, 0xC0, 0xAA]);
    hit_and_run(&addr, &attack);
    probe("a non-UTF8 payload");

    // 3. valid JSON that is not a protocol message
    let body = b"{\"msg\": \"no-such-message\"}";
    let mut attack = frame_prefix(body.len() as u32).to_vec();
    attack.extend_from_slice(body);
    hit_and_run(&addr, &attack);
    probe("an unknown message type");

    // 4. oversize length prefix, just past the frame cap — must be
    // rejected without any attempt to allocate or read a gigabyte
    hit_and_run(&addr, &frame_prefix((1 << 30) + 1));
    probe("an oversize length prefix");

    // 5. mid-frame disconnect with the length fully delivered
    let mut attack = frame_prefix(64).to_vec();
    attack.extend_from_slice(&[b'x'; 32]);
    hit_and_run(&addr, &attack);
    probe("a mid-frame disconnect");

    // 6. slow loris: a partial HTTP request line that never completes,
    // held open across everything below — it may consume one handshake
    // slot until its deadline, never the event loop
    let mut loris = TcpStream::connect(&addr).unwrap();
    loris.write_all(b"GET /met").unwrap();
    probe("a slow-loris half request");

    // 7. an honest HTTP request for a bogus path is answered (404),
    // not dropped
    let mut http = TcpStream::connect(&addr).unwrap();
    http.write_all(b"GET /nope HTTP/1.0\r\n\r\n").unwrap();
    http.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut response = String::new();
    http.read_to_string(&mut response).unwrap();
    assert!(response.contains("404"), "bogus path got: {response}");
    probe("an HTTP 404 exchange");

    // with the loris still latched on, a real job must run end to end
    // and stay byte-identical
    let worker = {
        let mut opts = WorkerOpts::new(addr.clone(), gcod_bin());
        opts.connect_retries = 200;
        thread::spawn(move || worker_loop(&opts))
    };
    let mut spec = JobSpec::new(c);
    spec.grain = 8;
    let out = submit_job(&addr, spec, Duration::from_secs(120)).unwrap();
    assert_eq!(out.manifest, single.render(), "tortured coordinator bent the result");

    drain.store(true, Ordering::Relaxed);
    server.join().unwrap().expect("drain must exit Ok despite the torture");
    worker.join().unwrap().expect("worker loop should end on goodbye");
    drop(loris);
}
