//! Gram-cached gradient path: conformance + determinism suite.
//!
//! * property test: Gram-cached block gradients match the streaming
//!   computation within tolerance at random problem shapes;
//! * the `gd-final` sweep on the new kernel stays **bit-identical**
//!   across thread counts (1 ≡ 8) and shard splits (1 ≡ 4), for both
//!   the Gram and streaming kernels and for the warm-started LSQR
//!   decoder whose state is chunk-scoped;
//! * `grad=auto` selection is a pure function of the config (explicit
//!   `gram` at an auto-gram shape produces the same bits);
//! * scratch reuse across trials never changes results.

use gcod::data::LstsqData;
use gcod::gd::{GdScratch, GradSource, GramCache};
use gcod::prng::Rng;
use gcod::sweep::shard::{self, MergedSweep, ShardSpec, SweepConfig, SweepKind};
use std::collections::BTreeMap;

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Random-shape property test: for any (n_points, dim, blocks) and any
/// theta, the Gram form G_i θ − c_i equals the streaming form
/// X_iᵀ(X_i θ − y_i) to rounding.
#[test]
fn gram_matches_streaming_at_random_shapes() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..25 {
        let blocks = 1 + rng.below(12);
        let b = 1 + rng.below(24);
        let n_points = blocks * b;
        // keep N comfortably above dim so theta* is well-conditioned
        let dim = 1 + rng.below(n_points.min(20));
        if 2 * dim > n_points {
            continue;
        }
        let data = LstsqData::generate(n_points, dim, blocks, 0.7, &mut rng);
        let cache = GramCache::new(&data);
        let theta = rng.gaussian_vec(dim, 2.0);
        let mut s = &data;
        let mut g = &cache;
        let gs = GradSource::block_grads(&mut s, &theta);
        let gg = GradSource::block_grads(&mut g, &theta);
        assert_eq!(gs.data.len(), gg.data.len());
        for (i, (a, b)) in gs.data.iter().zip(&gg.data).enumerate() {
            assert!(
                rel_close(*a, *b, 1e-8),
                "case {case} (N={n_points} d={dim} n={blocks}) entry {i}: {a} vs {b}"
            );
        }
    }
}

/// The engine-parallel Gram build (PR 4 follow-up: per-block SYRKs
/// fanned across scoped workers) is byte-identical to the serial build
/// for any thread count — blocks are independent and each worker owns a
/// disjoint output slice, so scheduling cannot reorder any float op.
#[test]
fn parallel_gram_build_matches_serial_bitwise() {
    let mut rng = Rng::new(0xBEE);
    for case in 0..10 {
        let blocks = 1 + rng.below(10);
        let b = 2 + rng.below(16);
        let dim = 1 + rng.below(12);
        let data = LstsqData::generate(blocks * b, dim, blocks, 0.6, &mut rng);
        let serial = GramCache::new(&data);
        for threads in [2usize, 5, 8] {
            let par = GramCache::new_parallel(&data, threads);
            for i in 0..blocks {
                for (x, y) in par.block_gram(i).iter().zip(serial.block_gram(i)) {
                    assert_eq!(x.to_bits(), y.to_bits(), "case {case} gram blk {i} t={threads}");
                }
                for (x, y) in par.block_c(i).iter().zip(serial.block_c(i)) {
                    assert_eq!(x.to_bits(), y.to_bits(), "case {case} c blk {i} t={threads}");
                }
            }
        }
    }
}

fn gd_cfg(decoder: &str, trials: usize, chunk: usize, grad: Option<&str>) -> SweepConfig {
    let mut params = BTreeMap::new();
    // 256 points over 8 blocks: b = 32 >= dim = 8, so `auto` picks gram
    params.insert("n-points".into(), "256".into());
    params.insert("dim".into(), "8".into());
    params.insert("iters".into(), "10".into());
    if let Some(g) = grad {
        params.insert("grad".into(), g.into());
    }
    SweepConfig {
        sweep: SweepKind::GdFinal,
        scheme: "graph-rr:8,3".into(),
        decoder: decoder.into(),
        p: 0.25,
        seed: 17,
        trials,
        chunk,
        params,
    }
}

fn assert_same_bits(a: &MergedSweep, b: &MergedSweep, what: &str) {
    assert_eq!(a.render(), b.render(), "{what}: merged JSON bytes differ");
}

/// 1-thread ≡ 8-thread gd-final sweeps, exact to the merged JSON byte,
/// on both kernels and with the stateful (chunk-scoped, warm-started)
/// LSQR decoder.
#[test]
fn gd_final_threads_bit_exact_on_both_kernels() {
    for grad in [None, Some("gram"), Some("streaming")] {
        for decoder in ["optimal", "optimal-lsqr"] {
            let c = gd_cfg(decoder, 24, 4, grad);
            let t1 = shard::run_full(&c, 1).unwrap();
            let t8 = shard::run_full(&c, 8).unwrap();
            assert_same_bits(&t1, &t8, &format!("threads 1 vs 8 ({decoder}, grad={grad:?})"));
        }
    }
}

/// 1-shard ≡ 4-shard gd-final merges, exact to the byte — the balanced
/// split lands mid-chunk (24 trials / chunk 4 / 4 shards = 6-trial
/// shards), exercising the warm-state replay of partial leading chunks
/// on the new chunk-scoped GD context.
#[test]
fn gd_final_shards_bit_exact_on_gram_kernel() {
    for grad in [None, Some("streaming")] {
        let c = gd_cfg("optimal-lsqr", 24, 4, grad);
        let single = shard::run_full(&c, 2).unwrap();
        let shards: Vec<_> = (0..4)
            .map(|i| shard::run_shard(&c, 2, ShardSpec::new(i, 4).unwrap()).unwrap())
            .collect();
        let merged = shard::merge(shards).unwrap();
        assert_same_bits(&single, &merged, &format!("1 vs 4 shards (grad={grad:?})"));
    }
}

/// `auto` at a tall-block shape is literally the gram kernel (and both
/// differ from streaming only within tolerance, never wildly).
#[test]
fn auto_grad_selection_is_deterministic() {
    let auto_cfg = gd_cfg("optimal", 8, 4, None);
    let gram_cfg = gd_cfg("optimal", 8, 4, Some("gram"));
    let stream_cfg = gd_cfg("optimal", 8, 4, Some("streaming"));
    let auto = shard::run_full(&auto_cfg, 2).unwrap();
    let gram = shard::run_full(&gram_cfg, 2).unwrap();
    let stream = shard::run_full(&stream_cfg, 2).unwrap();
    // the `grad` param is part of the sweep identity, so only the
    // values (not the manifests) can be compared across configs
    assert_eq!(auto.values.len(), gram.values.len());
    for (i, (a, g)) in auto.values.iter().zip(&gram.values).enumerate() {
        assert_eq!(a.to_bits(), g.to_bits(), "trial {i}: auto != gram at a tall-block shape");
    }
    for (i, (g, s)) in gram.values.iter().zip(&stream.values).enumerate() {
        assert!(
            rel_close(*g, *s, 1e-5),
            "trial {i}: gram {g} vs streaming {s} diverged beyond rounding"
        );
    }
}

/// Reusing one scratch across many trials (the chunk-scoped sweep
/// context) is value-neutral: a dirty scratch reproduces the fresh
/// result bit-for-bit.
#[test]
fn scratch_reuse_across_trials_is_value_neutral() {
    use gcod::codes::{GradientCode, GraphCode};
    use gcod::decode::OptimalGraphDecoder;
    use gcod::gd::{SimulatedGcod, StepSize};
    use gcod::straggler::BernoulliStragglers;
    let mut rng = Rng::new(2);
    let code = GraphCode::random_regular(16, 4, &mut rng);
    let data = LstsqData::generate(192, 6, 16, 0.5, &mut rng);
    let cache = GramCache::new(&data);
    let dec = OptimalGraphDecoder::new(&code.graph);
    let mut run = |seed: u64, scratch: &mut GdScratch| {
        let mut strag = BernoulliStragglers::new(0.2, seed);
        let mut gd = SimulatedGcod {
            decoder: &dec,
            stragglers: &mut strag,
            step: StepSize::Const(0.02),
            rho: None,
            m: code.n_machines(),
            alpha_scale: 1.0,
        };
        let mut src = &cache;
        gd.run_with(&mut src, &[0.0; 6], 12, scratch).final_progress()
    };
    // fresh scratch per trial
    let fresh: Vec<f64> = (0..6).map(|s| run(s, &mut GdScratch::new())).collect();
    // one shared scratch across all trials
    let mut shared = GdScratch::new();
    let reused: Vec<f64> = (0..6).map(|s| run(s, &mut shared)).collect();
    for (i, (a, b)) in fresh.iter().zip(&reused).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "trial {i}");
    }
}
