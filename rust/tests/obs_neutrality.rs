//! Observability bit-neutrality suite: the flight recorder, trace
//! sinks and metrics bridge may *watch* a dispatch, never *touch* it.
//!
//! * every runnable kernel (`decode-error`, `gd-final`, `attack`,
//!   `adv-gd`) dispatches twice over real subprocess boundaries — obs
//!   fully on (flight recorder + JSONL trace file + counters) vs the
//!   disabled no-op handle — and the merged manifests must be
//!   byte-identical to each other *and* to the single-process run;
//! * a chaos-seeded dispatch with a trace file attached stays bit-exact
//!   too, and the trace carries the seeded fault decisions as
//!   `chaos-fault` events (what the CI chaos soak asserts on instead of
//!   grepping stderr);
//! * `fig4-cluster` is excluded by construction: it is an external
//!   producer (`SweepKind::external_producer`) the dispatcher refuses,
//!   so there is nothing to trace.
//!
//! (Ring-buffer wraparound and torn-JSONL-line tolerance are pinned by
//! the unit tests in `src/obs/mod.rs` / `src/obs/report.rs`.)

use gcod::dispatch::{ChaosProfile, ChaosTransport, DispatchConfig, Dispatcher, LocalProcess};
use gcod::obs::Obs;
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gcod_obsneu_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small-but-real config per kernel: enough trials to need several
/// leases at grain 8, GD problems shrunk so four kernels stay fast.
fn sweep_cfg(kind: SweepKind, trials: usize) -> SweepConfig {
    let mut params = BTreeMap::new();
    if kind == SweepKind::GdFinal || kind == SweepKind::AdvGd {
        params.insert("n-points".into(), "64".into());
        params.insert("dim".into(), "8".into());
        params.insert("iters".into(), "5".into());
    }
    SweepConfig {
        sweep: kind,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 11,
        trials,
        chunk: 8,
        params,
    }
}

fn dcfg(tag: &str, obs: Obs) -> DispatchConfig {
    DispatchConfig {
        grain: 8,
        poll_interval: Duration::from_millis(2),
        out_dir: tmp_dir(tag),
        obs,
        ..DispatchConfig::default()
    }
}

/// Dispatch `cfg` on two local subprocess workers under the given obs
/// handle and return the merged manifest bytes.
fn dispatch_bytes(cfg: &SweepConfig, tag: &str, obs: Obs) -> String {
    let mut t = LocalProcess::new(gcod_bin(), 2);
    let out = Dispatcher::new(dcfg(tag, obs)).run(cfg, &mut t).unwrap();
    out.merged.render()
}

/// The tentpole invariant: tracing on is byte-neutral, for every
/// runnable kernel.
#[test]
fn tracing_is_bit_neutral_for_every_runnable_kernel() {
    let kinds = [
        (SweepKind::DecodeError, 48),
        (SweepKind::GdFinal, 12),
        (SweepKind::Attack, 12),
        (SweepKind::AdvGd, 8),
    ];
    for (kind, trials) in kinds {
        let cfg = sweep_cfg(kind, trials);
        let single = shard::run_full(&cfg, 1).unwrap().render();
        let dark = dispatch_bytes(&cfg, &format!("{kind}_off"), Obs::default());

        let dir = tmp_dir(&format!("{kind}_on"));
        let trace = dir.join("trace.jsonl");
        let obs = Obs::new().with_trace_file(&trace).unwrap();
        let lit = dispatch_bytes(&cfg, &format!("{kind}_on"), obs.clone());

        assert_eq!(dark, single, "{kind}: obs-off dispatch vs single-process");
        assert_eq!(lit, dark, "{kind}: tracing moved the merged bytes");

        // the observation itself happened: recorder + trace both saw
        // the run, bracketed by the dispatch lifecycle events
        let log = obs.flight_log();
        assert!(!log.is_empty(), "{kind}: empty flight recorder");
        assert_eq!(log.first().unwrap().1.kind(), "dispatch-started", "{kind}");
        assert_eq!(log.last().unwrap().1.kind(), "dispatch-done", "{kind}");
        obs.flush();
        let text = std::fs::read_to_string(&trace).unwrap();
        assert!(text.contains("\"ev\": \"lease-issued\""), "{kind}: no leases in trace");
        assert!(text.contains("\"ev\": \"lease-completed\""), "{kind}: no completions");
        assert!(text.contains("\"ev\": \"dispatch-done\""), "{kind}: no terminal event");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Chaos-seeded dispatch with a trace attached: bytes still exact, and
/// the fault plan's decisions land in the trace as `chaos-fault` events
/// (the CI soak's assertion surface).
#[test]
fn chaos_faults_land_in_the_trace_and_stay_bit_neutral() {
    let cfg = sweep_cfg(SweepKind::DecodeError, 96);
    let single = shard::run_full(&cfg, 2).unwrap();

    let dir = tmp_dir("chaos_trace");
    let trace = dir.join("trace.jsonl");
    let obs = Obs::new().with_trace_file(&trace).unwrap();
    let profile = ChaosProfile::parse("kill=0.25,delay=0.45").unwrap();
    let mut t = ChaosTransport::new(LocalProcess::new(gcod_bin(), 3), 1234, profile);
    t.set_obs(obs.clone());
    let mut d = dcfg("chaos_trace", obs.clone());
    d.max_retries = 10;
    let out = Dispatcher::new(d).run(&cfg, &mut t).unwrap();

    assert_eq!(out.merged.render(), single.render(), "{}", out.report.summary());
    assert!(!t.plan.log.is_empty(), "seeded profile never drew a fault");
    obs.flush();
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(
        text.contains("\"ev\": \"chaos-fault\""),
        "fault plan drew {} fault(s) but none reached the trace",
        t.plan.log.len()
    );
    // every live fault event mirrors a fault-plan log line verbatim
    assert!(text.contains(&gcod::bench_util::json_escape(&t.plan.log[0])), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
