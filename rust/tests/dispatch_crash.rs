//! Crash-safety integration suite for the durable coordinator.
//!
//! The headline invariant of `--state-dir`: kill the coordinator with
//! SIGKILL at any point, restart it on the same state dir, and the
//! merged manifest a client eventually fetches is byte-identical to a
//! single-process run of the same sweep. Exercised three ways:
//!
//! * a real `gcod serve` subprocess killed -9 mid-job and restarted,
//!   with in-process `worker_loop`s riding out the outage through their
//!   reconnect backoff, plus an idempotent duplicate submit and a
//!   SIGTERM drain (exit 0) at the end;
//! * an in-process `serve_on` drained mid-job via the cooperative drain
//!   handle, restarted on the same state dir, resuming from the per-job
//!   sweep journal;
//! * idempotency-key dedup and unknown-id fetch rejection.

use gcod::dispatch::{
    fetch_job, query_status, serve_on, submit_job, submit_job_nowait, worker_loop, JobSpec,
    ServeConfig, WorkerOpts,
};
use gcod::obs::{Event, Obs};
use gcod::sweep::shard::{self, SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn gcod_bin() -> &'static str {
    env!("CARGO_BIN_EXE_gcod")
}

fn sweep_cfg(trials: usize) -> SweepConfig {
    SweepConfig {
        sweep: SweepKind::DecodeError,
        scheme: "graph-rr:16,3".into(),
        decoder: "optimal".into(),
        p: 0.2,
        seed: 11,
        trials,
        chunk: 8,
        params: BTreeMap::new(),
    }
}

fn spawn_worker(addr: &str) -> thread::JoinHandle<gcod::error::Result<u64>> {
    let mut opts = WorkerOpts::new(addr, gcod_bin());
    opts.connect_retries = 200;
    thread::spawn(move || worker_loop(&opts))
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("gcod_crash_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn wait_until_up(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        if query_status(addr, Duration::from_secs(2)).is_ok() {
            return;
        }
        assert!(Instant::now() < deadline, "coordinator at {addr} never came up");
        thread::sleep(Duration::from_millis(50));
    }
}

/// Kill -9 a real coordinator subprocess mid-job, restart it on the
/// same state dir, and fetch the result: byte-identical to the
/// single-process run. A duplicate submit with the same idempotency key
/// returns the original job id from the bank, and SIGTERM drains the
/// daemon to a clean exit 0.
#[test]
#[cfg(unix)]
fn sigkill_restart_resumes_byte_identical_and_sigterm_drains() {
    let c = sweep_cfg(400);
    let single = shard::run_full(&c, 2).unwrap();
    let state = temp_dir("sigkill");
    // fixed port: the restarted coordinator must rebind the address the
    // workers keep reconnecting to (SO_REUSEADDR makes this immediate)
    let addr = "127.0.0.1:17917";
    let spawn_serve = || -> Child {
        Command::new(gcod_bin())
            .args([
                "serve",
                "--bind",
                addr,
                "--state-dir",
                state.to_str().unwrap(),
                "--min-workers",
                "2",
                "--poll-ms",
                "2",
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn gcod serve")
    };
    let mut server = spawn_serve();
    wait_until_up(addr);
    let workers = [spawn_worker(addr), spawn_worker(addr)];

    let mut spec = JobSpec::new(c.clone());
    spec.grain = 8;
    spec.max_retries = 10;
    spec.idempotency_key = "crash-suite/sigkill".into();
    let id = submit_job_nowait(addr, spec.clone(), Duration::from_secs(20)).unwrap();

    // let the job get some leases into flight, then murder the
    // coordinator — no goodbye, no fsync beyond what already happened
    thread::sleep(Duration::from_millis(150));
    server.kill().unwrap();
    server.wait().unwrap();

    let mut server = spawn_serve();
    wait_until_up(addr);
    let out = fetch_job(addr, id, Duration::from_secs(180)).unwrap();
    assert_eq!(out.job, id);
    assert_eq!(out.manifest, single.render(), "post-crash manifest != single-process run");

    // idempotent resubmission: same key → the original id and the
    // banked manifest, no re-execution
    let dup = submit_job(addr, spec, Duration::from_secs(30)).unwrap();
    assert_eq!(dup.job, id, "duplicate submit minted a fresh job");
    assert_eq!(dup.manifest, single.render());

    // SIGTERM = drain, not death: exit code 0, workers get goodbyes
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    // SAFETY: signalling a child process this test spawned and owns.
    unsafe {
        assert_eq!(kill(server.id() as i32, 15), 0);
    }
    let status = server.wait().unwrap();
    assert!(status.success(), "SIGTERM drain exited nonzero: {status}");
    for w in workers {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }
    let _ = std::fs::remove_dir_all(&state);
}

/// Cooperative drain mid-job: the dispatcher unwinds into the per-job
/// sweep journal, `serve_on` returns Ok, and a restarted coordinator on
/// the same state dir resumes the job to a byte-identical result.
#[test]
fn drain_mid_job_then_restart_resumes_byte_identical() {
    let c = sweep_cfg(400);
    let single = shard::run_full(&c, 2).unwrap();
    let state = temp_dir("drain");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let drain1 = Arc::new(AtomicBool::new(false));
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 2;
    scfg.poll = Duration::from_millis(2);
    scfg.state_dir = Some(state.clone());
    scfg.drain = Some(drain1.clone());
    let server = thread::spawn(move || serve_on(listener, &scfg));
    let wave1 = [spawn_worker(&addr), spawn_worker(&addr)];

    let mut spec = JobSpec::new(c.clone());
    spec.grain = 8;
    spec.max_retries = 10;
    let id = submit_job_nowait(&addr, spec, Duration::from_secs(20)).unwrap();

    thread::sleep(Duration::from_millis(150));
    drain1.store(true, Ordering::Relaxed);
    server.join().unwrap().expect("drain must exit Ok");
    // drain said goodbye to the fleet — wave 1 exits cleanly
    for w in wave1 {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }

    // restart on the same state dir and address; the recovery replay is
    // visible on the obs handle
    let obs = Obs::new();
    let drain2 = Arc::new(AtomicBool::new(false));
    let listener = {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match TcpListener::bind(&addr) {
                Ok(l) => break l,
                Err(e) => {
                    assert!(Instant::now() < deadline, "could not rebind {addr}: {e}");
                    thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 2;
    scfg.poll = Duration::from_millis(2);
    scfg.state_dir = Some(state.clone());
    scfg.drain = Some(drain2.clone());
    scfg.obs = obs.clone();
    let server = thread::spawn(move || serve_on(listener, &scfg));
    let wave2 = [spawn_worker(&addr), spawn_worker(&addr)];

    let out = fetch_job(&addr, id, Duration::from_secs(180)).unwrap();
    assert_eq!(out.job, id);
    assert_eq!(out.manifest, single.render(), "post-drain manifest != single-process run");
    // the job is in the state journal whether the drain caught it
    // mid-run (re-queued + JobResumed) or already finished (banked), so
    // the restart always announces a recovery
    let recovered = obs
        .flight_log()
        .into_iter()
        .filter(|(_, e)| matches!(e, Event::CoordinatorRecovered { .. }))
        .count();
    assert_eq!(recovered, 1, "restart never replayed the state journal");

    drain2.store(true, Ordering::Relaxed);
    server.join().unwrap().expect("second drain must exit Ok");
    for w in wave2 {
        w.join().unwrap().expect("worker loop should end on goodbye");
    }
    let _ = std::fs::remove_dir_all(&state);
}

/// Idempotency keys dedup entirely in memory too (no state dir): the
/// second submit returns the original id and the banked manifest, with
/// a structured `deduplicated` event and no second execution. Unknown
/// job ids are rejected loudly.
#[test]
fn duplicate_key_returns_original_job_without_rerun() {
    let c = sweep_cfg(32);
    let single = shard::run_full(&c, 1).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let obs = Obs::new();
    let drain = Arc::new(AtomicBool::new(false));
    let mut scfg = ServeConfig::new(addr.clone());
    scfg.min_workers = 1;
    scfg.poll = Duration::from_millis(2);
    scfg.drain = Some(drain.clone());
    scfg.obs = obs.clone();
    let server = thread::spawn(move || serve_on(listener, &scfg));
    let worker = spawn_worker(&addr);

    let mut spec = JobSpec::new(c);
    spec.grain = 8;
    spec.idempotency_key = "crash-suite/dup".into();
    let first = submit_job(&addr, spec.clone(), Duration::from_secs(120)).unwrap();
    assert_eq!(first.manifest, single.render());

    let second = submit_job(&addr, spec, Duration::from_secs(30)).unwrap();
    assert_eq!(second.job, first.job, "duplicate key minted a fresh job");
    assert_eq!(second.manifest, first.manifest);
    let deduped = obs
        .flight_log()
        .into_iter()
        .filter(|(_, e)| matches!(e, Event::ServeJob { state, .. } if state == "deduplicated"))
        .count();
    assert_eq!(deduped, 1, "expected exactly one structured dedup event");
    let ran = obs
        .flight_log()
        .into_iter()
        .filter(|(_, e)| matches!(e, Event::ServeJob { state, .. } if state == "started"))
        .count();
    assert_eq!(ran, 1, "the sweep must execute exactly once");

    let unknown = fetch_job(&addr, 999, Duration::from_secs(10)).unwrap_err();
    assert!(unknown.to_string().contains("unknown job id"), "got: {unknown}");

    drain.store(true, Ordering::Relaxed);
    server.join().unwrap().expect("drain must exit Ok");
    worker.join().unwrap().expect("worker loop should end on goodbye");
}
