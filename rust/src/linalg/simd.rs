//! The raw-speed (**fast**) linalg tier: 8-wide fixed-order kernels
//! with an explicit SIMD path, selected at runtime via [`LinalgBackend`].
//!
//! ## The exact|fast contract
//!
//! The parent module is the **exact** tier: its accumulation orders are
//! the bit-exactness reference that every golden manifest, dispatch
//! audit and merge cross-check is pinned against. This module is the
//! **fast** tier. It buys throughput by *declaring* a different — but
//! still completely fixed — accumulation order:
//!
//! * inner products run **8 independent lanes** over `chunks_exact(8)`
//!   and reduce with the fixed tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` ([`reduce8`]);
//! * remainders (`len % 8`) go through the shared scalar tail helpers
//!   in the parent module, in index order;
//! * no FMA contraction anywhere — every kernel is a sequence of plain
//!   IEEE-754 `mul` then `add`, so the optimizer cannot legally fuse
//!   and change bits.
//!
//! Because the order is fixed, fast results are **deterministic**: the
//! same input produces the same bits on every machine, at every thread
//! count and under every shard split. That is what lets the fast tier
//! flow through the dispatch audit (byte-compares re-executed ranges)
//! and `merge()` (assumes split invariance) unchanged. What fast
//! results are *not* is bit-identical to the exact tier — they agree to
//! roughly `~n * eps` relative error (see [`FAST_REL_TOL`] and the
//! conformance suite) — which is why the backend choice rides in the
//! sweep config and merges refuse to mix tiers.
//!
//! ## The SIMD path
//!
//! The portable 8-wide kernels are always compiled; with the `simd`
//! cargo feature on `x86_64` an AVX2 path is compiled too and selected
//! at runtime via `is_x86_feature_detected!`. The intrinsic kernels
//! perform the **identical IEEE op sequence** as the portable ones
//! (same lanes, same `mul`/`add` pairs, same [`reduce8`] tree, same
//! scalar tails), so portable-fast and intrinsic-fast are bit-identical
//! and runtime CPU detection can never leak into results — a machine
//! without AVX2 produces the same fast-tier bytes as one with it.
//!
//! ## Cache blocking ([`syrk_into_fast`])
//!
//! The SYRK kernel is restructured from the exact tier's row-at-a-time
//! rank-1 updates into a panel form: rows are processed in panels of
//! [`SYRK_PANEL_ROWS`], and for each output strip `G[j][j..]` the
//! 8-wide segments are accumulated in a register block (two 4-lane
//! accumulators living in registers across the whole panel) and flushed
//! to `G` once per panel. The panel bounds the working set (panel rows
//! stream from L2, the current segment's accumulators stay in
//! registers), and the fixed panel size keeps the accumulation order —
//! and therefore the bits — independent of the total row count split.

use crate::error::{Error, Result};

use super::{tail_axpy, tail_dot, Mat};

/// Relative agreement documented between the fast and exact tiers:
/// `|fast - exact| <= FAST_REL_TOL * max(|exact|, 1)` for the shapes
/// the repo's kernels actually hit (dims up to a few thousand). This
/// is a *contract* checked by the conformance suite, not a bound used
/// in any numeric decision.
pub const FAST_REL_TOL: f64 = 1e-10;

/// Row-panel height for the cache-blocked fast SYRK. Fixed (never
/// derived from input size or thread count) so the accumulation order
/// is a pure function of the input matrix.
pub const SYRK_PANEL_ROWS: usize = 64;

// ---------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------

/// Which linalg tier a computation runs on. Rides through
/// `SweepConfig` as the `linalg` param (`exact` | `fast`) and is
/// recorded in every shard manifest; `merge()` refuses to combine
/// shards produced by different backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum LinalgBackend {
    /// The scalar reference tier in [`crate::linalg`]; byte-identical
    /// to every manifest produced before the fast tier existed.
    #[default]
    Exact,
    /// The 8-wide fixed-order tier in this module. Deterministic, but
    /// not bit-identical to `Exact`.
    Fast,
}

impl LinalgBackend {
    /// Parse the `linalg` sweep-param value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "exact" => Ok(LinalgBackend::Exact),
            "fast" => Ok(LinalgBackend::Fast),
            _ => Err(Error::msg(format!("bad linalg backend '{s}' (want exact|fast)"))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            LinalgBackend::Exact => "exact",
            LinalgBackend::Fast => "fast",
        }
    }

    pub fn is_fast(self) -> bool {
        matches!(self, LinalgBackend::Fast)
    }

    /// Backend-dispatched dot product. `Exact` keeps the plain
    /// sequential order of [`super::dot`] (the pinned-bits reference),
    /// `Fast` uses [`dot_fast`].
    #[inline]
    pub fn dot(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            LinalgBackend::Exact => super::dot(a, b),
            LinalgBackend::Fast => dot_fast(a, b),
        }
    }

    /// Backend-dispatched `y = alpha * A x + beta * y` over a packed
    /// row-major slice; see [`super::gemv_slice_into`].
    #[inline]
    pub fn gemv_slice_into(
        self,
        alpha: f64,
        a: &[f64],
        cols: usize,
        x: &[f64],
        beta: f64,
        y: &mut [f64],
    ) {
        match self {
            LinalgBackend::Exact => super::gemv_slice_into(alpha, a, cols, x, beta, y),
            LinalgBackend::Fast => gemv_slice_into_fast(alpha, a, cols, x, beta, y),
        }
    }

    /// Backend-dispatched `G = A^T A`; see [`super::syrk_into`].
    #[inline]
    pub fn syrk_into(self, a: &[f64], cols: usize, g: &mut Mat) {
        match self {
            LinalgBackend::Exact => super::syrk_into(a, cols, g),
            LinalgBackend::Fast => syrk_into_fast(a, cols, g),
        }
    }
}

// ---------------------------------------------------------------------
// The fixed 8-lane reduction
// ---------------------------------------------------------------------

/// The fast tier's one and only horizontal reduction:
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`. Every kernel — portable or
/// intrinsic — funnels its lane accumulators through this tree, which
/// is what makes the two implementations bit-identical.
#[inline(always)]
fn reduce8(acc: &[f64; 8]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

// ---------------------------------------------------------------------
// Portable 8-wide kernels (always compiled; the semantic definition)
// ---------------------------------------------------------------------

#[inline]
fn dot8_portable(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
        acc[4] += xa[4] * xb[4];
        acc[5] += xa[5] * xb[5];
        acc[6] += xa[6] * xb[6];
        acc[7] += xa[7] * xb[7];
    }
    tail_dot(reduce8(&acc), ra, rb)
}

/// One register-blocked SYRK micro-step: accumulate
/// `sum_r panel[r][j] * panel[r][j+off .. j+off+8]` into an 8-lane
/// block, skipping rows with `panel[r][j] == 0.0` (the exact tier's
/// sparsity skip, kept so structured schemes pay for their density,
/// not their dimension).
#[inline]
fn syrk_seg8_portable(panel: &[f64], cols: usize, j: usize, off: usize) -> [f64; 8] {
    let mut acc = [0.0f64; 8];
    for r in panel.chunks_exact(cols) {
        let rj = r[j];
        if rj != 0.0 {
            let src = &r[j + off..j + off + 8];
            acc[0] += rj * src[0];
            acc[1] += rj * src[1];
            acc[2] += rj * src[2];
            acc[3] += rj * src[3];
            acc[4] += rj * src[4];
            acc[5] += rj * src[5];
            acc[6] += rj * src[6];
            acc[7] += rj * src[7];
        }
    }
    acc
}

// ---------------------------------------------------------------------
// AVX2 kernels (`--features simd`, x86_64 only, runtime-detected)
// ---------------------------------------------------------------------
//
// Each intrinsic kernel mirrors its portable twin op for op: the same
// lanes see the same `_mm256_mul_pd` / `_mm256_add_pd` pairs the
// portable code expresses as `acc[l] += x[l] * y[l]`, remainders and
// reductions are shared scalar code, and no FMA intrinsic is used.
// `dispatch_path_is_bit_identical_to_portable_definition` below pins
// the resulting bit-identity on AVX2 hardware.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::{reduce8, tail_dot};

    #[inline]
    pub fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    /// # Safety
    /// Caller must have checked [`available`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot8(a: &[f64], b: &[f64]) -> f64 {
        use std::arch::x86_64::*;
        debug_assert_eq!(a.len(), b.len());
        let n8 = (a.len() / 8) * 8;
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i < n8 {
            let m0 = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)));
            let m1 = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i + 4)), _mm256_loadu_pd(pb.add(i + 4)));
            acc0 = _mm256_add_pd(acc0, m0);
            acc1 = _mm256_add_pd(acc1, m1);
            i += 8;
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        tail_dot(reduce8(&lanes), &a[n8..], &b[n8..])
    }

    /// # Safety
    /// Caller must have checked [`available`]; `j + off + 8 <= cols`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn syrk_seg8(panel: &[f64], cols: usize, j: usize, off: usize) -> [f64; 8] {
        use std::arch::x86_64::*;
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        for r in panel.chunks_exact(cols) {
            let rj = r[j];
            if rj != 0.0 {
                let v = _mm256_set1_pd(rj);
                let s = r.as_ptr().add(j + off);
                acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(v, _mm256_loadu_pd(s)));
                acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(v, _mm256_loadu_pd(s.add(4))));
            }
        }
        let mut out = [0.0f64; 8];
        _mm256_storeu_pd(out.as_mut_ptr(), acc0);
        _mm256_storeu_pd(out.as_mut_ptr().add(4), acc1);
        out
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn use_avx2() -> bool {
    avx2::available()
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn use_avx2() -> bool {
    false
}

// ---------------------------------------------------------------------
// Public fast-tier kernels
// ---------------------------------------------------------------------

/// Fast-tier dot product: 8-lane fixed-order accumulation + the
/// [`reduce8`] tree + the shared scalar tail. Deterministic; agrees
/// with [`super::dot`] to [`FAST_REL_TOL`].
#[inline]
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if use_avx2() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { avx2::dot8(a, b) };
    }
    dot8_portable(a, b)
}

/// Fast-tier `y = alpha * A x + beta * y` over a packed row-major
/// slice. Same signature, asserts and `beta == 0.0` overwrite
/// semantics as [`super::gemv_slice_into`]; only the per-row inner
/// product differs ([`dot_fast`] instead of the 4-wide exact kernel).
pub fn gemv_slice_into_fast(
    alpha: f64,
    a: &[f64],
    cols: usize,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    assert_eq!(x.len(), cols, "x length must equal cols");
    assert!(a.len() == y.len() * cols, "packed slice is not y.len() rows of cols");
    if cols == 0 {
        for yi in y.iter_mut() {
            *yi = if beta == 0.0 { 0.0 } else { beta * *yi };
        }
        return;
    }
    for (row, yi) in a.chunks_exact(cols).zip(y.iter_mut()) {
        let s = alpha * dot_fast(row, x);
        *yi = if beta == 0.0 { s } else { s + beta * *yi };
    }
}

/// Fast-tier `G = A^T A`: the cache-blocked, register-blocked SYRK
/// described in the module docs. Same signature and reset semantics as
/// [`super::syrk_into`]; the accumulation order is panel-major
/// (panels of [`SYRK_PANEL_ROWS`] rows in order, rows within a panel
/// in order, 8-lane register block per output segment) and therefore a
/// pure function of the input — independent of thread count and shard
/// split.
pub fn syrk_into_fast(a: &[f64], cols: usize, g: &mut Mat) {
    syrk_fast_impl(a, cols, g, use_avx2());
}

fn syrk_fast_impl(a: &[f64], cols: usize, g: &mut Mat, avx2: bool) {
    assert!(cols == 0 || a.len() % cols == 0, "packed slice is not a whole number of rows");
    g.reset(cols, cols);
    if cols == 0 {
        return;
    }
    // silence the unused warning on non-simd builds, where `avx2` is
    // statically false
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    let _ = avx2;
    let n8 = |width: usize| (width / 8) * 8;
    for panel in a.chunks(SYRK_PANEL_ROWS * cols) {
        for j in 0..cols {
            let grow = &mut g.data[j * cols + j..(j + 1) * cols];
            let width = cols - j;
            let full = n8(width);
            let mut off = 0;
            while off < full {
                // register-blocked micro-kernel: the 8 accumulators
                // live across the whole panel, G is touched once
                let acc = {
                    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                    {
                        if avx2 {
                            // SAFETY: AVX2 checked by the caller;
                            // j + off + 8 <= j + width == cols.
                            unsafe { avx2::syrk_seg8(panel, cols, j, off) }
                        } else {
                            syrk_seg8_portable(panel, cols, j, off)
                        }
                    }
                    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
                    {
                        syrk_seg8_portable(panel, cols, j, off)
                    }
                };
                for (gd, al) in grow[off..off + 8].iter_mut().zip(&acc) {
                    *gd += al;
                }
                off += 8;
            }
            if off < width {
                // remainder segment (width % 8 lanes), same panel-local
                // accumulation, shared scalar tail semantics
                let mut acc = [0.0f64; 8];
                let rem = width - off;
                for r in panel.chunks_exact(cols) {
                    let rj = r[j];
                    if rj != 0.0 {
                        tail_axpy(rj, &r[j + off..], &mut acc[..rem]);
                    }
                }
                for (gd, al) in grow[off..].iter_mut().zip(&acc[..rem]) {
                    *gd += al;
                }
            }
        }
    }
    // mirror the strict upper triangle, exactly as the exact tier does
    for i in 1..cols {
        for j in 0..i {
            g.data[i * cols + j] = g.data[j * cols + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= FAST_REL_TOL * a.abs().max(b.abs()).max(1.0)
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect()
    }

    #[test]
    fn backend_parse_round_trips() {
        for b in [LinalgBackend::Exact, LinalgBackend::Fast] {
            assert_eq!(LinalgBackend::parse(b.as_str()).unwrap(), b);
        }
        assert_eq!(LinalgBackend::default(), LinalgBackend::Exact);
        assert!(LinalgBackend::Fast.is_fast());
        assert!(!LinalgBackend::Exact.is_fast());
        let err = LinalgBackend::parse("turbo").unwrap_err().to_string();
        assert!(err.contains("exact|fast"), "unhelpful error: {err}");
    }

    #[test]
    fn dot_fast_matches_exact_across_remainders() {
        let mut rng = Rng::new(0x51AD_0001);
        // every remainder class 0..8, plus sizes that cross panel and
        // unroll boundaries
        for n in [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 31, 64, 100, 257] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let exact = super::super::dot(&a, &b);
            let fast = dot_fast(&a, &b);
            assert!(close(exact, fast), "n={n}: exact {exact} vs fast {fast}");
            // backend dispatch agrees with the direct calls, bitwise
            assert_eq!(LinalgBackend::Fast.dot(&a, &b).to_bits(), fast.to_bits());
            assert_eq!(LinalgBackend::Exact.dot(&a, &b).to_bits(), exact.to_bits());
        }
    }

    #[test]
    fn gemv_fast_matches_exact_and_overwrites_on_beta_zero() {
        let mut rng = Rng::new(0x51AD_0002);
        for &(rows, cols) in &[(1usize, 1usize), (3, 5), (8, 8), (7, 13), (16, 33)] {
            let a = rand_vec(&mut rng, rows * cols);
            let x = rand_vec(&mut rng, cols);
            let mut y_exact = vec![f64::NAN; rows];
            let mut y_fast = vec![f64::NAN; rows];
            // beta == 0.0 must overwrite even NaN-poisoned outputs
            super::super::gemv_slice_into(2.5, &a, cols, &x, 0.0, &mut y_exact);
            gemv_slice_into_fast(2.5, &a, cols, &x, 0.0, &mut y_fast);
            for (e, f) in y_exact.iter().zip(&y_fast) {
                assert!(close(*e, *f), "{rows}x{cols}: {e} vs {f}");
            }
            // accumulate form
            let mut z_exact = rand_vec(&mut rng, rows);
            let mut z_fast = z_exact.clone();
            super::super::gemv_slice_into(1.0, &a, cols, &x, -0.5, &mut z_exact);
            gemv_slice_into_fast(1.0, &a, cols, &x, -0.5, &mut z_fast);
            for (e, f) in z_exact.iter().zip(&z_fast) {
                assert!(close(*e, *f), "{rows}x{cols} beta: {e} vs {f}");
            }
        }
    }

    #[test]
    fn gemv_fast_cols_zero_matches_exact() {
        let mut y_exact = vec![1.0, -2.0, 3.0];
        let mut y_fast = y_exact.clone();
        super::super::gemv_slice_into(1.0, &[], 0, &[], 0.5, &mut y_exact);
        gemv_slice_into_fast(1.0, &[], 0, &[], 0.5, &mut y_fast);
        assert_eq!(y_exact, y_fast);
        super::super::gemv_slice_into(1.0, &[], 0, &[], 0.0, &mut y_exact);
        gemv_slice_into_fast(1.0, &[], 0, &[], 0.0, &mut y_fast);
        assert_eq!(y_exact, y_fast);
        assert_eq!(y_fast, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn syrk_fast_matches_exact_across_shapes() {
        let mut rng = Rng::new(0x51AD_0003);
        // shapes spanning remainder widths, panel boundaries and the
        // d/k ranges GramCache actually sees
        for &(rows, cols) in &[
            (1usize, 1usize),
            (4, 3),
            (8, 8),
            (16, 9),
            (63, 17),
            (64, 32),
            (65, 32),
            (130, 48),
        ] {
            let a = rand_vec(&mut rng, rows * cols);
            let mut g_exact = Mat::zeros(cols, cols);
            let mut g_fast = Mat::zeros(cols, cols);
            super::super::syrk_into(&a, cols, &mut g_exact);
            syrk_into_fast(&a, cols, &mut g_fast);
            for (e, f) in g_exact.data.iter().zip(&g_fast.data) {
                assert!(close(*e, *f), "{rows}x{cols}: {e} vs {f}");
            }
            // symmetry survives the blocked path
            for i in 0..cols {
                for j in 0..cols {
                    assert_eq!(
                        g_fast.data[i * cols + j].to_bits(),
                        g_fast.data[j * cols + i].to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn syrk_fast_respects_sparsity_skip_and_zero_cols() {
        // rows with leading zeros exercise the rj == 0.0 skip
        let a = vec![0.0, 1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 5.0];
        let mut g_exact = Mat::zeros(3, 3);
        let mut g_fast = Mat::zeros(3, 3);
        super::super::syrk_into(&a, 3, &mut g_exact);
        syrk_into_fast(&a, 3, &mut g_fast);
        for (e, f) in g_exact.data.iter().zip(&g_fast.data) {
            assert!(close(*e, *f), "{e} vs {f}");
        }
        let mut g = Mat::zeros(5, 5);
        syrk_into_fast(&[], 0, &mut g);
        assert_eq!(g.rows, 0);
        assert_eq!(g.cols, 0);
    }

    #[test]
    fn syrk_fast_is_panel_split_invariant() {
        // crossing the SYRK_PANEL_ROWS boundary must not change the
        // relationship to exact — and the fast result itself is a pure
        // function of the input (same call, same bits)
        let mut rng = Rng::new(0x51AD_0004);
        let (rows, cols) = (SYRK_PANEL_ROWS * 2 + 7, 24);
        let a = rand_vec(&mut rng, rows * cols);
        let mut g1 = Mat::zeros(cols, cols);
        let mut g2 = Mat::zeros(cols, cols);
        syrk_into_fast(&a, cols, &mut g1);
        syrk_into_fast(&a, cols, &mut g2);
        for (x, y) in g1.data.iter().zip(&g2.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dispatch_path_is_bit_identical_to_portable_definition() {
        // Whatever dot_fast/syrk_into_fast select at runtime (AVX2 when
        // the simd feature and hardware allow, portable otherwise) must
        // produce the bits of the portable 8-wide definition — the
        // documented guarantee that CPU detection cannot leak into
        // results.
        let mut rng = Rng::new(0x51AD_0005);
        for n in [3usize, 8, 21, 64, 250] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            assert_eq!(dot_fast(&a, &b).to_bits(), dot8_portable(&a, &b).to_bits());
        }
        let (rows, cols) = (70usize, 19usize);
        let a = rand_vec(&mut rng, rows * cols);
        let mut g_dispatch = Mat::zeros(cols, cols);
        let mut g_portable = Mat::zeros(cols, cols);
        syrk_into_fast(&a, cols, &mut g_dispatch);
        syrk_fast_impl(&a, cols, &mut g_portable, false);
        for (x, y) in g_dispatch.data.iter().zip(&g_portable.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
