//! Power iteration on implicit symmetric operators.
//!
//! Two uses in the reproduction:
//!  * `CovOperator` — the spectral norm of the empirical covariance
//!    |E[(alpha - 1)(alpha - 1)^T]|_2 plotted in Figure 3(b)(d), computed
//!    from centered samples without materializing the n x n matrix;
//!  * graph adjacency spectra (graphs::spectral) via the same trait.

use crate::linalg::{axpy, dot, norm2, scale, Mat};
use crate::prng::Rng;

/// A symmetric linear operator y = M x given implicitly.
pub trait SymmetricOp {
    fn dim(&self) -> usize;
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

impl SymmetricOp for Mat {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols);
        self.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let r = self.mul_vec(x);
        y.copy_from_slice(&r);
    }
}

/// Empirical second-moment operator of centered sample vectors:
/// C x = (1/R) sum_r a_r (a_r . x). Samples are centered by `new`.
pub struct CovOperator {
    /// R x n matrix of centered samples, row-major
    samples: Mat,
}

impl CovOperator {
    /// Build from raw samples (each of length n); subtracts the empirical
    /// mean so `apply` is the covariance, not the second moment.
    pub fn from_samples(raw: &[Vec<f64>]) -> Self {
        assert!(!raw.is_empty());
        let n = raw[0].len();
        let r = raw.len();
        let mut mean = vec![0.0; n];
        for s in raw {
            axpy(1.0, s, &mut mean);
        }
        scale(1.0 / r as f64, &mut mean);
        let mut m = Mat::zeros(r, n);
        for (i, s) in raw.iter().enumerate() {
            for j in 0..n {
                m[(i, j)] = s[j] - mean[j];
            }
        }
        Self { samples: m }
    }

    /// Build from deviation vectors around a *fixed* center (e.g. the
    /// all-ones vector: a_r = alpha_r - 1), no re-centering. This is the
    /// paper's |E (alpha-1)(alpha-1)^T|_2 quantity.
    pub fn from_deviations(devs: &[Vec<f64>]) -> Self {
        assert!(!devs.is_empty());
        let n = devs[0].len();
        let mut m = Mat::zeros(devs.len(), n);
        for (i, s) in devs.iter().enumerate() {
            m.row_mut(i).copy_from_slice(s);
        }
        Self { samples: m }
    }

    pub fn n_samples(&self) -> usize {
        self.samples.rows
    }
}

impl SymmetricOp for CovOperator {
    fn dim(&self) -> usize {
        self.samples.cols
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        // y = (1/R) S^T (S x)
        let sx = self.samples.mul_vec(x);
        let mut out = self.samples.t_mul_vec(&sx);
        scale(1.0 / self.samples.rows as f64, &mut out);
        y.copy_from_slice(&out);
    }
}

/// Largest-|eigenvalue| estimate of a symmetric operator by power
/// iteration with random start; returns (|lambda_max|, eigvec).
pub fn power_iteration<M: SymmetricOp>(
    op: &M,
    iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> (f64, Vec<f64>) {
    let n = op.dim();
    let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let nv = norm2(&v);
    scale(1.0 / nv.max(1e-300), &mut v);
    let mut y = vec![0.0; n];
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        op.apply(&v, &mut y);
        let ny = norm2(&y);
        if ny < 1e-300 {
            return (0.0, v); // operator annihilated the start vector
        }
        let new_lambda = dot(&v, &y);
        let converged = (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-30);
        lambda = new_lambda;
        v.copy_from_slice(&y);
        scale(1.0 / ny, &mut v);
        if converged {
            break;
        }
    }
    (lambda.abs(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_finds_top_eigenvalue() {
        // diag(5, 2, 1) — top eigenvalue 5
        let mut m = Mat::zeros(3, 3);
        m[(0, 0)] = 5.0;
        m[(1, 1)] = 2.0;
        m[(2, 2)] = 1.0;
        let mut rng = Rng::new(0);
        let (l, v) = power_iteration(&m, 500, 1e-12, &mut rng);
        assert!((l - 5.0).abs() < 1e-6, "l={l}");
        assert!(v[0].abs() > 0.999);
    }

    #[test]
    fn power_iteration_handles_negative_dominant() {
        let mut m = Mat::zeros(2, 2);
        m[(0, 0)] = -7.0;
        m[(1, 1)] = 3.0;
        let mut rng = Rng::new(1);
        let (l, _) = power_iteration(&m, 500, 1e-12, &mut rng);
        assert!((l - 7.0).abs() < 1e-6, "l={l}");
    }

    #[test]
    fn cov_operator_matches_dense_covariance() {
        let mut rng = Rng::new(2);
        let n = 6;
        let samples: Vec<Vec<f64>> = (0..40).map(|_| rng.gaussian_vec(n, 1.0)).collect();
        let cov_op = CovOperator::from_samples(&samples);
        // dense covariance
        let mut mean = vec![0.0; n];
        for s in &samples {
            axpy(1.0, s, &mut mean);
        }
        scale(1.0 / samples.len() as f64, &mut mean);
        let mut dense = Mat::zeros(n, n);
        for s in &samples {
            for i in 0..n {
                for j in 0..n {
                    dense[(i, j)] += (s[i] - mean[i]) * (s[j] - mean[j]);
                }
            }
        }
        scale(1.0 / samples.len() as f64, &mut dense.data);
        let x: Vec<f64> = (0..n).map(|i| (i as f64) - 2.5).collect();
        let mut y1 = vec![0.0; n];
        cov_op.apply(&x, &mut y1);
        let y2 = dense.mul_vec(&x);
        for i in 0..n {
            assert!((y1[i] - y2[i]).abs() < 1e-10);
        }
        // spectral norms agree
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let (l1, _) = power_iteration(&cov_op, 1000, 1e-12, &mut r1);
        let (l2, _) = power_iteration(&dense, 1000, 1e-12, &mut r2);
        assert!((l1 - l2).abs() < 1e-8 * l2.max(1.0), "{l1} vs {l2}");
    }

    #[test]
    fn deviations_operator_no_centering() {
        // single deviation vector d -> C = d d^T, norm |d|^2
        let d = vec![1.0, 2.0, 2.0];
        let op = CovOperator::from_deviations(&[d.clone()]);
        let mut rng = Rng::new(4);
        let (l, _) = power_iteration(&op, 500, 1e-12, &mut rng);
        assert!((l - 9.0).abs() < 1e-9, "l={l}");
    }
}
