//! Cholesky factorization + solve for symmetric positive-definite systems.
//!
//! Used for the exact least-squares minimizer theta* = (X^T X)^{-1} X^T Y
//! that every convergence figure measures distance to (paper §VIII-B),
//! and as the small-n oracle the LSQR decoder is property-tested against.

use super::Mat;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CholeskyError {
    NotSquare,
    NotPositiveDefinite { pivot: usize },
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotSquare => write!(f, "matrix is not square"),
            CholeskyError::NotPositiveDefinite { pivot } => {
                write!(f, "matrix is not positive definite (pivot {pivot})")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor L with A = L L^T (in-place style).
pub fn cholesky(a: &Mat) -> Result<Mat, CholeskyError> {
    if a.rows != a.cols {
        return Err(CholeskyError::NotSquare);
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(CholeskyError::NotPositiveDefinite { pivot: i });
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve A x = b for SPD A via Cholesky (forward + backward substitution).
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>, CholeskyError> {
    let l = cholesky(a)?;
    let n = l.rows;
    assert_eq!(b.len(), n);
    // L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    Ok(x)
}

/// Ridge-regularized normal-equation least squares:
/// argmin_x |A x - b|^2 + reg |x|^2 via Cholesky on A^T A + reg I.
pub fn lstsq_normal(a: &Mat, b: &[f64], reg: f64) -> Result<Vec<f64>, CholeskyError> {
    let mut g = a.gram();
    for i in 0..g.rows {
        g[(i, i)] += reg;
    }
    let rhs = a.t_mul_vec(b);
    cholesky_solve(&g, &rhs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2_sq, Mat};

    #[test]
    fn cholesky_of_known_spd() {
        let a = Mat::from_rows(vec![
            vec![4.0, 12.0, -16.0],
            vec![12.0, 37.0, -43.0],
            vec![-16.0, -43.0, 98.0],
        ]);
        let l = cholesky(&a).unwrap();
        // classic textbook factor
        assert_eq!(l[(0, 0)], 2.0);
        assert_eq!(l[(1, 0)], 6.0);
        assert_eq!(l[(1, 1)], 1.0);
        assert_eq!(l[(2, 0)], -8.0);
        assert_eq!(l[(2, 1)], 5.0);
        assert_eq!(l[(2, 2)], 3.0);
    }

    #[test]
    fn solve_recovers_x() {
        let a = Mat::from_rows(vec![
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.mul_vec(&x_true);
        let x = cholesky_solve(&a, &b).unwrap();
        assert!(dist2_sq(&x, &x_true) < 1e-18);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(matches!(
            cholesky(&a),
            Err(CholeskyError::NotPositiveDefinite { .. })
        ));
    }

    #[test]
    fn lstsq_matches_exact_on_overdetermined() {
        // A (4x2), b in col space + noise; compare against direct solve
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ]);
        let b = vec![1.0, 2.9, 5.1, 7.0];
        let x = lstsq_normal(&a, &b, 0.0).unwrap();
        // residual must be orthogonal to the column space
        let r: Vec<f64> = a
            .mul_vec(&x)
            .iter()
            .zip(&b)
            .map(|(ax, bb)| ax - bb)
            .collect();
        let atr = a.t_mul_vec(&r);
        assert!(atr.iter().all(|v| v.abs() < 1e-10), "{atr:?}");
    }
}
