//! Dense linear-algebra substrate.
//!
//! Row-major `f64` matrices plus the vector kernels the decoding-error
//! analysis and the GD engines need. Heavy compute on the request path
//! goes through the PJRT artifacts (runtime/); this module exists for
//! the coordinator-side math the paper does on the parameter server —
//! covariance spectral norms, exact least-squares references, bounds.

pub mod chol;
pub mod power;

pub use chol::{cholesky_solve, CholeskyError};
pub use power::{power_iteration, CovOperator, SymmetricOp};

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(&row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = A^T x
    pub fn t_mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                axpy(xi, self.row(i), &mut y);
            }
        }
        y
    }

    /// C = A^T A (Gram matrix), symmetric (cols x cols).
    pub fn gram(&self) -> Mat {
        let k = self.cols;
        let mut g = Mat::zeros(k, k);
        for i in 0..self.rows {
            let r = self.row(i);
            for a in 0..k {
                let ra = r[a];
                if ra != 0.0 {
                    let grow = g.row_mut(a);
                    for b in 0..k {
                        grow[b] += ra * r[b];
                    }
                }
            }
        }
        g
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// 2-norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// |x - y|_2^2
#[inline]
pub fn dist2_sq(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

/// |x - 1|_2^2 — the paper's decoding error for a coefficient vector.
#[inline]
pub fn dist_to_ones_sq(x: &[f64]) -> f64 {
    x.iter().map(|&v| (v - 1.0) * (v - 1.0)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.mul_vec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.t_mul_vec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn gram_matches_manual() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        assert_eq!(g[(0, 0)], 35.0);
        assert_eq!(g[(0, 1)], 44.0);
        assert_eq!(g[(1, 0)], 44.0);
        assert_eq!(g[(1, 1)], 56.0);
    }

    #[test]
    fn vector_kernels() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(dist_to_ones_sq(&[1.0, 2.0, 0.0]), 2.0);
        assert_eq!(dist2_sq(&[1.0, 2.0], &[0.0, 0.0]), 5.0);
        let mut x = vec![2.0, -4.0];
        scale(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn eye_is_identity_for_mul() {
        let i = Mat::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.mul_vec(&x), x);
    }
}
