//! Dense linear-algebra substrate.
//!
//! Row-major `f64` matrices plus the vector kernels the decoding-error
//! analysis and the GD engines need. Heavy compute on the request path
//! goes through the PJRT artifacts (runtime/); this module exists for
//! the coordinator-side math the paper does on the parameter server —
//! covariance spectral norms, exact least-squares references, bounds —
//! and for the simulated-GD hot loop, which runs entirely on the
//! CPU kernels below.
//!
//! ## Kernel contract ( §Perf)
//!
//! The GD hot path ([`crate::gd::SimulatedGcod::run_with`]) is built on
//! the `*_into` variants here — [`matvec_into`], [`matvec_t_into`],
//! [`gemv_into`]/[`gemv_slice_into`] and the [`syrk_into`] Gram kernel
//! — all of which write caller-owned buffers and allocate nothing.
//! [`matvec_into`]/[`matvec_t_into`] keep the exact accumulation order
//! of the legacy allocating wrappers (which now delegate to them), so
//! swapping a call site to the `_into` form never changes bits.
//! [`gemv_slice_into`] and [`syrk_into`] are the cache-blocked fast
//! path: their inner loops run 4-wide independent accumulators over
//! `chunks_exact(4)` so LLVM autovectorizes the reduction (see
//! [`dot_unrolled`]); they are used by the Gram-cached gradient path
//! ([`crate::gd::GramCache`]), whose outputs are compared against the
//! streaming kernels by tolerance, not bits.
//!
//! ## The raw-speed tier ([`simd`])
//!
//! Everything in this module is the **exact** tier: the accumulation
//! orders above are the bit-exactness reference every sweep manifest is
//! pinned against. The [`simd`] submodule holds the declared-reordering
//! **fast** tier — 8-wide fixed-order kernels ([`simd::dot_fast`],
//! [`simd::gemv_slice_into_fast`], [`simd::syrk_into_fast`]) selected
//! at runtime through [`LinalgBackend`]. Fast results agree with exact
//! to a documented relative tolerance and are themselves fully
//! deterministic (same bits on every machine, thread count and shard
//! split), but they are *not* bit-identical to the exact tier — which
//! is why the choice rides in the sweep config and merges refuse to mix
//! tiers.

pub mod chol;
pub mod power;
pub mod simd;

pub use chol::{cholesky_solve, CholeskyError};
pub use power::{power_iteration, CovOperator, SymmetricOp};
pub use simd::LinalgBackend;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Resize to (rows, cols) and zero-fill. Keeps capacity, so
    /// repeated resets on the same shape never reallocate (the scratch
    /// idiom [`crate::decode::Decoding::reset`] uses).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(&row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x (allocating wrapper around [`matvec_into`])
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        matvec_into(self, x, &mut y);
        y
    }

    /// y = A^T x (allocating wrapper around [`matvec_t_into`])
    pub fn t_mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        matvec_t_into(self, x, &mut y);
        y
    }

    /// C = A^T A (Gram matrix), symmetric (cols x cols). Allocating
    /// wrapper around the [`syrk_into`] kernel.
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        syrk_into(&self.data, self.cols, &mut g);
        g
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

// ---------------------------------------------------------------------
// Shared remainder (tail) handling for the unrolled kernels
// ---------------------------------------------------------------------
//
// Every unrolled kernel — the 4-wide exact-tier kernels here
// ([`dot_unrolled`], [`gemv_slice_into`] via it, [`syrk_into`]) and the
// 8-wide fast tier in [`simd`] — ends with a scalar loop over the 0–3
// (or 0–7) elements `chunks_exact` left behind. The two helpers below
// are the single home of that remainder semantics: a reduction tail
// (fold `a[i]*b[i]` onto a running sum, in index order) and an update
// tail (`dst[i] += alpha * src[i]`, in index order). Both are plain
// sequential loops, so routing an existing kernel's tail through them
// is bit-neutral by construction.

/// Reduction tail: fold the element products of `ra`/`rb` onto `s`
/// in index order. `ra.len() == rb.len()` expected (zip truncates).
#[inline(always)]
pub(crate) fn tail_dot(mut s: f64, ra: &[f64], rb: &[f64]) -> f64 {
    for (xa, xb) in ra.iter().zip(rb) {
        s += xa * xb;
    }
    s
}

/// Update tail: `dst[i] += alpha * src[i]` in index order.
#[inline(always)]
pub(crate) fn tail_axpy(alpha: f64, src: &[f64], dst: &mut [f64]) {
    for (d, x) in dst.iter_mut().zip(src) {
        *d += alpha * x;
    }
}

/// Dot product over four independent accumulators (`chunks_exact(4)`
/// unrolling, so LLVM autovectorizes the reduction). NOTE: the
/// accumulation order differs from [`dot`] — use this in the blocked
/// fast-path kernels ([`gemv_slice_into`], [`syrk_into`]), not as a
/// drop-in for call sites whose bits are pinned.
#[inline]
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    tail_dot((acc[0] + acc[1]) + (acc[2] + acc[3]), ra, rb)
}

/// y = A x, allocation-free. Same accumulation order as
/// [`Mat::mul_vec`] (which delegates here), so results are
/// bit-identical to the allocating path.
pub fn matvec_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.cols);
    assert_eq!(y.len(), a.rows);
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = dot(a.row(i), x);
    }
}

/// y = A^T x, allocation-free. Same accumulation order as
/// [`Mat::t_mul_vec`] (which delegates here).
pub fn matvec_t_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.rows);
    assert_eq!(y.len(), a.cols);
    y.fill(0.0);
    for i in 0..a.rows {
        let xi = x[i];
        if xi != 0.0 {
            axpy(xi, a.row(i), y);
        }
    }
}

/// y = alpha * A x + beta * y (row-major dgemv) on the unrolled dot
/// kernel. `beta == 0.0` overwrites (BLAS semantics: stale `y`
/// contents, including NaN, never propagate).
pub fn gemv_into(alpha: f64, a: &Mat, x: &[f64], beta: f64, y: &mut [f64]) {
    assert_eq!(y.len(), a.rows);
    gemv_slice_into(alpha, &a.data, a.cols, x, beta, y);
}

/// [`gemv_into`] over a packed row-major slice of `y.len()` rows by
/// `cols` columns — block views into a larger buffer (the per-block
/// Gram matrices of [`crate::gd::GramCache`]) avoid a copy into a
/// temporary [`Mat`].
pub fn gemv_slice_into(alpha: f64, a: &[f64], cols: usize, x: &[f64], beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), y.len() * cols);
    assert_eq!(x.len(), cols);
    if cols == 0 {
        for yi in y.iter_mut() {
            *yi = if beta == 0.0 { 0.0 } else { beta * *yi };
        }
        return;
    }
    for (yi, row) in y.iter_mut().zip(a.chunks_exact(cols)) {
        let s = alpha * dot_unrolled(row, x);
        *yi = if beta == 0.0 { s } else { s + beta * *yi };
    }
}

/// G = A^T A for a packed row-major slice of `a.len() / cols` rows —
/// the SYRK kernel behind [`Mat::gram`] and the per-block Gram caches.
/// Accumulates the upper triangle by rank-1 row updates whose inner
/// loop is 4-wide unrolled via `chunks_exact` (independent elementwise
/// FMAs, so unrolling does not change the per-entry accumulation
/// order), then mirrors. `g` is reset to (cols x cols) and overwritten.
pub fn syrk_into(a: &[f64], cols: usize, g: &mut Mat) {
    assert!(cols == 0 || a.len() % cols == 0, "packed slice is not a whole number of rows");
    g.reset(cols, cols);
    if cols == 0 {
        return;
    }
    for r in a.chunks_exact(cols) {
        for j in 0..cols {
            let rj = r[j];
            if rj != 0.0 {
                // g[j][j..] += rj * r[j..]
                let grow = &mut g.data[j * cols + j..(j + 1) * cols];
                let src = &r[j..];
                let gc = grow.chunks_exact_mut(4);
                let sc = src.chunks_exact(4);
                let tail = gc.len() * 4;
                for (gd, sd) in gc.zip(sc) {
                    gd[0] += rj * sd[0];
                    gd[1] += rj * sd[1];
                    gd[2] += rj * sd[2];
                    gd[3] += rj * sd[3];
                }
                tail_axpy(rj, &src[tail..], &mut grow[tail..]);
            }
        }
    }
    // mirror the strict upper triangle
    for i in 0..cols {
        for j in i + 1..cols {
            g.data[j * cols + i] = g.data[i * cols + j];
        }
    }
}

/// 2-norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// |x - y|_2^2
#[inline]
pub fn dist2_sq(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

/// |x - 1|_2^2 — the paper's decoding error for a coefficient vector.
#[inline]
pub fn dist_to_ones_sq(x: &[f64]) -> f64 {
    x.iter().map(|&v| (v - 1.0) * (v - 1.0)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.mul_vec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.t_mul_vec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn gram_matches_manual() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        assert_eq!(g[(0, 0)], 35.0);
        assert_eq!(g[(0, 1)], 44.0);
        assert_eq!(g[(1, 0)], 44.0);
        assert_eq!(g[(1, 1)], 56.0);
    }

    #[test]
    fn vector_kernels() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(dist_to_ones_sq(&[1.0, 2.0, 0.0]), 2.0);
        assert_eq!(dist2_sq(&[1.0, 2.0], &[0.0, 0.0]), 5.0);
        let mut x = vec![2.0, -4.0];
        scale(0.5, &mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn eye_is_identity_for_mul() {
        let i = Mat::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.mul_vec(&x), x);
    }

    #[test]
    fn into_kernels_match_allocating_paths_bitwise() {
        let mut rng = crate::prng::Rng::new(7);
        for (r, c) in [(1usize, 1usize), (3, 5), (8, 8), (17, 6), (5, 19)] {
            let a = Mat { rows: r, cols: c, data: rng.gaussian_vec(r * c, 1.0) };
            let x = rng.gaussian_vec(c, 1.0);
            let xt = rng.gaussian_vec(r, 1.0);
            let mut y = vec![f64::NAN; r];
            matvec_into(&a, &x, &mut y);
            for (u, v) in y.iter().zip(a.mul_vec(&x)) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
            let mut yt = vec![f64::NAN; c];
            matvec_t_into(&a, &xt, &mut yt);
            for (u, v) in yt.iter().zip(a.t_mul_vec(&xt)) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn dot_unrolled_matches_dot_to_tolerance() {
        let mut rng = crate::prng::Rng::new(3);
        for n in [0usize, 1, 3, 4, 7, 8, 33, 100] {
            let a = rng.gaussian_vec(n, 1.0);
            let b = rng.gaussian_vec(n, 1.0);
            let (s, u) = (dot(&a, &b), dot_unrolled(&a, &b));
            assert!((s - u).abs() <= 1e-12 * (1.0 + s.abs()), "n={n}: {s} vs {u}");
        }
    }

    #[test]
    fn gemv_semantics_and_beta_zero_overwrites() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let x = vec![1.0, -1.0];
        // beta = 0 overwrites even NaN-poisoned output
        let mut y = vec![f64::NAN; 3];
        gemv_into(2.0, &a, &x, 0.0, &mut y);
        assert_eq!(y, vec![-2.0, -2.0, -2.0]);
        // beta != 0 blends
        gemv_into(1.0, &a, &x, 0.5, &mut y);
        assert_eq!(y, vec![-2.0, -2.0, -2.0]);
        // zero-width matrix scales y only
        let e = Mat::zeros(2, 0);
        let mut z = vec![3.0, f64::NAN];
        gemv_slice_into(1.0, &e.data, 0, &[], 0.0, &mut z);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn syrk_matches_transpose_product() {
        let mut rng = crate::prng::Rng::new(11);
        for (r, c) in [(1usize, 1usize), (6, 4), (9, 7), (4, 12)] {
            let a = Mat { rows: r, cols: c, data: rng.gaussian_vec(r * c, 1.0) };
            let g = a.gram();
            let want = {
                let t = a.transpose();
                let mut w = Mat::zeros(c, c);
                for i in 0..c {
                    for j in 0..c {
                        w[(i, j)] = dot(t.row(i), t.row(j));
                    }
                }
                w
            };
            for i in 0..c {
                for j in 0..c {
                    let (x, y) = (g[(i, j)], want[(i, j)]);
                    assert!((x - y).abs() <= 1e-10 * (1.0 + y.abs()), "({i},{j}): {x} vs {y}");
                    // symmetry is exact by construction
                    assert_eq!(g[(i, j)].to_bits(), g[(j, i)].to_bits());
                }
            }
        }
    }

    #[test]
    fn mat_reset_keeps_capacity() {
        let mut m = Mat::zeros(4, 4);
        m.data[5] = 7.0;
        m.reset(2, 3);
        assert_eq!((m.rows, m.cols), (2, 3));
        assert!(m.data.iter().all(|&v| v == 0.0));
        // shrinking keeps the old capacity: growing back is alloc-free
        assert!(m.data.capacity() >= 16);
        m.reset(4, 4);
        assert!(m.data.iter().all(|&v| v == 0.0));
    }
}
