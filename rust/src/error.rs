//! Crate-local error substrate (no `anyhow` in the offline build).
//!
//! The coordinator and CLI previously pulled in `anyhow` for ad-hoc
//! errors; only the PJRT runtime (feature `pjrt`, which ships its own
//! vendored dependencies) still does. Everything on the default build
//! uses this message-carrying error, which is cheap, `Send + Sync`, and
//! formats identically under `{e}` and `{e:#}`.

use std::fmt;

/// A human-readable error message, optionally wrapping a chain of
/// context strings (outermost first, like `anyhow`).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Self { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context("...")` / `.with_context(|| ...)` on any displayable error.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        let r: std::result::Result<(), Error> = Err(Error::msg("inner"));
        let c = r.context("outer").unwrap_err();
        assert_eq!(format!("{c}"), "outer: inner");
    }

    #[test]
    fn conversions() {
        let _: Error = "s".into();
        let _: Error = String::from("s").into();
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }
}
