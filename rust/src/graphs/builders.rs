//! Graph builders: the assignment-scheme families used in the paper's
//! experiments plus structured graphs for tests.

use super::Graph;
use crate::prng::Rng;

/// Cycle C_n (2-regular, bipartite iff n even).
pub fn cycle_graph(n: usize) -> Graph {
    assert!(n >= 3);
    let edges = (0..n).map(|i| (i, (i + 1) % n)).collect();
    Graph::new(n, edges)
}

/// Complete graph K_n ((n-1)-regular, the best possible expander).
pub fn complete_graph(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u, v));
        }
    }
    Graph::new(n, edges)
}

/// Hypercube Q_dim (dim-regular, bipartite, spectral gap 2).
pub fn hypercube_graph(dim: usize) -> Graph {
    let n = 1usize << dim;
    let mut edges = Vec::with_capacity(n * dim / 2);
    for u in 0..n {
        for b in 0..dim {
            let v = u ^ (1 << b);
            if u < v {
                edges.push((u, v));
            }
        }
    }
    Graph::new(n, edges)
}

/// Random simple d-regular graph via the configuration (pairing) model
/// with rejection of self-loops and parallel edges. This is the paper's
/// regime-1 assignment A_1: "a random 3-regular graph on n=16 vertices
/// with m=24 edges" (Section VIII), which is w.h.p. a good expander.
pub fn random_regular_graph(n: usize, d: usize, rng: &mut Rng) -> Graph {
    assert!(n * d % 2 == 0, "n*d must be even");
    assert!(d < n, "need d < n for a simple graph");
    // fast path: full rejection (succeeds w.p. ~ e^{-(d^2-1)/4} per try,
    // fine for small d*n; hopeless to rely on alone at n ~ 10^4)
    'outer: for _attempt in 0..200 {
        let mut stubs: Vec<usize> = (0..n).flat_map(|v| std::iter::repeat(v).take(d)).collect();
        rng.shuffle(&mut stubs);
        let mut seen = std::collections::HashSet::new();
        let mut edges = Vec::with_capacity(n * d / 2);
        for pair in stubs.chunks(2) {
            let (u, v) = (pair[0], pair[1]);
            if u == v {
                continue 'outer; // self-loop -> reject and resample
            }
            let key = (u.min(v), u.max(v));
            if !seen.insert(key) {
                continue 'outer; // parallel edge -> reject
            }
            edges.push((u, v));
        }
        return Graph::new(n, edges);
    }
    // repair path: pair stubs once, then fix conflicts by double-edge
    // swaps with already-accepted edges (degree-preserving; the
    // standard way to realize the configuration model at scale)
    'restart: loop {
        let mut stubs: Vec<usize> = (0..n).flat_map(|v| std::iter::repeat(v).take(d)).collect();
        rng.shuffle(&mut stubs);
        let mut seen = std::collections::HashSet::new();
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * d / 2);
        let mut pending: Vec<(usize, usize)> = Vec::new();
        let key = |u: usize, v: usize| (u.min(v), u.max(v));
        for pair in stubs.chunks(2) {
            let (u, v) = (pair[0], pair[1]);
            if u != v && seen.insert(key(u, v)) {
                edges.push((u, v));
            } else {
                pending.push((u, v));
            }
        }
        for (u, v) in pending {
            // replace a random accepted edge (a,b) with (u,a) and (v,b)
            let mut fixed = false;
            for _try in 0..10_000 {
                let idx = rng.below(edges.len());
                let (a, b) = edges[idx];
                if u == a || v == b {
                    continue;
                }
                if seen.contains(&key(u, a)) || seen.contains(&key(v, b)) || key(u, a) == key(v, b)
                {
                    continue;
                }
                seen.remove(&key(a, b));
                seen.insert(key(u, a));
                seen.insert(key(v, b));
                edges[idx] = (u, a);
                edges.push((v, b));
                fixed = true;
                break;
            }
            if !fixed {
                continue 'restart; // pathological; resample everything
            }
        }
        return Graph::new(n, edges);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_properties() {
        let g = cycle_graph(5);
        assert_eq!(g.is_regular(), Some(2));
        assert!(g.is_connected());
        let a = super::super::components::analyze_components(&g, &vec![true; 5]);
        assert!(!a.components[0].is_bipartite()); // odd cycle
        let g6 = cycle_graph(6);
        let a6 = super::super::components::analyze_components(&g6, &vec![true; 6]);
        assert!(a6.components[0].is_bipartite());
    }

    #[test]
    fn complete_graph_properties() {
        let g = complete_graph(6);
        assert_eq!(g.m(), 15);
        assert_eq!(g.is_regular(), Some(5));
        assert!(!g.has_parallel_edges());
    }

    #[test]
    fn hypercube_properties() {
        let g = hypercube_graph(4);
        assert_eq!(g.n, 16);
        assert_eq!(g.is_regular(), Some(4));
        assert!(g.is_connected());
        // bipartite by parity
        let a = super::super::components::analyze_components(&g, &vec![true; g.m()]);
        assert!(a.components[0].is_bipartite());
    }

    #[test]
    fn random_regular_is_simple_regular_connected() {
        let mut rng = crate::prng::Rng::new(0xA5);
        for &(n, d) in &[(16usize, 3usize), (20, 4), (30, 6)] {
            let g = random_regular_graph(n, d, &mut rng);
            assert_eq!(g.is_regular(), Some(d), "n={n} d={d}");
            assert!(!g.has_parallel_edges());
            assert_eq!(g.m(), n * d / 2);
            // 3-regular random graphs on >= 16 vertices are connected whp;
            // assert connectivity for the seeds we actually use
            assert!(g.is_connected(), "n={n} d={d} disconnected");
        }
    }

    #[test]
    fn random_regular_paper_regime1_shape() {
        // the paper's A_1: n=16, d=3 -> m=24 machines
        let mut rng = crate::prng::Rng::new(1);
        let g = random_regular_graph(16, 3, &mut rng);
        assert_eq!(g.m(), 24);
        assert!((g.replication_factor() - 3.0).abs() < 1e-12);
    }
}
