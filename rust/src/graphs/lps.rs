//! Lubotzky–Phillips–Sarnak Ramanujan graphs X^{p,q} [LPS 1986].
//!
//! The paper's regime-2 assignment A_2 is "the degree 6 LPS expander on
//! n = 2184 vertices with 6552 edges" (Section VIII) — that is X^{5,13}:
//! the Cayley graph of PGL_2(F_13) (|PGL_2(13)| = 13·168 = 2184) with
//! p+1 = 6 generators, one per integer quaternion (a,b,c,d) with
//! a^2+b^2+c^2+d^2 = p, a odd and positive. Each quaternion maps to the
//! matrix  [[a + b·i, c + d·i], [-c + d·i, a - b·i]] mod q,  where
//! i^2 ≡ -1 (mod q). Because (5/13) = -1 the graph is bipartite; it is
//! 6-regular, vertex-transitive (Cayley), and Ramanujan:
//! lambda_2 <= 2*sqrt(p).

use super::Graph;
use std::collections::HashMap;

/// Modular exponentiation.
fn pow_mod(mut b: u64, mut e: u64, q: u64) -> u64 {
    let mut r = 1u64;
    b %= q;
    while e > 0 {
        if e & 1 == 1 {
            r = r * b % q;
        }
        b = b * b % q;
        e >>= 1;
    }
    r
}

/// Inverse mod prime q (Fermat).
fn inv_mod(a: u64, q: u64) -> u64 {
    assert!(a % q != 0);
    pow_mod(a, q - 2, q)
}

/// A square root of -1 mod q (requires q ≡ 1 mod 4).
fn sqrt_minus_one(q: u64) -> u64 {
    assert!(q % 4 == 1, "need q ≡ 1 (mod 4)");
    for x in 2..q {
        if x * x % q == q - 1 {
            return x;
        }
    }
    unreachable!("no sqrt(-1) mod {q}")
}

/// Legendre symbol (a/q) for odd prime q: 1, q-1 (=-1), or 0.
pub fn legendre(a: u64, q: u64) -> u64 {
    pow_mod(a % q, (q - 1) / 2, q)
}

/// 2x2 matrix over F_q in projective canonical form: scaled so the
/// first non-zero entry (scanning a,b,c,d) is 1.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct PglElt {
    a: u64,
    b: u64,
    c: u64,
    d: u64,
}

fn canon(a: u64, b: u64, c: u64, d: u64, q: u64) -> PglElt {
    let first = [a, b, c, d].into_iter().find(|&x| x % q != 0).expect("zero matrix");
    let s = inv_mod(first, q);
    PglElt { a: a * s % q, b: b * s % q, c: c * s % q, d: d * s % q }
}

fn mat_mul(x: PglElt, y: PglElt, q: u64) -> PglElt {
    canon(
        x.a * y.a % q + x.b * y.c % q,
        x.a * y.b % q + x.b * y.d % q,
        x.c * y.a % q + x.d * y.c % q,
        x.c * y.b % q + x.d * y.d % q,
        q,
    )
}

/// All integer quaternion solutions a^2+b^2+c^2+d^2 = p with a odd > 0
/// (for p ≡ 1 mod 4 there are exactly p+1 of them).
fn quaternion_generators(p: i64) -> Vec<(i64, i64, i64, i64)> {
    let mut gens = Vec::new();
    let bound = (p as f64).sqrt() as i64 + 1;
    for a in (1..=bound).step_by(2) {
        for b in -bound..=bound {
            for c in -bound..=bound {
                for d in -bound..=bound {
                    if a * a + b * b + c * c + d * d == p {
                        gens.push((a, b, c, d));
                    }
                }
            }
        }
    }
    gens
}

fn to_fq(x: i64, q: u64) -> u64 {
    x.rem_euclid(q as i64) as u64
}

/// Construct the LPS graph X^{p,q}. Requirements: p, q distinct primes,
/// p ≡ q ≡ 1 (mod 4), q > 2*sqrt(p). When (p/q) = -1 the graph is the
/// bipartite Cayley graph of PGL_2(F_q) with n = q(q^2-1) vertices;
/// when (p/q) = 1 it is the Cayley graph of PSL_2(F_q) with
/// n = q(q^2-1)/2 vertices. Degree is p+1 in both cases.
pub fn lps_graph(p: u64, q: u64) -> Graph {
    assert!(p % 4 == 1 && q % 4 == 1, "need p ≡ q ≡ 1 (mod 4)");
    assert_ne!(p, q);
    let i = sqrt_minus_one(q);
    let nonresidue = legendre(p, q) == q - 1;

    // generator matrices
    let quats = quaternion_generators(p as i64);
    assert_eq!(quats.len(), (p + 1) as usize, "expected p+1 quaternion generators");
    let gens: Vec<PglElt> = quats
        .iter()
        .map(|&(a, b, c, d)| {
            // [[a + b i, c + d i], [-c + d i, a - b i]]
            canon(
                (to_fq(a, q) + to_fq(b, q) * i) % q,
                (to_fq(c, q) + to_fq(d, q) * i) % q,
                (to_fq(-c, q) + to_fq(d, q) * i) % q,
                (to_fq(a, q) + (q - 1) * (to_fq(b, q) * i % q)) % q,
                q,
            )
        })
        .collect();

    // enumerate the vertex group: PGL_2(F_q) in full, or its index-2
    // subgroup PSL_2 (matrices whose det is a square) when (p/q)=1.
    let is_square: Vec<bool> = {
        let mut sq = vec![false; q as usize];
        for x in 1..q {
            sq[(x * x % q) as usize] = true;
        }
        sq
    };
    let mut index: HashMap<PglElt, usize> = HashMap::new();
    let mut elems: Vec<PglElt> = Vec::new();
    for a in 0..q {
        for b in 0..q {
            for c in 0..q {
                for d in 0..q {
                    let det = (a * d % q + q * q - b * c % q) % q;
                    if det == 0 {
                        continue;
                    }
                    if !nonresidue {
                        // PSL_2: determinant must be a QR (canonical-form
                        // scaling multiplies det by a square, so this is
                        // well defined on projective classes)
                        if !is_square[det as usize] {
                            continue;
                        }
                    }
                    let e = canon(a, b, c, d, q);
                    if e == (PglElt { a, b, c, d }) {
                        // only count canonical representatives once
                        let id = elems.len();
                        index.insert(e, id);
                        elems.push(e);
                    }
                }
            }
        }
    }
    let n = elems.len();
    let expected = if nonresidue {
        (q * (q * q - 1)) as usize
    } else {
        (q * (q * q - 1) / 2) as usize
    };
    assert_eq!(n, expected, "group enumeration size mismatch");

    // Cayley edges x -- x*g (generator set closed under inverse, so each
    // undirected edge is produced twice; dedupe by ordered pair)
    let mut edges = Vec::with_capacity(n * (p as usize + 1) / 2);
    for (xid, &x) in elems.iter().enumerate() {
        for &g in &gens {
            let y = mat_mul(x, g, q);
            let yid = *index.get(&y).expect("closed under generators");
            assert_ne!(yid, xid, "generator fixed a vertex (unexpected for LPS)");
            if xid < yid {
                edges.push((xid, yid));
            }
        }
    }
    let g = Graph::new(n, edges);
    assert_eq!(g.is_regular(), Some((p + 1) as usize), "LPS graph must be (p+1)-regular");
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modular_helpers() {
        assert_eq!(pow_mod(5, 6, 13), 12); // (5/13) = -1
        assert_eq!(inv_mod(5, 13) * 5 % 13, 1);
        let i = sqrt_minus_one(13);
        assert_eq!(i * i % 13, 12);
        assert_eq!(legendre(5, 13), 12);
        assert_eq!(legendre(3, 13), 1);
    }

    #[test]
    fn quaternions_for_p5() {
        let qs = quaternion_generators(5);
        assert_eq!(qs.len(), 6);
        for &(a, b, c, d) in &qs {
            assert_eq!(a * a + b * b + c * c + d * d, 5);
            assert_eq!(a % 2, 1);
            assert!(a > 0);
        }
    }

    #[test]
    fn lps_5_13_is_the_papers_graph() {
        let g = lps_graph(5, 13);
        // the paper: n = 2184 vertices, m = 6552 machines, d = 6
        assert_eq!(g.n, 2184);
        assert_eq!(g.m(), 6552);
        assert_eq!(g.is_regular(), Some(6));
        assert!(g.is_connected());
        assert!(!g.has_parallel_edges());
        // (5/13) = -1 -> bipartite Cayley graph of PGL_2(13)
        let alive = vec![true; g.m()];
        let a = super::super::components::analyze_components(&g, &alive);
        assert_eq!(a.components.len(), 1);
        assert!(a.components[0].is_bipartite());
        let (s0, s1) = a.components[0].sides.as_ref().unwrap();
        assert_eq!(s0.len(), 1092);
        assert_eq!(s1.len(), 1092);
    }

    #[test]
    fn lps_5_17_nonbipartite_psl() {
        // (5/17): 5^8 mod 17 = 390625 mod 17 = 16^2... compute: legendre
        if legendre(5, 17) == 1 {
            let g = lps_graph(5, 17);
            assert_eq!(g.n, (17 * (17 * 17 - 1) / 2) as usize); // 2448
            assert_eq!(g.is_regular(), Some(6));
            assert!(g.is_connected());
            let alive = vec![true; g.m()];
            let a = super::super::components::analyze_components(&g, &alive);
            assert!(!a.components[0].is_bipartite());
        } else {
            let g = lps_graph(5, 17);
            assert_eq!(g.n, (17 * (17 * 17 - 1)) as usize);
        }
    }
}
