//! Graph substrate for graph assignment schemes (paper Definition II.2).
//!
//! Data blocks are **vertices**, machines are **edges** (each machine
//! holds the two blocks at its endpoints — Remark II.3: this is *not*
//! the bipartite blocks-vs-machines graph other codes use). Everything
//! the optimal decoder needs reduces to connected-component analysis of
//! the straggler-sparsified graph G(p), and everything the error bounds
//! need reduces to the spectral expansion.

pub mod builders;
pub mod components;
pub mod lps;
pub mod spectral;

pub use builders::{complete_graph, cycle_graph, hypercube_graph, random_regular_graph};
pub use components::{analyze_components, Component, ComponentAnalysis};
pub use lps::lps_graph;

/// Undirected (multi)graph with indexed edges.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// edge list; edge id = index. Self-loops are not allowed.
    pub edges: Vec<(usize, usize)>,
    /// adjacency: for each vertex, (neighbor, edge id)
    pub adj: Vec<Vec<(usize, usize)>>,
}

impl Graph {
    pub fn new(n: usize, edges: Vec<(usize, usize)>) -> Self {
        let mut adj = vec![Vec::new(); n];
        for (id, &(u, v)) in edges.iter().enumerate() {
            assert!(u < n && v < n, "edge ({u},{v}) out of range");
            assert_ne!(u, v, "self-loops not allowed (edge {id})");
            adj[u].push((v, id));
            adj[v].push((u, id));
        }
        Self { n, edges, adj }
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Replication factor d = 2m/n (paper Table II).
    pub fn replication_factor(&self) -> f64 {
        2.0 * self.m() as f64 / self.n as f64
    }

    pub fn is_regular(&self) -> Option<usize> {
        let d = self.degree(0);
        (0..self.n).all(|v| self.degree(v) == d).then_some(d)
    }

    /// True if some pair of vertices has more than one edge between them.
    pub fn has_parallel_edges(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        for &(u, v) in &self.edges {
            let key = (u.min(v), u.max(v));
            if !seen.insert(key) {
                return true;
            }
        }
        false
    }

    /// Connectivity over all edges.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let alive = vec![true; self.m()];
        let analysis = analyze_components(self, &alive);
        analysis.components.len() == 1
    }

    /// The n x m block-to-machine assignment matrix A (Definition II.2):
    /// A_ij = 1 iff edge j has endpoint i. Exactly two 1s per column.
    pub fn assignment_matrix(&self) -> crate::sparse::Csc {
        let mut t = Vec::with_capacity(2 * self.m());
        for (j, &(u, v)) in self.edges.iter().enumerate() {
            t.push((u, j, 1.0));
            t.push((v, j, 1.0));
        }
        crate::sparse::Csc::from_triplets(self.n, self.m(), t)
    }

    /// Edge boundary size |∂(S)| — used by expander-mixing sanity tests.
    pub fn boundary_size(&self, in_s: &[bool]) -> usize {
        self.edges
            .iter()
            .filter(|&&(u, v)| in_s[u] != in_s[v])
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
        assert_eq!(g.m(), 4);
        assert_eq!(g.is_regular(), Some(2));
        assert!((g.replication_factor() - 2.0).abs() < 1e-12);
        assert!(g.is_connected());
        assert!(!g.has_parallel_edges());
    }

    #[test]
    fn assignment_matrix_two_ones_per_column() {
        let g = Graph::new(3, vec![(0, 1), (1, 2), (0, 2)]);
        let a = g.assignment_matrix();
        assert_eq!(a.rows, 3);
        assert_eq!(a.cols, 3);
        for j in 0..3 {
            let (ri, vals) = a.col(j);
            assert_eq!(ri.len(), 2);
            assert!(vals.iter().all(|&v| v == 1.0));
        }
        // row sums = degree = 2
        let ones = vec![1.0; 3];
        assert_eq!(a.mul_vec(&ones), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::new(4, vec![(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn boundary_size_cut() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
        assert_eq!(g.boundary_size(&[true, true, false, false]), 2);
        assert_eq!(g.boundary_size(&[true, false, true, false]), 4);
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        Graph::new(2, vec![(0, 0)]);
    }
}
