//! Connected-component analysis of the straggler-sparsified graph G(p).
//!
//! This implements the combinatorial core of Section III: given the set
//! of surviving machines (edges), BFS splits G(p) into components and
//! 2-colors each one. The optimal alpha* is then determined per
//! component (observations 1–3 after Eq. 4):
//!   * non-bipartite (odd cycle)  -> alpha*_v = 1 everywhere;
//!   * bipartite with sides L, R (|L| >= |R|) ->
//!       alpha*_v = 1 - (|L|-|R|)/(|L|+|R|)  for v in L,
//!       alpha*_u = 1 + (|L|-|R|)/(|L|+|R|)  for u in R;
//!   * isolated vertex (all incident machines straggle) -> alpha*_v = 0.

use super::Graph;

/// One connected component of the surviving subgraph.
#[derive(Clone, Debug)]
pub struct Component {
    pub vertices: Vec<usize>,
    /// surviving edge ids inside the component
    pub edges: Vec<usize>,
    /// None if the component contains an odd cycle; otherwise the two
    /// sides (side0, side1) of the 2-coloring with side0 = color of the
    /// BFS root.
    pub sides: Option<(Vec<usize>, Vec<usize>)>,
}

impl Component {
    pub fn is_bipartite(&self) -> bool {
        self.sides.is_some()
    }

    pub fn size(&self) -> usize {
        self.vertices.len()
    }

    /// The component's contribution to alpha* (value for each side).
    /// Returns (value on side0, value on side1); for non-bipartite
    /// components both are 1.
    pub fn alpha_values(&self) -> (f64, f64) {
        match &self.sides {
            None => (1.0, 1.0),
            Some((s0, s1)) => {
                let (l, r) = (s0.len() as f64, s1.len() as f64);
                // alpha on a side is 2*|other side| / (|L|+|R|):
                // for the larger side this is 1 - imbalance, for the
                // smaller side 1 + imbalance. An isolated vertex has
                // (l, r) = (1, 0) -> alpha = 0.
                (2.0 * r / (l + r), 2.0 * l / (l + r))
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct ComponentAnalysis {
    pub components: Vec<Component>,
    /// component id of each vertex
    pub comp_of: Vec<usize>,
    /// color (0/1) of each vertex in its BFS 2-coloring attempt; for
    /// non-bipartite components this is still the BFS coloring (used by
    /// the w* solver to find an odd non-tree edge).
    pub color: Vec<u8>,
}

/// BFS over surviving edges only. O(n + m_alive).
pub fn analyze_components(g: &Graph, edge_alive: &[bool]) -> ComponentAnalysis {
    assert_eq!(edge_alive.len(), g.m());
    let n = g.n;
    let mut comp_of = vec![usize::MAX; n];
    let mut color = vec![0u8; n];
    let mut components = Vec::new();
    let mut queue = std::collections::VecDeque::new();

    for root in 0..n {
        if comp_of[root] != usize::MAX {
            continue;
        }
        let cid = components.len();
        comp_of[root] = cid;
        color[root] = 0;
        queue.push_back(root);
        let mut vertices = vec![root];
        let mut edges = Vec::new();
        let mut bipartite = true;
        while let Some(u) = queue.pop_front() {
            for &(v, eid) in &g.adj[u] {
                if !edge_alive[eid] {
                    continue;
                }
                if comp_of[v] == usize::MAX {
                    comp_of[v] = cid;
                    color[v] = 1 - color[u];
                    vertices.push(v);
                    queue.push_back(v);
                    edges.push(eid);
                } else {
                    // count each edge once (from its lower-id endpoint visit);
                    // use the edge orientation to dedupe
                    let (eu, _ev) = g.edges[eid];
                    if eu == u && g.edges[eid].1 != u {
                        edges.push(eid);
                    } else if g.edges[eid].0 == g.edges[eid].1 {
                        unreachable!("self-loops rejected at construction");
                    }
                    if color[v] == color[u] {
                        bipartite = false;
                    }
                }
            }
        }
        // dedupe edges (tree edges pushed once; non-tree edges may be
        // pushed from both endpoints' scans)
        edges.sort_unstable();
        edges.dedup();
        let sides = if bipartite {
            let mut s0 = Vec::new();
            let mut s1 = Vec::new();
            for &v in &vertices {
                if color[v] == 0 {
                    s0.push(v);
                } else {
                    s1.push(v);
                }
            }
            Some((s0, s1))
        } else {
            None
        };
        components.push(Component { vertices, edges, sides });
    }
    ComponentAnalysis { components, comp_of, color }
}

/// The optimal alpha* vector for a surviving-edge pattern (Section III).
pub fn optimal_alpha(g: &Graph, edge_alive: &[bool]) -> Vec<f64> {
    let analysis = analyze_components(g, edge_alive);
    alpha_from_analysis(g, &analysis)
}

/// alpha* from a precomputed component analysis.
pub fn alpha_from_analysis(g: &Graph, analysis: &ComponentAnalysis) -> Vec<f64> {
    let mut alpha = vec![0.0; g.n];
    for comp in &analysis.components {
        let (a0, a1) = comp.alpha_values();
        for &v in &comp.vertices {
            alpha[v] = if analysis.color[v] == 0 { a0 } else { a1 };
        }
    }
    alpha
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_path() -> Graph {
        // vertices 0,1,2 triangle; 3-4 path; 5 isolated
        Graph::new(6, vec![(0, 1), (1, 2), (0, 2), (3, 4)])
    }

    #[test]
    fn all_alive_components() {
        let g = triangle_plus_path();
        let a = analyze_components(&g, &[true; 4]);
        assert_eq!(a.components.len(), 3);
        let tri = &a.components[a.comp_of[0]];
        assert!(!tri.is_bipartite());
        assert_eq!(tri.size(), 3);
        assert_eq!(tri.edges.len(), 3);
        let path = &a.components[a.comp_of[3]];
        assert!(path.is_bipartite());
        let iso = &a.components[a.comp_of[5]];
        assert_eq!(iso.size(), 1);
        assert_eq!(iso.alpha_values().0, 0.0);
    }

    #[test]
    fn alpha_odd_component_is_one() {
        let g = triangle_plus_path();
        let alpha = optimal_alpha(&g, &[true; 4]);
        assert_eq!(&alpha[0..3], &[1.0, 1.0, 1.0]);
        // balanced path component: alpha = 1 on both sides
        assert_eq!(&alpha[3..5], &[1.0, 1.0]);
        // isolated vertex
        assert_eq!(alpha[5], 0.0);
    }

    #[test]
    fn alpha_unbalanced_bipartite_star() {
        // star: center 0, leaves 1..4 — bipartite with |L|=4 (leaves) |R|=1
        let g = Graph::new(5, vec![(0, 1), (0, 2), (0, 3), (0, 4)]);
        let alpha = optimal_alpha(&g, &[true; 4]);
        // paper obs. 3: center gets 1 + 3/5 = 1.6, leaves 1 - 3/5 = 0.4
        assert!((alpha[0] - 1.6).abs() < 1e-12, "{alpha:?}");
        for v in 1..5 {
            assert!((alpha[v] - 0.4).abs() < 1e-12);
        }
        // Eq. (4): alpha_u + alpha_v = 2 on every surviving edge
        for &(u, v) in &g.edges {
            assert!((alpha[u] + alpha[v] - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn dead_edges_split_components() {
        let g = triangle_plus_path();
        // kill one triangle edge -> becomes a path (bipartite, balanced-ish)
        let alpha = optimal_alpha(&g, &[false, true, true, true]);
        // path 1-2-0: sides {1,0} and {2} -> alpha: 2*1/3 on big side, 2*2/3=4/3 on small
        let imb = 1.0 / 3.0;
        assert!((alpha[1] - (1.0 - imb)).abs() < 1e-12, "{alpha:?}");
        assert!((alpha[0] - (1.0 - imb)).abs() < 1e-12);
        assert!((alpha[2] - (1.0 + imb)).abs() < 1e-12);
    }

    #[test]
    fn even_cycle_balanced() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
        let alpha = optimal_alpha(&g, &[true; 4]);
        assert!(alpha.iter().all(|&a| (a - 1.0).abs() < 1e-12));
    }

    #[test]
    fn all_dead_gives_zero_alpha() {
        let g = triangle_plus_path();
        let alpha = optimal_alpha(&g, &[false; 4]);
        assert!(alpha.iter().all(|&a| a == 0.0));
    }
}
