//! Spectral analysis of regular graphs.
//!
//! The paper's expansion parameter is the *spectral gap*
//! lambda = d - lambda_2(A(G)) (largest minus second-largest adjacency
//! eigenvalue). For a d-regular graph the top eigenpair is (d, 1), so
//! lambda_2 is found by power iteration on A + dI deflated against the
//! all-ones vector (the shift makes the spectrum non-negative so the
//! iteration converges to the *largest signed* non-principal eigenvalue
//! rather than the largest magnitude one, which for bipartite graphs
//! would be -d).
//!
//! Corollary V.2 also needs sigma_2(A)^2 = lambda_2(A^T A) = 2d - lambda
//! for the assignment matrix; that identity (A^T A = A(G) + dI for
//! graph schemes) is unit-tested here.

use super::Graph;
use crate::linalg::power::SymmetricOp;
use crate::linalg::{dot, norm2, scale};
use crate::prng::Rng;

/// Adjacency operator of a graph (symmetric).
pub struct AdjacencyOp<'a> {
    pub g: &'a Graph,
}

impl SymmetricOp for AdjacencyOp<'_> {
    fn dim(&self) -> usize {
        self.g.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for &(u, v) in &self.g.edges {
            y[u] += x[v];
            y[v] += x[u];
        }
    }
}

/// Second-largest (signed) adjacency eigenvalue lambda_2 of a d-regular
/// graph, via shifted deflated power iteration.
pub fn lambda2(g: &Graph, iters: usize, rng: &mut Rng) -> f64 {
    let d = g.is_regular().expect("spectral gap defined for regular graphs") as f64;
    let n = g.n;
    let op = AdjacencyOp { g };
    let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    deflate_ones(&mut v);
    let nv = norm2(&v);
    scale(1.0 / nv.max(1e-300), &mut v);
    let mut y = vec![0.0; n];
    let mut mu = 0.0;
    for _ in 0..iters {
        op.apply(&v, &mut y);
        // shifted operator (A + dI) x = y + d v
        for i in 0..n {
            y[i] += d * v[i];
        }
        deflate_ones(&mut y);
        let ny = norm2(&y);
        if ny < 1e-300 {
            return -d; // graph-with-no-nonprincipal-mass edge case
        }
        mu = dot(&v, &y);
        v.copy_from_slice(&y);
        scale(1.0 / ny, &mut v);
    }
    mu - d
}

/// Largest |eigenvalue| among non-principal adjacency eigenvalues
/// (for bipartite graphs this is d, attained by the sign vector).
pub fn lambda_max_abs_nonprincipal(g: &Graph, iters: usize, rng: &mut Rng) -> f64 {
    let n = g.n;
    let op = AdjacencyOp { g };
    let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    deflate_ones(&mut v);
    let nv = norm2(&v);
    scale(1.0 / nv.max(1e-300), &mut v);
    let mut y = vec![0.0; n];
    let mut lam: f64 = 0.0;
    for _ in 0..iters {
        op.apply(&v, &mut y);
        deflate_ones(&mut y);
        let ny = norm2(&y);
        if ny < 1e-300 {
            return 0.0;
        }
        lam = dot(&v, &y);
        v.copy_from_slice(&y);
        scale(1.0 / ny, &mut v);
    }
    lam.abs()
}

/// The paper's spectral expansion lambda = d - lambda_2.
pub fn spectral_gap(g: &Graph, iters: usize, rng: &mut Rng) -> f64 {
    let d = g.is_regular().expect("regular graph required") as f64;
    d - lambda2(g, iters, rng)
}

fn deflate_ones(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

/// Expander mixing lemma check (Lemma IV.6): returns the worst slack of
/// |E(S, V\S)| >= lambda |S| (1 - |S|/n) over `trials` random cuts.
/// Non-negative slack everywhere is evidence the estimated gap is sound.
pub fn mixing_lemma_min_slack(g: &Graph, lambda: f64, trials: usize, rng: &mut Rng) -> f64 {
    let n = g.n;
    let mut worst = f64::INFINITY;
    for _ in 0..trials {
        let s_size = 1 + rng.below(n - 1);
        let idx = rng.sample_indices(n, s_size);
        let mut in_s = vec![false; n];
        for &i in &idx {
            in_s[i] = true;
        }
        let cut = g.boundary_size(&in_s) as f64;
        let bound = lambda * s_size as f64 * (1.0 - s_size as f64 / n as f64);
        worst = worst.min(cut - bound);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs::{complete_graph, cycle_graph, hypercube_graph};

    #[test]
    fn complete_graph_spectrum() {
        // K_n: eigenvalues n-1 and -1 -> lambda_2 = -1, gap = n
        let g = complete_graph(8);
        let mut rng = Rng::new(0);
        let l2 = lambda2(&g, 3000, &mut rng);
        assert!((l2 + 1.0).abs() < 1e-6, "l2={l2}");
        let gap = spectral_gap(&g, 3000, &mut Rng::new(1));
        assert!((gap - 8.0).abs() < 1e-6, "gap={gap}");
    }

    #[test]
    fn cycle_spectrum() {
        // C_n: lambda_2 = 2 cos(2 pi / n)
        let n = 10;
        let g = cycle_graph(n);
        let mut rng = Rng::new(2);
        let l2 = lambda2(&g, 20_000, &mut rng);
        let want = 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos();
        assert!((l2 - want).abs() < 1e-4, "l2={l2} want={want}");
    }

    #[test]
    fn hypercube_spectrum() {
        // Q_d: eigenvalues d-2k -> lambda_2 = d-2; bipartite so
        // max-abs non-principal = d
        let g = hypercube_graph(4);
        let l2 = lambda2(&g, 20_000, &mut Rng::new(3));
        assert!((l2 - 2.0).abs() < 1e-3, "l2={l2}");
        let labs = lambda_max_abs_nonprincipal(&g, 20_000, &mut Rng::new(4));
        assert!((labs - 4.0).abs() < 1e-3, "labs={labs}");
    }

    #[test]
    fn gram_identity_for_graph_assignment() {
        // A^T A = A(G) + d I for graph schemes (Corollary V.2 proof)
        let g = complete_graph(5);
        let a = g.assignment_matrix().to_dense();
        let mut gram = crate::linalg::Mat::zeros(g.m(), g.m());
        // gram = A^T A computed column-by-column
        for i in 0..g.m() {
            let mut e = vec![0.0; g.m()];
            e[i] = 1.0;
            let col = a.t_mul_vec(&a.mul_vec(&e));
            for j in 0..g.m() {
                gram[(j, i)] = col[j];
            }
        }
        // diagonal should be 2 (= d per *column*: each machine holds 2 blocks)
        for i in 0..g.m() {
            assert_eq!(gram[(i, i)], 2.0);
        }
        // off-diagonal (i,j) = number of shared endpoints of edges i,j
        for i in 0..g.m() {
            for j in 0..g.m() {
                if i != j {
                    let (u1, v1) = g.edges[i];
                    let (u2, v2) = g.edges[j];
                    let shared = [u1 == u2, u1 == v2, v1 == u2, v1 == v2]
                        .iter()
                        .filter(|&&b| b)
                        .count() as f64;
                    assert_eq!(gram[(i, j)], shared);
                }
            }
        }
    }

    #[test]
    fn mixing_lemma_holds_on_complete_graph() {
        let g = complete_graph(12);
        let mut rng = Rng::new(5);
        // true gap = n = 12
        let slack = mixing_lemma_min_slack(&g, 12.0, 200, &mut rng);
        assert!(slack > -1e-9, "slack={slack}");
    }
}
