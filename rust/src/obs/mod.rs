//! Structured observability for the dispatch stack: typed events, sinks
//! (ring-buffer flight recorder, JSONL trace file, stderr text/JSON log)
//! and the event→metrics bridge feeding the process-global
//! [`crate::metrics::registry`] behind `gcod serve`'s `/metrics`.
//!
//! Design contract — **bit-neutrality**: nothing in this module may feed
//! back into sweep values, shard manifests or merge output. Events carry
//! wall-clock timestamps and are therefore nondeterministic by nature;
//! they flow only into sinks and counters, never into results. The
//! `obs_neutrality` integration suite enforces this by diffing manifests
//! produced with tracing on against tracing off, byte for byte.
//!
//! The [`Obs`] handle is the unit of plumbing: `Obs::default()` is
//! disabled (every emit is a no-op, no allocation), `Obs::new()` is
//! enabled. Cloning shares the sink set, so one handle built in `main`
//! threads through `DispatchConfig`, the transports and the server.

pub mod report;

use std::fmt;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bench_util::json_escape;
use crate::error::{Error, Result};
use crate::metrics;

/// Default flight-recorder capacity (events). Bounded at construction:
/// once full the ring overwrites its oldest entry, so a long dispatch
/// holds memory proportional to this constant, not to its event count.
pub const DEFAULT_RECORDER_CAP: usize = 1024;

/// Everything the dispatch stack reports about *how* a run unfolded.
/// One variant per observable transition; fields are the minimum needed
/// to reconstruct a timeline (`gcod report`) from a trace file.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Dispatcher entered its main loop. `linalg` is the sweep's linalg
    /// tier label (`exact` | `fast`), so traces, `/metrics` and
    /// `gcod report` all show which tier a job ran on.
    DispatchStarted { trials: usize, workers: usize, grain: usize, linalg: String },
    /// A lease (or speculative duplicate) was handed to a worker.
    LeaseIssued { lease: u64, worker: usize, lo: usize, hi: usize, speculative: bool },
    /// A worker returned a validated manifest for its lease.
    LeaseCompleted { lease: u64, worker: usize, lo: usize, hi: usize, secs: f64, duplicate: bool },
    /// The worker reported failure (crash, validation reject, chaos).
    LeaseFailed { lease: u64, worker: usize, lo: usize, hi: usize, error: String },
    /// The dispatcher reclaimed the lease without a result (deadline
    /// expiry, or the job died with the lease in flight).
    LeaseReaped { lease: u64, worker: usize, lo: usize, hi: usize, secs: f64, cause: String },
    /// A reclaimed range went back on the queue for another attempt.
    LeaseRetried { lo: usize, hi: usize, attempt: usize },
    /// A losing speculative duplicate was cancelled.
    LeaseCancelled { lease: u64, worker: usize },
    /// A banked range was re-executed on a second worker for audit.
    AuditIssued { auditor: usize, lo: usize, hi: usize, original: usize },
    /// Audit re-execution matched the banked bytes.
    AuditPassed { auditor: usize, lo: usize, hi: usize },
    /// Audit mismatch (with the tiebreak verdict once known).
    AuditFailed { lo: usize, hi: usize, detail: String },
    /// An audit was abandoned (no eligible worker, attempts exhausted).
    AuditDropped { lo: usize, hi: usize, reason: String },
    /// Health layer pulled a worker from rotation.
    WorkerQuarantined { worker: usize, reason: String, detail: String },
    /// A condemned worker's banked contributions were re-queued.
    RangeInvalidated { worker: usize, lo: usize, hi: usize },
    /// The seeded chaos layer injected a fault.
    ChaosFault { detail: String },
    /// TCP transport declared a silent peer dead (satellite: the reap
    /// window is `DispatchConfig::peer_silence_timeout`).
    PeerReaped { worker: usize, silence_ms: u64 },
    /// Per-worker scorecard, emitted with the final report and on the
    /// all-quarantined post-mortem path.
    WorkerPostMortem {
        worker: usize,
        state: String,
        completions: u64,
        failures: u64,
        timeouts: u64,
        audit_passes: u64,
        audit_failures: u64,
        mean_lease_secs: f64,
        last_error: String,
    },
    /// Dispatcher finished (successfully or not).
    DispatchDone { completed: u64, retried: u64, elapsed_secs: f64, ok: bool },
    /// `gcod serve` job lifecycle (queued / started / done / failed /
    /// deduplicated / drained).
    ServeJob { job: u64, state: String, detail: String },
    /// A restarted coordinator replayed its durable state journal.
    CoordinatorRecovered { jobs: u64, requeued: u64 },
    /// A recovered unfinished job went back on the queue (resuming
    /// mid-sweep through its per-job journal when one exists).
    JobResumed { job: u64, detail: String },
    /// The coordinator began a graceful drain (SIGTERM or `--drain`).
    DrainStarted { detail: String },
    /// A worker lost its coordinator socket mid-session and
    /// re-registered after backoff.
    WorkerReconnected { attempts: u64, detail: String },
    /// Free-form annotation.
    Note { text: String },
}

/// A field value for generic rendering.
pub enum Field<'a> {
    U(u64),
    F(f64),
    B(bool),
    S(&'a str),
}

impl Event {
    /// Stable kebab-case tag, used as the `ev` key in JSONL traces.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::DispatchStarted { .. } => "dispatch-started",
            Event::LeaseIssued { .. } => "lease-issued",
            Event::LeaseCompleted { .. } => "lease-completed",
            Event::LeaseFailed { .. } => "lease-failed",
            Event::LeaseReaped { .. } => "lease-reaped",
            Event::LeaseRetried { .. } => "lease-retried",
            Event::LeaseCancelled { .. } => "lease-cancelled",
            Event::AuditIssued { .. } => "audit-issued",
            Event::AuditPassed { .. } => "audit-passed",
            Event::AuditFailed { .. } => "audit-failed",
            Event::AuditDropped { .. } => "audit-dropped",
            Event::WorkerQuarantined { .. } => "worker-quarantined",
            Event::RangeInvalidated { .. } => "range-invalidated",
            Event::ChaosFault { .. } => "chaos-fault",
            Event::PeerReaped { .. } => "peer-reaped",
            Event::WorkerPostMortem { .. } => "worker-post-mortem",
            Event::DispatchDone { .. } => "dispatch-done",
            Event::ServeJob { .. } => "serve-job",
            Event::CoordinatorRecovered { .. } => "coordinator-recovered",
            Event::JobResumed { .. } => "job-resumed",
            Event::DrainStarted { .. } => "drain-started",
            Event::WorkerReconnected { .. } => "worker-reconnected",
            Event::Note { .. } => "note",
        }
    }

    /// Field list in declaration order, for uniform JSON/text rendering.
    pub fn fields(&self) -> Vec<(&'static str, Field<'_>)> {
        use Field::*;
        match self {
            Event::DispatchStarted { trials, workers, grain, linalg } => vec![
                ("trials", U(*trials as u64)),
                ("workers", U(*workers as u64)),
                ("grain", U(*grain as u64)),
                ("linalg_backend", S(linalg)),
            ],
            Event::LeaseIssued { lease, worker, lo, hi, speculative } => vec![
                ("lease", U(*lease)),
                ("worker", U(*worker as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
                ("speculative", B(*speculative)),
            ],
            Event::LeaseCompleted { lease, worker, lo, hi, secs, duplicate } => vec![
                ("lease", U(*lease)),
                ("worker", U(*worker as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
                ("secs", F(*secs)),
                ("duplicate", B(*duplicate)),
            ],
            Event::LeaseFailed { lease, worker, lo, hi, error } => vec![
                ("lease", U(*lease)),
                ("worker", U(*worker as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
                ("error", S(error)),
            ],
            Event::LeaseReaped { lease, worker, lo, hi, secs, cause } => vec![
                ("lease", U(*lease)),
                ("worker", U(*worker as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
                ("secs", F(*secs)),
                ("cause", S(cause)),
            ],
            Event::LeaseRetried { lo, hi, attempt } => {
                vec![("lo", U(*lo as u64)), ("hi", U(*hi as u64)), ("attempt", U(*attempt as u64))]
            }
            Event::LeaseCancelled { lease, worker } => {
                vec![("lease", U(*lease)), ("worker", U(*worker as u64))]
            }
            Event::AuditIssued { auditor, lo, hi, original } => vec![
                ("auditor", U(*auditor as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
                ("original", U(*original as u64)),
            ],
            Event::AuditPassed { auditor, lo, hi } => vec![
                ("auditor", U(*auditor as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
            ],
            Event::AuditFailed { lo, hi, detail } => {
                vec![("lo", U(*lo as u64)), ("hi", U(*hi as u64)), ("detail", S(detail))]
            }
            Event::AuditDropped { lo, hi, reason } => {
                vec![("lo", U(*lo as u64)), ("hi", U(*hi as u64)), ("reason", S(reason))]
            }
            Event::WorkerQuarantined { worker, reason, detail } => vec![
                ("worker", U(*worker as u64)),
                ("reason", S(reason)),
                ("detail", S(detail)),
            ],
            Event::RangeInvalidated { worker, lo, hi } => vec![
                ("worker", U(*worker as u64)),
                ("lo", U(*lo as u64)),
                ("hi", U(*hi as u64)),
            ],
            Event::ChaosFault { detail } => vec![("detail", S(detail))],
            Event::PeerReaped { worker, silence_ms } => {
                vec![("worker", U(*worker as u64)), ("silence_ms", U(*silence_ms))]
            }
            Event::WorkerPostMortem {
                worker,
                state,
                completions,
                failures,
                timeouts,
                audit_passes,
                audit_failures,
                mean_lease_secs,
                last_error,
            } => vec![
                ("worker", U(*worker as u64)),
                ("state", S(state)),
                ("completions", U(*completions)),
                ("failures", U(*failures)),
                ("timeouts", U(*timeouts)),
                ("audit_passes", U(*audit_passes)),
                ("audit_failures", U(*audit_failures)),
                ("mean_lease_secs", F(*mean_lease_secs)),
                ("last_error", S(last_error)),
            ],
            Event::DispatchDone { completed, retried, elapsed_secs, ok } => vec![
                ("completed", U(*completed)),
                ("retried", U(*retried)),
                ("elapsed_secs", F(*elapsed_secs)),
                ("ok", B(*ok)),
            ],
            Event::ServeJob { job, state, detail } => {
                vec![("job", U(*job)), ("state", S(state)), ("detail", S(detail))]
            }
            Event::CoordinatorRecovered { jobs, requeued } => {
                vec![("jobs", U(*jobs)), ("requeued", U(*requeued))]
            }
            Event::JobResumed { job, detail } => {
                vec![("job", U(*job)), ("detail", S(detail))]
            }
            Event::DrainStarted { detail } => vec![("detail", S(detail))],
            Event::WorkerReconnected { attempts, detail } => {
                vec![("attempts", U(*attempts)), ("detail", S(detail))]
            }
            Event::Note { text } => vec![("text", S(text))],
        }
    }
}

/// One JSONL trace line: `{"t_ms": 12, "ev": "lease-issued", ...}`.
pub fn render_json(t_ms: u64, ev: &Event) -> String {
    let mut s = format!("{{\"t_ms\": {t_ms}, \"ev\": \"{}\"", ev.kind());
    for (k, v) in ev.fields() {
        match v {
            Field::U(n) => s.push_str(&format!(", \"{k}\": {n}")),
            Field::F(x) => s.push_str(&format!(", \"{k}\": {x:?}")),
            Field::B(b) => s.push_str(&format!(", \"{k}\": {b}")),
            Field::S(t) => s.push_str(&format!(", \"{k}\": \"{}\"", json_escape(t))),
        }
    }
    s.push('}');
    s
}

/// One human log line: `[obs +0.012s] lease-issued lease=3 worker=0 ...`.
pub fn render_text(t_ms: u64, ev: &Event) -> String {
    let mut s = format!("[obs +{:.3}s] {}", t_ms as f64 / 1e3, ev.kind());
    for (k, v) in ev.fields() {
        match v {
            Field::U(n) => s.push_str(&format!(" {k}={n}")),
            Field::F(x) => s.push_str(&format!(" {k}={x:.3}")),
            Field::B(b) => s.push_str(&format!(" {k}={b}")),
            Field::S(t) => s.push_str(&format!(" {k}=\"{}\"", json_escape(t))),
        }
    }
    s
}

/// Where structured events go. Sinks must never fail the run: IO errors
/// are swallowed (observability is best-effort by contract).
pub trait EventSink: Send {
    fn record(&mut self, t_ms: u64, ev: &Event);
    fn flush(&mut self) {}
}

/// stderr log format, selected by `--log-format`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LogFormat {
    Text,
    Json,
}

impl LogFormat {
    pub fn parse(s: &str) -> Result<LogFormat> {
        match s {
            "text" => Ok(LogFormat::Text),
            "json" => Ok(LogFormat::Json),
            other => {
                Err(Error::msg(format!("--log-format must be 'text' or 'json', got '{other}'")))
            }
        }
    }
}

/// stderr sink: human text lines or machine JSONL, per [`LogFormat`].
pub struct StderrSink {
    pub format: LogFormat,
}

impl EventSink for StderrSink {
    fn record(&mut self, t_ms: u64, ev: &Event) {
        match self.format {
            LogFormat::Text => eprintln!("{}", render_text(t_ms, ev)),
            LogFormat::Json => eprintln!("{}", render_json(t_ms, ev)),
        }
    }
}

/// JSONL trace-file sink (`--trace-out`): one event object per line,
/// flushed on drop so a crash loses at most the buffered tail. Readers
/// ([`report`]) tolerate a torn final line by construction.
pub struct JsonlSink {
    w: BufWriter<File>,
}

impl JsonlSink {
    pub fn create(path: &Path) -> Result<JsonlSink> {
        let f = File::create(path)
            .map_err(|e| Error::msg(format!("--trace-out {}: {e}", path.display())))?;
        Ok(JsonlSink { w: BufWriter::new(f) })
    }
}

impl EventSink for JsonlSink {
    fn record(&mut self, t_ms: u64, ev: &Event) {
        let _ = writeln!(self.w, "{}", render_json(t_ms, ev));
    }

    fn flush(&mut self) {
        let _ = self.w.flush();
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

/// Bounded in-memory ring of the most recent events. Capacity is fixed
/// at construction; once full, each push overwrites the oldest entry —
/// the recorder's footprint is O(capacity) regardless of run length.
#[derive(Clone, Debug, Default)]
pub struct FlightRecorder {
    cap: usize,
    buf: Vec<(u64, Event)>,
    next: usize,
    total: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        assert!(cap > 0, "flight recorder capacity must be positive");
        FlightRecorder { cap, buf: Vec::with_capacity(cap), next: 0, total: 0 }
    }

    pub fn push(&mut self, t_ms: u64, ev: Event) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push((t_ms, ev));
        } else {
            self.buf[self.next] = (t_ms, ev);
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Events ever pushed (including ones the ring has since dropped).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Retained events, oldest first.
    pub fn snapshot(&self) -> Vec<(u64, Event)> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() < self.cap {
            out.extend(self.buf.iter().cloned());
        } else {
            out.extend(self.buf[self.next..].iter().cloned());
            out.extend(self.buf[..self.next].iter().cloned());
        }
        out
    }
}

struct ObsInner {
    epoch: Instant,
    recorder: Mutex<FlightRecorder>,
    sinks: Mutex<Vec<Box<dyn EventSink>>>,
}

/// Cheap cloneable observability handle. `Obs::default()` is disabled —
/// `emit` returns immediately and allocates nothing — so every struct
/// that carries one pays nothing until a CLI flag turns tracing on.
#[derive(Clone, Default)]
pub struct Obs {
    inner: Option<Arc<ObsInner>>,
}

impl fmt::Debug for Obs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.inner {
            None => write!(f, "Obs(disabled)"),
            Some(i) => write!(f, "Obs(sinks={})", i.sinks.lock().unwrap().len()),
        }
    }
}

impl Obs {
    /// Enabled handle: flight recorder armed, counters bridged, no
    /// external sinks yet (add them with the `with_*` builders).
    pub fn new() -> Obs {
        Obs {
            inner: Some(Arc::new(ObsInner {
                epoch: Instant::now(),
                recorder: Mutex::new(FlightRecorder::new(DEFAULT_RECORDER_CAP)),
                sinks: Mutex::new(Vec::new()),
            })),
        }
    }

    /// Explicitly-disabled handle (same as `Obs::default()`).
    pub fn disabled() -> Obs {
        Obs::default()
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Attach an arbitrary sink (builder style; call before cloning the
    /// handle out to transports so every clone shares the sink set).
    pub fn with_sink(self, sink: Box<dyn EventSink>) -> Obs {
        if let Some(inner) = &self.inner {
            inner.sinks.lock().unwrap().push(sink);
        }
        self
    }

    /// Attach the stderr log sink in the given format.
    pub fn with_stderr(self, format: LogFormat) -> Obs {
        self.with_sink(Box::new(StderrSink { format }))
    }

    /// Attach a JSONL trace-file sink (`--trace-out`).
    pub fn with_trace_file(self, path: &Path) -> Result<Obs> {
        let sink = JsonlSink::create(path)?;
        Ok(self.with_sink(Box::new(sink)))
    }

    /// Record one event: bridge to the metrics registry, append to the
    /// flight recorder, fan out to every sink. No-op when disabled.
    pub fn emit(&self, ev: Event) {
        let Some(inner) = &self.inner else { return };
        bridge_metrics(&ev);
        let t_ms = inner.epoch.elapsed().as_millis() as u64;
        for sink in inner.sinks.lock().unwrap().iter_mut() {
            sink.record(t_ms, &ev);
        }
        inner.recorder.lock().unwrap().push(t_ms, ev);
    }

    /// Flush every sink (trace files buffer; call at run boundaries).
    pub fn flush(&self) {
        if let Some(inner) = &self.inner {
            for sink in inner.sinks.lock().unwrap().iter_mut() {
                sink.flush();
            }
        }
    }

    /// Flight-recorder snapshot, oldest first (empty when disabled).
    pub fn flight_log(&self) -> Vec<(u64, Event)> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => inner.recorder.lock().unwrap().snapshot(),
        }
    }
}

/// Event → counters/gauges bridge. Every emit updates the registry so
/// `/metrics` stays truthful even with no sinks attached. Names are
/// deliberately un-prefixed — CI asserts on them literally.
fn bridge_metrics(ev: &Event) {
    match ev {
        Event::DispatchStarted { linalg, .. } => {
            // labeled flag gauge: the active tier's series reads 1, so
            // `/metrics` shows e.g. `linalg_backend{backend="fast"} 1`
            metrics::gauge(&format!("linalg_backend{{backend=\"{linalg}\"}}")).set(1.0);
        }
        Event::LeaseIssued { speculative, .. } => {
            metrics::counter("leases_issued_total").inc();
            if *speculative {
                metrics::counter("speculative_leases_total").inc();
            }
        }
        Event::LeaseCompleted { worker, lo, hi, secs, .. } => {
            metrics::counter("leases_completed_total").inc();
            metrics::counter(&format!("worker_trials_total{{worker=\"{worker}\"}}"))
                .add((hi - lo) as u64);
            metrics::gauge(&format!("worker_busy_seconds{{worker=\"{worker}\"}}")).add(*secs);
        }
        Event::LeaseFailed { .. } => {
            metrics::counter("leases_failed_total").inc();
            metrics::counter("leases_reaped_total").inc();
        }
        Event::LeaseReaped { .. } => {
            metrics::counter("leases_reaped_total").inc();
        }
        Event::LeaseRetried { .. } => {
            metrics::counter("leases_retried_total").inc();
        }
        Event::LeaseCancelled { .. } => {
            metrics::counter("leases_cancelled_total").inc();
        }
        Event::AuditIssued { .. } => {
            metrics::counter("audits_issued_total").inc();
        }
        Event::AuditPassed { .. } => {
            metrics::counter("audits_passed_total").inc();
        }
        Event::AuditFailed { .. } => {
            metrics::counter("audits_failed_total").inc();
        }
        Event::AuditDropped { .. } => {
            metrics::counter("audits_dropped_total").inc();
        }
        Event::WorkerQuarantined { .. } => {
            metrics::counter("quarantines_total").inc();
            metrics::gauge("workers_quarantined").add(1.0);
        }
        Event::RangeInvalidated { .. } => {
            metrics::counter("ranges_invalidated_total").inc();
        }
        Event::ChaosFault { .. } => {
            metrics::counter("chaos_faults_total").inc();
        }
        Event::PeerReaped { .. } => {
            metrics::counter("peers_reaped_total").inc();
        }
        Event::CoordinatorRecovered { requeued, .. } => {
            metrics::counter("coordinator_recoveries_total").inc();
            metrics::counter("jobs_requeued_total").add(*requeued);
        }
        Event::JobResumed { .. } => {
            metrics::counter("jobs_resumed_total").inc();
        }
        Event::DrainStarted { .. } => {
            metrics::counter("drains_total").inc();
        }
        Event::WorkerReconnected { .. } => {
            metrics::counter("worker_reconnects_total").inc();
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_a_no_op() {
        let obs = Obs::default();
        assert!(!obs.enabled());
        obs.emit(Event::Note { text: "dropped".into() });
        obs.flush();
        assert!(obs.flight_log().is_empty());
    }

    #[test]
    fn flight_recorder_wraps_keeping_newest() {
        let mut r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.push(i, Event::Note { text: format!("e{i}") });
        }
        assert_eq!(r.total(), 10);
        assert_eq!(r.len(), 4);
        let snap = r.snapshot();
        let times: Vec<u64> = snap.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![6, 7, 8, 9], "oldest-first, newest retained");
    }

    #[test]
    fn clones_share_the_recorder() {
        let obs = Obs::new();
        let clone = obs.clone();
        clone.emit(Event::Note { text: "via clone".into() });
        let log = obs.flight_log();
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].1, Event::Note { text: "via clone".into() });
    }

    #[test]
    fn json_rendering_escapes_and_tags() {
        let line = render_json(
            7,
            &Event::LeaseFailed {
                lease: 3,
                worker: 1,
                lo: 0,
                hi: 32,
                error: "he said \"boom\"\n".into(),
            },
        );
        let doc = crate::config::json::Json::parse(&line).expect("valid json");
        assert_eq!(doc.get("ev").and_then(|j| j.as_str()), Some("lease-failed"));
        assert_eq!(doc.get("t_ms").and_then(|j| j.as_f64()), Some(7.0));
        assert_eq!(doc.get("error").and_then(|j| j.as_str()), Some("he said \"boom\"\n"));
    }

    #[test]
    fn emits_bridge_into_the_global_registry() {
        let before = metrics::counter("peers_reaped_total").get();
        let obs = Obs::new();
        obs.emit(Event::PeerReaped { worker: 2, silence_ms: 10_000 });
        assert_eq!(metrics::counter("peers_reaped_total").get(), before + 1);
    }

    #[test]
    fn jsonl_sink_writes_one_line_per_event() {
        let dir = std::env::temp_dir().join("gcod_obs_jsonl_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        {
            let obs = Obs::new().with_trace_file(&path).unwrap();
            obs.emit(Event::Note { text: "a".into() });
            obs.emit(Event::Note { text: "b".into() });
            obs.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            crate::config::json::Json::parse(line).expect("each line parses standalone");
        }
    }

    #[test]
    fn log_format_parses() {
        assert_eq!(LogFormat::parse("text").unwrap(), LogFormat::Text);
        assert_eq!(LogFormat::parse("json").unwrap(), LogFormat::Json);
        assert!(LogFormat::parse("xml").is_err());
    }
}
