//! `gcod report`: render a human-readable post-mortem from a JSONL
//! trace file written by `--trace-out` — per-job lease Gantt rows,
//! worker health table and chronological fault/audit annotations.
//!
//! The reader is deliberately forgiving: a crashed writer may leave a
//! torn final line (or interleaved garbage); unparseable lines are
//! counted and skipped, never fatal.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::metrics::Table;

const GANTT_WIDTH: usize = 40;

/// One parsed trace line.
struct Rec {
    t_ms: u64,
    ev: String,
    doc: Json,
}

impl Rec {
    fn u(&self, key: &str) -> u64 {
        self.doc.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
    }

    fn s(&self, key: &str) -> String {
        self.doc.get(key).and_then(Json::as_str).unwrap_or("").to_string()
    }
}

#[derive(Default)]
struct LeaseRow {
    worker: u64,
    lo: u64,
    hi: u64,
    start: u64,
    end: Option<u64>,
    outcome: String,
}

#[derive(Default)]
struct WorkerRow {
    issued: u64,
    completed: u64,
    failed: u64,
    reaped: u64,
    trials: u64,
    quarantined: String,
}

/// Render the report for a trace file on disk.
pub fn render(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("trace file {}: {e}", path.display())))?;
    let (body, skipped) = render_from_str(&text);
    let mut out = format!("gcod report — trace: {}\n", path.display());
    if skipped > 0 {
        out.push_str(&format!("warning: {skipped} unparseable line(s) skipped (torn write?)\n"));
    }
    out.push_str(&body);
    Ok(out)
}

/// Render from trace text; returns `(report, skipped_line_count)`.
/// Exposed for tests (torn-line tolerance is asserted on this).
pub fn render_from_str(text: &str) -> (String, usize) {
    let mut recs = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line) {
            Ok(doc) => {
                let t_ms = doc.get("t_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let ev = doc.get("ev").and_then(Json::as_str).unwrap_or("?").to_string();
                recs.push(Rec { t_ms, ev, doc });
            }
            Err(_) => skipped += 1,
        }
    }
    if recs.is_empty() {
        return ("(no parseable events)\n".to_string(), skipped);
    }

    // Segment into jobs on dispatch-started boundaries: a serve trace
    // interleaves serve-job markers with one dispatcher run per job.
    let mut segments: Vec<Vec<&Rec>> = vec![Vec::new()];
    for r in &recs {
        if r.ev == "dispatch-started" && !segments.last().unwrap().is_empty() {
            segments.push(Vec::new());
        }
        segments.last_mut().unwrap().push(r);
    }

    let span_ms = recs.iter().map(|r| r.t_ms).max().unwrap_or(0).max(1);
    let mut out = format!(
        "events: {} parsed, span {:.3}s, jobs: {}\n",
        recs.len(),
        span_ms as f64 / 1e3,
        segments.len()
    );
    for (i, seg) in segments.iter().enumerate() {
        if segments.len() > 1 {
            out.push_str(&format!("\n===== job segment {} =====\n", i + 1));
        }
        out.push_str(&render_segment(seg));
    }
    (out, skipped)
}

fn render_segment(recs: &[&Rec]) -> String {
    let mut leases: BTreeMap<u64, LeaseRow> = BTreeMap::new();
    let mut workers: BTreeMap<u64, WorkerRow> = BTreeMap::new();
    let mut notes: Vec<String> = Vec::new();
    let t0 = recs.iter().map(|r| r.t_ms).min().unwrap_or(0);
    let t1 = recs.iter().map(|r| r.t_ms).max().unwrap_or(0).max(t0 + 1);

    for r in recs {
        let rel = r.t_ms - t0;
        match r.ev.as_str() {
            "lease-issued" => {
                let w = r.u("worker");
                let spec = r.doc.get("speculative").and_then(Json::as_bool).unwrap_or(false);
                leases.insert(
                    r.u("lease"),
                    LeaseRow {
                        worker: w,
                        lo: r.u("lo"),
                        hi: r.u("hi"),
                        start: rel,
                        end: None,
                        outcome: if spec { "spec".into() } else { "…".into() },
                    },
                );
                workers.entry(w).or_default().issued += 1;
            }
            "lease-completed" | "lease-failed" | "lease-reaped" | "lease-cancelled" => {
                let w = r.u("worker");
                if let Some(l) = leases.get_mut(&r.u("lease")) {
                    l.end = Some(rel);
                    l.outcome = match r.ev.as_str() {
                        "lease-completed" => {
                            if r.doc.get("duplicate").and_then(Json::as_bool).unwrap_or(false) {
                                "dup".into()
                            } else {
                                "done".into()
                            }
                        }
                        "lease-failed" => "FAIL".into(),
                        "lease-reaped" => format!("reaped:{}", r.s("cause")),
                        _ => "cancel".into(),
                    };
                }
                let wr = workers.entry(w).or_default();
                match r.ev.as_str() {
                    "lease-completed" => {
                        wr.completed += 1;
                        wr.trials += r.u("hi").saturating_sub(r.u("lo"));
                    }
                    "lease-failed" => wr.failed += 1,
                    "lease-reaped" => wr.reaped += 1,
                    _ => {}
                }
            }
            "worker-quarantined" => {
                workers.entry(r.u("worker")).or_default().quarantined = r.s("reason");
                notes.push(format!(
                    "[+{:.3}s] QUARANTINE worker {} ({}): {}",
                    rel as f64 / 1e3,
                    r.u("worker"),
                    r.s("reason"),
                    r.s("detail")
                ));
            }
            "chaos-fault" => {
                notes.push(format!("[+{:.3}s] chaos: {}", rel as f64 / 1e3, r.s("detail")));
            }
            "peer-reaped" => {
                notes.push(format!(
                    "[+{:.3}s] peer {} reaped after {}ms of silence",
                    rel as f64 / 1e3,
                    r.u("worker"),
                    r.u("silence_ms")
                ));
            }
            "audit-issued" | "audit-passed" | "audit-failed" | "audit-dropped" => {
                let tail = match r.ev.as_str() {
                    "audit-issued" => {
                        format!("worker {} re-runs [{}..{})", r.u("auditor"), r.u("lo"), r.u("hi"))
                    }
                    "audit-passed" => format!(
                        "[{}..{}) matched on worker {}",
                        r.u("lo"),
                        r.u("hi"),
                        r.u("auditor")
                    ),
                    "audit-failed" => {
                        format!("[{}..{}) MISMATCH: {}", r.u("lo"), r.u("hi"), r.s("detail"))
                    }
                    _ => format!("[{}..{}) dropped: {}", r.u("lo"), r.u("hi"), r.s("reason")),
                };
                notes.push(format!("[+{:.3}s] {}: {}", rel as f64 / 1e3, r.ev, tail));
            }
            "range-invalidated" => {
                notes.push(format!(
                    "[+{:.3}s] invalidated [{}..{}) banked by condemned worker {}",
                    rel as f64 / 1e3,
                    r.u("lo"),
                    r.u("hi"),
                    r.u("worker")
                ));
            }
            "worker-post-mortem" | "serve-job" | "dispatch-started" | "dispatch-done" | "note"
            | "coordinator-recovered" | "job-resumed" | "drain-started"
            | "worker-reconnected" => {
                notes.push(format!("[+{:.3}s] {}", rel as f64 / 1e3, summarize(r)));
            }
            _ => {}
        }
    }

    let mut out = String::new();
    if !leases.is_empty() {
        out.push_str("\nLease timeline\n");
        let span = (t1 - t0).max(1);
        for (id, l) in &leases {
            let end = l.end.unwrap_or(span);
            let a = (l.start as usize * GANTT_WIDTH / span as usize).min(GANTT_WIDTH - 1);
            let b = (end as usize * GANTT_WIDTH / span as usize).clamp(a + 1, GANTT_WIDTH);
            let bar: String = (0..GANTT_WIDTH)
                .map(|i| if i >= a && i < b { '#' } else { '·' })
                .collect();
            out.push_str(&format!(
                "  lease {id:>4}  w{:<3} [{:>6}..{:<6}) |{bar}| {:>8.3}s→{:<8.3}s {}\n",
                l.worker,
                l.lo,
                l.hi,
                l.start as f64 / 1e3,
                end as f64 / 1e3,
                l.outcome
            ));
        }
    }
    if !workers.is_empty() {
        out.push_str("\nWorker health\n");
        let mut t =
            Table::new(&["worker", "issued", "done", "failed", "reaped", "trials", "state"]);
        for (w, row) in &workers {
            t.row(vec![
                w.to_string(),
                row.issued.to_string(),
                row.completed.to_string(),
                row.failed.to_string(),
                row.reaped.to_string(),
                row.trials.to_string(),
                if row.quarantined.is_empty() { "active".into() } else { row.quarantined.clone() },
            ]);
        }
        out.push_str(&t.render());
    }
    if !notes.is_empty() {
        out.push_str("\nAnnotations\n");
        for n in &notes {
            out.push_str("  ");
            out.push_str(n);
            out.push('\n');
        }
    }
    out
}

/// One-line digest of a lifecycle/marker event for the annotations list.
fn summarize(r: &Rec) -> String {
    let mut s = r.ev.clone();
    for key in [
        "job", "state", "detail", "trials", "workers", "grain", "linalg_backend", "completed",
        "retried", "ok", "worker", "completions", "failures", "timeouts", "last_error", "text",
    ] {
        if let Some(v) = r.doc.get(key) {
            match v {
                Json::Str(t) if t.is_empty() => {}
                Json::Str(t) => s.push_str(&format!(" {key}={t}")),
                Json::Num(x) => s.push_str(&format!(" {key}={x}")),
                Json::Bool(b) => s.push_str(&format!(" {key}={b}")),
                _ => {}
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{render_json, Event};

    fn line(t: u64, ev: Event) -> String {
        render_json(t, &ev)
    }

    #[test]
    fn renders_timeline_health_and_annotations() {
        let trace = [
            line(
                0,
                Event::DispatchStarted {
                    trials: 96,
                    workers: 2,
                    grain: 32,
                    linalg: "exact".into(),
                },
            ),
            line(1, Event::LeaseIssued { lease: 1, worker: 0, lo: 0, hi: 32, speculative: false }),
            line(2, Event::LeaseIssued { lease: 2, worker: 1, lo: 32, hi: 64, speculative: false }),
            line(
                50,
                Event::LeaseCompleted {
                    lease: 1,
                    worker: 0,
                    lo: 0,
                    hi: 32,
                    secs: 0.049,
                    duplicate: false,
                },
            ),
            line(60, Event::ChaosFault { detail: "kill worker 1".into() }),
            line(
                70,
                Event::LeaseReaped {
                    lease: 2,
                    worker: 1,
                    lo: 32,
                    hi: 64,
                    secs: 0.068,
                    cause: "worker-failure".into(),
                },
            ),
            line(
                80,
                Event::WorkerQuarantined {
                    worker: 1,
                    reason: "byzantine".into(),
                    detail: "audit mismatch".into(),
                },
            ),
            line(99, Event::DispatchDone { completed: 3, retried: 1, elapsed_secs: 0.1, ok: true }),
        ]
        .join("\n");
        let (report, skipped) = render_from_str(&trace);
        assert_eq!(skipped, 0);
        assert!(report.contains("Lease timeline"));
        assert!(report.contains("lease    1"));
        assert!(report.contains("done"));
        assert!(report.contains("reaped:worker-failure"));
        assert!(report.contains("Worker health"));
        assert!(report.contains("byzantine"));
        assert!(report.contains("chaos: kill worker 1"));
        assert!(report.contains("dispatch-done"));
    }

    #[test]
    fn tolerates_torn_final_line() {
        let mut trace = [
            line(0, Event::LeaseIssued { lease: 1, worker: 0, lo: 0, hi: 8, speculative: false }),
            line(
                9,
                Event::LeaseCompleted {
                    lease: 1,
                    worker: 0,
                    lo: 0,
                    hi: 8,
                    secs: 0.009,
                    duplicate: false,
                },
            ),
        ]
        .join("\n");
        trace.push('\n');
        trace.push_str("{\"t_ms\": 12, \"ev\": \"lease-iss"); // torn mid-write
        let (report, skipped) = render_from_str(&trace);
        assert_eq!(skipped, 1, "the torn tail is skipped, not fatal");
        assert!(report.contains("Lease timeline"));
    }

    #[test]
    fn segments_multiple_jobs() {
        let trace = [
            line(
                0,
                Event::DispatchStarted { trials: 8, workers: 1, grain: 8, linalg: "exact".into() },
            ),
            line(1, Event::LeaseIssued { lease: 1, worker: 0, lo: 0, hi: 8, speculative: false }),
            line(
                9,
                Event::DispatchStarted { trials: 8, workers: 1, grain: 8, linalg: "fast".into() },
            ),
            line(10, Event::LeaseIssued { lease: 1, worker: 0, lo: 0, hi: 8, speculative: false }),
        ]
        .join("\n");
        let (report, _) = render_from_str(&trace);
        assert!(report.contains("jobs: 2"));
        assert!(report.contains("job segment 2"));
        // the tier label surfaces in the per-job annotation line
        assert!(report.contains("linalg_backend=exact"), "{report}");
        assert!(report.contains("linalg_backend=fast"), "{report}");
    }

    #[test]
    fn empty_trace_reports_no_events() {
        let (report, skipped) = render_from_str("");
        assert_eq!(skipped, 0);
        assert!(report.contains("no parseable events"));
    }
}
