//! Decoding-error statistics (the quantities plotted in Figure 3).
//!
//! For a scheme + decoder + straggler model this estimates, over R
//! Monte-Carlo draws:
//!   * the normalized expected error  E[|alpha-bar - 1|^2] / n  where
//!     alpha-bar = alpha * sqrt(n) / |E[alpha]|_2 (the paper normalizes
//!     biased schemes by their mean before comparing);
//!   * the spectral norm of the deviation second moment
//!     |E[(alpha-bar - 1)(alpha-bar - 1)^T]|_2  via implicit power
//!     iteration on the stored samples (never materializing n x n).

use crate::decode::Decoder;
use crate::linalg::power::{power_iteration, CovOperator};
use crate::linalg::{axpy, norm2, scale};
use crate::prng::Rng;
use crate::straggler::StragglerModel;

#[derive(Clone, Debug)]
pub struct DecodingStats {
    /// E[|alpha-bar - 1|^2] / n
    pub mean_err_per_block: f64,
    /// |E[(alpha-bar - 1)(alpha-bar - 1)^T]|_2
    pub cov_norm: f64,
    /// |E[alpha]|_2 / sqrt(n) — the normalization constant c-hat
    pub mean_alpha_scale: f64,
    /// raw (unnormalized) E[|alpha - 1|^2] / n
    pub raw_err_per_block: f64,
    pub runs: usize,
}

/// Estimate Figure-3 statistics with `runs` straggler draws.
///
/// Serial reference path (stateful [`StragglerModel`]s can't be fanned
/// out); the parallel counterpart is
/// [`crate::sweep::decoding_stats_par`], which collects the same alpha
/// samples across threads and reduces them through the shared
/// [`stats_from_samples`].
pub fn decoding_stats(
    decoder: &dyn Decoder,
    stragglers: &mut dyn StragglerModel,
    m: usize,
    n: usize,
    runs: usize,
    rng: &mut Rng,
) -> DecodingStats {
    assert!(runs >= 2);
    let mut samples: Vec<Vec<f64>> = Vec::with_capacity(runs);
    let mut out = crate::decode::Decoding::empty();
    for _ in 0..runs {
        let mask = stragglers.sample(m);
        decoder.decode_into(&mask, &mut out);
        assert_eq!(out.alpha.len(), n);
        samples.push(out.alpha.clone());
    }
    stats_from_samples(samples, rng)
}

/// Reduce a set of per-trial alpha samples to the Figure-3 statistics.
/// Deterministic in the sample order; both the serial and the parallel
/// collection paths feed this, so they agree exactly on identical
/// samples.
pub fn stats_from_samples(samples: Vec<Vec<f64>>, rng: &mut Rng) -> DecodingStats {
    let runs = samples.len();
    assert!(runs >= 2);
    let n = samples[0].len();
    let mut mean = vec![0.0; n];
    let mut raw_err = 0.0;
    for sample in &samples {
        raw_err += crate::linalg::dist_to_ones_sq(sample);
        axpy(1.0, sample, &mut mean);
    }
    scale(1.0 / runs as f64, &mut mean);
    // normalization alpha-bar = alpha * |1|_2 / |E[alpha]|_2
    let mean_norm = norm2(&mean);
    let c = mean_norm / (n as f64).sqrt();
    let s = if c > 1e-12 { 1.0 / c } else { 0.0 };

    let mut mean_err = 0.0;
    let mut deviations: Vec<Vec<f64>> = Vec::with_capacity(runs);
    for sample in &samples {
        let dev: Vec<f64> = sample.iter().map(|&a| a * s - 1.0).collect();
        mean_err += dev.iter().map(|d| d * d).sum::<f64>();
        deviations.push(dev);
    }
    let op = CovOperator::from_deviations(&deviations);
    let (cov_norm, _) = power_iteration(&op, 300, 1e-10, rng);
    DecodingStats {
        mean_err_per_block: mean_err / (runs as f64 * n as f64),
        cov_norm,
        mean_alpha_scale: c,
        raw_err_per_block: raw_err / (runs as f64 * n as f64),
        runs,
    }
}

/// Theory reference lines for the figures.
pub mod theory {
    /// Optimal-decoding lower bound for any unbiased scheme with
    /// replication d (Proposition A.3): p^d / (1 - p^d).
    pub fn optimal_lower_bound(p: f64, d: f64) -> f64 {
        let pd = p.powf(d);
        pd / (1.0 - pd)
    }

    /// Fixed-coefficient lower bound (Proposition A.1): p / (d (1-p)).
    pub fn fixed_lower_bound(p: f64, d: f64) -> f64 {
        p / (d * (1.0 - p))
    }

    /// FRC covariance norm identity used in Figure 3(b)(d):
    /// |cov|_2 = ell * E|alpha-1|^2 / N with ell = blocks per machine.
    pub fn frc_cov_norm(p: f64, d: f64, ell: f64) -> f64 {
        ell * optimal_lower_bound(p, d)
    }

    /// Corollary V.2 adversarial upper bound for graph schemes:
    /// |alpha-1|^2/n <= (2d - lambda)/(2d) * p/(1-p).
    pub fn graph_adversarial_bound(p: f64, d: f64, lambda: f64) -> f64 {
        (2.0 * d - lambda) / (2.0 * d) * p / (1.0 - p)
    }

    /// Remark V.4 adversarial lower bound for graph schemes: p/2.
    pub fn graph_adversarial_lower(p: f64) -> f64 {
        p / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FrcCode, GradientCode, GraphCode};
    use crate::decode::{FixedDecoder, FrcOptimalDecoder, OptimalGraphDecoder};
    use crate::straggler::BernoulliStragglers;

    #[test]
    fn frc_matches_theory() {
        // FRC optimal decoding achieves exactly E[err]/n = p^d (the
        // probability a block's whole group dies), matching [8]
        let code = FrcCode::new(64, 64, 2);
        let p = 0.3;
        let dec = FrcOptimalDecoder::new(&code);
        let mut strag = BernoulliStragglers::new(p, 0);
        let mut rng = Rng::new(1);
        let stats = decoding_stats(&dec, &mut strag, 64, 64, 3000, &mut rng);
        // raw error ~ p^d = 0.09
        assert!(
            (stats.raw_err_per_block - p * p).abs() < 0.02,
            "raw={} want~{}",
            stats.raw_err_per_block,
            p * p
        );
        // normalized error ~ p^d/(1-p^d) within Monte-Carlo noise
        let want = theory::optimal_lower_bound(p, 2.0);
        assert!(
            (stats.mean_err_per_block - want).abs() < 0.03,
            "norm={} want~{}",
            stats.mean_err_per_block,
            want
        );
    }

    #[test]
    fn optimal_graph_beats_fixed() {
        let mut rng = Rng::new(2);
        let code = GraphCode::random_regular(16, 3, &mut rng);
        let p = 0.15;
        let opt = OptimalGraphDecoder::new(&code.graph);
        let fix = FixedDecoder::new(code.assignment(), p);
        let m = code.n_machines();
        let s_opt = decoding_stats(
            &opt, &mut BernoulliStragglers::new(p, 3), m, 16, 2000, &mut rng);
        let s_fix = decoding_stats(
            &fix, &mut BernoulliStragglers::new(p, 3), m, 16, 2000, &mut rng);
        assert!(
            s_opt.mean_err_per_block < 0.5 * s_fix.mean_err_per_block,
            "opt={} fix={}",
            s_opt.mean_err_per_block,
            s_fix.mean_err_per_block
        );
        // fixed decoder should sit near its lower bound p/(d(1-p))
        let fix_lb = theory::fixed_lower_bound(p, 3.0);
        assert!(s_fix.mean_err_per_block > 0.8 * fix_lb);
    }

    #[test]
    fn unbiased_scheme_scale_near_one() {
        let mut rng = Rng::new(4);
        let code = GraphCode::random_regular(20, 4, &mut rng);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let stats = decoding_stats(
            &dec, &mut BernoulliStragglers::new(0.1, 5), code.n_machines(), 20, 1500, &mut rng);
        assert!((stats.mean_alpha_scale - 1.0).abs() < 0.05, "c={}", stats.mean_alpha_scale);
    }

    #[test]
    fn theory_values() {
        assert!((theory::optimal_lower_bound(0.2, 3.0) - 0.008 / 0.992).abs() < 1e-12);
        assert!((theory::fixed_lower_bound(0.2, 3.0) - 0.2 / 2.4).abs() < 1e-12);
        assert!(theory::graph_adversarial_bound(0.2, 6.0, 6.0 - 2.0 * 5f64.sqrt()) > 0.0);
        assert_eq!(theory::graph_adversarial_lower(0.3), 0.15);
    }
}
