//! Step-size grid search (paper Appendix G / Table IV).
//!
//! "To be fair to all algorithms, we use a grid search to find the best
//! step size": cluster regime sweeps gamma = 1e-6 * 1.3^c, simulated
//! regime sweeps gamma_t = min(0.6, 0.3 * 1.3^c / (t+1)), c in 0..=20.

use super::StepSize;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridKind {
    /// gamma = 1e-6 * 1.3^c (distributed cluster regime, d=3)
    Cluster,
    /// gamma_t = min(0.6, 0.3*1.3^c/(t+1)) (simulated regime, d=6)
    Simulated,
}

#[derive(Clone, Debug)]
pub struct GridResult {
    pub best_c: u32,
    pub best_error: f64,
    /// final error for every c tried
    pub errors: Vec<f64>,
}

/// Sweep c over [c_lo, c_hi] and keep the best final error. `run` maps
/// a step schedule to the run's final error (lower = better); NaN runs
/// (diverged) are treated as +inf.
pub fn grid_search<F>(kind: GridKind, c_lo: u32, c_hi: u32, mut run: F) -> GridResult
where
    F: FnMut(StepSize) -> f64,
{
    assert!(c_lo <= c_hi);
    let mut errors = Vec::with_capacity((c_hi - c_lo + 1) as usize);
    let mut best_c = c_lo;
    let mut best_error = f64::INFINITY;
    for c in c_lo..=c_hi {
        let step = match kind {
            GridKind::Cluster => StepSize::cluster_grid(c),
            GridKind::Simulated => StepSize::simulated_grid(c),
        };
        let mut err = run(step);
        if !err.is_finite() {
            err = f64::INFINITY;
        }
        errors.push(err);
        if err < best_error {
            best_error = err;
            best_c = c;
        }
    }
    GridResult { best_c, best_error, errors }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_the_valley() {
        // error is minimized at c = 7 (cluster grid: gammas are unique)
        let r = grid_search(GridKind::Cluster, 0, 20, |s| {
            let gamma0 = s.at(0);
            let target = StepSize::cluster_grid(7).at(0);
            (gamma0 - target).abs()
        });
        assert_eq!(r.best_c, 7);
        assert!(r.best_error < 1e-12);
        assert_eq!(r.errors.len(), 21);
    }

    #[test]
    fn divergent_runs_are_skipped() {
        let r = grid_search(GridKind::Cluster, 0, 5, |s| {
            if s.at(0) > 2e-6 {
                f64::NAN
            } else {
                1.0 / s.at(0)
            }
        });
        // c=2 -> 1.69e-6 is the largest non-NaN gamma -> smallest 1/gamma
        assert_eq!(r.best_c, 2);
        assert!(r.errors[3].is_infinite());
    }
}
