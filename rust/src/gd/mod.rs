//! Coded gradient-descent engines.
//!
//! [`SimulatedGcod`] is Algorithm 3 / the paper's §VIII-B simulation:
//! each iteration samples a straggler pattern, decodes coefficients,
//! and applies theta <- theta - gamma_t * sum_i alpha_i grad_i(theta).
//! Gradients come from a [`GradSource`] so the same engine drives the
//! pure-rust oracle, the PJRT least-squares artifacts, and the
//! transformer artifacts. The distributed Algorithm 2 lives in
//! [`crate::coordinator`].

pub mod analysis;
pub mod bounds;
pub mod gram;
pub mod grid;
#[cfg(pjrt_runtime)]
pub mod pjrt;

pub use gram::GramCache;

use crate::decode::{Decoder, Decoding};
use crate::linalg::Mat;
use crate::straggler::StragglerModel;

/// Per-block gradient provider.
pub trait GradSource {
    fn n_blocks(&self) -> usize;
    /// parameter dimension
    fn dim(&self) -> usize;
    /// Write G (n_blocks x dim) at theta into `out` (reset to shape —
    /// a warm buffer is reused). Implementations must not allocate per
    /// call beyond growing `out` on first use: this is the GD loop's
    /// per-iteration path.
    fn block_grads_into(&mut self, theta: &[f64], out: &mut Mat);
    /// Allocating convenience wrapper around
    /// [`GradSource::block_grads_into`].
    fn block_grads(&mut self, theta: &[f64]) -> Mat {
        let mut out = Mat::zeros(self.n_blocks(), self.dim());
        self.block_grads_into(theta, &mut out);
        out
    }
    /// progress metric: |theta - theta*|^2 for least squares, loss for
    /// models without a closed-form optimum
    fn progress(&mut self, theta: &[f64]) -> f64;
}

impl GradSource for &crate::data::LstsqData {
    fn n_blocks(&self) -> usize {
        self.n_blocks
    }
    fn dim(&self) -> usize {
        self.k
    }
    fn block_grads_into(&mut self, theta: &[f64], out: &mut Mat) {
        crate::data::LstsqData::block_grads_into(self, theta, out)
    }
    fn progress(&mut self, theta: &[f64]) -> f64 {
        self.dist_to_opt(theta)
    }
}

/// Streaming [`GradSource`] over an [`crate::data::LstsqData`] on an
/// explicit linalg tier (the plain `&LstsqData` impl above *is* the
/// exact tier; `Exact` here is bit-identical to it). The sweep kernels
/// use this when `linalg=fast` selects the 8-wide dot for the per-row
/// residuals.
pub struct StreamingGrads<'a> {
    pub data: &'a crate::data::LstsqData,
    pub backend: crate::linalg::LinalgBackend,
}

impl GradSource for StreamingGrads<'_> {
    fn n_blocks(&self) -> usize {
        self.data.n_blocks
    }
    fn dim(&self) -> usize {
        self.data.k
    }
    fn block_grads_into(&mut self, theta: &[f64], out: &mut Mat) {
        self.data.block_grads_into_backend(theta, out, self.backend)
    }
    fn progress(&mut self, theta: &[f64]) -> f64 {
        self.data.dist_to_opt(theta)
    }
}

/// Step-size schedules used in the paper's experiments (Appendix G).
#[derive(Clone, Copy, Debug)]
pub enum StepSize {
    Const(f64),
    /// gamma_t = min(cap, scale / (t+1)) — the simulated-regime schedule
    LinearDecay { cap: f64, scale: f64 },
}

impl StepSize {
    pub fn at(&self, t: usize) -> f64 {
        match *self {
            StepSize::Const(g) => g,
            StepSize::LinearDecay { cap, scale } => (scale / (t as f64 + 1.0)).min(cap),
        }
    }

    /// The paper's distributed-regime grid: gamma = 1e-6 * 1.3^c.
    pub fn cluster_grid(c: u32) -> StepSize {
        StepSize::Const(1e-6 * 1.3f64.powi(c as i32))
    }

    /// The paper's simulated-regime grid:
    /// gamma_t = min(0.6, 0.3 * 1.3^c / (t+1)).
    pub fn simulated_grid(c: u32) -> StepSize {
        StepSize::LinearDecay { cap: 0.6, scale: 0.3 * 1.3f64.powi(c as i32) }
    }
}

/// History of one coded-GD run.
#[derive(Clone, Debug)]
pub struct RunHistory {
    /// progress metric after each iteration (index 0 = before any step)
    pub progress: Vec<f64>,
    /// decoding error |alpha - 1|^2 of each iteration's pattern
    pub decode_errors: Vec<f64>,
}

impl RunHistory {
    pub fn final_progress(&self) -> f64 {
        *self.progress.last().expect("empty run")
    }
}

/// Algorithm-3 simulated coded gradient descent.
pub struct SimulatedGcod<'a> {
    pub decoder: &'a dyn Decoder,
    pub stragglers: &'a mut dyn StragglerModel,
    pub step: StepSize,
    /// optional block shuffle rho (Algorithms 2/3 draw rho uniformly):
    /// data block i is assigned to assignment-row rho[i]
    pub rho: Option<Vec<usize>>,
    /// number of machines m (the straggler mask length)
    pub m: usize,
    /// normalize the update by 1/E-hat[alpha] to debias (used with the
    /// fixed decoder this is a no-op since it is already unbiased)
    pub alpha_scale: f64,
}

/// Reusable buffers for [`SimulatedGcod::run_with`]: the straggler
/// mask, the decoded coefficients, the gradient matrix and the iterate.
/// After the first iteration on a given problem shape, the GD loop
/// performs **zero heap allocations per iteration** — and a warm
/// scratch carried across trials (e.g. the sweep engine's chunk-scoped
/// context) skips even the first-iteration growth. Scratch contents are
/// fully overwritten each run, so reuse never changes results.
pub struct GdScratch {
    mask: Vec<bool>,
    dec: Decoding,
    g: Mat,
    theta: Vec<f64>,
}

impl GdScratch {
    pub fn new() -> Self {
        Self { mask: Vec::new(), dec: Decoding::empty(), g: Mat::zeros(0, 0), theta: Vec::new() }
    }
}

impl Default for GdScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl SimulatedGcod<'_> {
    /// Run `iters` steps from `theta0`, recording progress every
    /// iteration. Allocating wrapper around [`SimulatedGcod::run_with`]
    /// (fresh scratch per call) — results are identical.
    pub fn run<S: GradSource>(&mut self, src: &mut S, theta0: &[f64], iters: usize) -> RunHistory {
        let mut scratch = GdScratch::new();
        self.run_with(src, theta0, iters, &mut scratch)
    }

    /// [`SimulatedGcod::run`] on caller-owned scratch: after setup (the
    /// history vectors, sized once up front) the iteration loop is
    /// allocation-free — decode, mask sampling and gradients all write
    /// into `scratch`, and the sweep engine reuses one scratch across
    /// every trial of a chunk.
    pub fn run_with<S: GradSource>(
        &mut self,
        src: &mut S,
        theta0: &[f64],
        iters: usize,
        scratch: &mut GdScratch,
    ) -> RunHistory {
        let n = src.n_blocks();
        let dim = src.dim();
        assert_eq!(theta0.len(), dim);
        if let Some(rho) = &self.rho {
            assert_eq!(rho.len(), n);
        }
        let GdScratch { mask, dec, g, theta } = scratch;
        theta.clear();
        theta.extend_from_slice(theta0);
        let mut progress = Vec::with_capacity(iters + 1);
        let mut decode_errors = Vec::with_capacity(iters);
        progress.push(src.progress(theta));
        for t in 0..iters {
            self.stragglers.sample_into(self.m, mask);
            self.decoder.decode_into(mask, dec);
            decode_errors.push(dec.error_sq());
            src.block_grads_into(theta, g);
            let gamma = self.step.at(t);
            // theta -= gamma * sum_i alpha_{rho(i)} * G_i
            for i in 0..n {
                let a = match &self.rho {
                    Some(rho) => dec.alpha[rho[i]],
                    None => dec.alpha[i],
                } * self.alpha_scale;
                if a != 0.0 {
                    crate::linalg::axpy(-gamma * a, g.row(i), theta);
                }
            }
            progress.push(src.progress(theta));
        }
        RunHistory { progress, decode_errors }
    }
}

/// Uncoded baseline: same machinery, but per Remark VIII.1 it runs
/// d times as many iterations (each coded iteration computes a d-times
/// larger gradient).
pub fn uncoded_iters(coded_iters: usize, d: usize) -> usize {
    coded_iters * d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, GraphCode};
    use crate::data::LstsqData;
    use crate::decode::{FixedDecoder, OptimalGraphDecoder};
    use crate::prng::Rng;
    use crate::straggler::BernoulliStragglers;

    fn setup() -> (LstsqData, GraphCode) {
        let mut rng = Rng::new(0);
        let code = GraphCode::random_regular(16, 3, &mut rng);
        let data = LstsqData::generate(64, 8, 16, 0.3, &mut rng);
        (data, code)
    }

    #[test]
    fn no_stragglers_matches_batch_gd() {
        let (data, code) = setup();
        let dec = OptimalGraphDecoder::new(&code.graph);
        let mut strag = BernoulliStragglers::new(0.0, 1);
        let mut engine = SimulatedGcod {
            decoder: &dec,
            stragglers: &mut strag,
            step: StepSize::Const(0.05),
            rho: None,
            m: code.n_machines(),
            alpha_scale: 1.0,
        };
        let mut src = &data;
        let hist = engine.run(&mut src, &vec![0.0; 8], 30);
        // with p=0 optimal decoding is exact, so this IS batch GD
        let mut theta = vec![0.0; 8];
        for _ in 0..30 {
            let g = data.full_grad(&theta);
            crate::linalg::axpy(-0.05, &g, &mut theta);
        }
        assert!((hist.final_progress() - data.dist_to_opt(&theta)).abs() < 1e-10);
        assert!(hist.decode_errors.iter().all(|&e| e < 1e-18));
    }

    #[test]
    fn optimal_converges_with_stragglers() {
        let (data, code) = setup();
        let dec = OptimalGraphDecoder::new(&code.graph);
        let mut strag = BernoulliStragglers::new(0.2, 2);
        let mut engine = SimulatedGcod {
            decoder: &dec,
            stragglers: &mut strag,
            step: StepSize::Const(0.04),
            rho: Some(Rng::new(3).permutation(16)),
            m: code.n_machines(),
            alpha_scale: 1.0,
        };
        let mut src = &data;
        let e0 = data.dist_to_opt(&vec![0.0; 8]);
        let hist = engine.run(&mut src, &vec![0.0; 8], 120);
        assert!(
            hist.final_progress() < e0 * 0.05,
            "no convergence: {} -> {}",
            e0,
            hist.final_progress()
        );
    }

    #[test]
    fn optimal_beats_fixed_on_average() {
        let (data, code) = setup();
        let p = 0.25;
        let opt = OptimalGraphDecoder::new(&code.graph);
        let fixed = FixedDecoder::new(code.assignment(), p);
        let run = |dec: &dyn crate::decode::Decoder, seed: u64| {
            let mut strag = BernoulliStragglers::new(p, seed);
            let mut engine = SimulatedGcod {
                decoder: dec,
                stragglers: &mut strag,
                step: StepSize::Const(0.03),
                rho: None,
                m: code.n_machines(),
                alpha_scale: 1.0,
            };
            let mut src = &data;
            engine.run(&mut src, &vec![0.0; 8], 100).final_progress()
        };
        let mut opt_sum = 0.0;
        let mut fix_sum = 0.0;
        for s in 0..5 {
            opt_sum += run(&opt, 100 + s);
            fix_sum += run(&fixed, 100 + s);
        }
        assert!(
            opt_sum < fix_sum,
            "optimal {} should beat fixed {}",
            opt_sum / 5.0,
            fix_sum / 5.0
        );
    }

    #[test]
    fn run_with_reused_scratch_is_bit_identical() {
        let (data, code) = setup();
        let dec = OptimalGraphDecoder::new(&code.graph);
        let run = |scratch: &mut GdScratch| {
            let mut strag = BernoulliStragglers::new(0.2, 5);
            let mut engine = SimulatedGcod {
                decoder: &dec,
                stragglers: &mut strag,
                step: StepSize::Const(0.04),
                rho: None,
                m: code.n_machines(),
                alpha_scale: 1.0,
            };
            let mut src = &data;
            engine.run_with(&mut src, &vec![0.0; 8], 40, scratch)
        };
        let fresh = run(&mut GdScratch::new());
        let mut warm = GdScratch::new();
        let _ = run(&mut warm); // dirty every buffer
        let reused = run(&mut warm);
        assert_eq!(fresh.progress.len(), reused.progress.len());
        for (a, b) in fresh.progress.iter().zip(&reused.progress) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in fresh.decode_errors.iter().zip(&reused.decode_errors) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and run() (fresh scratch wrapper) agrees bit-for-bit
        let via_run = {
            let mut strag = BernoulliStragglers::new(0.2, 5);
            let mut engine = SimulatedGcod {
                decoder: &dec,
                stragglers: &mut strag,
                step: StepSize::Const(0.04),
                rho: None,
                m: code.n_machines(),
                alpha_scale: 1.0,
            };
            let mut src = &data;
            engine.run(&mut src, &vec![0.0; 8], 40)
        };
        assert_eq!(via_run.final_progress().to_bits(), fresh.final_progress().to_bits());
    }

    #[test]
    fn step_schedules() {
        let s = StepSize::simulated_grid(0);
        assert!((s.at(0) - 0.3).abs() < 1e-12);
        assert!(s.at(9) < s.at(0));
        let c = StepSize::cluster_grid(0);
        assert!((c.at(5) - 1e-6).abs() < 1e-18);
    }
}
