//! PJRT-backed coded-GD engines: the real three-layer request path.
//!
//! Per iteration the leader (this thread) samples stragglers, runs the
//! linear-time decoder, executes the AOT `block_grad` artifact for all
//! blocks in one dispatch, executes `decode_combine` with the decoded
//! alpha, and applies the SGD step — the Pallas kernels do all FLOPs.

use crate::data::LstsqData;
use crate::decode::Decoder;
use crate::runtime::{Runtime, Tensor};
use crate::straggler::StragglerModel;
use anyhow::{anyhow, Result};

/// Simulated GCOD (Algorithm 3) where gradients and the combine run on
/// the PJRT artifacts.
pub struct PjrtGcod<'a> {
    pub rt: &'a Runtime,
    pub decoder: &'a dyn Decoder,
    pub stragglers: &'a mut dyn StragglerModel,
    pub m: usize,
    pub step: super::StepSize,
    /// optional block shuffle rho: data block i -> assignment row rho[i]
    pub rho: Option<Vec<usize>>,
}

impl PjrtGcod<'_> {
    /// Run `iters` iterations on `data`, using the artifacts matching
    /// its (n, b, k) shape. Returns the progress history |theta-theta*|^2.
    pub fn run(
        &mut self,
        data: &LstsqData,
        theta0: &[f64],
        iters: usize,
    ) -> Result<super::RunHistory> {
        let (n, b, k) = (data.n_blocks, data.b, data.k);
        let grad_name = self
            .rt
            .manifest
            .find_block_grad(n, b, k)
            .ok_or_else(|| {
                anyhow!("no block_grad artifact for shape ({n},{b},{k}); re-run `make artifacts`")
            })?
            .name
            .clone();
        let combine_name = self
            .rt
            .manifest
            .find_decode_combine(n, k)
            .ok_or_else(|| anyhow!("no decode_combine artifact for shape ({n},{k})"))?
            .name
            .clone();
        let grad_exe = self.rt.load(&grad_name)?;
        let combine_exe = self.rt.load(&combine_name)?;
        let (xb, yb) = data.to_f32_buffers();
        // upload the static data once; only theta/alpha move per iter
        let x_buf = grad_exe.upload(&Tensor::f32(&[n, b, k], xb), &self.rt.client)?;
        let y_buf = grad_exe.upload(&Tensor::f32(&[n, b], yb), &self.rt.client)?;

        let mut theta: Vec<f64> = theta0.to_vec();
        let mut progress = Vec::with_capacity(iters + 1);
        let mut decode_errors = Vec::with_capacity(iters);
        progress.push(data.dist_to_opt(&theta));
        for t in 0..iters {
            let mask = self.stragglers.sample(self.m);
            let dec = self.decoder.decode(&mask);
            decode_errors.push(dec.error_sq());
            // alpha routed through the shuffle: block i weight alpha[rho[i]]
            let alpha32: Vec<f32> = (0..n)
                .map(|i| match &self.rho {
                    Some(rho) => dec.alpha[rho[i]] as f32,
                    None => dec.alpha[i] as f32,
                })
                .collect();
            let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
            let theta_buf = grad_exe.upload(&Tensor::f32(&[k], theta32), &self.rt.client)?;
            // L1 kernel 1+2: all block gradients in one dispatch
            let g_out = grad_exe.run_b(&[&theta_buf, &x_buf, &y_buf])?;
            let g = g_out.into_iter().next().unwrap();
            // L1 combine kernel: u = G^T alpha
            let alpha_t = Tensor::f32(&[n], alpha32);
            let u = combine_exe
                .run(&[g, alpha_t])?
                .into_iter()
                .next()
                .unwrap()
                .into_f32()?;
            let gamma = self.step.at(t);
            for c in 0..k {
                theta[c] -= gamma * u[c] as f64;
            }
            progress.push(data.dist_to_opt(&theta));
        }
        Ok(super::RunHistory { progress, decode_errors })
    }
}

/// Coded training of the AOT transformer (the E2E driver's engine).
pub struct PjrtTransformerTrainer<'a> {
    pub rt: &'a Runtime,
    pub decoder: &'a dyn Decoder,
    pub stragglers: &'a mut dyn StragglerModel,
    pub m: usize,
    pub gamma: f64,
}

#[derive(Debug, Clone)]
pub struct TransformerRun {
    /// mean per-block training loss each iteration
    pub train_loss: Vec<f64>,
    /// held-out eval loss every `eval_every` iterations: (iter, loss)
    pub eval_loss: Vec<(usize, f64)>,
    pub final_params: Vec<f32>,
}

impl PjrtTransformerTrainer<'_> {
    /// `tokens_all`: (n_blocks, batch, seq+1) i32 blocks; `eval_tokens`:
    /// one (batch, seq+1) held-out block.
    pub fn run(
        &mut self,
        tokens_all: &[i32],
        eval_tokens: &[i32],
        iters: usize,
        eval_every: usize,
        rho: Option<&[usize]>,
    ) -> Result<TransformerRun> {
        let tfm = self
            .rt
            .manifest
            .transformer
            .clone()
            .ok_or_else(|| anyhow!("manifest has no transformer metadata"))?;
        let (nb, batch, s1) = (tfm.n_blocks, tfm.batch, tfm.seq_len + 1);
        assert_eq!(tokens_all.len(), nb * batch * s1, "token blocks shape");
        assert_eq!(eval_tokens.len(), batch * s1, "eval tokens shape");
        let p_dim = tfm.n_params;
        let grad_exe = self.rt.load("tfm_block_grad_all")?;
        let eval_exe = self.rt.load("tfm_eval_loss")?;
        let tokens_buf = grad_exe.upload(
            &Tensor::i32(&[nb, batch, s1], tokens_all.to_vec()),
            &self.rt.client,
        )?;
        let eval_t = Tensor::i32(&[batch, s1], eval_tokens.to_vec());

        let mut params: Vec<f32> = self.rt.read_transformer_init()?;
        let mut train_loss = Vec::with_capacity(iters);
        let mut eval_loss = Vec::new();
        for t in 0..iters {
            let mask = self.stragglers.sample(self.m);
            let dec = self.decoder.decode(&mask);
            let params_buf =
                grad_exe.upload(&Tensor::f32(&[p_dim], params.clone()), &self.rt.client)?;
            let out = grad_exe.run_b(&[&params_buf, &tokens_buf])?;
            let mut it = out.into_iter();
            let grads = it.next().unwrap().into_f32()?; // (nb, P)
            let losses = it.next().unwrap().into_f32()?; // (nb,)
            // coded update: params -= gamma * sum_i alpha_i grad_i
            for i in 0..nb {
                let a = match rho {
                    Some(r) => dec.alpha[r[i]],
                    None => dec.alpha[i],
                } as f32;
                if a != 0.0 {
                    let row = &grads[i * p_dim..(i + 1) * p_dim];
                    let ga = self.gamma as f32 * a;
                    for c in 0..p_dim {
                        params[c] -= ga * row[c];
                    }
                }
            }
            // with loss_scale = 1/(nb*batch*seq), sum_i f_i IS the
            // global mean next-token CE (test_sum_of_block_losses_...)
            let mean_loss: f64 = losses.iter().map(|&l| l as f64).sum();
            train_loss.push(mean_loss);
            if t % eval_every == 0 || t + 1 == iters {
                let out = eval_exe.run(&[Tensor::f32(&[p_dim], params.clone()), eval_t.clone()])?;
                eval_loss.push((t, out[0].as_f32()?[0] as f64));
            }
        }
        Ok(TransformerRun { train_loss, eval_loss, final_params: params })
    }
}

// Integration tests for these engines live in rust/tests/ (they need
// built artifacts on disk).
