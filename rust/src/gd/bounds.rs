//! Convergence-theory calculators: the closed-form rates and floors the
//! paper proves, used by benches to print "theory vs measured" columns.

/// Inputs shared by the convergence propositions: f = sum f_i is
/// mu-strongly convex with L-Lipschitz gradient, each grad f_i is
/// L'-Lipschitz, sigma^2 = sum_i |grad f_i(theta*)|^2.
#[derive(Clone, Copy, Debug)]
pub struct ProblemConstants {
    pub mu: f64,
    pub l: f64,
    pub l_prime: f64,
    pub sigma_sq: f64,
    pub n: usize,
}

/// Proposition VI.1: expected squared distance after k steps of
/// SGD-ALG with E[beta]=1, r = E|beta-1|^2/n, s = |E (beta-1)(beta-1)^T|.
pub fn prop_vi1_bound(
    c: &ProblemConstants,
    r: f64,
    s: f64,
    gamma: f64,
    k: usize,
    dist0_sq: f64,
) -> f64 {
    let damp = 1.0 - 2.0 * gamma * c.mu * (1.0 - gamma * (s * c.l_prime + c.l));
    let floor = gamma * r * (1.0 + 1.0 / (c.n as f64 - 1.0)) * c.sigma_sq
        / (c.mu * (1.0 - gamma * (s * c.l_prime + c.l)));
    damp.max(0.0).powi(k as i32) * dist0_sq + floor
}

/// Corollary VI.2: the step size and iteration count reaching accuracy
/// eps from dist0_sq. Returns (gamma, k).
pub fn cor_vi2_schedule(
    c: &ProblemConstants,
    r: f64,
    s: f64,
    eps: f64,
    dist0_sq: f64,
) -> (f64, f64) {
    let n1 = 1.0 + 1.0 / (c.n as f64 - 1.0);
    let gamma = c.mu * eps
        / (2.0 * c.mu * eps * (s * c.l_prime + c.l) + 2.0 * r * n1 * c.sigma_sq);
    let k = 2.0 * (2.0 * dist0_sq / eps).ln()
        * (s * c.l_prime / c.mu + c.l / c.mu + r * n1 * c.sigma_sq / (c.mu * c.mu * eps));
    (gamma, k.max(0.0))
}

/// Corollary VII.2 (adversarial): with per-iteration decoding error
/// |alpha - 1|^2 <= r_sq and mu > sqrt(r) L', gradient descent reaches
/// the noise floor  4 r sigma^2 / (mu - sqrt(mu r L'))^2.
/// Returns (iteration bound, floor); None if the strong-convexity
/// condition fails and no guarantee exists.
pub fn cor_vii2(c: &ProblemConstants, r_sq: f64, dist0_sq: f64) -> Option<(f64, f64)> {
    let r = r_sq;
    if c.mu <= (r * c.l_prime * c.mu).sqrt() {
        return None;
    }
    let denom = c.mu - (c.mu * r * c.l_prime).sqrt();
    let floor = 4.0 * r * c.sigma_sq / (denom * denom);
    let k = 3.0 * (c.l + 2.0 * r.sqrt() * c.l_prime).powi(2)
        * ((c.mu * c.mu * dist0_sq / (2.0 * r * c.sigma_sq)).max(1.0)).ln()
        / (denom * denom);
    Some((k.max(0.0), floor))
}

/// Proposition VI.3 headline iteration count for graph schemes with
/// spectral gap d - o(d): k = 2 log(eps0/eps) (L/mu
///  + log^2(n) p^{2d-o(d)} L'/mu + p^{d-o(d)} sigma^2/(mu^2 eps)).
/// We drop the o(d) slack (exact exponent d) for a reference curve.
pub fn prop_vi3_iters(c: &ProblemConstants, p: f64, d: f64, eps: f64, dist0_sq: f64) -> f64 {
    let logn = (c.n as f64).ln();
    let pd = p.powf(d);
    2.0 * (dist0_sq / eps).ln().max(0.0)
        * (c.l / c.mu
            + logn * logn * p.powf(2.0 * d) * c.l_prime / c.mu
            + pd * c.sigma_sq / (c.mu * c.mu * eps))
}

/// Rough spectral constants for the paper's Gaussian regression data
/// (Remark VII.3): mu ~ 2N(1 - sqrt(k/N))... but since rows are scaled
/// by 1/sqrt(k) in our generator, X^T X ~ (N/k) I at N >> k; we expose
/// the empirical estimator instead.
pub fn estimate_lstsq_constants(
    data: &crate::data::LstsqData,
    rng: &mut crate::prng::Rng,
) -> ProblemConstants {
    // power-iterate X^T X for L = lambda_max; mu via inverse-ish bound
    // from trace: lambda_min >= trace - (n-1) lambda_max is useless;
    // instead use the Gaussian concentration estimate (Remark VII.3)
    // adapted to our 1/sqrt(k) row scaling:
    //   spectrum of X^T X concentrates in (N/k)(1 ± sqrt(k/N))^2
    let n = data.n_points() as f64;
    let k = data.k as f64;
    let ratio = (k / n).sqrt();
    let base = n / k;
    let mu = base * (1.0 - ratio).max(0.05).powi(2);
    // empirical L via power iteration (20 iters is plenty for a bound)
    let gram_op = GramOp { x: &data.x };
    let (l, _) = crate::linalg::power::power_iteration(&gram_op, 60, 1e-9, rng);
    // L' = max block operator norm <= max block frobenius^2
    let mut l_prime = 0.0f64;
    for blk in 0..data.n_blocks {
        let mut fro = 0.0;
        for r in 0..data.b {
            let row = data.x.row(blk * data.b + r);
            fro += crate::linalg::dot(row, row);
        }
        l_prime = l_prime.max(fro);
    }
    let g = data.block_grads(&data.theta_star);
    let sigma_sq: f64 = (0..data.n_blocks)
        .map(|i| crate::linalg::dot(g.row(i), g.row(i)))
        .sum();
    ProblemConstants { mu, l, l_prime, sigma_sq, n: data.n_blocks }
}

struct GramOp<'a> {
    x: &'a crate::linalg::Mat,
}

impl crate::linalg::power::SymmetricOp for GramOp<'_> {
    fn dim(&self) -> usize {
        self.x.cols
    }
    fn apply(&self, v: &[f64], y: &mut [f64]) {
        let xv = self.x.mul_vec(v);
        y.copy_from_slice(&self.x.t_mul_vec(&xv));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn consts() -> ProblemConstants {
        ProblemConstants { mu: 1.0, l: 4.0, l_prime: 2.0, sigma_sq: 10.0, n: 64 }
    }

    #[test]
    fn vi1_contracts_without_noise() {
        let c = consts();
        // r = 0 (exact recovery): bound decays geometrically to 0
        let b10 = prop_vi1_bound(&c, 0.0, 0.0, 0.1, 10, 1.0);
        let b50 = prop_vi1_bound(&c, 0.0, 0.0, 0.1, 50, 1.0);
        assert!(b50 < b10 && b10 < 1.0);
        assert!(b50 < 3e-3); // 0.88^50 ~ 1.7e-3
    }

    #[test]
    fn vi1_floor_scales_with_r() {
        let c = consts();
        let f1 = prop_vi1_bound(&c, 0.01, 0.0, 0.05, 10_000, 1.0);
        let f2 = prop_vi1_bound(&c, 0.02, 0.0, 0.05, 10_000, 1.0);
        assert!((f2 / f1 - 2.0).abs() < 0.01, "{f1} {f2}");
    }

    #[test]
    fn vi2_schedule_hits_eps_via_vi1() {
        let c = consts();
        let (gamma, k) = cor_vi2_schedule(&c, 0.01, 0.1, 0.05, 1.0);
        assert!(gamma > 0.0 && k > 0.0);
        let reached = prop_vi1_bound(&c, 0.01, 0.1, gamma, k.ceil() as usize, 1.0);
        assert!(reached <= 0.05 * 1.05, "reached={reached}");
    }

    #[test]
    fn vi2_iterations_increase_as_eps_shrinks() {
        let c = consts();
        let (_, k1) = cor_vi2_schedule(&c, 0.01, 0.1, 0.1, 1.0);
        let (_, k2) = cor_vi2_schedule(&c, 0.01, 0.1, 0.001, 1.0);
        assert!(k2 > k1);
    }

    #[test]
    fn vii2_floor_linear_in_r() {
        let c = consts();
        let (_, f1) = cor_vii2(&c, 0.001, 1.0).unwrap();
        let (_, f2) = cor_vii2(&c, 0.002, 1.0).unwrap();
        // floor = 4 r sigma^2 / (mu - sqrt(mu r L'))^2 — near-linear for small r
        assert!(f2 / f1 > 1.8 && f2 / f1 < 2.3, "{f1} {f2}");
    }

    #[test]
    fn vii2_requires_strong_convexity_margin() {
        let mut c = consts();
        c.l_prime = 1e6; // adversarial error overwhelms mu
        assert!(cor_vii2(&c, 1.0, 1.0).is_none());
    }

    #[test]
    fn vi3_decays_with_replication() {
        let c = consts();
        let k3 = prop_vi3_iters(&c, 0.2, 3.0, 1e-3, 1.0);
        let k6 = prop_vi3_iters(&c, 0.2, 6.0, 1e-3, 1.0);
        assert!(k6 < k3);
    }

    #[test]
    fn lstsq_constants_reasonable() {
        let mut rng = Rng::new(0);
        let data = crate::data::LstsqData::generate(128, 8, 16, 0.1, &mut rng);
        let c = estimate_lstsq_constants(&data, &mut rng);
        // L must upper-bound mu, sigma near noise level
        assert!(c.l >= c.mu, "L={} mu={}", c.l, c.mu);
        assert!(c.l_prime > 0.0 && c.sigma_sq >= 0.0);
        // with rows ~ N(0, I/k): X^T X ~ (N/k) I = 16 I
        assert!(c.l > 8.0 && c.l < 40.0, "L={}", c.l);
    }
}
