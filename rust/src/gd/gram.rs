//! Gram-cached least-squares gradients.
//!
//! For the paper's least-squares workload the block gradient is
//! `∇f_i(θ) = X_iᵀ(X_i θ − y_i) = G_i θ − c_i` with the per-block Gram
//! matrix `G_i = X_iᵀX_i` (k x k) and `c_i = X_iᵀ y_i`. Both are
//! independent of θ, so a GD run can pay one pass over the data matrix
//! up front ([`GramCache::new`], ~N·k² flops on the [`syrk_into`]
//! kernel) and then compute every iteration's full gradient set as n
//! small `gemv`s (~n·k² flops) instead of streaming all N rows again
//! (~2·N·k flops). With b = N/n rows per block the per-iteration ratio
//! is k/(2b): the cache wins when blocks are tall (b ≫ k, the Fig. 4
//! regime and the `gd-final` sweep defaults) and loses when blocks are
//! short (the Fig. 5 regime-2 shape, b = 3 ≪ k = 200) — which is why
//! [`GramCache::pays_off`] exists and the sweep layer picks per config.
//!
//! Numerics: the Gram form is algebraically equal to the streaming
//! form but rounds differently (and the gemv kernel reduces 4-wide),
//! so the two sources agree to tolerance, not bits. Each source is
//! individually deterministic: for a fixed config the cache build and
//! every gradient are pure functions of the data, so sweep results
//! remain bit-exact across threads, shards and processes either way.

use crate::data::LstsqData;
use crate::gd::GradSource;
use crate::linalg::{dist2_sq, LinalgBackend, Mat};

/// Precomputed per-block `(G_i, c_i)` pairs for one [`LstsqData`].
/// Immutable after construction; implements [`GradSource`] through a
/// shared reference (`&GramCache`), so one build can serve every trial
/// of a sweep concurrently.
pub struct GramCache {
    n_blocks: usize,
    k: usize,
    /// per-block Gram matrices, packed row-major: block i occupies
    /// `[i*k*k, (i+1)*k*k)`
    gram: Vec<f64>,
    /// c_i = X_i^T y_i (n_blocks x k)
    c: Mat,
    /// copied from the data so progress() needs no second borrow
    theta_star: Vec<f64>,
    /// which linalg tier built the cache and serves its gemvs; part of
    /// the cache's identity (exact and fast caches round differently)
    backend: LinalgBackend,
}

impl GramCache {
    /// One pass over the data matrix: `G_i` via the SYRK kernel on the
    /// zero-copy block views, `c_i` as a fused transpose-gather.
    /// Exact-tier build — byte-identical to every pre-backend cache.
    pub fn new(data: &LstsqData) -> Self {
        Self::new_backend(data, LinalgBackend::Exact)
    }

    /// [`GramCache::new`] on an explicit linalg tier: the per-block
    /// SYRK and every served gemv dispatch through `backend`. The `c_i`
    /// gather stays on the shared `axpy` — element-wise updates carry
    /// no reduction order, so they are bit-identical under any tier.
    pub fn new_backend(data: &LstsqData, backend: LinalgBackend) -> Self {
        let (n, k) = (data.n_blocks, data.k);
        let mut gram = vec![0.0; n * k * k];
        let mut c = Mat::zeros(n, k);
        let mut gblk = Mat::zeros(k, k);
        for i in 0..n {
            let bx = data.block_x(i);
            backend.syrk_into(bx, k, &mut gblk);
            gram[i * k * k..(i + 1) * k * k].copy_from_slice(&gblk.data);
            let ci = c.row_mut(i);
            for (r, &yr) in data.block_y(i).iter().enumerate() {
                if yr != 0.0 {
                    crate::linalg::axpy(yr, &bx[r * k..(r + 1) * k], ci);
                }
            }
        }
        Self { n_blocks: n, k, gram, c, theta_star: data.theta_star.clone(), backend }
    }

    /// [`GramCache::new`] with the per-block SYRK builds fanned across
    /// `threads` scoped workers (the same `std::thread::scope` model as
    /// the sweep engine's workers — `gd-final`/`adv-gd` pass
    /// `engine.threads()`). **Byte-identical to the serial build**:
    /// blocks are partitioned contiguously, each worker owns a disjoint
    /// slice of the output arrays, and every block's `(G_i, c_i)` is
    /// the same sequence of float operations regardless of which worker
    /// computes it — scheduling can reorder nothing that reaches the
    /// output. `rust/tests/gd_gram.rs` pins the bit-equality.
    pub fn new_parallel(data: &LstsqData, threads: usize) -> Self {
        Self::new_parallel_backend(data, threads, LinalgBackend::Exact)
    }

    /// [`GramCache::new_parallel`] on an explicit linalg tier. The
    /// byte-identical-to-serial contract holds per tier: every block's
    /// `(G_i, c_i)` is the same op sequence on `backend` whichever
    /// worker computes it.
    pub fn new_parallel_backend(data: &LstsqData, threads: usize, backend: LinalgBackend) -> Self {
        let (n, k) = (data.n_blocks, data.k);
        let threads = threads.clamp(1, n.max(1));
        if threads <= 1 || n < 2 {
            return Self::new_backend(data, backend);
        }
        let mut gram = vec![0.0; n * k * k];
        let mut c = Mat::zeros(n, k);
        std::thread::scope(|s| {
            let mut gram_rest: &mut [f64] = &mut gram;
            let mut c_rest: &mut [f64] = &mut c.data;
            let base = n / threads;
            let rem = n % threads;
            let mut first = 0usize;
            for w in 0..threads {
                let cnt = base + usize::from(w < rem);
                if cnt == 0 {
                    continue;
                }
                let (gchunk, grest) =
                    std::mem::take(&mut gram_rest).split_at_mut(cnt * k * k);
                gram_rest = grest;
                let (cchunk, crest) = std::mem::take(&mut c_rest).split_at_mut(cnt * k);
                c_rest = crest;
                let blk0 = first;
                first += cnt;
                s.spawn(move || {
                    let mut gblk = Mat::zeros(k, k);
                    for i in 0..cnt {
                        let bx = data.block_x(blk0 + i);
                        backend.syrk_into(bx, k, &mut gblk);
                        gchunk[i * k * k..(i + 1) * k * k].copy_from_slice(&gblk.data);
                        let ci = &mut cchunk[i * k..(i + 1) * k];
                        for (r, &yr) in data.block_y(blk0 + i).iter().enumerate() {
                            if yr != 0.0 {
                                crate::linalg::axpy(yr, &bx[r * k..(r + 1) * k], ci);
                            }
                        }
                    }
                });
            }
        });
        Self { n_blocks: n, k, gram, c, theta_star: data.theta_star.clone(), backend }
    }

    /// Whether the Gram path beats streaming for a (n_points, dim,
    /// n_blocks) shape: per-iteration it trades ~2·N·k streaming flops
    /// for ~n·k², i.e. wins iff k < 2b. `k <= b` is the conservative
    /// cut actually used (it also leaves room to amortize the ~N·k²
    /// build across a run) — a pure function of the sweep config, so
    /// the choice is identical in every shard and thread. The cut is
    /// provisional until `bench_gd_perf`'s regime-2 section produces
    /// both sides of the measured curve — and note that moving it is
    /// byte-affecting for `grad=auto` sweeps whose shape crosses the
    /// cut (the two kernels agree only to rounding), so a re-tune
    /// lands like any other byte-affecting change: schema bump +
    /// golden re-bless.
    pub fn pays_off(n_points: usize, dim: usize, n_blocks: usize) -> bool {
        // b = rows per block; guard degenerate shapes
        n_blocks > 0 && dim <= n_points / n_blocks
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn dim(&self) -> usize {
        self.k
    }

    /// Block i's cached Gram matrix as a packed (k x k) slice.
    pub fn block_gram(&self, i: usize) -> &[f64] {
        &self.gram[i * self.k * self.k..(i + 1) * self.k * self.k]
    }

    /// Block i's cached c_i = X_i^T y_i.
    pub fn block_c(&self, i: usize) -> &[f64] {
        self.c.row(i)
    }

    /// The linalg tier this cache was built on (and serves gemvs with).
    pub fn backend(&self) -> LinalgBackend {
        self.backend
    }
}

impl GradSource for &GramCache {
    fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn block_grads_into(&mut self, theta: &[f64], out: &mut Mat) {
        out.reset(self.n_blocks, self.k);
        for i in 0..self.n_blocks {
            let row = &mut out.data[i * self.k..(i + 1) * self.k];
            // row = G_i theta
            self.backend.gemv_slice_into(1.0, self.block_gram(i), self.k, theta, 0.0, row);
            // row -= c_i
            crate::linalg::axpy(-1.0, self.c.row(i), row);
        }
    }

    fn progress(&mut self, theta: &[f64]) -> f64 {
        dist2_sq(theta, &self.theta_star)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn gram_grads_match_streaming_to_tolerance() {
        let mut rng = Rng::new(21);
        for (n_points, k, blocks) in [(40usize, 5usize, 8usize), (96, 8, 4), (64, 16, 4)] {
            let data = LstsqData::generate(n_points, k, blocks, 0.5, &mut rng);
            let cache = GramCache::new(&data);
            let theta = rng.gaussian_vec(k, 1.0);
            let mut stream = &data;
            let mut gram = &cache;
            let gs = GradSource::block_grads(&mut stream, &theta);
            let gg = GradSource::block_grads(&mut gram, &theta);
            for (i, (a, b)) in gs.data.iter().zip(&gg.data).enumerate() {
                assert!(rel_close(*a, *b, 1e-9), "entry {i}: streaming {a} vs gram {b}");
            }
            // progress metric is the same function on both sources
            assert_eq!(
                GradSource::progress(&mut stream, &theta).to_bits(),
                GradSource::progress(&mut gram, &theta).to_bits()
            );
        }
    }

    #[test]
    fn cache_blocks_match_direct_products() {
        let mut rng = Rng::new(5);
        let data = LstsqData::generate(24, 4, 6, 0.3, &mut rng);
        let cache = GramCache::new(&data);
        for i in 0..6 {
            let bx = data.block_x(i);
            let gi = cache.block_gram(i);
            for a in 0..4 {
                for b in 0..4 {
                    let want: f64 = (0..4).map(|r| bx[r * 4 + a] * bx[r * 4 + b]).sum();
                    assert!(
                        rel_close(gi[a * 4 + b], want, 1e-12),
                        "block {i} ({a},{b}): {} vs {want}",
                        gi[a * 4 + b]
                    );
                }
            }
            let ci = cache.block_c(i);
            for a in 0..4 {
                let want: f64 =
                    (0..4).map(|r| bx[r * 4 + a] * data.block_y(i)[r]).sum();
                assert!(rel_close(ci[a], want, 1e-12), "block {i} c[{a}]: {} vs {want}", ci[a]);
            }
        }
    }

    #[test]
    fn parallel_build_is_bit_identical_to_serial() {
        let mut rng = Rng::new(33);
        // shapes straddling the worker count: fewer blocks than
        // workers, ragged split, and an even split
        for (n_points, k, blocks) in [(12usize, 3usize, 2usize), (70, 5, 7), (96, 8, 8)] {
            let data = LstsqData::generate(n_points, k, blocks, 0.4, &mut rng);
            let serial = GramCache::new(&data);
            for threads in [1usize, 3, 4, 16] {
                let par = GramCache::new_parallel(&data, threads);
                assert_eq!(par.n_blocks(), serial.n_blocks());
                assert_eq!(par.dim(), serial.dim());
                for i in 0..blocks {
                    for (a, b) in par.block_gram(i).iter().zip(serial.block_gram(i)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "gram block {i} threads={threads}");
                    }
                    for (a, b) in par.block_c(i).iter().zip(serial.block_c(i)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "c block {i} threads={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn fast_backend_cache_agrees_with_exact_and_stays_deterministic() {
        let mut rng = Rng::new(77);
        let data = LstsqData::generate(96, 8, 4, 0.5, &mut rng);
        let exact = GramCache::new(&data);
        let fast = GramCache::new_backend(&data, LinalgBackend::Fast);
        assert_eq!(exact.backend(), LinalgBackend::Exact);
        assert_eq!(fast.backend(), LinalgBackend::Fast);
        for i in 0..4 {
            // the tiers agree to tolerance on the SYRK outputs
            for (a, b) in exact.block_gram(i).iter().zip(fast.block_gram(i)) {
                assert!(rel_close(*a, *b, 1e-9), "gram block {i}: exact {a} vs fast {b}");
            }
            // the c_i gather is the shared element-wise axpy: bit-equal
            for (a, b) in exact.block_c(i).iter().zip(fast.block_c(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "c block {i}");
            }
        }
        // the parallel fast build keeps the byte-identical-to-serial
        // contract within its own tier
        let par = GramCache::new_parallel_backend(&data, 3, LinalgBackend::Fast);
        for i in 0..4 {
            for (a, b) in par.block_gram(i).iter().zip(fast.block_gram(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "parallel fast gram block {i}");
            }
        }
    }

    #[test]
    fn pays_off_heuristic() {
        // tall blocks (b = 1024 >= k = 32): gram wins
        assert!(GramCache::pays_off(65536, 32, 64));
        // the paper's Fig. 5 regime-2 shape (b = 3 << k = 200): streaming
        assert!(!GramCache::pays_off(6552, 200, 2184));
        // boundary b == k counts as paying off
        assert!(GramCache::pays_off(64, 8, 8));
        assert!(!GramCache::pays_off(64, 9, 8));
        // degenerate
        assert!(!GramCache::pays_off(0, 1, 0));
    }

    #[test]
    fn gd_on_gram_source_converges_like_streaming() {
        use crate::codes::{GradientCode, GraphCode};
        use crate::decode::OptimalGraphDecoder;
        use crate::gd::{SimulatedGcod, StepSize};
        use crate::straggler::BernoulliStragglers;
        let mut rng = Rng::new(0);
        let code = GraphCode::random_regular(16, 3, &mut rng);
        let data = LstsqData::generate(256, 8, 16, 0.3, &mut rng);
        let cache = GramCache::new(&data);
        let dec = OptimalGraphDecoder::new(&code.graph);
        let run = |gram: bool| {
            let mut strag = BernoulliStragglers::new(0.2, 7);
            let mut engine = SimulatedGcod {
                decoder: &dec,
                stragglers: &mut strag,
                step: StepSize::Const(0.01),
                rho: None,
                m: code.n_machines(),
                alpha_scale: 1.0,
            };
            if gram {
                let mut src = &cache;
                engine.run(&mut src, &[0.0; 8], 60).final_progress()
            } else {
                let mut src = &data;
                engine.run(&mut src, &[0.0; 8], 60).final_progress()
            }
        };
        let (es, eg) = (run(false), run(true));
        let e0 = data.dist_to_opt(&[0.0; 8]);
        assert!(es < e0 * 0.05, "streaming did not converge: {e0} -> {es}");
        assert!(rel_close(es, eg, 1e-6), "streaming {es} vs gram {eg}");
    }
}
