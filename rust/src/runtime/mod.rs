//! PJRT runtime: load and execute the AOT artifacts from the rust
//! request path. Compiled only with the `pjrt` feature: this module
//! (and `gd::pjrt`, the `ComputeBackend::Pjrt` worker path and the
//! PJRT integration tests) needs the `xla` and `anyhow` crates, which
//! are environment-provided (vendored registry / `[patch]`) — the
//! default offline build excludes them entirely.
//!
//! `make artifacts` (build time, python) lowers every L2 function to
//! HLO *text* and writes `artifacts/MANIFEST.json`; this module parses
//! the manifest, compiles artifacts on the PJRT CPU client on first
//! use, and exposes typed execute helpers. HLO text (not serialized
//! protos) is the interchange format — xla_extension 0.5.1 rejects
//! jax >= 0.5's 64-bit-instruction-id protos, while the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! `PjRtClient` wraps an `Rc`, so a `Runtime` is **not** `Send`: every
//! thread that executes artifacts builds its own `Runtime` (the
//! coordinator's workers each do this; see coordinator/).

pub mod manifest;

pub use manifest::{ArtifactMeta, Dtype, Manifest, TensorMeta, TransformerMeta};

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A host-side tensor to feed or read from an executable.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            t => bail!("unsupported output element type {t:?}"),
        }
    }
}

/// One compiled artifact plus its metadata.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    pub exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let mut out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let parts = out.decompose_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Upload a tensor to the device once; reuse across `run_b` calls
    /// (the hot-path variant: only the iterate changes per step).
    ///
    /// Uses `buffer_from_host_buffer` (kImmutableOnlyDuringCall — the
    /// copy is synchronous). Do NOT switch to `buffer_from_host_literal`:
    /// the TFRT CPU client's `BufferFromHostLiteral` is asynchronous and
    /// the literal would be freed before the transfer completes
    /// (observed as a size-check crash in abstract_tfrt_cpu_buffer.cc).
    pub fn upload(&self, t: &Tensor, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            Tensor::F32 { shape, data } => client.buffer_from_host_buffer(data, shape, None)?,
            Tensor::I32 { shape, data } => client.buffer_from_host_buffer(data, shape, None)?,
        };
        Ok(buf)
    }

    /// Execute with pre-uploaded device buffers.
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        let result = self.exe.execute_b(inputs)?;
        let mut out = result[0][0].to_literal_sync()?;
        let parts = out.decompose_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, m)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.shape() != m.shape.as_slice() {
                bail!(
                    "artifact {} input {i}: shape {:?} != manifest {:?}",
                    self.meta.name,
                    t.shape(),
                    m.shape
                );
            }
        }
        Ok(())
    }
}

/// Artifact registry + executable cache bound to one PJRT client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain MANIFEST.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("MANIFEST.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: $GCOD_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("GCOD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let loaded = Rc::new(LoadedArtifact { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Convenience: execute by name with host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run(inputs)
    }

    /// Read the transformer's initial flat parameters (f32 .bin).
    pub fn read_transformer_init(&self) -> Result<Vec<f32>> {
        let tfm = self
            .manifest
            .transformer
            .as_ref()
            .ok_or_else(|| anyhow!("no transformer metadata in manifest"))?;
        let bytes = std::fs::read(self.dir.join(&tfm.init_file))?;
        if bytes.len() != 4 * tfm.n_params {
            bail!("init file has {} bytes, expected {}", bytes.len(), 4 * tfm.n_params);
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.as_f32().is_ok());
        let i = Tensor::i32(&[2], vec![1, 2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_rejects_bad_shape() {
        Tensor::f32(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
        let ti = Tensor::i32(&[3], vec![7, 8, 9]);
        let back_i = Tensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(ti, back_i);
    }

    // Executable-level tests live in rust/tests/runtime_integration.rs
    // (they need built artifacts).
}
