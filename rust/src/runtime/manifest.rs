//! MANIFEST.json schema: the contract between `python/compile/aot.py`
//! and the rust runtime.

use crate::config::json::Json;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
    F64,
    Bf16,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "s32" => Dtype::S32,
            "f64" => Dtype::F64,
            "bf16" => Dtype::Bf16,
            _ => bail!("unknown dtype tag '{s}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

#[derive(Clone, Debug)]
pub struct TransformerMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub seq_len: usize,
    pub n_params: usize,
    pub n_blocks: usize,
    pub batch: usize,
    pub loss_scale: f64,
    pub init_file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub transformer: Option<TransformerMeta>,
}

fn tensor_meta(v: &Json) -> Result<TensorMeta> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor meta missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(
        v.get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor meta missing dtype"))?,
    )?;
    Ok(TensorMeta { shape, dtype })
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest missing usize field '{key}'"))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing string field '{key}'"))?
        .to_string())
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut artifacts = Vec::new();
        for row in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?
        {
            let inputs = row
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing inputs"))?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            let outputs = row
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing outputs"))?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: req_str(row, "name")?,
                file: req_str(row, "file")?,
                inputs,
                outputs,
            });
        }
        let transformer = match root.get("transformer") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TransformerMeta {
                vocab: req_usize(t, "vocab")?,
                d_model: req_usize(t, "d_model")?,
                n_head: req_usize(t, "n_head")?,
                n_layer: req_usize(t, "n_layer")?,
                seq_len: req_usize(t, "seq_len")?,
                n_params: req_usize(t, "n_params")?,
                n_blocks: req_usize(t, "n_blocks")?,
                batch: req_usize(t, "batch")?,
                loss_scale: t
                    .get("loss_scale")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("transformer missing loss_scale"))?,
                init_file: req_str(t, "init_file")?,
            }),
        };
        Ok(Self { artifacts, transformer })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the block_grad artifact matching an (n, b, k) shape.
    pub fn find_block_grad(&self, n: usize, b: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.name.starts_with("block_grad_")
                && a.inputs.len() == 3
                && a.inputs[1].shape == vec![n, b, k]
        })
    }

    /// Find the worker_grad artifact for (blocks_per_machine, b, k).
    pub fn find_worker_grad(&self, blocks: usize, b: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.name.starts_with("worker_grad_")
                && a.inputs.len() == 3
                && a.inputs[1].shape == vec![blocks, b, k]
        })
    }

    /// Find the decode_combine artifact for (n, k).
    pub fn find_decode_combine(&self, n: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.name.starts_with("decode_combine_")
                && a.inputs.len() == 2
                && a.inputs[0].shape == vec![n, k]
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "block_grad_t_4x2x8", "file": "bg.hlo.txt",
         "inputs": [{"shape": [8], "dtype": "f32"},
                     {"shape": [4, 2, 8], "dtype": "f32"},
                     {"shape": [4, 2], "dtype": "f32"}],
         "outputs": [{"shape": [4, 8], "dtype": "f32"}]},
        {"name": "decode_combine_t_4x8", "file": "dc.hlo.txt",
         "inputs": [{"shape": [4, 8], "dtype": "f32"},
                     {"shape": [4], "dtype": "f32"}],
         "outputs": [{"shape": [8], "dtype": "f32"}]}
      ],
      "transformer": {"vocab": 256, "d_model": 128, "n_head": 4,
        "n_layer": 2, "seq_len": 64, "n_params": 437760, "n_blocks": 16,
        "batch": 8, "loss_scale": 1.22e-4, "init_file": "init.bin"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.artifact("block_grad_t_4x2x8").unwrap();
        assert_eq!(a.inputs[1].shape, vec![4, 2, 8]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.outputs[0].elements(), 32);
        let t = m.transformer.as_ref().unwrap();
        assert_eq!(t.n_params, 437760);
        assert!((t.loss_scale - 1.22e-4).abs() < 1e-12);
    }

    #[test]
    fn shape_lookups() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find_block_grad(4, 2, 8).is_some());
        assert!(m.find_block_grad(4, 2, 9).is_none());
        assert!(m.find_decode_combine(4, 8).is_some());
        assert!(m.find_worker_grad(2, 2, 8).is_none());
    }

    #[test]
    fn null_transformer_ok() {
        let m = Manifest::parse(r#"{"artifacts": [], "transformer": null}"#).unwrap();
        assert!(m.transformer.is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
        assert!(Dtype::parse("f16").is_err());
    }
}
