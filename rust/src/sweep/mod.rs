//! Parallel Monte-Carlo trial engine.
//!
//! Every experiment in the paper — Fig. 3's decoding-error curves,
//! Fig. 5's simulated GD error bars, Table I's expected-error column,
//! the adversarial searches — is "run the decoder against N straggler
//! patterns and reduce". [`TrialEngine`] fans those N trials across
//! `std::thread::scope` workers while keeping the results **bit-for-bit
//! independent of the thread count**:
//!
//! * **Per-trial PRNG substreams.** Trial `t` always draws from
//!   [`TrialEngine::trial_rng`]`(t)`, a SplitMix64-derived xoshiro
//!   stream keyed only by `(seed, t)` — never from a shared sequential
//!   stream — so the mask for trial 17 is the same whether 1 or 32
//!   threads ran the sweep.
//! * **Chunk-scoped worker state.** Trials are dealt in fixed-size
//!   chunks (an atomic cursor hands chunks to idle workers). The
//!   per-worker context — decoder scratch, output buffers, LSQR
//!   warm-start state — is rebuilt at every chunk boundary, so any
//!   carry-over between consecutive trials (e.g.
//!   [`crate::decode::GenericOptimalDecoder`]'s warm start) sees a
//!   deterministic trial sequence regardless of which thread got the
//!   chunk.
//! * **Ordered reduction.** [`TrialEngine::run_map`] returns results in
//!   trial order; reductions (into [`Stats`] or anything else) then fold
//!   sequentially, which is trivially order-independent of scheduling.
//!   (A streaming alternative that skips materializing per-trial
//!   results can fold per-chunk partials in chunk order via
//!   [`Stats::merge`] with the same guarantee.)
//!
//! Determinism contract: `engine.run_map(...)` with the same seed,
//! trial count, chunk size and per-trial closure returns identical bits
//! for every `threads` value. The sweep tests in
//! `rust/tests/sweep_determinism.rs` pin this.

use crate::decode::{Decoder, Decoding};
use crate::metrics::Stats;
use crate::prng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

pub mod kernels;
pub mod shard;

/// Default trials per chunk: big enough to amortize context
/// construction and keep warm starts effective, small enough to load
/// balance across workers.
pub const DEFAULT_CHUNK: usize = 32;

#[derive(Clone, Debug)]
pub struct TrialEngine {
    threads: usize,
    seed: u64,
    chunk: usize,
}

impl TrialEngine {
    pub fn new(threads: usize, seed: u64) -> Self {
        Self { threads: threads.max(1), seed, chunk: DEFAULT_CHUNK }
    }

    /// One worker per available core.
    pub fn auto(seed: u64) -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(threads, seed)
    }

    /// Override the chunk size. NOTE: the chunk size is part of the
    /// determinism contract — results are identical across thread
    /// counts, but changing the chunk size re-scopes stateful contexts
    /// (warm starts) and may change low-order bits.
    pub fn with_chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The deterministic PRNG substream for one trial, independent of
    /// thread assignment and of every other trial's stream (keyed only
    /// by `(seed, trial)` via [`crate::prng::substream`]).
    pub fn trial_rng(&self, trial: usize) -> Rng {
        crate::prng::substream(self.seed, trial as u64)
    }

    /// Run `n_trials` trials and collect their results **in trial
    /// order**. `make_ctx(chunk_index)` builds the mutable per-chunk
    /// context (decoder + scratch buffers); `trial_fn(ctx, trial, rng)`
    /// runs one trial on its deterministic substream.
    pub fn run_map<Ctx, T, FC, FT>(&self, n_trials: usize, make_ctx: FC, trial_fn: FT) -> Vec<T>
    where
        FC: Fn(usize) -> Ctx + Sync,
        FT: Fn(&mut Ctx, usize, &mut Rng) -> T + Sync,
        T: Send,
    {
        self.run_range_map(0, n_trials, make_ctx, trial_fn)
    }

    /// Run the trial subrange `[lo, hi)` of a conceptual `[0, N)` sweep
    /// and collect results in trial order. This is the shard primitive:
    /// chunks are aligned to the engine's *global* chunk grid (chunk `c`
    /// always covers trials `[c*chunk, (c+1)*chunk)` no matter which
    /// range is requested), and when `lo` falls inside a chunk the
    /// worker silently **replays** the chunk's leading trials to rebuild
    /// the per-chunk context state (e.g. LSQR warm starts) before
    /// recording — so every recorded trial value is bit-identical to the
    /// value a full `[0, N)` run produces, for *any* split of the range
    /// across shards, processes, or threads. The replay overhead is at
    /// most `chunk - 1` trials per shard.
    pub fn run_range_map<Ctx, T, FC, FT>(
        &self,
        lo: usize,
        hi: usize,
        make_ctx: FC,
        trial_fn: FT,
    ) -> Vec<T>
    where
        FC: Fn(usize) -> Ctx + Sync,
        FT: Fn(&mut Ctx, usize, &mut Rng) -> T + Sync,
        T: Send,
    {
        assert!(lo <= hi, "bad trial range [{lo}, {hi})");
        if lo == hi {
            return Vec::new();
        }
        let n_out = hi - lo;
        let c_lo = lo / self.chunk; // first chunk on the global grid
        let c_hi = hi.div_ceil(self.chunk); // one past the last chunk
        let n_chunks = c_hi - c_lo;
        let run_chunk = |chunk_idx: usize, sink: &mut Vec<(usize, T)>| {
            let start = chunk_idx * self.chunk; // global-grid chunk start
            let end = (start + self.chunk).min(hi);
            let mut ctx = make_ctx(chunk_idx);
            for t in start..end {
                let mut rng = self.trial_rng(t);
                let v = trial_fn(&mut ctx, t, &mut rng);
                // trials below lo are warm-up replay: state only
                if t >= lo {
                    sink.push((t, v));
                }
            }
        };

        let mut parts: Vec<Vec<(usize, T)>> = Vec::new();
        if self.threads == 1 || n_chunks == 1 {
            let mut sink = Vec::with_capacity(n_out);
            for c in c_lo..c_hi {
                run_chunk(c, &mut sink);
            }
            parts.push(sink);
        } else {
            let cursor = AtomicUsize::new(0);
            let workers = self.threads.min(n_chunks);
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers)
                    .map(|_| {
                        s.spawn(|| {
                            let mut sink = Vec::new();
                            loop {
                                let c = c_lo + cursor.fetch_add(1, Ordering::Relaxed);
                                if c >= c_hi {
                                    return sink;
                                }
                                run_chunk(c, &mut sink);
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    parts.push(h.join().expect("sweep worker panicked"));
                }
            });
        }

        // place results by trial index — the ordered reduction
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n_out);
        slots.resize_with(n_out, || None);
        for part in parts {
            for (t, v) in part {
                debug_assert!(slots[t - lo].is_none(), "trial {t} ran twice");
                slots[t - lo] = Some(v);
            }
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, v)| v.unwrap_or_else(|| panic!("trial {} never ran", lo + i)))
            .collect()
    }
}

/// Context for one sweep chunk: a decoder plus reusable buffers.
pub struct DecodeCtx<D> {
    pub decoder: D,
    pub out: Decoding,
    pub mask: Vec<bool>,
}

/// Sweep N straggler patterns through a decoder and accumulate the
/// decoding error |alpha - 1|^2 of every trial into a [`Stats`].
///
/// `make_decoder(chunk)` builds a fresh decoder per chunk (scratch and
/// warm-start state are chunk-scoped, see the module docs);
/// `fill_mask(trial, rng, mask)` writes trial `trial`'s straggler
/// pattern into the reusable buffer. The whole loop is allocation-free
/// after each chunk's first trial.
pub fn decoding_error_sweep<D, FD, FM>(
    engine: &TrialEngine,
    make_decoder: FD,
    fill_mask: FM,
    trials: usize,
) -> Stats
where
    D: Decoder,
    FD: Fn(usize) -> D + Sync,
    FM: Fn(usize, &mut Rng, &mut Vec<bool>) + Sync,
{
    Stats::from_values(&decoding_error_values(engine, make_decoder, fill_mask, 0, trials))
}

/// Per-trial decoding errors for the trial subrange `[lo, hi)` of an
/// `N`-trial sweep — the shard building block behind
/// [`decoding_error_sweep`] (which is the `[0, N)` case folded into a
/// [`Stats`]). Values are bit-identical to the corresponding slice of a
/// full single-process run for any split, per
/// [`TrialEngine::run_range_map`]'s replay contract.
pub fn decoding_error_values<D, FD, FM>(
    engine: &TrialEngine,
    make_decoder: FD,
    fill_mask: FM,
    lo: usize,
    hi: usize,
) -> Vec<f64>
where
    D: Decoder,
    FD: Fn(usize) -> D + Sync,
    FM: Fn(usize, &mut Rng, &mut Vec<bool>) + Sync,
{
    engine.run_range_map(
        lo,
        hi,
        |chunk| DecodeCtx {
            decoder: make_decoder(chunk),
            out: Decoding::empty(),
            mask: Vec::new(),
        },
        |ctx, t, rng| {
            fill_mask(t, rng, &mut ctx.mask);
            ctx.decoder.decode_into(&ctx.mask, &mut ctx.out);
            // one relaxed atomic add per trial for iterative decoders;
            // closed-form decoders return None and skip it entirely
            if let Some(n) = ctx.decoder.lsqr_iterations() {
                lsqr_iterations_total().add(n);
            }
            ctx.out.error_sq()
        },
    )
}

/// Cached handle for the `lsqr_iterations_total` counter, so the
/// per-trial hot path pays one relaxed atomic add, not a registry
/// lookup.
fn lsqr_iterations_total() -> &'static crate::metrics::Counter {
    static C: std::sync::OnceLock<crate::metrics::Counter> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::metrics::counter("lsqr_iterations_total"))
}

/// Parallel counterpart of [`crate::gd::analysis::decoding_stats`]: the
/// Figure-3 statistics (normalized error, covariance spectral norm) with
/// the trials fanned across the engine. The post-processing reuses
/// [`crate::gd::analysis::stats_from_samples`], so for a given sample
/// set the numbers are identical to the serial path.
pub fn decoding_stats_par<D, FD, FM>(
    engine: &TrialEngine,
    make_decoder: FD,
    fill_mask: FM,
    runs: usize,
    rng: &mut Rng,
) -> crate::gd::analysis::DecodingStats
where
    D: Decoder,
    FD: Fn(usize) -> D + Sync,
    FM: Fn(usize, &mut Rng, &mut Vec<bool>) + Sync,
{
    assert!(runs >= 2);
    let samples = engine.run_map(
        runs,
        |chunk| DecodeCtx {
            decoder: make_decoder(chunk),
            out: Decoding::empty(),
            mask: Vec::new(),
        },
        |ctx, t, trial_rng| {
            fill_mask(t, trial_rng, &mut ctx.mask);
            ctx.decoder.decode_into(&ctx.mask, &mut ctx.out);
            ctx.out.alpha.clone()
        },
    );
    crate::gd::analysis::stats_from_samples(samples, rng)
}

/// Bernoulli(p) mask filler for the common random-straggler sweeps.
pub fn bernoulli_masks(m: usize, p: f64) -> impl Fn(usize, &mut Rng, &mut Vec<bool>) + Sync {
    move |_t, rng, mask| rng.bernoulli_mask_into(m, p, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, GraphCode};
    use crate::decode::OptimalGraphDecoder;

    #[test]
    fn run_map_returns_results_in_trial_order() {
        let engine = TrialEngine::new(4, 9).with_chunk(3);
        let out = engine.run_map(17, |_c| (), |_ctx, t, _rng| t * 10);
        assert_eq!(out, (0..17).map(|t| t * 10).collect::<Vec<_>>());
    }

    #[test]
    fn trial_rng_is_stable_per_trial() {
        let engine = TrialEngine::new(8, 42);
        let a: Vec<u64> = (0..5).map(|t| engine.trial_rng(t).next_u64()).collect();
        let b: Vec<u64> = (0..5).map(|t| engine.trial_rng(t).next_u64()).collect();
        assert_eq!(a, b);
        // distinct trials get distinct streams
        assert!(a.windows(2).all(|w| w[0] != w[1]));
        // distinct seeds get distinct streams
        let c = TrialEngine::new(8, 43).trial_rng(0).next_u64();
        assert_ne!(a[0], c);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(0);
        let code = GraphCode::random_regular(24, 4, &mut rng);
        let g = &code.graph;
        let m = code.n_machines();
        let run = |threads: usize| {
            let engine = TrialEngine::new(threads, 7).with_chunk(8);
            decoding_error_sweep(
                &engine,
                |_c| OptimalGraphDecoder::new(g),
                bernoulli_masks(m, 0.25),
                200,
            )
        };
        let s1 = run(1);
        let s8 = run(8);
        assert_eq!(s1.count(), s8.count());
        assert_eq!(s1.mean().to_bits(), s8.mean().to_bits());
        assert_eq!(s1.var().to_bits(), s8.var().to_bits());
        assert_eq!(s1.min().to_bits(), s8.min().to_bits());
        assert_eq!(s1.max().to_bits(), s8.max().to_bits());
    }

    #[test]
    fn chunk_context_is_rebuilt_per_chunk() {
        let engine = TrialEngine::new(1, 1).with_chunk(4);
        // ctx counts trials within its chunk; every chunk must restart at 0
        let counts = engine.run_map(
            10,
            |_c| 0usize,
            |ctx, _t, _rng| {
                *ctx += 1;
                *ctx
            },
        );
        assert_eq!(counts, vec![1, 2, 3, 4, 1, 2, 3, 4, 1, 2]);
    }

    #[test]
    fn zero_trials_is_empty() {
        let engine = TrialEngine::new(4, 0);
        let out: Vec<u8> = engine.run_map(0, |_c| (), |_ctx, _t, _rng| 0u8);
        assert!(out.is_empty());
        let out: Vec<u8> = engine.run_range_map(5, 5, |_c| (), |_ctx, _t, _rng| 0u8);
        assert!(out.is_empty());
    }

    /// A range run must return exactly the corresponding slice of the
    /// full run, even for a *stateful* per-chunk context whose first
    /// covered chunk is only partially inside the range (the replay
    /// path), and regardless of threads.
    #[test]
    fn range_map_matches_full_run_slice() {
        let full_engine = TrialEngine::new(1, 21).with_chunk(5);
        // ctx = running sum within the chunk: trial value depends on all
        // chunk predecessors, so unreplayed partial chunks would differ
        let run_full = |e: &TrialEngine| {
            e.run_map(
                23,
                |_c| 0u64,
                |acc, t, rng| {
                    *acc = acc.wrapping_add(rng.next_u64()).wrapping_add(t as u64);
                    *acc
                },
            )
        };
        let full = run_full(&full_engine);
        for threads in [1usize, 4] {
            let engine = TrialEngine::new(threads, 21).with_chunk(5);
            for (lo, hi) in [(0usize, 23usize), (3, 23), (7, 11), (4, 5), (22, 23), (0, 1)] {
                let part = engine.run_range_map(
                    lo,
                    hi,
                    |_c| 0u64,
                    |acc, t, rng| {
                        *acc = acc.wrapping_add(rng.next_u64()).wrapping_add(t as u64);
                        *acc
                    },
                );
                assert_eq!(part, full[lo..hi], "range [{lo},{hi}) threads={threads}");
            }
        }
    }

    #[test]
    fn decoding_error_values_slice_invariant() {
        let mut rng = Rng::new(3);
        let code = GraphCode::random_regular(16, 4, &mut rng);
        let g = &code.graph;
        let m = code.n_machines();
        let engine = TrialEngine::new(2, 11).with_chunk(8);
        let run = |lo: usize, hi: usize| {
            decoding_error_values(
                &engine,
                |_c| OptimalGraphDecoder::new(g),
                bernoulli_masks(m, 0.3),
                lo,
                hi,
            )
        };
        let full = run(0, 60);
        let a = run(0, 13);
        let b = run(13, 60);
        let stitched: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(full.len(), stitched.len());
        for (i, (x, y)) in full.iter().zip(&stitched).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial {i}");
        }
    }
}
