//! Sharded sweeps: split a trial range across processes, merge the
//! partial results bit-exactly.
//!
//! The engine layer ([`crate::sweep::TrialEngine`]) already makes a
//! Monte-Carlo sweep independent of the *thread* count; this module
//! extends the same determinism contract across *process* boundaries so
//! a 10^6-trial sweep can be split over machines and folded back
//! together:
//!
//! * [`ShardSpec`] partitions `[0, N)` into contiguous shards
//!   (`--shard i/k` on the CLI). Any contiguous split works — not just
//!   the balanced one — because trial `t`'s PRNG substream is keyed
//!   only by `(seed, t)` and
//!   [`TrialEngine::run_range_map`](crate::sweep::TrialEngine::run_range_map)
//!   aligns chunks to the global grid (replaying partial leading chunks
//!   for warm state), so per-trial values never depend on the split.
//! * [`ShardResult`] serializes a shard's output — the [`SweepConfig`]
//!   identity, the per-trial metric vector, and a [`Stats`] partial —
//!   to a versioned JSON manifest ([`SHARD_SCHEMA`]). Floats are
//!   carried as IEEE-754 hex bit patterns
//!   ([`crate::bench_util::f64_to_hex_bits`]) so they round-trip
//!   exactly through text.
//! * [`merge`] validates a set of manifests (matching config, matching
//!   schema version, gap-free/overlap-free coverage of `[0, N)`),
//!   refolds the concatenated per-trial vectors through
//!   [`Stats::from_values`] — the *same* sequential fold a
//!   single-process run performs, hence bit-identical output for any
//!   shard split — and cross-checks the result against the
//!   [`Stats::merge`] (Chan) combination of the shard partials
//!   (`count`/`min`/`max` exactly, the float moments to 1e-9).
//!
//! The sweeps themselves are **pluggable kernels**
//! ([`crate::sweep::kernels`]): [`SweepKind`] is an open registry name,
//! and [`run_range`] dispatches to whatever [`SweepKernel`] is
//! registered under it. The built-ins cover the paper's experiment
//! families — `decode-error` (Figure 3 style Monte-Carlo decoding
//! error), `gd-final` (Figure 4/5 style simulated coded-GD final
//! error), `attack` (the greedy adversarial error-vs-budget curve,
//! sliced along the budget axis via the nested
//! [`crate::straggler::greedy_decode_attack_trace`]), `adv-gd` (GD
//! convergence under a greedy adversarial straggler budget — the noise
//! floor regime) and the bench-produced `fig4-cluster` — and
//! [`register_kernel`] adds new ones that immediately work through
//! every layer here (manifests, merge, CLI, dispatcher).
//!
//! Two extensions serve the elastic dispatcher ([`crate::dispatch`]):
//!
//! * [`ShardResult::slice`] and [`dedup_cover`] turn an over-complete
//!   set of shard results (speculative re-execution of straggling
//!   ranges produces duplicate covers) into an exact gap-free cover of
//!   `[0, N)` before [`merge`] — safe because per-trial values are
//!   split-invariant, so any trimmed cover folds to the same bits.
//! * **Stats-only manifests** ([`ShardResult::into_stats_only`], CLI
//!   `--stats-only`) omit the per-trial vector to cap manifest size for
//!   very large N. The merge contract relaxes from the bit-exact refold
//!   to the [`Stats::merge`] (Chan) combination: `count`/`min`/`max`
//!   stay exact, the float moments agree only to rounding and depend on
//!   the shard split. [`merge`] refuses to mix stats-only and full
//!   manifests.

use crate::bench_util::{f64_from_hex_bits, f64_to_hex_bits, json_escape, json_f64_display};
use crate::codes::zoo::{build, DecoderSpec, SchemeSpec};
use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::metrics::Stats;
use crate::prng::Rng;
use crate::sweep::TrialEngine;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

// The kernel layer is the extension point; re-exported here because a
// sweep's identity (`SweepConfig.sweep`) and its runner live together.
pub use crate::sweep::kernels::{register_kernel, SweepKernel, SweepKind};

/// Version stamped into every shard/merged manifest. [`merge`] (and so
/// `gcod sweep-merge`) rejects manifests written by a different schema.
/// Schema 2 added the `stats_only` flag (schema-1 manifests, which
/// predate it, are rejected rather than guessed at). Schema 3 changed
/// `gd-final` per-trial values for identical configs (Gram-cached
/// gradient kernel with `grad` auto-selection, chunk-scoped
/// warm-started decoder state), so schema-2 manifests must not be
/// mixed into post-PR4 merges.
pub const SHARD_SCHEMA: u64 = 3;

/// `"kind"` of a per-shard manifest.
pub const SHARD_KIND: &str = "gcod-sweep-shard";

/// `"kind"` of a merged sweep result.
pub const MERGED_KIND: &str = "gcod-sweep-merged";

/// Salt for the scheme-construction RNG so the (shared) scheme build
/// never draws from a trial substream. Public because the scheme is
/// part of the sweep-identity contract (byte-identity oracle tests
/// rebuild it independently); the data-generation counterpart is
/// [`crate::sweep::kernels::DATA_SALT`].
pub const SCHEME_SALT: u64 = 0x5C4E_4D45_B11D;

// ---------------------------------------------------------------------
// Shard ranges
// ---------------------------------------------------------------------

/// One contiguous shard of a trial range: `index` of `count`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    pub fn new(index: usize, count: usize) -> Result<Self> {
        if count == 0 {
            return Err(Error::msg("shard count must be >= 1"));
        }
        if index >= count {
            return Err(Error::msg(format!("shard index {index} out of range for {count} shards")));
        }
        Ok(Self { index, count })
    }

    /// Parse `"i/k"` (e.g. `--shard 2/8`).
    pub fn parse(s: &str) -> Result<Self> {
        let (i, k) = s
            .split_once('/')
            .ok_or_else(|| Error::msg(format!("bad shard spec '{s}': want i/k, e.g. 0/4")))?;
        let index = i
            .trim()
            .parse::<usize>()
            .map_err(|e| Error::msg(format!("bad shard index '{i}': {e}")))?;
        let count = k
            .trim()
            .parse::<usize>()
            .map_err(|e| Error::msg(format!("bad shard count '{k}': {e}")))?;
        Self::new(index, count)
    }

    /// The balanced contiguous trial range `[lo, hi)` this shard covers
    /// out of `n_trials`: shard sizes differ by at most one, earlier
    /// shards take the remainder.
    pub fn range(&self, n_trials: usize) -> (usize, usize) {
        let base = n_trials / self.count;
        let rem = n_trials % self.count;
        let lo = self.index * base + self.index.min(rem);
        let hi = lo + base + usize::from(self.index < rem);
        (lo, hi)
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// Parse an explicit trial range `"lo..hi"` (e.g. `--range 128..256`,
/// the form the dispatcher hands to its workers). `lo <= hi` is
/// enforced; the upper bound against `trials` is checked by
/// [`run_range`].
pub fn parse_range(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once("..")
        .ok_or_else(|| Error::msg(format!("bad range '{s}': want lo..hi, e.g. 0..256")))?;
    let lo = a
        .trim()
        .parse::<usize>()
        .map_err(|e| Error::msg(format!("bad range start '{a}': {e}")))?;
    let hi = b
        .trim()
        .parse::<usize>()
        .map_err(|e| Error::msg(format!("bad range end '{b}': {e}")))?;
    if lo > hi {
        return Err(Error::msg(format!("bad range '{s}': start exceeds end")));
    }
    Ok((lo, hi))
}

// ---------------------------------------------------------------------
// Sweep identity
// ---------------------------------------------------------------------

/// Everything that identifies a sweep — two manifests merge only if all
/// of this matches (with `p` compared bit-for-bit). `chunk` is part of
/// the identity because chunk scoping re-seats stateful decoder
/// contexts (see `TrialEngine::with_chunk`); `threads` is *not*, by the
/// engine's thread-invariance contract.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub sweep: SweepKind,
    /// scheme spec string, e.g. "graph-rr:16,3" (see `codes::zoo`)
    pub scheme: String,
    /// decoder spec string: optimal|optimal-lsqr|fixed|ignore
    pub decoder: String,
    /// straggler probability (decode-error, gd-final) / fixed-decoder
    /// calibration (attack)
    pub p: f64,
    pub seed: u64,
    /// total trials N across all shards
    pub trials: usize,
    /// engine chunk size (part of the determinism contract)
    pub chunk: usize,
    /// extra sweep parameters (e.g. gd-final's n-points/dim/iters),
    /// canonically sorted by key
    pub params: BTreeMap<String, String>,
}

impl PartialEq for SweepConfig {
    fn eq(&self, o: &Self) -> bool {
        self.sweep == o.sweep
            && self.scheme == o.scheme
            && self.decoder == o.decoder
            && self.p.to_bits() == o.p.to_bits()
            && self.seed == o.seed
            && self.trials == o.trials
            && self.chunk == o.chunk
            && self.params == o.params
    }
}

impl Eq for SweepConfig {}

impl SweepConfig {
    pub fn param_usize(&self, key: &str, default: usize) -> usize {
        self.params.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.params.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// The linalg-tier label this sweep's manifests record: the
    /// [`LINALG_PARAM`] params entry, `"exact"` when absent (the
    /// canonical spelling of the default tier — see
    /// [`canonicalize_linalg`]).
    pub fn linalg_label(&self) -> &str {
        self.params.get(LINALG_PARAM).map(String::as_str).unwrap_or("exact")
    }
}

/// The params key that selects the linalg tier
/// ([`crate::linalg::LinalgBackend`]); the value flows into every
/// kernel's dense kernels and — via `params` — into manifest identity,
/// so [`merge`] refuses to fold exact and fast shards together.
pub const LINALG_PARAM: &str = "linalg";

/// Canonicalize the [`LINALG_PARAM`] entry: `exact` is the default
/// tier, so an explicit `--set linalg=exact` is stripped down to the
/// key being absent — the resulting manifests stay byte-identical to
/// every manifest written before the fast tier existed. Other values
/// (valid or not) pass through verbatim for the kernel's `validate` to
/// accept or reject. Called at the CLI construction point
/// (`sweep_config_from` in `main.rs`), before the config's identity is
/// fixed.
pub fn canonicalize_linalg(params: &mut BTreeMap<String, String>) {
    if params.get(LINALG_PARAM).map(String::as_str) == Some("exact") {
        params.remove(LINALG_PARAM);
    }
}

// ---------------------------------------------------------------------
// Shard + merged results
// ---------------------------------------------------------------------

/// One shard's output: the per-trial metric vector for `[lo, hi)` plus
/// its sequential-fold [`Stats`] partial. In stats-only mode the vector
/// is omitted (empty) and only the partial travels.
#[derive(Clone, Debug)]
pub struct ShardResult {
    pub config: SweepConfig,
    pub lo: usize,
    pub hi: usize,
    /// metric value of trial `lo + i` at index `i`; empty when
    /// `stats_only`
    pub values: Vec<f64>,
    /// the shard's sequential fold: `Stats::from_values(&values)` —
    /// recomputed (never trusted) when a full manifest is parsed, taken
    /// verbatim from the manifest when stats-only
    pub stats: Stats,
    /// per-trial vector omitted: the manifest carries only the [`Stats`]
    /// partial (relaxed Chan-merge contract)
    pub stats_only: bool,
}

impl ShardResult {
    pub fn from_values(config: SweepConfig, lo: usize, hi: usize, values: Vec<f64>) -> Self {
        assert_eq!(values.len(), hi - lo, "shard [{lo},{hi}) got {} values", values.len());
        let stats = Stats::from_values(&values);
        Self { config, lo, hi, values, stats, stats_only: false }
    }

    /// Drop the per-trial vector, keeping only the (already exact,
    /// sequentially folded) [`Stats`] partial. Caps manifest size for
    /// very large N at the cost of the bit-exact merge contract: a
    /// merge of stats-only shards combines partials via [`Stats::merge`]
    /// (Chan), whose float moments depend on the split.
    pub fn into_stats_only(mut self) -> Self {
        self.values = Vec::new();
        self.stats_only = true;
        self
    }

    /// The sub-range `[lo, hi)` of this shard's result, with values and
    /// stats recomputed for the slice. Split-invariance of per-trial
    /// values makes the slice bit-identical to a shard run directly on
    /// `[lo, hi)` — this is what lets [`dedup_cover`] trim overlapping
    /// speculative covers. Stats-only shards cannot be sliced (no
    /// per-trial vector to cut).
    pub fn slice(&self, lo: usize, hi: usize) -> Result<ShardResult> {
        if self.stats_only {
            return Err(Error::msg(format!(
                "cannot slice stats-only shard [{}, {}): per-trial values were dropped",
                self.lo, self.hi
            )));
        }
        if lo < self.lo || hi > self.hi || lo > hi {
            return Err(Error::msg(format!(
                "slice [{lo}, {hi}) outside shard [{}, {})",
                self.lo, self.hi
            )));
        }
        Ok(ShardResult::from_values(
            self.config.clone(),
            lo,
            hi,
            self.values[lo - self.lo..hi - self.lo].to_vec(),
        ))
    }

    /// Serialize to the versioned shard-manifest JSON.
    pub fn render(&self) -> String {
        render_doc(
            SHARD_KIND,
            &self.config,
            Some((self.lo, self.hi)),
            &self.values,
            &self.stats,
            self.stats_only,
        )
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.render())
            .map_err(|e| Error::msg(format!("write {}: {e}", path.display())))
    }

    /// Parse and validate a shard manifest: kind and schema must match
    /// this binary, and the recorded [`Stats`] partial must agree
    /// bit-for-bit with a refold of the recorded values (corruption
    /// check).
    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse_doc(text, SHARD_KIND)?;
        let lo = get_usize(&doc.json, "lo")?;
        let hi = get_usize(&doc.json, "hi")?;
        if lo > hi || hi > doc.config.trials {
            return Err(Error::msg(format!(
                "shard range [{lo}, {hi}) outside sweep of {} trials",
                doc.config.trials
            )));
        }
        if doc.stats_only {
            if doc.stats.count() != (hi - lo) as u64 {
                return Err(Error::msg(format!(
                    "stats-only shard [{lo}, {hi}) records count {}, expected {}",
                    doc.stats.count(),
                    hi - lo
                )));
            }
        } else if doc.values.len() != hi - lo {
            return Err(Error::msg(format!(
                "shard [{lo}, {hi}) carries {} values, expected {}",
                doc.values.len(),
                hi - lo
            )));
        }
        Ok(Self {
            config: doc.config,
            lo,
            hi,
            values: doc.values,
            stats: doc.stats,
            stats_only: doc.stats_only,
        })
    }

    pub fn read(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?;
        Self::parse(&text).map_err(|e| Error::msg(format!("{}: {e}", path.display())))
    }
}

/// A fully merged sweep: the per-trial vector for all of `[0, N)` and
/// its canonical sequential-fold [`Stats`] (vector empty and stats
/// Chan-combined in stats-only mode).
#[derive(Clone, Debug)]
pub struct MergedSweep {
    pub config: SweepConfig,
    pub values: Vec<f64>,
    pub stats: Stats,
    pub stats_only: bool,
}

impl MergedSweep {
    /// Serialize the merged result. For full manifests the output
    /// depends only on the config and the per-trial values — never on
    /// how many shards fed the merge — so any split of the same sweep
    /// renders byte-identical JSON. Stats-only merges are deterministic
    /// for a given shard split but their float moments carry
    /// split-dependent Chan rounding.
    pub fn render(&self) -> String {
        render_doc(MERGED_KIND, &self.config, None, &self.values, &self.stats, self.stats_only)
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.render())
            .map_err(|e| Error::msg(format!("write {}: {e}", path.display())))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse_doc(text, MERGED_KIND)?;
        if doc.stats_only {
            if doc.stats.count() != doc.config.trials as u64 {
                return Err(Error::msg(format!(
                    "stats-only merged sweep records count {} for {} trials",
                    doc.stats.count(),
                    doc.config.trials
                )));
            }
        } else if doc.values.len() != doc.config.trials {
            return Err(Error::msg(format!(
                "merged sweep carries {} values for {} trials",
                doc.values.len(),
                doc.config.trials
            )));
        }
        Ok(Self {
            config: doc.config,
            values: doc.values,
            stats: doc.stats,
            stats_only: doc.stats_only,
        })
    }
}

/// Validate and fold a set of shard results into the canonical merged
/// sweep. Shards may arrive in any order but must share one config,
/// cover `[0, N)` exactly (no gaps, no overlaps) and carry partials
/// consistent with their values; the merged [`Stats`] is the sequential
/// refold of the concatenated trial vector (bit-identical to a
/// single-process run), cross-checked against the [`Stats::merge`]
/// combination of the shard partials.
pub fn merge(mut shards: Vec<ShardResult>) -> Result<MergedSweep> {
    let first = shards.first().ok_or_else(|| Error::msg("no shard manifests to merge"))?;
    let config = first.config.clone();
    let stats_only = first.stats_only;
    for s in &shards {
        // targeted diagnosis before the generic identity check: mixing
        // linalg tiers is the foreseeable operator error (the tiers
        // round differently, so folding them would silently corrupt the
        // merged moments)
        if s.config.linalg_label() != config.linalg_label() {
            return Err(Error::msg(format!(
                "cannot merge shards from different linalg tiers: shard [{}, {}) ran \
                 linalg={}, expected linalg={} — re-run the odd shards on one tier",
                s.lo,
                s.hi,
                s.config.linalg_label(),
                config.linalg_label()
            )));
        }
        if s.config != config {
            return Err(Error::msg(format!(
                "shard config mismatch: [{}, {}) was run as {:?}, expected {config:?}",
                s.lo, s.hi, s.config
            )));
        }
        if s.stats_only != stats_only {
            return Err(Error::msg(format!(
                "cannot merge stats-only and full shard manifests: shard [{}, {}) is {}, \
                 expected {} — re-run the odd shards in the other mode",
                s.lo,
                s.hi,
                if s.stats_only { "stats-only" } else { "full" },
                if stats_only { "stats-only" } else { "full" }
            )));
        }
    }
    shards.sort_by_key(|s| (s.lo, s.hi));
    let mut covered = 0usize;
    for s in &shards {
        match s.lo.cmp(&covered) {
            std::cmp::Ordering::Greater => {
                return Err(Error::msg(format!(
                    "trial coverage gap: [{covered}, {}) missing before shard [{}, {})",
                    s.lo, s.lo, s.hi
                )));
            }
            std::cmp::Ordering::Less => {
                return Err(Error::msg(format!(
                    "trial coverage overlap: shard [{}, {}) re-covers trials below {covered}",
                    s.lo, s.hi
                )));
            }
            std::cmp::Ordering::Equal => covered = s.hi,
        }
    }
    if covered != config.trials {
        return Err(Error::msg(format!(
            "trial coverage incomplete: shards cover [0, {covered}) of {} trials",
            config.trials
        )));
    }

    if stats_only {
        // relaxed contract: no per-trial vector to refold, so the
        // merged stats are the Chan combination of the (internally
        // exact, sequentially folded) shard partials in range order.
        // count/min/max stay exact; mean/m2 carry split-dependent
        // rounding.
        let mut chan = Stats::new();
        for s in &shards {
            chan.merge(&s.stats);
        }
        return Ok(MergedSweep { config, values: Vec::new(), stats: chan, stats_only: true });
    }

    let mut values = Vec::with_capacity(config.trials);
    let mut chan = Stats::new();
    for s in &shards {
        values.extend_from_slice(&s.values);
        chan.merge(&s.stats);
    }
    let stats = Stats::from_values(&values);

    // Redundancy cross-check: the Chan merge of the shard partials must
    // agree with the canonical refold — exactly on count/min/max,
    // to rounding on the float moments.
    if chan.count() != stats.count()
        || chan.min().to_bits() != stats.min().to_bits()
        || chan.max().to_bits() != stats.max().to_bits()
    {
        return Err(Error::msg("shard partials inconsistent with trial values (count/min/max)"));
    }
    // the float moments are only cross-checkable when finite: a
    // non-finite trial value (diverged gd-final run, say) degenerates
    // the Welford fold and the Chan merge differently (inf - inf = NaN)
    // even for honest manifests, and with all-finite values the Chan
    // merge cannot go non-finite — so bitwise-equal or either-non-finite
    // counts as consistent, and count/min/max above still validate
    // exactly
    let close = |a: f64, b: f64| {
        a.to_bits() == b.to_bits()
            || !(a.is_finite() && b.is_finite())
            || (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    };
    if stats.count() > 0 && (!close(chan.mean(), stats.mean()) || !close(chan.m2(), stats.m2())) {
        return Err(Error::msg(format!(
            "shard partials inconsistent with trial values: merged mean/m2 {}/{} vs refold {}/{}",
            chan.mean(),
            chan.m2(),
            stats.mean(),
            stats.m2()
        )));
    }

    Ok(MergedSweep { config, values, stats, stats_only: false })
}

/// Reduce an over-complete set of shard results — duplicates and
/// overlaps included, as produced by speculative re-execution of
/// straggling ranges — to an exact gap-free cover of `[0, N)`, ready
/// for [`merge`]. Redundant results are dropped and partially-redundant
/// ones trimmed via [`ShardResult::slice`]; because per-trial values
/// are split-invariant, *which* duplicate survives cannot change the
/// merged bits. Returns the cover plus the number of results dropped
/// or trimmed. Stats-only results dedup only at exact-duplicate-range
/// granularity (no vector to trim); a partial overlap among them is an
/// error.
pub fn dedup_cover(mut results: Vec<ShardResult>) -> Result<(Vec<ShardResult>, usize)> {
    let first = results.first().ok_or_else(|| Error::msg("no shard results to dedup"))?;
    let config = first.config.clone();
    for r in &results {
        if r.config != config {
            return Err(Error::msg(format!(
                "shard config mismatch: [{}, {}) was run as {:?}, expected {config:?}",
                r.lo, r.hi, r.config
            )));
        }
    }
    // degenerate 0-trial sweep: every honest result is the empty shard
    // [0, 0); keep one so merge still sees full coverage
    if config.trials == 0 {
        let dropped = results.len() - 1;
        return Ok((vec![results.swap_remove(0)], dropped));
    }
    // longest cover first at each start, so trims are rare
    results.sort_by(|a, b| a.lo.cmp(&b.lo).then(b.hi.cmp(&a.hi)));
    let mut cover = Vec::new();
    let mut deduped = 0usize;
    let mut covered = 0usize;
    for r in results {
        if r.hi <= covered {
            deduped += 1; // fully redundant (duplicate cover or empty shard)
            continue;
        }
        if r.lo > covered {
            return Err(Error::msg(format!(
                "trial coverage gap: [{covered}, {}) missing before result [{}, {})",
                r.lo, r.lo, r.hi
            )));
        }
        if r.lo < covered {
            deduped += 1;
            cover.push(r.slice(covered, r.hi).map_err(|e| {
                Error::msg(format!(
                    "result [{}, {}) partially re-covers trials below {covered}: {e}",
                    r.lo, r.hi
                ))
            })?);
        } else {
            cover.push(r);
        }
        covered = cover.last().map(|c| c.hi).unwrap_or(covered);
    }
    if covered != config.trials {
        return Err(Error::msg(format!(
            "trial coverage incomplete: results cover [0, {covered}) of {} trials",
            config.trials
        )));
    }
    Ok((cover, deduped))
}

// ---------------------------------------------------------------------
// Standard sweep runners
// ---------------------------------------------------------------------

/// Run this process's shard of a standard sweep.
pub fn run_shard(cfg: &SweepConfig, threads: usize, shard: ShardSpec) -> Result<ShardResult> {
    let (lo, hi) = shard.range(cfg.trials);
    run_range(cfg, threads, lo, hi)
}

/// Run an explicit trial range `[lo, hi)` of a standard sweep through
/// the kernel registered for `cfg.sweep`. Values are bit-identical to
/// the corresponding slice of the full `[0, N)` run for any range,
/// thread count and process placement (the [`SweepKernel`] contract).
pub fn run_range(cfg: &SweepConfig, threads: usize, lo: usize, hi: usize) -> Result<ShardResult> {
    if lo > hi || hi > cfg.trials {
        return Err(Error::msg(format!(
            "trial range [{lo}, {hi}) outside sweep of {} trials",
            cfg.trials
        )));
    }
    // the engine would clamp chunk 0 to 1, but the manifest would then
    // record a chunk the reader (parse_doc) rejects — fail fast instead
    if cfg.chunk == 0 {
        return Err(Error::msg("sweep chunk must be >= 1 (it is part of the sweep identity)"));
    }
    let kernel = cfg.sweep.kernel();
    if let Some(msg) = kernel.external_producer() {
        return Err(Error::msg(msg));
    }
    kernel.validate(cfg)?;
    let spec = SchemeSpec::parse(&cfg.scheme).map_err(Error::msg)?;
    let dspec = DecoderSpec::parse(&cfg.decoder).map_err(Error::msg)?;
    // every shard rebuilds the identical scheme from the salted seed
    let scheme = build(&spec, &mut Rng::new(cfg.seed ^ SCHEME_SALT));
    let engine = TrialEngine::new(threads, cfg.seed).with_chunk(cfg.chunk);
    let started = std::time::Instant::now();
    let values = kernel.run_range(cfg, &scheme, dspec, &engine, lo, hi)?;
    // per-kernel phase timer (accumulates across ranges) + trial count
    crate::metrics::gauge(&format!("phase_seconds{{phase=\"{}\"}}", kernel.name()))
        .add(started.elapsed().as_secs_f64());
    crate::metrics::counter("sweep_trials_total").add((hi - lo) as u64);
    if values.len() != hi - lo {
        return Err(Error::msg(format!(
            "sweep kernel '{}' returned {} values for trial range [{lo}, {hi})",
            kernel.name(),
            values.len()
        )));
    }
    Ok(ShardResult::from_values(cfg.clone(), lo, hi, values))
}

/// Run the whole sweep in-process (the single-process reference a
/// multi-shard merge must reproduce byte-for-byte).
pub fn run_full(cfg: &SweepConfig, threads: usize) -> Result<MergedSweep> {
    merge(vec![run_range(cfg, threads, 0, cfg.trials)?])
}

// ---------------------------------------------------------------------
// Manifest JSON (hand-rolled, deterministic, no serde)
// ---------------------------------------------------------------------

fn render_doc(
    kind: &str,
    cfg: &SweepConfig,
    range: Option<(usize, usize)>,
    values: &[f64],
    stats: &Stats,
    stats_only: bool,
) -> String {
    let mut out = String::with_capacity(256 + 32 * values.len());
    out.push_str("{\n");
    out.push_str(&format!("  \"kind\": \"{}\",\n", json_escape(kind)));
    out.push_str(&format!("  \"schema\": {SHARD_SCHEMA},\n"));
    out.push_str(&format!("  \"sweep\": \"{}\",\n", cfg.sweep.as_str()));
    out.push_str(&format!("  \"scheme\": \"{}\",\n", json_escape(&cfg.scheme)));
    out.push_str(&format!("  \"decoder\": \"{}\",\n", json_escape(&cfg.decoder)));
    out.push_str(&format!(
        "  \"p\": {}, \"p_bits\": \"{}\",\n",
        json_f64_display(cfg.p),
        f64_to_hex_bits(cfg.p)
    ));
    out.push_str(&format!("  \"seed\": \"{}\",\n", cfg.seed));
    out.push_str(&format!("  \"trials\": {},\n", cfg.trials));
    out.push_str(&format!("  \"chunk\": {},\n", cfg.chunk));
    out.push_str("  \"params\": {");
    for (i, (k, v)) in cfg.params.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
    }
    out.push_str("},\n");
    out.push_str(&format!("  \"stats_only\": {stats_only},\n"));
    if let Some((lo, hi)) = range {
        out.push_str(&format!("  \"lo\": {lo},\n  \"hi\": {hi},\n"));
    }
    out.push_str("  \"stats\": {\n");
    out.push_str(&format!("    \"count\": {},\n", stats.count()));
    for (name, x) in
        [("mean", stats.mean()), ("m2", stats.m2()), ("min", stats.min()), ("max", stats.max())]
    {
        out.push_str(&format!(
            "    \"{name}\": {}, \"{name}_bits\": \"{}\",\n",
            json_f64_display(x),
            f64_to_hex_bits(x)
        ));
    }
    out.push_str(&format!("    \"std\": {}\n", json_f64_display(stats.std())));
    if stats_only {
        out.push_str("  }\n");
        out.push_str("}\n");
        return out;
    }
    out.push_str("  },\n");
    out.push_str("  \"values_bits\": [");
    for (i, v) in values.iter().enumerate() {
        if i % 8 == 0 {
            out.push_str("\n    ");
        } else {
            out.push(' ');
        }
        out.push('"');
        out.push_str(&f64_to_hex_bits(*v));
        out.push('"');
        if i + 1 < values.len() {
            out.push(',');
        }
    }
    if values.is_empty() {
        out.push_str("]\n");
    } else {
        out.push_str("\n  ]\n");
    }
    out.push_str("}\n");
    out
}

struct ParsedDoc {
    json: Json,
    config: SweepConfig,
    values: Vec<f64>,
    /// full manifests: refold of `values`, validated against the
    /// recorded partial; stats-only manifests: the recorded partial
    /// itself
    stats: Stats,
    stats_only: bool,
}

fn get<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| Error::msg(format!("manifest missing field '{key}'")))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(get(j, key)?
        .as_str()
        .ok_or_else(|| Error::msg(format!("manifest field '{key}' is not a string")))?
        .to_string())
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    get(j, key)?
        .as_usize()
        .ok_or_else(|| Error::msg(format!("manifest field '{key}' is not a non-negative integer")))
}

fn get_f64_bits(j: &Json, name: &str) -> Result<f64> {
    let key = format!("{name}_bits");
    let s = get_str(j, &key)?;
    f64_from_hex_bits(&s)
        .ok_or_else(|| Error::msg(format!("manifest field '{key}' is not 16 hex digits")))
}

fn parse_doc(text: &str, expect_kind: &str) -> Result<ParsedDoc> {
    let json = Json::parse(text).map_err(|e| Error::msg(format!("manifest is not JSON: {e}")))?;
    let kind = get_str(&json, "kind")?;
    if kind != expect_kind {
        return Err(Error::msg(format!("manifest kind '{kind}', expected '{expect_kind}'")));
    }
    let schema = get_usize(&json, "schema")? as u64;
    if schema != SHARD_SCHEMA {
        return Err(Error::msg(format!(
            "manifest schema version {schema} does not match this binary's {SHARD_SCHEMA} — \
             re-run the shards and the merge with the same gcod build"
        )));
    }
    let sweep = SweepKind::parse(&get_str(&json, "sweep")?)?;
    let scheme = get_str(&json, "scheme")?;
    let decoder = get_str(&json, "decoder")?;
    let p = get_f64_bits(&json, "p")?;
    let seed = get_str(&json, "seed")?
        .parse::<u64>()
        .map_err(|e| Error::msg(format!("manifest field 'seed' is not a u64: {e}")))?;
    let trials = get_usize(&json, "trials")?;
    let chunk = get_usize(&json, "chunk")?;
    if chunk == 0 {
        return Err(Error::msg("manifest field 'chunk' must be >= 1"));
    }
    let mut params = BTreeMap::new();
    match get(&json, "params")? {
        Json::Obj(m) => {
            for (k, v) in m {
                let v = v
                    .as_str()
                    .ok_or_else(|| Error::msg(format!("manifest param '{k}' is not a string")))?;
                params.insert(k.clone(), v.to_string());
            }
        }
        _ => return Err(Error::msg("manifest field 'params' is not an object")),
    }
    let config = SweepConfig { sweep, scheme, decoder, p, seed, trials, chunk, params };

    let stats_only = get(&json, "stats_only")?
        .as_bool()
        .ok_or_else(|| Error::msg("manifest field 'stats_only' is not a boolean"))?;
    if stats_only {
        if json.get("values_bits").is_some() {
            return Err(Error::msg(
                "stats-only manifest must not carry 'values_bits' (corrupt or hand-edited)",
            ));
        }
        // no vector to refold against: the recorded partial is taken
        // verbatim (internal count/range consistency is checked by the
        // callers)
        let rec = get(&json, "stats")?;
        let stats = Stats::from_raw(
            get_usize(rec, "count")? as u64,
            get_f64_bits(rec, "mean")?,
            get_f64_bits(rec, "m2")?,
            get_f64_bits(rec, "min")?,
            get_f64_bits(rec, "max")?,
        );
        return Ok(ParsedDoc { json, config, values: Vec::new(), stats, stats_only: true });
    }

    let raw = get(&json, "values_bits")?
        .as_arr()
        .ok_or_else(|| Error::msg("manifest field 'values_bits' is not an array"))?;
    let mut values = Vec::with_capacity(raw.len());
    for (i, v) in raw.iter().enumerate() {
        let s = v
            .as_str()
            .ok_or_else(|| Error::msg(format!("values_bits[{i}] is not a string")))?;
        values.push(
            f64_from_hex_bits(s)
                .ok_or_else(|| Error::msg(format!("values_bits[{i}] is not 16 hex digits")))?,
        );
    }

    // integrity: the recorded partial must match a refold of the values
    let stats = Stats::from_values(&values);
    let rec = get(&json, "stats")?;
    let rec_count = get_usize(rec, "count")? as u64;
    let consistent = rec_count == stats.count()
        && get_f64_bits(rec, "mean")?.to_bits() == stats.mean().to_bits()
        && get_f64_bits(rec, "m2")?.to_bits() == stats.m2().to_bits()
        && get_f64_bits(rec, "min")?.to_bits() == stats.min().to_bits()
        && get_f64_bits(rec, "max")?.to_bits() == stats.max().to_bits();
    if !consistent {
        return Err(Error::msg(
            "manifest stats block does not match its values (corrupt or hand-edited manifest)",
        ));
    }

    Ok(ParsedDoc { json, config, values, stats, stats_only: false })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(trials: usize) -> SweepConfig {
        SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:12,3".into(),
            decoder: "optimal".into(),
            p: 0.25,
            seed: 42,
            trials,
            chunk: 8,
            params: BTreeMap::new(),
        }
    }

    #[test]
    fn shard_spec_parse_and_range() {
        let s = ShardSpec::parse("2/5").unwrap();
        assert_eq!((s.index, s.count), (2, 5));
        assert_eq!(format!("{s}"), "2/5");
        assert!(ShardSpec::parse("5/5").is_err());
        assert!(ShardSpec::parse("0/0").is_err());
        assert!(ShardSpec::parse("x/2").is_err());
        assert!(ShardSpec::parse("3").is_err());
        // ranges partition [0, n) contiguously, sizes within 1
        for n in [0usize, 1, 7, 16, 23] {
            for k in [1usize, 2, 3, 5, 8] {
                let mut cur = 0;
                for i in 0..k {
                    let (lo, hi) = ShardSpec::new(i, k).unwrap().range(n);
                    assert_eq!(lo, cur, "n={n} k={k} i={i}");
                    assert!(hi - lo <= n / k + 1);
                    cur = hi;
                }
                assert_eq!(cur, n, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn manifest_round_trip_bitwise() {
        let values = vec![0.5, -0.0, 3.25e-30, 1.0 / 3.0, f64::MIN_POSITIVE];
        let mut c = cfg(5);
        c.params.insert("dim".into(), "32".into());
        let shard = ShardResult::from_values(c, 0, 5, values.clone());
        let text = shard.render();
        let back = ShardResult::parse(&text).unwrap();
        assert_eq!(back.config, shard.config);
        assert_eq!((back.lo, back.hi), (0, 5));
        for (a, b) in back.values.iter().zip(&values) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // render is deterministic
        assert_eq!(text, ShardResult::parse(&text).unwrap().render());
    }

    #[test]
    fn empty_shard_round_trips() {
        let shard = ShardResult::from_values(cfg(4), 2, 2, vec![]);
        let back = ShardResult::parse(&shard.render()).unwrap();
        assert_eq!((back.lo, back.hi), (2, 2));
        assert!(back.values.is_empty());
    }

    #[test]
    fn parse_rejects_schema_and_kind_mismatch() {
        let text = ShardResult::from_values(cfg(2), 0, 2, vec![1.0, 2.0]).render();
        let bad_schema = text.replace("\"schema\": 3", "\"schema\": 99");
        let err = ShardResult::parse(&bad_schema).unwrap_err();
        assert!(format!("{err}").contains("schema version 99"), "{err}");
        let bad_kind = text.replace(SHARD_KIND, "gcod-other");
        assert!(ShardResult::parse(&bad_kind).is_err());
        assert!(ShardResult::parse("{}").is_err());
        assert!(ShardResult::parse("not json").is_err());
    }

    #[test]
    fn parse_rejects_tampered_values() {
        let text = ShardResult::from_values(cfg(2), 0, 2, vec![1.0, 2.0]).render();
        // flip one value without updating the stats block
        let tampered = text.replace(&f64_to_hex_bits(2.0), &f64_to_hex_bits(3.0));
        let err = ShardResult::parse(&tampered).unwrap_err();
        assert!(format!("{err}").contains("does not match its values"), "{err}");
    }

    #[test]
    fn merge_validates_coverage() {
        let c = cfg(10);
        let mk = |lo: usize, hi: usize| {
            ShardResult::from_values(c.clone(), lo, hi, (lo..hi).map(|t| t as f64).collect())
        };
        // out-of-order shards merge fine
        let merged = merge(vec![mk(6, 10), mk(0, 3), mk(3, 6)]).unwrap();
        assert_eq!(merged.values, (0..10).map(|t| t as f64).collect::<Vec<_>>());
        assert_eq!(merged.stats.count(), 10);
        // gap
        let err = merge(vec![mk(0, 3), mk(4, 10)]).unwrap_err();
        assert!(format!("{err}").contains("gap"), "{err}");
        // overlap
        let err = merge(vec![mk(0, 5), mk(4, 10)]).unwrap_err();
        assert!(format!("{err}").contains("overlap"), "{err}");
        // incomplete
        let err = merge(vec![mk(0, 9)]).unwrap_err();
        assert!(format!("{err}").contains("incomplete"), "{err}");
        // empty
        assert!(merge(vec![]).is_err());
        // config mismatch
        let mut other = cfg(10);
        other.seed = 43;
        let b = ShardResult::from_values(other, 5, 10, (5..10).map(|t| t as f64).collect());
        let err = merge(vec![mk(0, 5), b]).unwrap_err();
        assert!(format!("{err}").contains("config mismatch"), "{err}");
    }

    #[test]
    fn merge_matches_single_fold_bitwise() {
        let c = cfg(97);
        let vals: Vec<f64> = (0..97).map(|i| ((i * i) as f64 * 0.37).sin() * 3.0).collect();
        let single = Stats::from_values(&vals);
        let shards = vec![
            ShardResult::from_values(c.clone(), 0, 13, vals[0..13].to_vec()),
            ShardResult::from_values(c.clone(), 13, 50, vals[13..50].to_vec()),
            ShardResult::from_values(c.clone(), 50, 50, vec![]),
            ShardResult::from_values(c.clone(), 50, 97, vals[50..97].to_vec()),
        ];
        let merged = merge(shards).unwrap();
        assert_eq!(merged.stats.count(), single.count());
        assert_eq!(merged.stats.mean().to_bits(), single.mean().to_bits());
        assert_eq!(merged.stats.m2().to_bits(), single.m2().to_bits());
        assert_eq!(merged.stats.min().to_bits(), single.min().to_bits());
        assert_eq!(merged.stats.max().to_bits(), single.max().to_bits());
    }

    #[test]
    fn merge_accepts_non_finite_values() {
        // a diverged gd-final run can legitimately record inf/NaN; the
        // Chan cross-check must not reject the honest manifests (the
        // Welford fold and the Chan merge degenerate differently there)
        let c = cfg(4);
        let a = ShardResult::from_values(c.clone(), 0, 2, vec![1.0, f64::INFINITY]);
        let b = ShardResult::from_values(c.clone(), 2, 4, vec![f64::NAN, 2.0]);
        // shard manifests round-trip their non-finite values bit-exactly
        let a = ShardResult::parse(&a.render()).unwrap();
        let merged = merge(vec![a, b]).unwrap();
        assert_eq!(merged.stats.count(), 4);
        assert!(merged.values[1].is_infinite());
        assert!(merged.values[2].is_nan());
    }

    #[test]
    fn merged_render_parses_back() {
        let c = cfg(3);
        let m = merge(vec![ShardResult::from_values(c, 0, 3, vec![1.0, 2.0, 4.0])]).unwrap();
        let text = m.render();
        let back = MergedSweep::parse(&text).unwrap();
        assert_eq!(back.config, m.config);
        assert_eq!(back.values.len(), 3);
        assert_eq!(back.stats.mean().to_bits(), m.stats.mean().to_bits());
    }

    #[test]
    fn sweep_kind_strings() {
        for k in [
            SweepKind::DecodeError,
            SweepKind::GdFinal,
            SweepKind::Attack,
            SweepKind::Fig4Cluster,
            SweepKind::AdvGd,
        ] {
            assert_eq!(SweepKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(SweepKind::parse("nope").is_err());
        // fig4-cluster is bench-produced: the standard runner refuses it
        // with the kernel's own message
        let mut c = cfg(4);
        c.sweep = SweepKind::Fig4Cluster;
        let err = run_range(&c, 1, 0, 4).unwrap_err();
        assert!(format!("{err}").contains("bench_fig4_cluster"), "{err}");
    }

    #[test]
    fn parse_range_forms() {
        assert_eq!(parse_range("0..256").unwrap(), (0, 256));
        assert_eq!(parse_range(" 7 .. 7 ").unwrap(), (7, 7));
        assert!(parse_range("5..3").is_err());
        assert!(parse_range("5").is_err());
        assert!(parse_range("a..b").is_err());
    }

    #[test]
    fn slice_matches_direct_range() {
        let c = cfg(10);
        let vals: Vec<f64> = (0..10).map(|t| (t as f64).sqrt()).collect();
        let full = ShardResult::from_values(c.clone(), 0, 10, vals.clone());
        let s = full.slice(3, 7).unwrap();
        assert_eq!((s.lo, s.hi), (3, 7));
        for (i, v) in s.values.iter().enumerate() {
            assert_eq!(v.to_bits(), vals[3 + i].to_bits());
        }
        // stats are refolded for the slice, not inherited
        assert_eq!(s.stats.count(), 4);
        // out-of-bounds and inverted slices rejected
        assert!(full.slice(3, 11).is_err());
        assert!(full.slice(7, 3).is_err());
        // stats-only shards cannot be sliced
        assert!(full.into_stats_only().slice(3, 7).is_err());
    }

    #[test]
    fn dedup_cover_trims_speculative_duplicates() {
        let c = cfg(10);
        let vals: Vec<f64> = (0..10).map(|t| t as f64 * 1.5).collect();
        let mk = |lo: usize, hi: usize| {
            ShardResult::from_values(c.clone(), lo, hi, vals[lo..hi].to_vec())
        };
        // exact duplicate + partial overlap + containment, out of order
        let (cover, deduped) = dedup_cover(vec![
            mk(6, 10),
            mk(0, 4),
            mk(0, 4), // exact duplicate
            mk(2, 8), // partial overlap on both sides
            mk(7, 9), // contained in [6, 10)
        ])
        .unwrap();
        assert!(deduped >= 2, "deduped={deduped}");
        let merged = merge(cover).unwrap();
        for (i, v) in merged.values.iter().enumerate() {
            assert_eq!(v.to_bits(), vals[i].to_bits(), "trial {i}");
        }
        // the merged bits equal the single-shard fold
        let single = merge(vec![mk(0, 10)]).unwrap();
        assert_eq!(merged.render(), single.render());
        // gaps and incompleteness still fail loudly
        let err = dedup_cover(vec![mk(0, 3), mk(5, 10)]).unwrap_err();
        assert!(format!("{err}").contains("gap"), "{err}");
        let err = dedup_cover(vec![mk(0, 9)]).unwrap_err();
        assert!(format!("{err}").contains("incomplete"), "{err}");
        assert!(dedup_cover(vec![]).is_err());
    }

    #[test]
    fn stats_only_round_trip_and_merge() {
        let c = cfg(6);
        let vals: Vec<f64> = (0..6).map(|t| ((t * t) as f64 * 0.11).cos()).collect();
        let a = ShardResult::from_values(c.clone(), 0, 3, vals[0..3].to_vec()).into_stats_only();
        let b = ShardResult::from_values(c.clone(), 3, 6, vals[3..6].to_vec()).into_stats_only();
        // manifest round trip preserves the partial bit-for-bit and
        // carries no per-trial vector
        let text = a.render();
        assert!(text.contains("\"stats_only\": true"));
        assert!(!text.contains("values_bits"));
        let back = ShardResult::parse(&text).unwrap();
        assert!(back.stats_only && back.values.is_empty());
        assert_eq!(back.stats.mean().to_bits(), a.stats.mean().to_bits());
        assert_eq!(back.stats.m2().to_bits(), a.stats.m2().to_bits());
        // merge combines partials via Chan: count/min/max exact
        let merged = merge(vec![back, b.clone()]).unwrap();
        assert!(merged.stats_only && merged.values.is_empty());
        let refold = Stats::from_values(&vals);
        assert_eq!(merged.stats.count(), refold.count());
        assert_eq!(merged.stats.min().to_bits(), refold.min().to_bits());
        assert_eq!(merged.stats.max().to_bits(), refold.max().to_bits());
        assert!((merged.stats.mean() - refold.mean()).abs() < 1e-12);
        // merged stats-only manifest parses back
        let m2 = MergedSweep::parse(&merged.render()).unwrap();
        assert!(m2.stats_only);
        assert_eq!(m2.stats.count(), 6);
        // count inconsistent with the range is rejected
        let bad = a.render().replace("\"count\": 3", "\"count\": 4");
        assert!(ShardResult::parse(&bad).is_err());
    }

    #[test]
    fn merge_rejects_mixed_linalg_tiers() {
        let c = cfg(4);
        let mut cf = cfg(4);
        cf.params.insert("linalg".into(), "fast".into());
        let a = ShardResult::from_values(c, 0, 2, vec![1.0, 2.0]);
        let b = ShardResult::from_values(cf, 2, 4, vec![3.0, 4.0]);
        let err = merge(vec![a, b]).unwrap_err();
        assert!(format!("{err}").contains("linalg tiers"), "{err}");
        assert!(format!("{err}").contains("linalg=fast"), "{err}");
    }

    #[test]
    fn canonicalize_linalg_strips_exact_only() {
        let mut p = BTreeMap::new();
        p.insert("linalg".to_string(), "exact".to_string());
        p.insert("dim".to_string(), "32".to_string());
        canonicalize_linalg(&mut p);
        assert!(!p.contains_key("linalg"), "explicit exact must canonicalize to absent");
        assert_eq!(p.get("dim").map(String::as_str), Some("32"));
        p.insert("linalg".to_string(), "fast".to_string());
        canonicalize_linalg(&mut p);
        assert_eq!(p.get("linalg").map(String::as_str), Some("fast"));
        // the label helper spells the default tier
        assert_eq!(cfg(1).linalg_label(), "exact");
        let mut cf = cfg(1);
        cf.params.insert("linalg".into(), "fast".into());
        assert_eq!(cf.linalg_label(), "fast");
    }

    #[test]
    fn merge_rejects_mixed_stats_only_and_full() {
        let c = cfg(4);
        let full = ShardResult::from_values(c.clone(), 0, 2, vec![1.0, 2.0]);
        let so = ShardResult::from_values(c.clone(), 2, 4, vec![3.0, 4.0]).into_stats_only();
        let err = merge(vec![full, so]).unwrap_err();
        assert!(format!("{err}").contains("stats-only"), "{err}");
    }

    #[test]
    fn run_range_rejects_unknown_grad_kernel() {
        let mut c = cfg(4);
        c.sweep = SweepKind::GdFinal;
        c.params.insert("grad".into(), "graam".into());
        let err = run_range(&c, 1, 0, 4).unwrap_err();
        assert!(format!("{err}").contains("grad kernel"), "{err}");
        // the three valid spellings pass validation
        for ok in ["auto", "gram", "streaming"] {
            c.params.insert("grad".into(), ok.into());
            assert!(run_range(&c, 1, 0, 4).is_ok(), "grad={ok}");
        }
    }

    #[test]
    fn run_range_rejects_chunk_zero() {
        // a chunk-0 manifest would be unreadable by parse_doc, so the
        // runner must refuse to produce one
        let mut c = cfg(4);
        c.chunk = 0;
        let err = run_range(&c, 1, 0, 4).unwrap_err();
        assert!(format!("{err}").contains("chunk"), "{err}");
    }
}
