//! `decode-error`: Figure-3-style Monte-Carlo decoding error.
//!
//! Trial `t` draws a Bernoulli(p) straggler mask from substream `t` and
//! records the decoding error |alpha* - 1|^2. The decoder is the
//! chunk-scoped state (its scratch and — for the LSQR decoder — its
//! warm-start `(mask, w)` pair carry across a chunk's trials and are
//! replayed at partial leading chunks), the mask filler is the
//! per-trial value function; both plug into
//! [`decoding_error_values`]'s engine loop.

use super::{linalg_param, precond_param, SweepKernel};
use crate::codes::zoo::{make_decoder_cfg, BuiltScheme, DecoderSpec};
use crate::error::Result;
use crate::sweep::shard::SweepConfig;
use crate::sweep::{bernoulli_masks, decoding_error_values, TrialEngine};

pub const NAME: &str = "decode-error";

pub struct DecodeErrorKernel;

impl SweepKernel for DecodeErrorKernel {
    fn name(&self) -> &'static str {
        NAME
    }

    fn validate(&self, cfg: &SweepConfig) -> Result<()> {
        precond_param(cfg)?;
        linalg_param(cfg)?;
        Ok(())
    }

    fn run_range(
        &self,
        cfg: &SweepConfig,
        scheme: &BuiltScheme,
        dspec: DecoderSpec,
        engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let m = scheme.n_machines();
        let precond = precond_param(cfg)?;
        let backend = linalg_param(cfg)?;
        // chunk-scoped decoder factory + Bernoulli(p) trial masks; the
        // engine's replay contract makes the warm-started LSQR decoder
        // split-invariant
        Ok(decoding_error_values(
            engine,
            |_chunk| make_decoder_cfg(scheme, dspec, cfg.p, precond, backend),
            bernoulli_masks(m, cfg.p),
            lo,
            hi,
        ))
    }
}
