//! `gd-final`: Figure-4/5-style simulated coded GD.
//!
//! Trial `t` runs one full deterministic trajectory (straggler seed,
//! block permutation and step grid from substream `t`) and records the
//! final optimality gap |theta - theta*|^2. The gradient kernel is
//! selected by the `grad` param (`gram` | `streaming` | default `auto`,
//! which applies the [`GramCache::pays_off`] flop cut); the decoder and
//! GD scratch are chunk-scoped, so `chunk` re-seats warm-start state
//! exactly like the decode-error sweep.

use super::{grad_param, linalg_param, precond_param, SweepKernel, DATA_SALT};
use crate::codes::zoo::{make_decoder_cfg, BuiltScheme, DecoderSpec};
use crate::data::LstsqData;
use crate::error::Result;
use crate::gd::{GdScratch, GramCache, SimulatedGcod, StepSize, StreamingGrads};
use crate::linalg::LinalgBackend;
use crate::prng::Rng;
use crate::straggler::{BernoulliStragglers, StragglerModel};
use crate::sweep::shard::SweepConfig;
use crate::sweep::TrialEngine;

pub const NAME: &str = "gd-final";

pub struct GdFinalKernel;

/// Per-chunk mutable state for the `gd-final` sweep: the decoder (its
/// scratch and warm-start state carry across the chunk's trials and are
/// replayed at partial leading chunks, like every other chunk-scoped
/// sweep) plus the GD scratch and the zero start vector. The Gram/data
/// sources stay outside: they are immutable pure functions of the
/// config, so sharing one build across chunks cannot affect bits.
pub(crate) struct GdChunkCtx<'a> {
    pub(crate) dec: Box<dyn crate::decode::Decoder + 'a>,
    pub(crate) scratch: GdScratch,
    pub(crate) theta0: Vec<f64>,
}

/// The shared `gd-final`/`adv-gd` least-squares problem: point count
/// rounded up to a block multiple (LstsqData requires n_blocks | N) and
/// kept above dim so theta* stays well-defined, dataset derived from
/// the salted sweep seed — identical in every shard.
pub(crate) struct GdProblem {
    pub(crate) data: LstsqData,
    pub(crate) dim: usize,
    pub(crate) iters: usize,
    pub(crate) step_c: u32,
    /// linalg tier (the validated `linalg` param): dispatched into the
    /// decoder's LSQR, the Gram build/gemvs and the streaming dots
    pub(crate) backend: LinalgBackend,
}

impl GdProblem {
    pub(crate) fn build(cfg: &SweepConfig, scheme: &BuiltScheme, backend: LinalgBackend) -> Self {
        let dim = cfg.param_usize("dim", 32);
        let n_points = cfg
            .param_usize("n-points", 512)
            .max(dim + 1)
            .div_ceil(scheme.n_blocks())
            * scheme.n_blocks();
        let iters = cfg.param_usize("iters", 30);
        let sigma = cfg.param_f64("sigma", 1.0);
        let step_c = cfg.param_usize("step-c", 9) as u32;
        // the dataset is part of the sweep identity: same seed, same
        // data in every shard
        let data = LstsqData::generate(
            n_points,
            dim,
            scheme.n_blocks(),
            sigma,
            &mut Rng::new(cfg.seed ^ DATA_SALT),
        );
        Self { data, dim, iters, step_c, backend }
    }

    /// Gradient source per the (already validated) `grad` param;
    /// `None` = auto applies the `k <= b` flop cut — a pure function of
    /// the config, hence identical in every shard and thread. The cache
    /// itself is immutable and deterministic (the parallel build is
    /// bit-identical to the serial one, block by block), so one build
    /// is shared by all chunks/workers without touching the
    /// bit-exactness contract.
    pub(crate) fn gram_cache(
        &self,
        explicit: Option<bool>,
        engine: &TrialEngine,
    ) -> Option<GramCache> {
        let use_gram = explicit.unwrap_or_else(|| {
            GramCache::pays_off(self.data.n_points(), self.dim, self.data.n_blocks)
        });
        use_gram
            .then(|| GramCache::new_parallel_backend(&self.data, engine.threads(), self.backend))
    }

    /// The chunk-scoped state factory shared by `gd-final` and
    /// `adv-gd`: decoder (warm starts carry across the chunk, replayed
    /// at partial leading chunks), GD scratch, zero start vector.
    pub(crate) fn chunk_ctx<'a>(
        &self,
        scheme: &'a BuiltScheme,
        dspec: DecoderSpec,
        p: f64,
        precond: bool,
    ) -> GdChunkCtx<'a> {
        GdChunkCtx {
            dec: make_decoder_cfg(scheme, dspec, p, precond, self.backend),
            scratch: GdScratch::new(),
            theta0: vec![0.0; self.dim],
        }
    }

    /// One full deterministic coded-GD trajectory on a chunk-scoped
    /// context, returning the final optimality gap |theta - theta*|^2.
    /// Shared by `gd-final` (Bernoulli stragglers) and `adv-gd`
    /// (committed adversarial mask), so the two kernels' numerics can
    /// never drift apart.
    pub(crate) fn run_trial(
        &self,
        ctx: &mut GdChunkCtx<'_>,
        stragglers: &mut dyn StragglerModel,
        rho: Vec<usize>,
        m: usize,
        cache: &Option<GramCache>,
    ) -> f64 {
        let GdChunkCtx { dec, scratch, theta0 } = ctx;
        let mut gd = SimulatedGcod {
            decoder: dec.as_ref(),
            stragglers,
            step: StepSize::simulated_grid(self.step_c),
            rho: Some(rho),
            m,
            alpha_scale: 1.0,
        };
        match cache {
            Some(c) => {
                let mut src = c;
                gd.run_with(&mut src, theta0, self.iters, scratch)
            }
            None => {
                let mut src = StreamingGrads { data: &self.data, backend: self.backend };
                gd.run_with(&mut src, theta0, self.iters, scratch)
            }
        }
        .final_progress()
    }
}

impl SweepKernel for GdFinalKernel {
    fn name(&self) -> &'static str {
        NAME
    }

    fn validate(&self, cfg: &SweepConfig) -> Result<()> {
        grad_param(cfg)?;
        precond_param(cfg)?;
        linalg_param(cfg)?;
        Ok(())
    }

    fn run_range(
        &self,
        cfg: &SweepConfig,
        scheme: &BuiltScheme,
        dspec: DecoderSpec,
        engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let built = std::time::Instant::now();
        let prob = GdProblem::build(cfg, scheme, linalg_param(cfg)?);
        let precond = precond_param(cfg)?;
        let cache = prob.gram_cache(grad_param(cfg)?, engine);
        crate::metrics::gauge("phase_seconds{phase=\"gram-build\"}")
            .add(built.elapsed().as_secs_f64());
        Ok(engine.run_range_map(
            lo,
            hi,
            // the chunk-scoped state factory (warm-state replay contract)
            |_chunk| prob.chunk_ctx(scheme, dspec, cfg.p, precond),
            // trial_value: one full deterministic GD trajectory. The
            // trial's randomness (straggler seed, block shuffle) derives
            // from the trial substream; the decoder and scratch are
            // chunk-scoped, so values are split-invariant via the
            // engine's partial-chunk replay
            |ctx, _t, rng| {
                let mut strag = BernoulliStragglers::new(cfg.p, rng.next_u64());
                let rho = rng.permutation(scheme.n_blocks());
                prob.run_trial(ctx, &mut strag, rho, scheme.n_machines(), &cache)
            },
        ))
    }
}
