//! `fig4-cluster`: Figure 4 on the real worker-thread cluster.
//!
//! Trial `t` is one wall-clock-budgeted distributed GD run. Manifests
//! of this kind are produced by `bench_fig4_cluster` (the trial values
//! depend on real scheduling, so they are *not* bit-reproducible —
//! merge validation still applies, the bit-exactness contract does
//! not). The kernel exists in the registry so the manifest pipeline
//! (parse/merge/validate) knows the kind; the standard runner and the
//! dispatcher both refuse it via [`SweepKernel::external_producer`].

use super::SweepKernel;
use crate::codes::zoo::{BuiltScheme, DecoderSpec};
use crate::error::{Error, Result};
use crate::sweep::shard::SweepConfig;
use crate::sweep::TrialEngine;

pub const NAME: &str = "fig4-cluster";

const PRODUCER_MSG: &str =
    "fig4-cluster shards are produced by `cargo bench --bench bench_fig4_cluster -- \
     --shard i/k --out-dir DIR`, not by the standard runner (they need the \
     worker-thread cluster)";

pub struct Fig4ClusterKernel;

impl SweepKernel for Fig4ClusterKernel {
    fn name(&self) -> &'static str {
        NAME
    }

    fn external_producer(&self) -> Option<&'static str> {
        Some(PRODUCER_MSG)
    }

    fn run_range(
        &self,
        _cfg: &SweepConfig,
        _scheme: &BuiltScheme,
        _dspec: DecoderSpec,
        _engine: &TrialEngine,
        _lo: usize,
        _hi: usize,
    ) -> Result<Vec<f64>> {
        // unreachable through `shard::run_range` (it checks
        // external_producer first); kept loud for direct callers
        Err(Error::msg(PRODUCER_MSG))
    }
}
