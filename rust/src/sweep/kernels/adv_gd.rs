//! `adv-gd`: gradient descent under a greedy adversarial straggler
//! budget — the paper's *second* convergence regime, made sweepable.
//!
//! The paper's central claim spans two straggler regimes: random
//! stragglers (where the optimal decoder's error decays exponentially
//! in the replication factor, the `gd-final` sweep) and **adversarial**
//! stragglers, where Corollaries V.2/V.3 bound the decoding error an
//! adversary with budget `pm` can force, and GD correspondingly
//! converges down to a **noise floor that scales with that adversarial
//! error** rather than to the optimum. This kernel makes the second
//! regime empirically checkable across every scheme in
//! [`crate::codes::zoo`]:
//!
//! * Each run the adversary spends a budget of `budget` machines
//!   (param; default `floor(p * m)`, Definition I.3) using the generic
//!   greedy attack [`crate::straggler::greedy_decode_attack`] — the
//!   machine whose loss most increases the optimal decoding error,
//!   repeatedly. The greedy choice maximizes decoding error, which is
//!   independent of the iterate θ and of the block shuffle ρ, so the
//!   per-iteration greedy adversary commits to one mask per run WLOG;
//!   the mask is a pure function of `(scheme, decoder, budget)` and is
//!   computed once, identically in every shard.
//! * Trial `t` then runs one full deterministic coded-GD trajectory
//!   ([`crate::gd::SimulatedGcod`] with [`FixedMaskStragglers`]
//!   replaying the
//!   adversarial mask every iteration; block permutation ρ and the step
//!   grid drawn from substream `t`) and records the final optimality
//!   gap |θ − θ*|² — the empirical noise floor. Monte-Carlo spread
//!   comes from ρ: which data blocks land on the attacked coordinates
//!   varies per trial.
//! * Gradients use the Gram-cached `gd` kernels from PR 4 (`grad`
//!   param: `gram` | `streaming` | `auto`), with the cache built once
//!   across the engine's workers and shared by all chunks.
//!
//! Params: `n-points`, `dim`, `iters`, `sigma`, `step-c` as `gd-final`;
//! plus `budget` (attacked machines, default `floor(p*m)`), `grad`,
//! `precond`.

use super::gd_final::GdProblem;
use super::{grad_param, linalg_param, precond_param, SweepKernel};
use crate::codes::zoo::{make_decoder_cfg, BuiltScheme, DecoderSpec};
use crate::error::{Error, Result};
use crate::straggler::{greedy_decode_attack, FixedMaskStragglers};
use crate::sweep::shard::SweepConfig;
use crate::sweep::TrialEngine;

pub const NAME: &str = "adv-gd";

pub struct AdvGdKernel;

impl SweepKernel for AdvGdKernel {
    fn name(&self) -> &'static str {
        NAME
    }

    fn validate(&self, cfg: &SweepConfig) -> Result<()> {
        grad_param(cfg)?;
        precond_param(cfg)?;
        linalg_param(cfg)?;
        if let Some(b) = cfg.params.get("budget") {
            b.parse::<usize>().map_err(|e| {
                Error::msg(format!("bad budget '{b}' (want a machine count): {e}"))
            })?;
        }
        Ok(())
    }

    fn run_range(
        &self,
        cfg: &SweepConfig,
        scheme: &BuiltScheme,
        dspec: DecoderSpec,
        engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let m = scheme.n_machines();
        let precond = precond_param(cfg)?;
        let budget = match cfg.params.get("budget") {
            Some(b) => b.parse::<usize>().map_err(|e| {
                Error::msg(format!("bad budget '{b}' (want a machine count): {e}"))
            })?,
            None => (cfg.p * m as f64).floor() as usize,
        };
        let prob = GdProblem::build(cfg, scheme, linalg_param(cfg)?);
        // the adversarial mask: deterministic, serial, shared by every
        // trial/chunk/shard (the greedy search threads one decoder
        // through all its candidate evaluations, so warm-start state
        // sees the identical sequence in every process)
        let atk_dec = make_decoder_cfg(scheme, dspec, cfg.p, precond, prob.backend);
        let mask = greedy_decode_attack(atk_dec.as_ref(), &scheme.a, budget.min(m));
        drop(atk_dec);
        let built = std::time::Instant::now();
        let cache = prob.gram_cache(grad_param(cfg)?, engine);
        crate::metrics::gauge("phase_seconds{phase=\"gram-build\"}")
            .add(built.elapsed().as_secs_f64());
        Ok(engine.run_range_map(
            lo,
            hi,
            // chunk-scoped state, exactly as gd-final: decoder warm
            // starts and GD scratch replay at partial leading chunks
            |_chunk| prob.chunk_ctx(scheme, dspec, cfg.p, precond),
            // same shared trajectory as gd-final; the adversary replays
            // its committed mask every iteration, so the block shuffle
            // is the only trial randomness
            |ctx, _t, rng| {
                let mut strag = FixedMaskStragglers::new(&mask);
                let rho = rng.permutation(scheme.n_blocks());
                prob.run_trial(ctx, &mut strag, rho, m, &cache)
            },
        ))
    }
}
