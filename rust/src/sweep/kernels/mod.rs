//! Pluggable sweep kernels: the open registry behind [`SweepKind`].
//!
//! PR 2–4 grew the sharded sweep layer around a closed enum of four
//! standard sweeps, with a `match` in `shard::run_range` fanning out to
//! hard-coded runner functions. This module replaces that closed core
//! with an **open kernel architecture**:
//!
//! * [`SweepKernel`] — one pluggable sweep implementation: a registry
//!   `name()` (the manifest `sweep` field), up-front param validation,
//!   and `run_range`, which computes the per-trial metric values for a
//!   trial subrange on a [`TrialEngine`].
//! * the **registry** — a process-global name → kernel table.
//!   The built-in kernels ([`decode_error`], [`gd_final`], [`attack`],
//!   [`fig4_cluster`], [`adv_gd`]) are installed on first use;
//!   [`register_kernel`] adds user kernels at runtime (duplicate names
//!   are rejected). Everything downstream — `gcod sweep-shard`,
//!   `sweep-merge`, the elastic dispatcher and `sweep-launch` — routes
//!   through [`SweepKind`], so a newly registered kernel is immediately
//!   shardable, mergeable and dispatchable with **zero changes** to the
//!   CLI or dispatch layers.
//! * [`SweepKind`] — the open replacement for the old enum: a copyable
//!   interned kernel name. The old variant spellings survive as
//!   associated constants (`SweepKind::DecodeError`, ...), so existing
//!   configs, benches and tests read unchanged.
//!
//! ## The kernel contract
//!
//! `run_range(cfg, scheme, dspec, engine, lo, hi)` must return exactly
//! `hi - lo` values, and the value recorded for trial `t` must be a
//! **pure function of `(cfg, t)`** — bit-identical for any split of
//! `[0, N)` across threads, shards and processes. The standard recipe
//! (see any built-in kernel) is:
//!
//! 1. *Immutable run state* (datasets, Gram caches, attack masks) is
//!    derived deterministically from the config — typically from
//!    `Rng::new(cfg.seed ^ SALT)` — so every shard rebuilds identical
//!    state. Sharing it across chunks cannot affect bits.
//! 2. *Mutable trial state* (decoder warm starts, GD scratch) lives in
//!    a **chunk-scoped state factory** passed to
//!    [`TrialEngine::run_range_map`]: the factory rebuilds the state at
//!    every chunk boundary, and the engine replays the leading trials
//!    of a partially-covered chunk to warm it (the warm-state replay
//!    contract), so per-trial values never depend on where a shard
//!    boundary fell.
//! 3. *Per-trial randomness* comes only from the trial's `rng`
//!    argument — the `(seed, t)`-keyed substream — never from shared
//!    sequential state.
//!
//! A kernel that cannot be produced by the standard runner at all
//! (`fig4-cluster` needs the real worker-thread cluster) says so via
//! [`SweepKernel::external_producer`]; the runner and the dispatcher
//! both refuse it with the kernel's own message.

pub mod adv_gd;
pub mod attack;
pub mod decode_error;
pub mod fig4_cluster;
pub mod gd_final;

use crate::codes::zoo::{BuiltScheme, DecoderSpec};
use crate::error::{Error, Result};
use crate::sweep::shard::SweepConfig;
use crate::sweep::TrialEngine;
use std::fmt;
use std::sync::{Mutex, Once};

/// Salt for the `gd-final`/`adv-gd` data-generation RNG: every shard
/// derives the identical dataset from `cfg.seed ^ DATA_SALT`. Public
/// because the dataset is part of the sweep-identity contract (the
/// byte-identity oracle tests rebuild it independently).
pub const DATA_SALT: u64 = 0xDA7A_6E4E;

/// One pluggable standard sweep. See the module docs for the
/// determinism contract `run_range` implementations must uphold.
pub trait SweepKernel: Sync + Send {
    /// Registry key; travels as the manifest `sweep` field. Must be
    /// non-empty and unique across the registry.
    fn name(&self) -> &'static str;

    /// Reject malformed `cfg.params` before any work happens (unknown
    /// enum-valued selectors, unparseable numbers). Params this kernel
    /// does not know are ignored, not rejected — they are still part of
    /// the sweep identity, so merges stay safe.
    fn validate(&self, cfg: &SweepConfig) -> Result<()> {
        let _ = cfg;
        Ok(())
    }

    /// `Some(msg)` when this kind's manifests are produced outside the
    /// standard runner (e.g. by a bench driving real hardware); the
    /// runner and the dispatcher refuse such kinds with `msg`.
    fn external_producer(&self) -> Option<&'static str> {
        None
    }

    /// Per-trial metric values for trials `[lo, hi)` of the `[0, N)`
    /// sweep. Must return exactly `hi - lo` values, bit-identical to
    /// the corresponding slice of any other split (module docs).
    fn run_range(
        &self,
        cfg: &SweepConfig,
        scheme: &BuiltScheme,
        dspec: DecoderSpec,
        engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>>;
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// Process-global kernel table. Built-ins are installed once on first
/// access; user kernels are appended by [`register_kernel`]. Entries
/// are `&'static` (user kernels are leaked on registration — a handful
/// of small objects over a process lifetime).
static REGISTRY: Mutex<Vec<&'static dyn SweepKernel>> = Mutex::new(Vec::new());
static BUILTINS: Once = Once::new();

fn with_registry<T>(f: impl FnOnce(&mut Vec<&'static dyn SweepKernel>) -> T) -> T {
    BUILTINS.call_once(|| {
        let mut reg = REGISTRY.lock().expect("kernel registry poisoned");
        reg.push(&decode_error::DecodeErrorKernel);
        reg.push(&gd_final::GdFinalKernel);
        reg.push(&attack::AttackKernel);
        reg.push(&fig4_cluster::Fig4ClusterKernel);
        reg.push(&adv_gd::AdvGdKernel);
    });
    f(&mut REGISTRY.lock().expect("kernel registry poisoned"))
}

/// The kernel registered under `name`, if any.
pub fn lookup(name: &str) -> Option<&'static dyn SweepKernel> {
    with_registry(|reg| reg.iter().copied().find(|k| k.name() == name))
}

/// Registered kernel names, in registration order (built-ins first).
pub fn kernel_names() -> Vec<&'static str> {
    with_registry(|reg| reg.iter().map(|k| k.name()).collect())
}

/// Register a user sweep kernel, making its name parseable by
/// [`SweepKind::parse`] and runnable through `shard::run_range`, the
/// `gcod sweep-shard`/`sweep-merge` manifest pipeline and the elastic
/// dispatcher. The kernel is leaked to `'static`. Fails on an empty or
/// already-taken name — the manifest `sweep` field must stay
/// unambiguous.
pub fn register_kernel(kernel: Box<dyn SweepKernel>) -> Result<SweepKind> {
    let name = kernel.name();
    if name.is_empty() || name.chars().any(char::is_whitespace) {
        return Err(Error::msg(format!(
            "invalid sweep kernel name '{name}': must be non-empty, no whitespace"
        )));
    }
    with_registry(|reg| {
        if reg.iter().any(|k| k.name() == name) {
            return Err(Error::msg(format!(
                "sweep kernel '{name}' is already registered — kernel names must be unique"
            )));
        }
        reg.push(Box::leak(kernel));
        Ok(SweepKind(name))
    })
}

// ---------------------------------------------------------------------
// SweepKind: an interned kernel name
// ---------------------------------------------------------------------

/// Which sweep kernel a config/manifest refers to — an interned
/// registry name. Replaces the old closed enum: the old variant
/// spellings survive as associated constants, and [`SweepKind::parse`]
/// accepts any registered kernel (built-in or user-registered), so new
/// workloads plug in without touching this type, the CLI, or the
/// dispatcher.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct SweepKind(&'static str);

#[allow(non_upper_case_globals)] // the old enum variants' spellings, kept for source compatibility
impl SweepKind {
    /// Figure-3-style Monte-Carlo decoding error ([`decode_error`]).
    pub const DecodeError: SweepKind = SweepKind(decode_error::NAME);
    /// Figure-4/5-style simulated coded GD ([`gd_final`]).
    pub const GdFinal: SweepKind = SweepKind(gd_final::NAME);
    /// Greedy adversarial error-vs-budget curve ([`attack`]).
    pub const Attack: SweepKind = SweepKind(attack::NAME);
    /// Real worker-thread-cluster Figure 4 ([`fig4_cluster`];
    /// bench-produced, not runnable by the standard runner).
    pub const Fig4Cluster: SweepKind = SweepKind(fig4_cluster::NAME);
    /// GD under a greedy adversarial straggler budget ([`adv_gd`]).
    pub const AdvGd: SweepKind = SweepKind(adv_gd::NAME);

    /// Resolve a kernel name against the registry. Unknown names are
    /// rejected (a manifest naming an unregistered kernel cannot be
    /// validated, let alone re-run).
    pub fn parse(s: &str) -> Result<Self> {
        match lookup(s) {
            Some(k) => Ok(SweepKind(k.name())),
            None => Err(Error::msg(format!(
                "unknown sweep kind '{s}' ({})",
                kernel_names().join("|")
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        self.0
    }

    /// The registered kernel. Every `SweepKind` in circulation came
    /// from [`SweepKind::parse`], [`register_kernel`] or a built-in
    /// constant, so the lookup cannot fail.
    pub fn kernel(&self) -> &'static dyn SweepKernel {
        lookup(self.0).expect("SweepKind name is always interned in the registry")
    }

    /// `Some(msg)` when this kind cannot be executed by the standard
    /// runner (see [`SweepKernel::external_producer`]). The dispatcher
    /// keys off this instead of naming kinds.
    pub fn external_producer(&self) -> Option<&'static str> {
        self.kernel().external_producer()
    }
}

impl fmt::Debug for SweepKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SweepKind({})", self.0)
    }
}

impl fmt::Display for SweepKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

// ---------------------------------------------------------------------
// Shared param helpers
// ---------------------------------------------------------------------

/// Parse the shared enum-valued `precond` param (`on` | `off`, default
/// off): degree-diagonal LSQR preconditioning in the generic optimal
/// decoder. Part of the sweep identity via `params`, so existing
/// manifests (param absent) stay bit-exact.
pub(crate) fn precond_param(cfg: &SweepConfig) -> Result<bool> {
    match cfg.params.get("precond").map(String::as_str) {
        None | Some("off") => Ok(false),
        Some("on") => Ok(true),
        Some(v) => Err(Error::msg(format!("unknown precond setting '{v}' (on|off)"))),
    }
}

/// Parse the shared enum-valued `grad` param (`gram` | `streaming` |
/// default `auto`): reject unknown spellings instead of silently
/// falling through to auto. Returns the explicit choice, `None` = auto.
pub(crate) fn grad_param(cfg: &SweepConfig) -> Result<Option<bool>> {
    match cfg.params.get("grad").map(String::as_str) {
        None | Some("auto") => Ok(None),
        Some("gram") => Ok(Some(true)),
        Some("streaming") => Ok(Some(false)),
        Some(g) => Err(Error::msg(format!("unknown grad kernel '{g}' (auto|gram|streaming)"))),
    }
}

/// Parse the shared enum-valued `linalg` param (`exact` | `fast`,
/// default exact): which [`crate::linalg::LinalgBackend`] tier the
/// kernel's dense linear algebra runs on. Part of the sweep identity
/// via `params` — and `exact` is canonicalized to *absent* by
/// [`crate::sweep::shard::canonicalize_linalg`] so pre-existing
/// manifests (param absent) stay byte-identical.
pub(crate) fn linalg_param(cfg: &SweepConfig) -> Result<crate::linalg::LinalgBackend> {
    match cfg.params.get("linalg").map(String::as_str) {
        None => Ok(crate::linalg::LinalgBackend::Exact),
        Some(s) => crate::linalg::LinalgBackend::parse(s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered_in_order() {
        let names = kernel_names();
        for want in ["decode-error", "gd-final", "attack", "fig4-cluster", "adv-gd"] {
            assert!(names.contains(&want), "missing builtin '{want}' in {names:?}");
        }
        // the first four keep the legacy enum order (error messages,
        // help strings)
        assert_eq!(&names[..4], &["decode-error", "gd-final", "attack", "fig4-cluster"]);
    }

    #[test]
    fn sweep_kind_constants_round_trip() {
        for k in [
            SweepKind::DecodeError,
            SweepKind::GdFinal,
            SweepKind::Attack,
            SweepKind::Fig4Cluster,
            SweepKind::AdvGd,
        ] {
            assert_eq!(SweepKind::parse(k.as_str()).unwrap(), k);
            assert_eq!(k.kernel().name(), k.as_str());
        }
        let err = SweepKind::parse("nope").unwrap_err();
        assert!(format!("{err}").contains("unknown sweep kind"), "{err}");
        assert!(format!("{err}").contains("adv-gd"), "{err}");
    }

    #[test]
    fn only_fig4_cluster_is_externally_produced() {
        assert!(SweepKind::Fig4Cluster.external_producer().is_some());
        let runnable =
            [SweepKind::DecodeError, SweepKind::GdFinal, SweepKind::Attack, SweepKind::AdvGd];
        for k in runnable {
            assert!(k.external_producer().is_none(), "{k}");
        }
    }

    #[test]
    fn register_rejects_bad_and_duplicate_names() {
        struct Bad(&'static str);
        impl SweepKernel for Bad {
            fn name(&self) -> &'static str {
                self.0
            }
            fn run_range(
                &self,
                _cfg: &SweepConfig,
                _scheme: &BuiltScheme,
                _dspec: DecoderSpec,
                _engine: &TrialEngine,
                lo: usize,
                hi: usize,
            ) -> Result<Vec<f64>> {
                Ok(vec![0.0; hi - lo])
            }
        }
        assert!(register_kernel(Box::new(Bad(""))).is_err());
        assert!(register_kernel(Box::new(Bad("has space"))).is_err());
        let err = register_kernel(Box::new(Bad("decode-error"))).unwrap_err();
        assert!(format!("{err}").contains("already registered"), "{err}");
        // a fresh name registers exactly once
        let kind = register_kernel(Box::new(Bad("kernels-mod-test"))).unwrap();
        assert_eq!(kind.as_str(), "kernels-mod-test");
        assert!(register_kernel(Box::new(Bad("kernels-mod-test"))).is_err());
        assert_eq!(SweepKind::parse("kernels-mod-test").unwrap(), kind);
    }
}
