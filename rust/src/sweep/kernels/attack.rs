//! `attack`: the greedy adversarial error-vs-budget curve.
//!
//! Trial `t` records the per-block decoding error after `t + 1`
//! greedily-chosen stragglers (the trial axis is the attack budget).
//! NOTE: the greedy search is inherently sequential — a shard
//! recomputes the nested trace from budget 0 up to its own `hi`
//! (serially; the engine's `threads` is unused), so sharding the
//! budget axis only saves the *trailing* budgets' steps, not the
//! prefix. The trace is a pure function of `(decoder, assignment)`,
//! which is what makes the budget-axis slices bit-exact across shards.

use super::{linalg_param, precond_param, SweepKernel};
use crate::codes::zoo::{make_decoder_cfg, BuiltScheme, DecoderSpec};
use crate::error::Result;
use crate::straggler::greedy_decode_attack_trace;
use crate::sweep::shard::SweepConfig;
use crate::sweep::TrialEngine;

pub const NAME: &str = "attack";

pub struct AttackKernel;

impl SweepKernel for AttackKernel {
    fn name(&self) -> &'static str {
        NAME
    }

    fn validate(&self, cfg: &SweepConfig) -> Result<()> {
        precond_param(cfg)?;
        linalg_param(cfg)?;
        Ok(())
    }

    fn run_range(
        &self,
        cfg: &SweepConfig,
        scheme: &BuiltScheme,
        dspec: DecoderSpec,
        _engine: &TrialEngine,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let precond = precond_param(cfg)?;
        let dec = make_decoder_cfg(scheme, dspec, cfg.p, precond, linalg_param(cfg)?);
        let (_, trace) = greedy_decode_attack_trace(dec.as_ref(), &scheme.a, hi);
        let n = scheme.n_blocks() as f64;
        Ok(trace[lo..hi].iter().map(|e| e / n).collect())
    }
}
