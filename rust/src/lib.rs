//! # gcod — Approximate Gradient Coding with Optimal Decoding
//!
//! A production-shaped reproduction of Glasgow & Wootters, *"Approximate
//! Gradient Coding with Optimal Decoding"*, IEEE JSAIT 2021
//! (DOI 10.1109/JSAIT.2021.3100110), as a three-layer rust + JAX/Pallas
//! stack: Pallas kernels (L1) and JAX compute graphs (L2) are AOT-lowered
//! to HLO text at build time; this crate (L3) is the coordinator that
//! owns assignment construction, straggler handling, optimal decoding and
//! the coded gradient-descent loop, executing the AOT artifacts via the
//! PJRT CPU client (feature `pjrt`). Python never runs on the request
//! path.
//!
//! Top-level layout (see DESIGN.md for the full inventory):
//! * [`graphs`] — graph assignment schemes incl. LPS Ramanujan expanders
//! * [`codes`] — the paper's scheme + every baseline (FRC, expander, …)
//! * [`decode`] — linear-time optimal graph decoder, LSQR generic decoder
//! * [`straggler`] — random & adversarial straggler models
//! * [`sweep`] — parallel deterministic Monte-Carlo trial engine;
//!   [`sweep::shard`] splits sweeps across processes with bit-exact
//!   JSON-manifest merging (`gcod sweep-shard` / `gcod sweep-merge`);
//!   [`sweep::kernels`] is the open sweep-kernel registry behind
//!   `SweepKind` (register a [`sweep::kernels::SweepKernel`] and it is
//!   immediately shardable, mergeable and dispatchable)
//! * [`dispatch`] — elastic fault-tolerant work-queue coordinator:
//!   leases trial ranges to a worker-process pool, re-dispatches lost
//!   ranges, dedups speculative covers, merges to the single-process
//!   bits (`gcod sweep-launch`)
//! * [`gd`] — coded gradient descent engines & convergence bounds
//! * [`coordinator`] — distributed leader/worker runtime (Algorithm 2)
//! * [`runtime`] — PJRT artifact loading & execution (feature `pjrt`)
//! * [`obs`] — structured events, sinks (flight recorder / JSONL trace /
//!   stderr log) and the event→metrics bridge behind `gcod serve`'s
//!   `/metrics` endpoint and `gcod report`; bit-neutral by contract
//! * substrates: [`prng`], [`linalg`], [`sparse`], [`config`], [`cli`],
//!   [`metrics`], [`bench_util`], [`testing`], [`data`], [`error`]
//!
//! ## Performance architecture
//!
//! The paper's systems claim (Section III) is that optimal graph
//! decoding costs `c*m` operations — the same order as the update
//! itself — so the experiment harness must not drown that constant in
//! allocator and layout overhead. Three mechanisms keep the per-trial
//! hot path lean (README.md has the long-form version):
//!
//! 1. **Scratch reuse.** [`decode::Decoder::decode_into`] writes into a
//!    caller-owned [`decode::Decoding`]; every decoder parks its working
//!    set (BFS queues, survivor counts, LSQR Krylov vectors) in
//!    interior-mutable scratch sized on first use. After warm-up a
//!    decode performs zero heap allocations.
//! 2. **CSC + CSR mirrors.** The assignment matrix lives in
//!    [`sparse::Csc`] (column = machine: per-machine access, transpose
//!    products) with a read-only [`sparse::Csr`] mirror built once
//!    (row = data block: forward products as one contiguous sweep).
//!    [`sparse::MaskedColumnsOp`] combines both so the generic LSQR
//!    decoder needs no per-trial survivor index, which also makes its
//!    warm start (previous trial's `w`) a plain buffer copy.
//! 3. **Deterministic parallel sweeps.** [`sweep::TrialEngine`] fans
//!    Monte-Carlo trials across scoped threads with per-trial PRNG
//!    substreams, chunk-scoped decoder state and an ordered reduction,
//!    so the accumulated metrics are bit-identical for every thread
//!    count — parallelism is purely a wall-clock lever. The
//!    [`sweep::shard`] layer extends the same contract across process
//!    boundaries: any contiguous split of a trial range, run anywhere,
//!    merges back to the single-process bits.
//! 4. **Gram-cached, allocation-free GD.** The simulated-GD loop
//!    ([`gd::SimulatedGcod::run_with`]) runs on blocked `*_into`
//!    kernels ([`linalg::gemv_slice_into`], [`linalg::syrk_into`]) and
//!    a reusable [`gd::GdScratch`] — zero heap allocations per
//!    iteration — and [`gd::GramCache`] precomputes per-block
//!    `(XᵀX, Xᵀy)` so each iteration costs n d×d gemvs instead of a
//!    full data pass when blocks are tall (`grad=auto` in the
//!    `gd-final` sweep picks the winning kernel per config).

// `--features pjrt` in a tree without the vendored deps: fail with the
// vendoring instructions, not a wall of unresolved imports. build.rs
// emits `pjrt_runtime` only when the deps are really declared.
#[cfg(all(feature = "pjrt", not(pjrt_runtime)))]
compile_error!(
    "feature `pjrt` needs the vendored `xla` and `anyhow` dependencies: uncomment the \
     [dependencies] lines in rust/Cargo.toml and switch the feature to \
     pjrt = [\"dep:xla\", \"dep:anyhow\"] (see src/runtime/mod.rs)"
);

pub mod bench_util;
pub mod cli;
pub mod codes;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod dispatch;
pub mod error;
pub mod gd;
pub mod graphs;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod prng;
#[cfg(pjrt_runtime)]
pub mod runtime;
pub mod sparse;
pub mod straggler;
pub mod sweep;
pub mod testing;
