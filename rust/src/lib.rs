//! # gcod — Approximate Gradient Coding with Optimal Decoding
//!
//! A production-shaped reproduction of Glasgow & Wootters, *"Approximate
//! Gradient Coding with Optimal Decoding"*, IEEE JSAIT 2021
//! (DOI 10.1109/JSAIT.2021.3100110), as a three-layer rust + JAX/Pallas
//! stack: Pallas kernels (L1) and JAX compute graphs (L2) are AOT-lowered
//! to HLO text at build time; this crate (L3) is the coordinator that
//! owns assignment construction, straggler handling, optimal decoding and
//! the coded gradient-descent loop, executing the AOT artifacts via the
//! PJRT CPU client. Python never runs on the request path.
//!
//! Top-level layout (see DESIGN.md for the full inventory):
//! * [`graphs`] — graph assignment schemes incl. LPS Ramanujan expanders
//! * [`codes`] — the paper's scheme + every baseline (FRC, expander, …)
//! * [`decode`] — linear-time optimal graph decoder, LSQR generic decoder
//! * [`straggler`] — random & adversarial straggler models
//! * [`gd`] — coded gradient descent engines & convergence bounds
//! * [`coordinator`] — distributed leader/worker runtime (Algorithm 2)
//! * [`runtime`] — PJRT artifact loading & execution
//! * substrates: [`prng`], [`linalg`], [`sparse`], [`config`], [`cli`],
//!   [`metrics`], [`bench_util`], [`testing`], [`data`]

pub mod bench_util;
pub mod cli;
pub mod codes;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod gd;
pub mod graphs;
pub mod linalg;
pub mod metrics;
pub mod prng;
pub mod runtime;
pub mod sparse;
pub mod straggler;
pub mod testing;
