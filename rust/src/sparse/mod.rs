//! Sparse-matrix substrate: CSC/CSR assignment matrices + LSQR.
//!
//! Assignment matrices A (n data blocks x m machines) are sparse — graph
//! schemes have exactly 2 non-zeros per column, FRC/BIBD/rBGC/BRC a few
//! more. The generic optimal decoder (decode::GenericOptimalDecoder)
//! solves min_w |A_S w - 1|_2 over the surviving columns S with LSQR,
//! which needs fast `A_S w` and `A_S^T r` — i.e. column access, so CSC
//! is the primary layout.

pub mod lsqr;

pub use lsqr::{lsqr, LinearOp, LsqrResult};

/// Compressed sparse column matrix (column = machine).
#[derive(Clone, Debug)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// column pointer, len cols+1
    pub colptr: Vec<usize>,
    /// row indices, len nnz
    pub rowidx: Vec<usize>,
    /// values, len nnz
    pub values: Vec<f64>,
}

impl Csc {
    /// Build from (row, col, value) triplets (duplicates are summed).
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(usize, usize, f64)>) -> Self {
        t.sort_unstable_by_key(|&(r, c, _)| (c, r));
        // merge duplicates (adjacent after the sort)
        let mut merged: Vec<(usize, usize, f64)> = Vec::with_capacity(t.len());
        for (r, c, v) in t {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if let Some(last) = merged.last_mut() {
                if last.0 == r && last.1 == c {
                    last.2 += v;
                    continue;
                }
            }
            merged.push((r, c, v));
        }
        let mut colptr = vec![0usize; cols + 1];
        for &(_, c, _) in &merged {
            colptr[c + 1] += 1;
        }
        for c in 0..cols {
            colptr[c + 1] += colptr[c];
        }
        let rowidx = merged.iter().map(|&(r, _, _)| r).collect();
        let values = merged.iter().map(|&(_, _, v)| v).collect();
        Self { rows, cols, colptr, rowidx, values }
    }

    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    /// Rows (and values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.colptr[j], self.colptr[j + 1]);
        (&self.rowidx[a..b], &self.values[a..b])
    }

    /// y = A x (x over columns/machines, y over rows/blocks).
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                let (ri, vals) = self.col(j);
                for (k, &r) in ri.iter().enumerate() {
                    y[r] += vals[k] * xj;
                }
            }
        }
        y
    }

    /// y = A^T x.
    pub fn t_mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        (0..self.cols)
            .map(|j| {
                let (ri, vals) = self.col(j);
                ri.iter().enumerate().map(|(k, &r)| vals[k] * x[r]).sum()
            })
            .collect()
    }

    /// Number of non-zero entries divided by rows — the paper's
    /// replication factor d (Definition I.1, at block granularity).
    pub fn replication_factor(&self) -> f64 {
        self.nnz() as f64 / self.rows as f64
    }

    /// Max non-zeros in any column — computational load in blocks.
    pub fn max_col_nnz(&self) -> usize {
        (0..self.cols)
            .map(|j| self.colptr[j + 1] - self.colptr[j])
            .max()
            .unwrap_or(0)
    }

    /// Dense copy (tests / small-n oracles only).
    pub fn to_dense(&self) -> crate::linalg::Mat {
        let mut m = crate::linalg::Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (ri, vals) = self.col(j);
            for (k, &r) in ri.iter().enumerate() {
                m[(r, j)] += vals[k];
            }
        }
        m
    }
}

/// The column-restricted operator A_S used by the generic optimal
/// decoder: only the surviving (non-straggler) machines' columns.
pub struct ColumnSubsetOp<'a> {
    pub a: &'a Csc,
    /// surviving column indices
    pub cols: &'a [usize],
}

impl LinearOp for ColumnSubsetOp<'_> {
    fn rows(&self) -> usize {
        self.a.rows
    }
    fn cols(&self) -> usize {
        self.cols.len()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for (jj, &j) in self.cols.iter().enumerate() {
            let xj = x[jj];
            if xj != 0.0 {
                let (ri, vals) = self.a.col(j);
                for (k, &r) in ri.iter().enumerate() {
                    y[r] += vals[k] * xj;
                }
            }
        }
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        for (jj, &j) in self.cols.iter().enumerate() {
            let (ri, vals) = self.a.col(j);
            y[jj] = ri.iter().enumerate().map(|(k, &r)| vals[k] * x[r]).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csc {
        // A = [1 0 2; 0 3 0] (2x3)
        Csc::from_triplets(2, 3, vec![(0, 0, 1.0), (1, 1, 3.0), (0, 2, 2.0)])
    }

    #[test]
    fn triplets_round_trip() {
        let a = small();
        assert_eq!(a.nnz(), 3);
        let d = a.to_dense();
        assert_eq!(d[(0, 0)], 1.0);
        assert_eq!(d[(1, 1)], 3.0);
        assert_eq!(d[(0, 2)], 2.0);
        assert_eq!(d[(1, 2)], 0.0);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let a = Csc::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, 1.0)]);
        assert_eq!(a.to_dense()[(0, 0)], 3.5);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn empty_columns_ok() {
        let a = Csc::from_triplets(3, 4, vec![(0, 0, 1.0), (2, 3, 1.0)]);
        assert_eq!(a.col(1).0.len(), 0);
        assert_eq!(a.col(2).0.len(), 0);
        assert_eq!(a.mul_vec(&[1.0, 5.0, 5.0, 1.0]), vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn matvec_against_dense() {
        let a = small();
        let x = vec![1.0, -1.0, 0.5];
        assert_eq!(a.mul_vec(&x), a.to_dense().mul_vec(&x));
        let y = vec![2.0, -3.0];
        assert_eq!(a.t_mul_vec(&y), a.to_dense().t_mul_vec(&y));
    }

    #[test]
    fn replication_and_load() {
        let a = small();
        assert!((a.replication_factor() - 1.5).abs() < 1e-12);
        assert_eq!(a.max_col_nnz(), 1);
    }

    #[test]
    fn column_subset_op_matches_dense_subset() {
        let a = small();
        let cols = vec![0usize, 2];
        let op = ColumnSubsetOp { a: &a, cols: &cols };
        let mut y = vec![0.0; 2];
        op.apply(&[2.0, 3.0], &mut y);
        assert_eq!(y, vec![2.0 + 6.0, 0.0]);
        let mut yt = vec![0.0; 2];
        op.apply_t(&[1.0, 1.0], &mut yt);
        assert_eq!(yt, vec![1.0, 2.0]);
    }
}
