//! Sparse-matrix substrate: CSC/CSR assignment matrices + LSQR.
//!
//! Assignment matrices A (n data blocks x m machines) are sparse — graph
//! schemes have exactly 2 non-zeros per column, FRC/BIBD/rBGC/BRC a few
//! more. The generic optimal decoder (decode::GenericOptimalDecoder)
//! solves min_w |A_S w - 1|_2 over the surviving columns S with LSQR,
//! which needs fast `A_S w` and `A_S^T r`.
//!
//! Layout roles (see README.md "Performance architecture"):
//! * [`Csc`] — the primary layout. Column = machine, so per-machine
//!   access (`col`, `apply_t` gathers) is contiguous.
//! * [`Csr`] — a read-only row-major mirror built once from the CSC
//!   ([`Csc::to_csr`]). Forward products `y = A x` walk `rowptr`
//!   sequentially, one contiguous pass over the value array with a
//!   single write per row — the hot layout for the LSQR forward apply
//!   inside the Monte-Carlo trial loop.
//!
//! Every product has an `_into` variant writing a caller-owned buffer so
//! repeated decodes are allocation-free.

pub mod lsqr;

pub use lsqr::{lsqr, lsqr_into, lsqr_into_backend, LinearOp, LsqrResult, LsqrScratch, LsqrSummary};

/// Compressed sparse column matrix (column = machine).
#[derive(Clone, Debug)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// column pointer, len cols+1
    pub colptr: Vec<usize>,
    /// row indices, len nnz
    pub rowidx: Vec<usize>,
    /// values, len nnz
    pub values: Vec<f64>,
}

impl Csc {
    /// Build from (row, col, value) triplets (duplicates are summed).
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(usize, usize, f64)>) -> Self {
        t.sort_unstable_by_key(|&(r, c, _)| (c, r));
        // merge duplicates (adjacent after the sort)
        let mut merged: Vec<(usize, usize, f64)> = Vec::with_capacity(t.len());
        for (r, c, v) in t {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if let Some(last) = merged.last_mut() {
                if last.0 == r && last.1 == c {
                    last.2 += v;
                    continue;
                }
            }
            merged.push((r, c, v));
        }
        let mut colptr = vec![0usize; cols + 1];
        for &(_, c, _) in &merged {
            colptr[c + 1] += 1;
        }
        for c in 0..cols {
            colptr[c + 1] += colptr[c];
        }
        let rowidx = merged.iter().map(|&(r, _, _)| r).collect();
        let values = merged.iter().map(|&(_, _, v)| v).collect();
        Self { rows, cols, colptr, rowidx, values }
    }

    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    /// Rows (and values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.colptr[j], self.colptr[j + 1]);
        (&self.rowidx[a..b], &self.values[a..b])
    }

    /// Build the row-major mirror (one pass; call once, reuse forever).
    pub fn to_csr(&self) -> Csr {
        Csr::from_csc(self)
    }

    /// y = A x (x over columns/machines, y over rows/blocks).
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.mul_vec_into(x, &mut y);
        y
    }

    /// In-place y = A x; `y` is fully overwritten.
    #[inline]
    pub fn mul_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                let (ri, vals) = self.col(j);
                for k in 0..ri.len() {
                    y[ri[k]] += vals[k] * xj;
                }
            }
        }
    }

    /// y = A^T x.
    pub fn t_mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_mul_vec_into(x, &mut y);
        y
    }

    /// In-place y = A^T x; `y` is fully overwritten.
    #[inline]
    pub fn t_mul_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for j in 0..self.cols {
            let (ri, vals) = self.col(j);
            let mut s = 0.0;
            for k in 0..ri.len() {
                s += vals[k] * x[ri[k]];
            }
            y[j] = s;
        }
    }

    /// Number of non-zero entries divided by rows — the paper's
    /// replication factor d (Definition I.1, at block granularity).
    pub fn replication_factor(&self) -> f64 {
        self.nnz() as f64 / self.rows as f64
    }

    /// Max non-zeros in any column — computational load in blocks.
    pub fn max_col_nnz(&self) -> usize {
        (0..self.cols)
            .map(|j| self.colptr[j + 1] - self.colptr[j])
            .max()
            .unwrap_or(0)
    }

    /// Dense copy (tests / small-n oracles only).
    pub fn to_dense(&self) -> crate::linalg::Mat {
        let mut m = crate::linalg::Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (ri, vals) = self.col(j);
            for (k, &r) in ri.iter().enumerate() {
                m[(r, j)] += vals[k];
            }
        }
        m
    }
}

/// Compressed sparse row mirror of a [`Csc`] (row = data block).
///
/// Column indices within each row are ascending (inherited from the
/// column-major build order). Forward products read `colidx`/`values`
/// in one contiguous sweep and write each `y[i]` exactly once, so they
/// vectorize and never false-share. The batched decoding hot path is
/// [`MaskedColumnsOp::apply`], which iterates [`Csr::row`] directly
/// with the straggler mask applied; the `mul_vec*` methods here are
/// the standalone (unmasked) equivalents.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// row pointer, len rows+1
    pub rowptr: Vec<usize>,
    /// column indices, len nnz
    pub colidx: Vec<usize>,
    /// values, len nnz
    pub values: Vec<f64>,
}

impl Csr {
    /// Transpose-copy from the column-major primary.
    pub fn from_csc(a: &Csc) -> Self {
        let nnz = a.nnz();
        let mut rowptr = vec![0usize; a.rows + 1];
        for &r in &a.rowidx {
            rowptr[r + 1] += 1;
        }
        for i in 0..a.rows {
            rowptr[i + 1] += rowptr[i];
        }
        let mut next = rowptr.clone();
        let mut colidx = vec![0usize; nnz];
        let mut values = vec![0.0; nnz];
        for j in 0..a.cols {
            let (ri, vals) = a.col(j);
            for k in 0..ri.len() {
                let slot = next[ri[k]];
                next[ri[k]] += 1;
                colidx[slot] = j;
                values[slot] = vals[k];
            }
        }
        Self { rows: a.rows, cols: a.cols, rowptr, colidx, values }
    }

    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Columns (and values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.rowptr[i], self.rowptr[i + 1]);
        (&self.colidx[a..b], &self.values[a..b])
    }

    /// y = A x, row-contiguous.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.mul_vec_into(x, &mut y);
        y
    }

    /// In-place y = A x; one contiguous pass, one write per row.
    #[inline]
    pub fn mul_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let (cj, vals) = self.row(i);
            let mut s = 0.0;
            for k in 0..cj.len() {
                s += vals[k] * x[cj[k]];
            }
            y[i] = s;
        }
    }

    /// In-place y = A^T x: still a single contiguous sweep of the value
    /// array (scattered writes into y).
    #[inline]
    pub fn t_mul_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let (cj, vals) = self.row(i);
                for k in 0..cj.len() {
                    y[cj[k]] += vals[k] * xi;
                }
            }
        }
    }
}

/// The column-restricted operator A_S over an explicit survivor index
/// list. The generic optimal decoder now uses [`MaskedColumnsOp`]
/// (dense machine indexing, no per-trial index build); this operator is
/// kept as the independent reference implementation the masked-op
/// equivalence tests compare against.
pub struct ColumnSubsetOp<'a> {
    pub a: &'a Csc,
    /// surviving column indices
    pub cols: &'a [usize],
}

impl LinearOp for ColumnSubsetOp<'_> {
    fn rows(&self) -> usize {
        self.a.rows
    }
    fn cols(&self) -> usize {
        self.cols.len()
    }
    #[inline]
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for (jj, &j) in self.cols.iter().enumerate() {
            let xj = x[jj];
            if xj != 0.0 {
                let (ri, vals) = self.a.col(j);
                for k in 0..ri.len() {
                    y[ri[k]] += vals[k] * xj;
                }
            }
        }
    }
    #[inline]
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        for (jj, &j) in self.cols.iter().enumerate() {
            let (ri, vals) = self.a.col(j);
            let mut s = 0.0;
            for k in 0..ri.len() {
                s += vals[k] * x[ri[k]];
            }
            y[jj] = s;
        }
    }
}

/// Column-masked operator over the *full* machine axis: `x`/`w` are
/// dense length-m vectors and straggler columns contribute nothing
/// (their components stay exactly 0.0 through LSQR because `apply_t`
/// writes 0 there). Compared to [`ColumnSubsetOp`] this needs no
/// per-trial survivor index build and keeps machine indexing stable
/// across trials, which is what makes LSQR warm-starting from the
/// previous trial's `w` a plain buffer copy. Forward uses the CSR
/// mirror (row-contiguous), transpose the CSC (column-contiguous).
pub struct MaskedColumnsOp<'a> {
    pub csc: &'a Csc,
    pub csr: &'a Csr,
    /// straggler[j] == true means column j is dead
    pub straggler: &'a [bool],
}

impl LinearOp for MaskedColumnsOp<'_> {
    fn rows(&self) -> usize {
        self.csc.rows
    }
    fn cols(&self) -> usize {
        self.csc.cols
    }
    #[inline]
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        for i in 0..self.csr.rows {
            let (cj, vals) = self.csr.row(i);
            let mut s = 0.0;
            for k in 0..cj.len() {
                let j = cj[k];
                if !self.straggler[j] {
                    s += vals[k] * x[j];
                }
            }
            y[i] = s;
        }
    }
    #[inline]
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        for j in 0..self.csc.cols {
            if self.straggler[j] {
                y[j] = 0.0;
                continue;
            }
            let (ri, vals) = self.csc.col(j);
            let mut s = 0.0;
            for k in 0..ri.len() {
                s += vals[k] * x[ri[k]];
            }
            y[j] = s;
        }
    }
}

/// Right-preconditioned masked operator `A_S D`, `D = diag(scale)`.
/// LSQR solves `min_z |A_S D z - b|`; the caller recovers `w = D z`.
/// With `scale[j] = 1 / |a_j|_2` every surviving column has unit norm —
/// degree-diagonal (column-equilibration) preconditioning, which
/// tightens the singular-value spread for heterogeneous-degree codes
/// (rBGC, BRC, pairwise-balanced) where raw column norms vary and slow
/// the Golub-Kahan iteration. Straggler columns behave exactly like
/// [`MaskedColumnsOp`]'s: `apply_t` writes exactly 0.0 there, so dead
/// components never move off zero.
pub struct DiagScaledMaskedOp<'a> {
    pub inner: MaskedColumnsOp<'a>,
    /// per-column right scale, length m; 0.0 for empty columns
    pub scale: &'a [f64],
}

impl LinearOp for DiagScaledMaskedOp<'_> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }
    fn cols(&self) -> usize {
        self.inner.cols()
    }
    #[inline]
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        // y = A_S (D x): fold the scale into the CSR gather
        let csr = self.inner.csr;
        for i in 0..csr.rows {
            let (cj, vals) = csr.row(i);
            let mut s = 0.0;
            for k in 0..cj.len() {
                let j = cj[k];
                if !self.inner.straggler[j] {
                    s += vals[k] * self.scale[j] * x[j];
                }
            }
            y[i] = s;
        }
    }
    #[inline]
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        // y = D^T A_S^T x; the inner op leaves exact zeros on
        // stragglers, which the scale preserves
        self.inner.apply_t(x, y);
        for (yj, &dj) in y.iter_mut().zip(self.scale) {
            *yj *= dj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csc {
        // A = [1 0 2; 0 3 0] (2x3)
        Csc::from_triplets(2, 3, vec![(0, 0, 1.0), (1, 1, 3.0), (0, 2, 2.0)])
    }

    #[test]
    fn triplets_round_trip() {
        let a = small();
        assert_eq!(a.nnz(), 3);
        let d = a.to_dense();
        assert_eq!(d[(0, 0)], 1.0);
        assert_eq!(d[(1, 1)], 3.0);
        assert_eq!(d[(0, 2)], 2.0);
        assert_eq!(d[(1, 2)], 0.0);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let a = Csc::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, 1.0)]);
        assert_eq!(a.to_dense()[(0, 0)], 3.5);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn empty_columns_ok() {
        let a = Csc::from_triplets(3, 4, vec![(0, 0, 1.0), (2, 3, 1.0)]);
        assert_eq!(a.col(1).0.len(), 0);
        assert_eq!(a.col(2).0.len(), 0);
        assert_eq!(a.mul_vec(&[1.0, 5.0, 5.0, 1.0]), vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn matvec_against_dense() {
        let a = small();
        let x = vec![1.0, -1.0, 0.5];
        assert_eq!(a.mul_vec(&x), a.to_dense().mul_vec(&x));
        let y = vec![2.0, -3.0];
        assert_eq!(a.t_mul_vec(&y), a.to_dense().t_mul_vec(&y));
    }

    #[test]
    fn replication_and_load() {
        let a = small();
        assert!((a.replication_factor() - 1.5).abs() < 1e-12);
        assert_eq!(a.max_col_nnz(), 1);
    }

    #[test]
    fn column_subset_op_matches_dense_subset() {
        let a = small();
        let cols = vec![0usize, 2];
        let op = ColumnSubsetOp { a: &a, cols: &cols };
        let mut y = vec![0.0; 2];
        op.apply(&[2.0, 3.0], &mut y);
        assert_eq!(y, vec![2.0 + 6.0, 0.0]);
        let mut yt = vec![0.0; 2];
        op.apply_t(&[1.0, 1.0], &mut yt);
        assert_eq!(yt, vec![1.0, 2.0]);
    }

    #[test]
    fn csr_mirror_round_trip() {
        let mut rng = crate::prng::Rng::new(11);
        let mut t = Vec::new();
        for _ in 0..60 {
            t.push((rng.below(7), rng.below(9), rng.gaussian()));
        }
        let a = Csc::from_triplets(7, 9, t);
        let r = a.to_csr();
        assert_eq!(r.nnz(), a.nnz());
        let dense = a.to_dense();
        for i in 0..7 {
            let (cj, vals) = r.row(i);
            // ascending column indices within the row
            assert!(cj.windows(2).all(|w| w[0] < w[1]));
            let mut row_sum = 0.0;
            for k in 0..cj.len() {
                assert_eq!(vals[k], dense[(i, cj[k])]);
                row_sum += vals[k];
            }
            let want: f64 = (0..9).map(|j| dense[(i, j)]).sum();
            assert!((row_sum - want).abs() < 1e-12);
        }
    }

    #[test]
    fn csr_products_match_csc() {
        let mut rng = crate::prng::Rng::new(12);
        let mut t = Vec::new();
        for _ in 0..40 {
            t.push((rng.below(6), rng.below(8), rng.gaussian()));
        }
        let a = Csc::from_triplets(6, 8, t);
        let r = a.to_csr();
        let x: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let yr: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let mut y1 = vec![0.0; 6];
        r.mul_vec_into(&x, &mut y1);
        let y2 = a.mul_vec(&x);
        for i in 0..6 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
        let mut t1 = vec![0.0; 8];
        r.t_mul_vec_into(&yr, &mut t1);
        let t2 = a.t_mul_vec(&yr);
        for j in 0..8 {
            assert!((t1[j] - t2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn into_variants_overwrite_stale_buffers() {
        let a = small();
        let mut y = vec![99.0, 99.0];
        a.mul_vec_into(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 3.0]);
        let mut yt = vec![-5.0, -5.0, -5.0];
        a.t_mul_vec_into(&[1.0, 1.0], &mut yt);
        assert_eq!(yt, vec![1.0, 3.0, 2.0]);
    }

    #[test]
    fn diag_scaled_op_matches_explicit_scaling() {
        let mut rng = crate::prng::Rng::new(17);
        let mut t = Vec::new();
        for _ in 0..45 {
            t.push((rng.below(7), rng.below(9), rng.gaussian()));
        }
        let a = Csc::from_triplets(7, 9, t);
        let csr = a.to_csr();
        let straggler = rng.bernoulli_mask(9, 0.3);
        // unit-column scale (0 for empty columns)
        let scale: Vec<f64> = (0..9)
            .map(|j| {
                let n2: f64 = a.col(j).1.iter().map(|v| v * v).sum();
                if n2 > 0.0 { 1.0 / n2.sqrt() } else { 0.0 }
            })
            .collect();
        let inner = MaskedColumnsOp { csc: &a, csr: &csr, straggler: &straggler };
        let op = DiagScaledMaskedOp {
            inner: MaskedColumnsOp { csc: &a, csr: &csr, straggler: &straggler },
            scale: &scale,
        };
        // forward: A_S (D x) == inner.apply(D x)
        let x: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let dx: Vec<f64> = x.iter().zip(&scale).map(|(xi, di)| xi * di).collect();
        let mut y1 = vec![0.0; 7];
        op.apply(&x, &mut y1);
        let mut y2 = vec![0.0; 7];
        inner.apply(&dx, &mut y2);
        for i in 0..7 {
            assert!((y1[i] - y2[i]).abs() < 1e-12, "row {i}: {} vs {}", y1[i], y2[i]);
        }
        // transpose: D (A_S^T r) == scale .* inner.apply_t(r), exact
        // zeros on stragglers
        let r: Vec<f64> = (0..7).map(|_| rng.gaussian()).collect();
        let mut t1 = vec![9.0; 9]; // stale buffer must be overwritten
        op.apply_t(&r, &mut t1);
        let mut t2 = vec![0.0; 9];
        inner.apply_t(&r, &mut t2);
        for j in 0..9 {
            assert!((t1[j] - scale[j] * t2[j]).abs() < 1e-12, "col {j}");
            if straggler[j] {
                assert_eq!(t1[j], 0.0, "dead column {j} must read exactly 0");
            }
        }
    }

    #[test]
    fn masked_op_matches_column_subset_op() {
        let mut rng = crate::prng::Rng::new(13);
        let mut t = Vec::new();
        for _ in 0..50 {
            t.push((rng.below(8), rng.below(10), rng.gaussian()));
        }
        let a = Csc::from_triplets(8, 10, t);
        let csr = a.to_csr();
        let straggler = rng.bernoulli_mask(10, 0.4);
        let cols: Vec<usize> = (0..10).filter(|&j| !straggler[j]).collect();
        let masked = MaskedColumnsOp { csc: &a, csr: &csr, straggler: &straggler };
        let subset = ColumnSubsetOp { a: &a, cols: &cols };

        // dense x with zeros on stragglers vs compact x over survivors
        let x_dense: Vec<f64> =
            (0..10).map(|j| if straggler[j] { 0.0 } else { rng.gaussian() }).collect();
        let x_compact: Vec<f64> = cols.iter().map(|&j| x_dense[j]).collect();
        let mut ym = vec![0.0; 8];
        masked.apply(&x_dense, &mut ym);
        let mut ys = vec![0.0; 8];
        subset.apply(&x_compact, &mut ys);
        for i in 0..8 {
            assert!((ym[i] - ys[i]).abs() < 1e-12);
        }

        let r: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let mut tm = vec![1.0; 10]; // stale values must be overwritten
        masked.apply_t(&r, &mut tm);
        let mut ts = vec![0.0; cols.len()];
        subset.apply_t(&r, &mut ts);
        for (jj, &j) in cols.iter().enumerate() {
            assert!((tm[j] - ts[jj]).abs() < 1e-12);
        }
        for j in 0..10 {
            if straggler[j] {
                assert_eq!(tm[j], 0.0, "dead column {j} must read exactly 0");
            }
        }
    }
}
