//! LSQR (Paige & Saunders 1982): iterative least squares on implicit
//! linear operators.
//!
//! This is the *generic* optimal decoder's engine: for non-graph codes
//! (Raviv expander code, BIBD, rBGC, BRC) the optimal coefficients
//! `w* = argmin |A_S w - 1|_2` (paper Eq. 3) have no component-wise
//! closed form, so we solve the sparse least-squares problem directly.
//! LSQR converges to the minimum-norm solution, which matches the
//! Moore-Penrose-pseudoinverse characterization of Eq. (9).
//!
//! Two entry points:
//! * [`lsqr`] — the original allocate-per-call API (cold start).
//! * [`lsqr_into`] — allocation-free and warm-startable: the caller
//!   passes `x` holding an initial guess x0 (zeros = cold start) and a
//!   reusable [`LsqrScratch`]. Internally the solve runs on the shifted
//!   problem `min |A dx - (b - A x0)|` and accumulates `x = x0 + dx`,
//!   so a good x0 (e.g. the previous Monte-Carlo trial's `w`) cuts the
//!   Golub-Kahan iteration count without changing the minimizer of the
//!   residual (in the underdetermined case the *minimum-norm* tie-break
//!   is relative to x0; decoding only consumes alpha = A w, which is
//!   unique, so this is correctness-preserving).
//! * [`lsqr_into_backend`] — the same solve with the dense vector norms
//!   dispatched through a [`LinalgBackend`] tier. With
//!   `LinalgBackend::Exact` it is bit-identical to [`lsqr_into`] (the
//!   exact `dot` folds in the same sequential order as the local norm
//!   here always has); `Fast` runs the 8-wide fixed-order kernels, so
//!   iterates differ from exact at roundoff but stay deterministic
//!   across machines and splits. The sparse operator applications
//!   (`MaskedColumnsOp` gathers) are shared by both tiers — they are
//!   sparsity-bound, and the dense norms are where the flops are.

use crate::linalg::LinalgBackend;

/// An m x n linear operator with forward and transpose application.
pub trait LinearOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// y = A x  (x: cols, y: rows)
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// y = A^T x (x: rows, y: cols)
    fn apply_t(&self, x: &[f64], y: &mut [f64]);
}

#[derive(Clone, Debug)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    /// final |A x - b|
    pub residual_norm: f64,
    /// final |A^T (A x - b)| — optimality measure
    pub normal_residual_norm: f64,
    pub converged: bool,
}

/// [`lsqr_into`]'s summary (the solution lives in the caller's `x`).
#[derive(Clone, Copy, Debug)]
pub struct LsqrSummary {
    pub iterations: usize,
    /// final |A x - b|
    pub residual_norm: f64,
    /// final |A^T (A x - b)| — optimality measure
    pub normal_residual_norm: f64,
    pub converged: bool,
}

/// Reusable work vectors for [`lsqr_into`]; grown on demand, never
/// shrunk, so a long trial loop allocates exactly once.
#[derive(Clone, Debug, Default)]
pub struct LsqrScratch {
    u: Vec<f64>,
    v: Vec<f64>,
    w: Vec<f64>,
    tmp_m: Vec<f64>,
    tmp_n: Vec<f64>,
}

impl LsqrScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn resize(&mut self, m: usize, n: usize) {
        self.u.clear();
        self.u.resize(m, 0.0);
        self.v.clear();
        self.v.resize(n, 0.0);
        self.w.clear();
        self.w.resize(n, 0.0);
        self.tmp_m.clear();
        self.tmp_m.resize(m, 0.0);
        self.tmp_n.clear();
        self.tmp_n.resize(n, 0.0);
    }
}

/// Euclidean norm on the chosen tier. `Exact` reduces in the same
/// sequential order the pre-backend local `norm` here always used (it
/// delegates to `linalg::dot(v, v)`, the identical fold), so the
/// exact-tier solve is bit-for-bit the historical one.
#[inline]
fn norm_on(backend: LinalgBackend, v: &[f64]) -> f64 {
    backend.dot(v, v).sqrt()
}

fn scale_in(alpha: f64, v: &mut [f64]) {
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

/// Solve min_x |A x - b|_2 with LSQR from a cold start.
///
/// `atol` bounds the relative normal-equation residual
/// |A^T r| / (|A| |r|); `max_iter` caps the Golub-Kahan steps.
pub fn lsqr<M: LinearOp>(a: &M, b: &[f64], atol: f64, max_iter: usize) -> LsqrResult {
    let mut x = vec![0.0; a.cols()];
    let mut scratch = LsqrScratch::new();
    let s = lsqr_into(a, b, atol, max_iter, &mut x, &mut scratch);
    LsqrResult {
        x,
        iterations: s.iterations,
        residual_norm: s.residual_norm,
        normal_residual_norm: s.normal_residual_norm,
        converged: s.converged,
    }
}

/// Allocation-free, warm-startable LSQR. On entry `x` holds the initial
/// guess x0 (all-zero = cold start, bit-identical to [`lsqr`]); on exit
/// it holds the solution.
pub fn lsqr_into<M: LinearOp>(
    a: &M,
    b: &[f64],
    atol: f64,
    max_iter: usize,
    x: &mut [f64],
    scratch: &mut LsqrScratch,
) -> LsqrSummary {
    lsqr_into_backend(a, b, atol, max_iter, x, scratch, LinalgBackend::Exact)
}

/// [`lsqr_into`] with the dense norms dispatched through `backend`.
/// `Exact` is bit-identical to [`lsqr_into`]; `Fast` changes iterate
/// bits (within the tier's documented tolerance) but stays
/// deterministic for a given input on every machine and split.
pub fn lsqr_into_backend<M: LinearOp>(
    a: &M,
    b: &[f64],
    atol: f64,
    max_iter: usize,
    x: &mut [f64],
    scratch: &mut LsqrScratch,
    backend: LinalgBackend,
) -> LsqrSummary {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(b.len(), m);
    assert_eq!(x.len(), n);
    scratch.resize(m, n);
    let LsqrScratch { u, v, w, tmp_m, tmp_n } = scratch;

    // u = b - A x0; for the cold start this is exactly u = b.
    let cold = x.iter().all(|&xi| xi == 0.0);
    if cold {
        u.copy_from_slice(b);
    } else {
        a.apply(x, u);
        for i in 0..m {
            u[i] = b[i] - u[i];
        }
    }
    let mut beta = norm_on(backend, u);
    let rhs_norm = beta;
    if beta == 0.0 {
        // x0 already solves the system exactly
        return LsqrSummary {
            iterations: 0,
            residual_norm: 0.0,
            normal_residual_norm: 0.0,
            converged: true,
        };
    }
    scale_in(1.0 / beta, u);

    // v = A^T u; alpha = |v|
    a.apply_t(u, v);
    let mut alpha = norm_on(backend, v);
    if alpha == 0.0 {
        // residual orthogonal to range(A): x0 is optimal
        return LsqrSummary {
            iterations: 0,
            residual_norm: beta,
            normal_residual_norm: 0.0,
            converged: true,
        };
    }
    scale_in(1.0 / alpha, v);

    w.copy_from_slice(v);
    let mut phibar = beta;
    let mut rhobar = alpha;
    let mut anorm2 = 0.0f64; // running |A|_F^2 estimate

    let mut iters = 0;
    let mut converged = false;

    for it in 1..=max_iter {
        iters = it;
        anorm2 += alpha * alpha + beta * beta;

        // bidiagonalization: u = A v - alpha u
        a.apply(v, tmp_m);
        for i in 0..m {
            u[i] = tmp_m[i] - alpha * u[i];
        }
        beta = norm_on(backend, u);
        if beta > 0.0 {
            scale_in(1.0 / beta, u);
        }

        // v = A^T u - beta v
        a.apply_t(u, tmp_n);
        for i in 0..n {
            v[i] = tmp_n[i] - beta * v[i];
        }
        alpha = norm_on(backend, v);
        if alpha > 0.0 {
            scale_in(1.0 / alpha, v);
        }

        // Givens rotation
        let rho = (rhobar * rhobar + beta * beta).sqrt();
        let c = rhobar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rhobar = -c * alpha;
        let phi = c * phibar;
        phibar *= s;

        // update x, w
        let t1 = phi / rho;
        let t2 = -theta / rho;
        for i in 0..n {
            x[i] += t1 * w[i];
            w[i] = v[i] + t2 * w[i];
        }

        // convergence: |A^T r| = phibar * alpha * |c| ; |r| = phibar
        let norm_ar = phibar * alpha * c.abs();
        let anorm = anorm2.sqrt();
        if norm_ar <= atol * anorm * phibar.max(1e-300) || phibar <= atol * rhs_norm {
            converged = true;
            break;
        }
    }

    // exact final residuals (against the original b, with the full x)
    a.apply(x, tmp_m);
    for i in 0..m {
        tmp_m[i] -= b[i];
    }
    let rnorm = norm_on(backend, tmp_m);
    a.apply_t(tmp_m, tmp_n);
    let nrnorm = norm_on(backend, tmp_n);
    LsqrSummary {
        iterations: iters,
        residual_norm: rnorm,
        normal_residual_norm: nrnorm,
        converged,
    }
}

impl LinearOp for crate::linalg::Mat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(&self.mul_vec(x));
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(&self.t_mul_vec(x));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn solves_square_system() {
        let a = Mat::from_rows(vec![vec![3.0, 1.0], vec![1.0, 2.0]]);
        let b = vec![9.0, 8.0];
        let r = lsqr(&a, &b, 1e-12, 100);
        assert!(r.converged);
        assert!((r.x[0] - 2.0).abs() < 1e-8 && (r.x[1] - 3.0).abs() < 1e-8, "{:?}", r.x);
    }

    #[test]
    fn overdetermined_matches_normal_equations() {
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ]);
        let b = vec![1.0, 2.9, 5.1, 7.0];
        let r = lsqr(&a, &b, 1e-12, 200);
        let exact = crate::linalg::chol::lstsq_normal(&a, &b, 0.0).unwrap();
        assert!((r.x[0] - exact[0]).abs() < 1e-7);
        assert!((r.x[1] - exact[1]).abs() < 1e-7);
    }

    #[test]
    fn underdetermined_gives_min_norm_solution() {
        // x + y = 2 has min-norm solution (1, 1)
        let a = Mat::from_rows(vec![vec![1.0, 1.0]]);
        let r = lsqr(&a, &[2.0], 1e-14, 100);
        assert!((r.x[0] - 1.0).abs() < 1e-9 && (r.x[1] - 1.0).abs() < 1e-9, "{:?}", r.x);
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let r = lsqr(&a, &[0.0, 0.0], 1e-12, 10);
        assert_eq!(r.x, vec![0.0, 0.0]);
        assert!(r.converged);
    }

    #[test]
    fn inconsistent_system_minimizes_residual() {
        // A = [[1],[1]], b = [0, 2] -> x = 1, residual sqrt(2)
        let a = Mat::from_rows(vec![vec![1.0], vec![1.0]]);
        let r = lsqr(&a, &[0.0, 2.0], 1e-12, 100);
        assert!((r.x[0] - 1.0).abs() < 1e-9);
        assert!((r.residual_norm - std::f64::consts::SQRT_2).abs() < 1e-9);
        // optimality: A^T r = 0
        assert!(r.normal_residual_norm < 1e-9);
    }

    #[test]
    fn warm_start_from_exact_solution_is_immediate() {
        let a = Mat::from_rows(vec![vec![3.0, 1.0], vec![1.0, 2.0]]);
        let b = vec![9.0, 8.0];
        let mut x = vec![2.0, 3.0]; // the exact solution
        let mut scratch = LsqrScratch::new();
        let s = lsqr_into(&a, &b, 1e-12, 100, &mut x, &mut scratch);
        assert!(s.converged);
        assert_eq!(s.iterations, 0);
        assert!((x[0] - 2.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn warm_start_matches_cold_solution() {
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ]);
        let b = vec![1.0, 2.9, 5.1, 7.0];
        let cold = lsqr(&a, &b, 1e-12, 200);
        let mut x = vec![0.9, 1.8]; // near-but-not-exact guess
        let mut scratch = LsqrScratch::new();
        let s = lsqr_into(&a, &b, 1e-12, 200, &mut x, &mut scratch);
        assert!(s.converged);
        assert!((x[0] - cold.x[0]).abs() < 1e-7 && (x[1] - cold.x[1]).abs() < 1e-7);
    }

    #[test]
    fn cold_lsqr_into_is_bit_identical_to_lsqr() {
        let a = Mat::from_rows(vec![
            vec![2.0, -1.0, 0.5],
            vec![0.0, 1.5, 1.0],
            vec![1.0, 0.0, -2.0],
            vec![0.5, 0.5, 0.5],
        ]);
        let b = vec![1.0, -2.0, 0.25, 3.0];
        let r = lsqr(&a, &b, 1e-12, 300);
        let mut x = vec![0.0; 3];
        let mut scratch = LsqrScratch::new();
        let s = lsqr_into(&a, &b, 1e-12, 300, &mut x, &mut scratch);
        assert_eq!(s.iterations, r.iterations);
        for i in 0..3 {
            assert_eq!(x[i].to_bits(), r.x[i].to_bits(), "component {i}");
        }
    }

    #[test]
    fn fast_backend_agrees_with_exact_within_tolerance() {
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ]);
        let b = vec![1.0, 2.9, 5.1, 7.0];
        let mut xe = vec![0.0; 2];
        let mut xf = vec![0.0; 2];
        let mut scratch = LsqrScratch::new();
        let se = lsqr_into_backend(&a, &b, 1e-12, 200, &mut xe, &mut scratch, LinalgBackend::Exact);
        let sf = lsqr_into_backend(&a, &b, 1e-12, 200, &mut xf, &mut scratch, LinalgBackend::Fast);
        assert!(se.converged && sf.converged);
        for i in 0..2 {
            assert!((xe[i] - xf[i]).abs() < 1e-7, "component {i}: {} vs {}", xe[i], xf[i]);
        }
    }

    #[test]
    fn scratch_reuse_across_shapes() {
        let mut scratch = LsqrScratch::new();
        let a1 = Mat::from_rows(vec![vec![1.0, 1.0]]);
        let mut x1 = vec![0.0; 2];
        lsqr_into(&a1, &[2.0], 1e-14, 50, &mut x1, &mut scratch);
        assert!((x1[0] - 1.0).abs() < 1e-9);
        let a2 = Mat::from_rows(vec![vec![3.0, 1.0], vec![1.0, 2.0]]);
        let mut x2 = vec![0.0; 2];
        let s = lsqr_into(&a2, &[9.0, 8.0], 1e-12, 100, &mut x2, &mut scratch);
        assert!(s.converged);
        assert!((x2[0] - 2.0).abs() < 1e-8 && (x2[1] - 3.0).abs() < 1e-8);
    }
}
