//! LSQR (Paige & Saunders 1982): iterative least squares on implicit
//! linear operators.
//!
//! This is the *generic* optimal decoder's engine: for non-graph codes
//! (Raviv expander code, BIBD, rBGC, BRC) the optimal coefficients
//! `w* = argmin |A_S w - 1|_2` (paper Eq. 3) have no component-wise
//! closed form, so we solve the sparse least-squares problem directly.
//! LSQR converges to the minimum-norm solution, which matches the
//! Moore-Penrose-pseudoinverse characterization of Eq. (9).

/// An m x n linear operator with forward and transpose application.
pub trait LinearOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// y = A x  (x: cols, y: rows)
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// y = A^T x (x: rows, y: cols)
    fn apply_t(&self, x: &[f64], y: &mut [f64]);
}

#[derive(Clone, Debug)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    /// final |A x - b|
    pub residual_norm: f64,
    /// final |A^T (A x - b)| — optimality measure
    pub normal_residual_norm: f64,
    pub converged: bool,
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn scale_in(alpha: f64, v: &mut [f64]) {
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

/// Solve min_x |A x - b|_2 with LSQR.
///
/// `atol` bounds the relative normal-equation residual
/// |A^T r| / (|A| |r|); `max_iter` caps the Golub-Kahan steps.
pub fn lsqr<M: LinearOp>(a: &M, b: &[f64], atol: f64, max_iter: usize) -> LsqrResult {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(b.len(), m);
    let mut x = vec![0.0; n];

    // u = b; beta = |u|
    let mut u = b.to_vec();
    let mut beta = norm(&u);
    if beta == 0.0 {
        return LsqrResult { x, iterations: 0, residual_norm: 0.0,
                            normal_residual_norm: 0.0, converged: true };
    }
    scale_in(1.0 / beta, &mut u);

    // v = A^T u; alpha = |v|
    let mut v = vec![0.0; n];
    a.apply_t(&u, &mut v);
    let mut alpha = norm(&v);
    if alpha == 0.0 {
        // b orthogonal to range(A): x = 0 is optimal
        return LsqrResult { x, iterations: 0, residual_norm: beta,
                            normal_residual_norm: 0.0, converged: true };
    }
    scale_in(1.0 / alpha, &mut v);

    let mut w = v.clone();
    let mut phibar = beta;
    let mut rhobar = alpha;
    let mut anorm2 = 0.0f64; // running |A|_F^2 estimate

    let mut tmp_m = vec![0.0; m];
    let mut tmp_n = vec![0.0; n];
    let mut iters = 0;
    let mut converged = false;

    for it in 1..=max_iter {
        iters = it;
        anorm2 += alpha * alpha + beta * beta;

        // bidiagonalization: u = A v - alpha u
        a.apply(&v, &mut tmp_m);
        for i in 0..m {
            u[i] = tmp_m[i] - alpha * u[i];
        }
        beta = norm(&u);
        if beta > 0.0 {
            scale_in(1.0 / beta, &mut u);
        }

        // v = A^T u - beta v
        a.apply_t(&u, &mut tmp_n);
        for i in 0..n {
            v[i] = tmp_n[i] - beta * v[i];
        }
        alpha = norm(&v);
        if alpha > 0.0 {
            scale_in(1.0 / alpha, &mut v);
        }

        // Givens rotation
        let rho = (rhobar * rhobar + beta * beta).sqrt();
        let c = rhobar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rhobar = -c * alpha;
        let phi = c * phibar;
        phibar *= s;

        // update x, w
        let t1 = phi / rho;
        let t2 = -theta / rho;
        for i in 0..n {
            x[i] += t1 * w[i];
            w[i] = v[i] + t2 * w[i];
        }

        // convergence: |A^T r| = phibar * alpha * |c| ; |r| = phibar
        let norm_ar = phibar * alpha * c.abs();
        let anorm = anorm2.sqrt();
        if norm_ar <= atol * anorm * phibar.max(1e-300) || phibar <= atol * norm(b) {
            converged = true;
            break;
        }
    }

    // exact final residuals
    a.apply(&x, &mut tmp_m);
    let r: Vec<f64> = (0..m).map(|i| tmp_m[i] - b[i]).collect();
    let rnorm = norm(&r);
    a.apply_t(&r, &mut tmp_n);
    let nrnorm = norm(&tmp_n);
    LsqrResult { x, iterations: iters, residual_norm: rnorm,
                 normal_residual_norm: nrnorm, converged }
}

impl LinearOp for crate::linalg::Mat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(&self.mul_vec(x));
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(&self.t_mul_vec(x));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn solves_square_system() {
        let a = Mat::from_rows(vec![vec![3.0, 1.0], vec![1.0, 2.0]]);
        let b = vec![9.0, 8.0];
        let r = lsqr(&a, &b, 1e-12, 100);
        assert!(r.converged);
        assert!((r.x[0] - 2.0).abs() < 1e-8 && (r.x[1] - 3.0).abs() < 1e-8, "{:?}", r.x);
    }

    #[test]
    fn overdetermined_matches_normal_equations() {
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ]);
        let b = vec![1.0, 2.9, 5.1, 7.0];
        let r = lsqr(&a, &b, 1e-12, 200);
        let exact = crate::linalg::chol::lstsq_normal(&a, &b, 0.0).unwrap();
        assert!((r.x[0] - exact[0]).abs() < 1e-7);
        assert!((r.x[1] - exact[1]).abs() < 1e-7);
    }

    #[test]
    fn underdetermined_gives_min_norm_solution() {
        // x + y = 2 has min-norm solution (1, 1)
        let a = Mat::from_rows(vec![vec![1.0, 1.0]]);
        let r = lsqr(&a, &[2.0], 1e-14, 100);
        assert!((r.x[0] - 1.0).abs() < 1e-9 && (r.x[1] - 1.0).abs() < 1e-9, "{:?}", r.x);
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let r = lsqr(&a, &[0.0, 0.0], 1e-12, 10);
        assert_eq!(r.x, vec![0.0, 0.0]);
        assert!(r.converged);
    }

    #[test]
    fn inconsistent_system_minimizes_residual() {
        // A = [[1],[1]], b = [0, 2] -> x = 1, residual sqrt(2)
        let a = Mat::from_rows(vec![vec![1.0], vec![1.0]]);
        let r = lsqr(&a, &[0.0, 2.0], 1e-12, 100);
        assert!((r.x[0] - 1.0).abs() < 1e-9);
        assert!((r.residual_norm - std::f64::consts::SQRT_2).abs() < 1e-9);
        // optimality: A^T r = 0
        assert!(r.normal_residual_norm < 1e-9);
    }
}
