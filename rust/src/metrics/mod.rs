//! Metrics substrate: timers, running statistics, histograms, CSV sinks
//! and paper-style table printing shared by the coordinator and benches —
//! plus the process-global counters/gauges registry behind the
//! `gcod serve` `/metrics` endpoint (see [`registry`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Sequentially fold a slice of samples. This is the *canonical*
    /// reduction the sharded sweep path reproduces bit-for-bit: a merge
    /// of per-shard trial vectors refolds the concatenation through
    /// this, so the result is independent of how the trials were split
    /// across shards or threads.
    pub fn from_values(values: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in values {
            s.push(x);
        }
        s
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// The raw Welford second moment sum(x - mean)^2. Exposed so shard
    /// manifests can serialize and cross-check the accumulator state
    /// bit-for-bit (var() collapses n<2 to 0 and divides).
    pub fn m2(&self) -> f64 {
        self.m2
    }

    /// Rebuild an accumulator from its serialized state. Used by
    /// stats-only shard manifests (`sweep::shard`), whose per-trial
    /// vector is omitted so the recorded accumulator cannot be refolded
    /// from values and must be reconstructed verbatim.
    pub fn from_raw(count: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        Self { n: count, mean, m2, min, max }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (Chan et al. pairwise
    /// Welford update). For streaming sweep reductions that don't want
    /// to materialize per-trial results: merging per-chunk partials in
    /// fixed chunk order yields results independent of how chunks were
    /// scheduled across threads. (`sweep::TrialEngine::run_map` itself
    /// returns trial-ordered results and folds sequentially.)
    ///
    /// Exactness: `count`, `min` and `max` are *bitwise* associative
    /// under merge (integer add / IEEE min-max), so any merge tree of
    /// the same partials agrees exactly. `mean`/`m2` are associative
    /// only up to floating-point rounding — the Chan update is not the
    /// same sequence of operations as per-sample [`Stats::push`] — which
    /// is why the sharded sweep path ships per-trial vectors and refolds
    /// them through [`Stats::from_values`] for its bit-exact contract,
    /// using this merge as a redundancy cross-check. Merging an empty
    /// accumulator (either side) is a bitwise no-op/copy.
    pub fn merge(&mut self, other: &Stats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let d = other.mean - self.mean;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exponentially weighted moving average — recency-weighted companion
/// to [`Stats`] for signals where the *current* level matters more than
/// the all-time aggregate (e.g. a worker's lease latency after it
/// recovers from a slow patch). The first observation seeds the value;
/// later ones fold in with weight `alpha`.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of each new observation (1 = track the
    /// latest sample exactly, small = long memory).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "ewma alpha must be in (0, 1]");
        Self { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    /// Current level; `None` before the first observation.
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Fixed-bucket latency histogram (log-spaced), for dispatch timings.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * 2^i, base * 2^(i+1)) seconds
    base: f64,
    counts: Vec<u64>,
    stats: Stats,
}

impl LatencyHistogram {
    pub fn new(base_secs: f64, buckets: usize) -> Self {
        Self { base: base_secs, counts: vec![0; buckets], stats: Stats::new() }
    }

    pub fn record(&mut self, secs: f64) {
        self.stats.push(secs);
        let idx = if secs <= self.base {
            0
        } else {
            ((secs / self.base).log2().floor() as usize).min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
    }

    pub fn stats(&self) -> &Stats {
        &self.stats
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * 2f64.powi(i as i32 + 1);
            }
        }
        self.base * 2f64.powi(self.counts.len() as i32)
    }
}

/// Simple CSV writer for experiment outputs.
pub struct CsvWriter {
    out: Box<dyn std::io::Write>,
    cols: usize,
}

impl CsvWriter {
    pub fn to_file(path: &std::path::Path, header: &[&str]) -> std::io::Result<Self> {
        let f = std::fs::File::create(path)?;
        let mut w = Self { out: Box::new(std::io::BufWriter::new(f)), cols: header.len() };
        w.write_row_str(header)?;
        Ok(w)
    }

    pub fn write_row_str(&mut self, row: &[&str]) -> std::io::Result<()> {
        assert_eq!(row.len(), self.cols, "csv row width mismatch");
        writeln!(self.out, "{}", row.join(","))
    }

    pub fn write_row(&mut self, row: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = row.iter().map(|x| format!("{x:.6e}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_str(&refs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------
// Counters / gauges registry (Prometheus text exposition)
// ---------------------------------------------------------------------

/// Monotonic counter handle. Cloning shares the underlying atomic, so a
/// hot path can look the counter up once (paying the one-time map
/// insert) and bump a plain `Arc<AtomicU64>` thereafter — no allocation,
/// no lock.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Float-valued gauge handle (f64 bits in an `AtomicU64`). `add` is a
/// CAS loop so concurrent phase timers accumulate without a lock.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, dv: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + dv).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Process-global registry of named counters and gauges.
///
/// Names follow Prometheus conventions and may carry inline labels
/// (`worker_trials_total{worker="3"}`); the exposition groups label
/// variants under one `# TYPE` line per family. Counters render as
/// integers with type `counter`, gauges as floats with type `gauge`.
/// Metrics never feed back into sweep values or manifests — the
/// registry is observability-only, so the bit-exactness contract is
/// unaffected by anything recorded here.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl MetricsRegistry {
    /// Counter handle for `name`, creating it at zero on first touch.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap();
        Counter(Arc::clone(m.entry(name.to_string()).or_default()))
    }

    /// Gauge handle for `name`, creating it at 0.0 on first touch
    /// (0u64 and 0.0f64 share a bit pattern).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap();
        Gauge(Arc::clone(m.entry(name.to_string()).or_default()))
    }

    /// Zero every registered metric (tests; the handles stay valid).
    pub fn reset(&self) {
        for v in self.counters.lock().unwrap().values() {
            v.store(0, Ordering::Relaxed);
        }
        for v in self.gauges.lock().unwrap().values() {
            v.store(0, Ordering::Relaxed);
        }
    }

    /// Prometheus text exposition (version 0.0.4 format).
    pub fn render_prometheus(&self) -> String {
        fn family(name: &str) -> &str {
            name.split('{').next().unwrap_or(name)
        }
        let mut out = String::new();
        let mut last_family = String::new();
        let counters = self.counters.lock().unwrap();
        for (name, v) in counters.iter() {
            let fam = family(name);
            if fam != last_family {
                out.push_str(&format!("# TYPE {fam} counter\n"));
                last_family = fam.to_string();
            }
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        drop(counters);
        last_family.clear();
        let gauges = self.gauges.lock().unwrap();
        for (name, v) in gauges.iter() {
            let fam = family(name);
            if fam != last_family {
                out.push_str(&format!("# TYPE {fam} gauge\n"));
                last_family = fam.to_string();
            }
            out.push_str(&format!("{name} {}\n", f64::from_bits(v.load(Ordering::Relaxed))));
        }
        out
    }
}

/// The process-global registry (one per process; workers have their
/// own — the coordinator's `/metrics` reflects coordinator-side state).
pub fn registry() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::default)
}

/// Shorthand for `registry().counter(name)`.
pub fn counter(name: &str) -> Counter {
    registry().counter(name)
}

/// Shorthand for `registry().gauge(name)`.
pub fn gauge(name: &str) -> Gauge {
    registry().gauge(name)
}

/// Format a float like the paper's tables (e.g. "3.4e-30", "2.5e-3").
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if (0.001..1000.0).contains(&x.abs()) {
        format!("{x:.4}")
    } else {
        format!("{x:.1e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_welford() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn stats_merge_matches_sequential() {
        let xs: Vec<f64> = (0..97).map(|i| (i as f64 * 0.37).sin() * 3.0).collect();
        let mut all = Stats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut merged = Stats::new();
        for chunk in xs.chunks(10) {
            let mut part = Stats::new();
            for &x in chunk {
                part.push(x);
            }
            merged.merge(&part);
        }
        assert_eq!(merged.count(), all.count());
        assert!((merged.mean() - all.mean()).abs() < 1e-12);
        assert!((merged.var() - all.var()).abs() < 1e-12);
        assert_eq!(merged.min(), all.min());
        assert_eq!(merged.max(), all.max());
        // merging an empty accumulator is a no-op
        let before = merged.mean();
        merged.merge(&Stats::new());
        assert_eq!(merged.mean(), before);
    }

    #[test]
    fn ewma_seeds_then_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        e.observe(10.0);
        assert_eq!(e.get(), Some(10.0), "first observation seeds the level");
        e.observe(0.0);
        assert_eq!(e.get(), Some(5.0));
        for _ in 0..60 {
            e.observe(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9, "converges to a held level");
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn ewma_rejects_zero_alpha() {
        let _ = Ewma::new(0.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new(1e-6, 24);
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert!(q50 <= q99);
        assert!(h.stats().count() == 1000);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["p", "err"]);
        t.row(vec!["0.05".into(), "3.4e-30".into()]);
        let r = t.render();
        assert!(r.contains("3.4e-30"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(0.0), "0");
        assert!(sci(3.4e-30).contains("e-30"));
        assert_eq!(sci(1.5), "1.5000");
    }

    #[test]
    fn registry_counters_and_gauges_round_trip() {
        let reg = MetricsRegistry::default();
        let c = reg.counter("leases_reaped_total");
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // a second lookup shares the same atomic
        reg.counter("leases_reaped_total").inc();
        assert_eq!(c.get(), 4);
        let g = reg.gauge("workers_quarantined");
        assert_eq!(g.get(), 0.0);
        g.add(1.5);
        g.add(0.5);
        assert_eq!(g.get(), 2.0);
        g.set(0.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE leases_reaped_total counter"));
        assert!(text.contains("leases_reaped_total 4"));
        assert!(text.contains("# TYPE workers_quarantined gauge"));
        assert!(text.contains("workers_quarantined 0"));
    }

    #[test]
    fn registry_groups_label_variants_under_one_type_line() {
        let reg = MetricsRegistry::default();
        reg.counter("worker_trials_total{worker=\"0\"}").add(10);
        reg.counter("worker_trials_total{worker=\"1\"}").add(20);
        let text = reg.render_prometheus();
        assert_eq!(text.matches("# TYPE worker_trials_total counter").count(), 1);
        assert!(text.contains("worker_trials_total{worker=\"0\"} 10"));
        assert!(text.contains("worker_trials_total{worker=\"1\"} 20"));
        reg.reset();
        assert!(reg.render_prometheus().contains("worker_trials_total{worker=\"0\"} 0"));
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("gcod_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.csv");
        {
            let mut w = CsvWriter::to_file(&path, &["a", "b"]).unwrap();
            w.write_row(&[1.0, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert_eq!(text.lines().count(), 2);
    }
}
