//! Per-worker health scoring, respawn backoff and quarantine.
//!
//! The dispatcher's retry machinery treats every failure as transient
//! and every worker as interchangeable; this module adds the memory
//! that turns repeated offenses into policy:
//!
//! * every worker accumulates a scorecard ([`WorkerHealth`]) —
//!   completions, failures, timeouts, audit verdicts, lease latency
//!   (Welford [`Stats`] + a recency-weighted [`Ewma`]);
//! * a failed worker is not immediately rescheduled: it backs off
//!   exponentially (`base * 2^(consecutive-1)`, capped, with
//!   deterministic seeded jitter so a pool of crashed workers doesn't
//!   thunder back in lockstep);
//! * a worker condemned by the result audit [`HealthConfig::quarantine_after`]
//!   times is **quarantined as byzantine**: never scheduled again, and
//!   the dispatcher invalidates + recomputes everything it banked.
//!   Optionally ([`HealthConfig::quarantine_after_failures`]) a
//!   crash-looping worker is quarantined as unreliable — its banked
//!   results stand (they passed structural validation; crashing loses
//!   work, it doesn't forge it);
//! * when the quarantined pool can no longer cover the sweep, the
//!   dispatcher fails loudly with [`HealthTracker::post_mortem`] — a
//!   per-worker table of what happened — instead of burning the global
//!   retry budget on workers that can only fail.

use crate::metrics::{Ewma, Stats, Table};
use crate::prng;
use std::time::{Duration, Instant};

use super::queue::WorkerId;

/// Why a worker was removed from scheduling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuarantineReason {
    /// condemned by the result audit: its manifests cannot be trusted,
    /// banked contributions are invalidated and recomputed
    Byzantine,
    /// crash/timeout loop: banked results stand, but no new leases
    Unreliable,
}

impl QuarantineReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            QuarantineReason::Byzantine => "byzantine",
            QuarantineReason::Unreliable => "unreliable",
        }
    }
}

/// Health policy knobs (part of [`super::DispatchConfig`]).
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// audit condemnations before a worker is quarantined as byzantine
    pub quarantine_after: usize,
    /// consecutive failures/timeouts before a worker is quarantined as
    /// unreliable (0 = never; the per-range retry budget governs alone)
    pub quarantine_after_failures: usize,
    /// first respawn backoff after a failure (ZERO disables backoff)
    pub backoff_base: Duration,
    /// cap on the exponential backoff
    pub backoff_max: Duration,
    /// seed for the deterministic backoff jitter
    pub jitter_seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            quarantine_after: 2,
            quarantine_after_failures: 0,
            backoff_base: Duration::ZERO,
            backoff_max: Duration::from_secs(5),
            jitter_seed: 0xBAC0_FF,
        }
    }
}

/// One worker's scorecard.
#[derive(Clone, Debug)]
pub struct WorkerHealth {
    pub completions: u64,
    pub failures: u64,
    pub timeouts: u64,
    pub audit_passes: u64,
    /// audit condemnations (this worker was the guilty side of a
    /// mismatch, per tiebreak attribution)
    pub audit_failures: u64,
    pub consecutive_failures: u32,
    pub quarantined: Option<QuarantineReason>,
    /// completed-lease wall time (seconds)
    pub lease_secs: Stats,
    /// recency-weighted lease seconds (a formerly-slow worker that
    /// recovered scores well again)
    pub lease_secs_ewma: Ewma,
    pub last_error: Option<String>,
    backoff_until: Option<Instant>,
}

impl WorkerHealth {
    fn new() -> Self {
        Self {
            completions: 0,
            failures: 0,
            timeouts: 0,
            audit_passes: 0,
            audit_failures: 0,
            consecutive_failures: 0,
            quarantined: None,
            lease_secs: Stats::new(),
            lease_secs_ewma: Ewma::new(0.3),
            last_error: None,
            backoff_until: None,
        }
    }
}

/// Scorecards plus the policy that acts on them. The dispatcher calls
/// the `record_*` methods from its event loop and consults
/// [`HealthTracker::available`] before handing out work.
#[derive(Debug)]
pub struct HealthTracker {
    cfg: HealthConfig,
    workers: Vec<WorkerHealth>,
}

impl HealthTracker {
    pub fn new(n: usize, cfg: HealthConfig) -> Self {
        Self { cfg, workers: (0..n).map(|_| WorkerHealth::new()).collect() }
    }

    pub fn worker(&self, w: WorkerId) -> &WorkerHealth {
        &self.workers[w]
    }

    /// Ready for new work: not quarantined, backoff elapsed.
    pub fn available(&self, w: WorkerId, now: Instant) -> bool {
        let h = &self.workers[w];
        h.quarantined.is_none() && h.backoff_until.is_none_or(|t| now >= t)
    }

    /// Not quarantined (may still be backing off — i.e. will become
    /// available again without intervention).
    pub fn eligible(&self, w: WorkerId) -> bool {
        self.workers[w].quarantined.is_none()
    }

    pub fn all_quarantined(&self) -> bool {
        self.workers.iter().all(|h| h.quarantined.is_some())
    }

    pub fn record_completion(&mut self, w: WorkerId, lease_wall: Duration) {
        let h = &mut self.workers[w];
        h.completions += 1;
        h.consecutive_failures = 0;
        h.backoff_until = None;
        h.lease_secs.push(lease_wall.as_secs_f64());
        h.lease_secs_ewma.observe(lease_wall.as_secs_f64());
    }

    pub fn record_audit_pass(&mut self, w: WorkerId) {
        self.workers[w].audit_passes += 1;
    }

    /// An audit condemned this worker. Returns `Some(Byzantine)` when
    /// this tips it over the quarantine threshold (first time only).
    pub fn record_audit_failure(&mut self, w: WorkerId, msg: &str) -> Option<QuarantineReason> {
        let threshold = self.cfg.quarantine_after;
        let h = &mut self.workers[w];
        h.audit_failures += 1;
        h.last_error = Some(msg.to_string());
        if h.quarantined.is_none() && threshold > 0 && h.audit_failures as usize >= threshold {
            h.quarantined = Some(QuarantineReason::Byzantine);
            return Some(QuarantineReason::Byzantine);
        }
        None
    }

    pub fn record_failure(&mut self, w: WorkerId, now: Instant, msg: &str) -> Option<QuarantineReason> {
        self.workers[w].failures += 1;
        self.offense(w, now, msg)
    }

    pub fn record_timeout(&mut self, w: WorkerId, now: Instant, msg: &str) -> Option<QuarantineReason> {
        self.workers[w].timeouts += 1;
        self.offense(w, now, msg)
    }

    /// Shared crash/timeout bookkeeping: exponential backoff with
    /// deterministic jitter, and the optional unreliable-quarantine.
    fn offense(&mut self, w: WorkerId, now: Instant, msg: &str) -> Option<QuarantineReason> {
        let cfg = self.cfg.clone();
        let h = &mut self.workers[w];
        h.consecutive_failures += 1;
        h.last_error = Some(msg.to_string());
        if cfg.quarantine_after_failures > 0
            && h.quarantined.is_none()
            && h.consecutive_failures as usize >= cfg.quarantine_after_failures
        {
            h.quarantined = Some(QuarantineReason::Unreliable);
            return Some(QuarantineReason::Unreliable);
        }
        if cfg.backoff_base > Duration::ZERO {
            let shift = (h.consecutive_failures - 1).min(16);
            let raw = cfg.backoff_base.saturating_mul(1u32 << shift).min(cfg.backoff_max);
            // jitter in [1.0, 1.5): deterministic in (seed, worker,
            // offense count) so replayed runs back off identically
            let key = (w as u64) << 32 | u64::from(h.consecutive_failures);
            let jitter = 1.0 + 0.5 * prng::substream(cfg.jitter_seed, key).f64();
            h.backoff_until = Some(now + raw.mul_f64(jitter));
        }
        None
    }

    /// How long until `w` leaves backoff (None = available now or
    /// quarantined). Lets the dispatcher's idle sleep stay short.
    pub fn backoff_remaining(&self, w: WorkerId, now: Instant) -> Option<Duration> {
        let h = &self.workers[w];
        match (h.quarantined, h.backoff_until) {
            (None, Some(t)) if t > now => Some(t - now),
            _ => None,
        }
    }

    /// Final scorecards for the dispatch report.
    pub fn into_workers(self) -> Vec<WorkerHealth> {
        self.workers
    }

    /// Per-worker post-mortem table — rendered into the loud failure
    /// when the surviving pool can no longer cover the sweep.
    pub fn post_mortem(&self) -> String {
        let mut t = Table::new(&[
            "worker", "state", "done", "fail", "timeout", "audit+", "audit-", "mean lease(s)",
            "last error",
        ]);
        for (w, h) in self.workers.iter().enumerate() {
            t.row(vec![
                w.to_string(),
                h.quarantined.map_or("active", QuarantineReason::as_str).to_string(),
                h.completions.to_string(),
                h.failures.to_string(),
                h.timeouts.to_string(),
                h.audit_passes.to_string(),
                h.audit_failures.to_string(),
                if h.lease_secs.count() == 0 {
                    "-".into()
                } else {
                    format!("{:.3}", h.lease_secs.mean())
                },
                h.last_error.clone().unwrap_or_default(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(base_ms: u64) -> HealthConfig {
        HealthConfig {
            quarantine_after: 2,
            quarantine_after_failures: 0,
            backoff_base: Duration::from_millis(base_ms),
            backoff_max: Duration::from_millis(800),
            jitter_seed: 7,
        }
    }

    #[test]
    fn backoff_grows_exponentially_with_bounded_jitter() {
        let mut t = HealthTracker::new(1, cfg(100));
        let now = Instant::now();
        let mut prev = Duration::ZERO;
        for k in 0..3u32 {
            assert!(t.record_failure(0, now, "boom").is_none());
            let left = t.backoff_remaining(0, now).expect("backoff armed");
            let raw = Duration::from_millis(100 * (1 << k));
            assert!(left >= raw, "offense {k}: {left:?} < base {raw:?}");
            assert!(left < raw.mul_f64(1.5), "offense {k}: jitter out of range: {left:?}");
            assert!(left > prev, "backoff must grow: {left:?} <= {prev:?}");
            assert!(!t.available(0, now));
            assert!(t.available(0, now + Duration::from_secs(2)));
            prev = left;
        }
        // the cap holds even deep into a crash loop
        for _ in 0..20 {
            t.record_failure(0, now, "boom");
        }
        let left = t.backoff_remaining(0, now).unwrap();
        assert!(left <= Duration::from_millis(800).mul_f64(1.5), "{left:?}");
        // a completion resets the streak and clears the backoff
        t.record_completion(0, Duration::from_millis(10));
        assert!(t.available(0, now));
        assert_eq!(t.worker(0).consecutive_failures, 0);
    }

    #[test]
    fn backoff_jitter_is_deterministic() {
        let now = Instant::now();
        let run = || {
            let mut t = HealthTracker::new(2, cfg(100));
            t.record_failure(0, now, "x");
            t.record_failure(1, now, "x");
            (t.backoff_remaining(0, now).unwrap(), t.backoff_remaining(1, now).unwrap())
        };
        let (a0, a1) = run();
        let (b0, b1) = run();
        assert_eq!(a0, b0);
        assert_eq!(a1, b1);
        assert_ne!(a0, a1, "per-worker jitter must decorrelate the pool");
    }

    #[test]
    fn audit_failures_quarantine_as_byzantine_once() {
        let mut t = HealthTracker::new(2, cfg(0));
        assert!(t.record_audit_failure(1, "forged bits").is_none());
        assert_eq!(
            t.record_audit_failure(1, "forged bits again"),
            Some(QuarantineReason::Byzantine)
        );
        // already quarantined: no second trigger
        assert!(t.record_audit_failure(1, "still bad").is_none());
        assert!(!t.eligible(1));
        assert!(!t.available(1, Instant::now()));
        assert!(t.eligible(0));
        assert!(!t.all_quarantined());
        assert!(t.post_mortem().contains("byzantine"));
    }

    #[test]
    fn crash_loop_quarantines_as_unreliable_when_enabled() {
        let mut c = cfg(0);
        c.quarantine_after_failures = 3;
        let mut t = HealthTracker::new(1, c);
        let now = Instant::now();
        assert!(t.record_failure(0, now, "x").is_none());
        assert!(t.record_timeout(0, now, "y").is_none());
        assert_eq!(t.record_failure(0, now, "z"), Some(QuarantineReason::Unreliable));
        assert!(t.all_quarantined());
        let pm = t.post_mortem();
        assert!(pm.contains("unreliable") && pm.contains('z'), "{pm}");
    }

    #[test]
    fn zero_base_disables_backoff() {
        let mut t = HealthTracker::new(1, cfg(0));
        let now = Instant::now();
        t.record_failure(0, now, "x");
        assert!(t.available(0, now), "no backoff when base is ZERO");
        assert!(t.backoff_remaining(0, now).is_none());
    }
}
