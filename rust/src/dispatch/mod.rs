//! Elastic, fault-tolerant dispatch of sharded sweeps.
//!
//! The paper's premise is a computation that survives straggling and
//! adversarial machines; this module applies the same idea to the
//! repo's own Monte-Carlo sweep infrastructure. A [`Dispatcher`]
//! executes any standard [`SweepConfig`] across a pool of workers and
//! returns a merged result **byte-identical to a single-process run**:
//!
//! * a [`queue::WorkQueue`] partitions `[0, N)` into contiguous
//!   lease-able ranges (initial size from the `grain` knob, aligned to
//!   the engine's chunk grid so `run_range_map` warm-replay stays
//!   exact) and tracks leases with deadlines;
//! * a [`transport::WorkerTransport`] executes leased ranges —
//!   [`transport::LocalProcess`] spawns `gcod sweep-shard --range a..b`
//!   subprocesses; ssh/k8s transports slot in behind the same trait;
//! * the [`Dispatcher`] event loop polls workers, re-enqueues ranges
//!   from dead or deadline-blown workers (bounded retries, failure
//!   log), speculatively re-executes the slowest ranges on idle
//!   workers, and finally feeds the collected shard results through
//!   [`shard::dedup_cover`] (duplicate covers from speculation are
//!   dropped or trimmed — bit-neutral, because per-trial values are
//!   split-invariant) into [`shard::merge`], which still fails loudly
//!   on any coverage gap.
//!
//! Lost *worker* work is cheap by construction: any contiguous
//! re-cover of a lost range merges cleanly, so fault tolerance is pure
//! scheduling — no coordination with the surviving workers. Losing the
//! *dispatcher* itself is covered by the optional checkpoint
//! [`journal`]: completed leases persist as they arrive, and a resumed
//! launch recomputes only the uncovered remainder (byte-identity
//! preserved, since per-trial values are split-invariant).

pub mod journal;
pub mod queue;
pub mod transport;

use crate::error::{Error, Result};
use crate::straggler::{BernoulliStragglers, DelaySampler};
use crate::sweep::shard::{self, MergedSweep, ShardResult, SweepConfig};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

pub use journal::Journal;
pub use queue::{Lease, LeaseId, WorkQueue, WorkerId};
pub use transport::{LocalProcess, WorkerJob, WorkerPoll, WorkerTransport};

/// Simulate straggling workers: each assignment wave samples a
/// Bernoulli(p) mask over the pool and delays the chosen workers' jobs
/// by `delay` (via the transport's startup-delay hook). Reuses the
/// paper's random-straggler model for the dispatcher's own test bench.
#[derive(Clone, Debug)]
pub struct StragglerSimCfg {
    pub p: f64,
    pub delay: Duration,
    pub seed: u64,
}

/// Dispatcher tuning knobs.
#[derive(Clone, Debug)]
pub struct DispatchConfig {
    /// initial lease size in trials (0 = auto: `trials / (4 * workers)`,
    /// clamped to the chunk grid)
    pub grain: usize,
    /// shrink lease sizes geometrically as the frontier drains (tail
    /// latency: the last leases are small, so the sweep never waits on
    /// one straggler holding a full-grain range). `grain` stays the
    /// cap, `min_grain` the floor. Bit-neutral: lease boundaries stay
    /// chunk-aligned and per-trial values are split-invariant.
    pub adaptive_grain: bool,
    /// floor for adaptive carves (0 = one engine chunk)
    pub min_grain: usize,
    /// engine threads inside each worker
    pub threads_per_worker: usize,
    /// a lease older than this is presumed lost: its worker is killed
    /// and the range re-enqueued (catches hung workers that never
    /// complete — for a local transport, "never heartbeats")
    pub lease_timeout: Duration,
    /// re-enqueues allowed per range before the dispatch fails loudly
    pub max_retries: usize,
    /// event-loop pause between polls
    pub poll_interval: Duration,
    /// duplicate the slowest running ranges onto idle workers once the
    /// queue drains (duplicates are deduplicated before the merge)
    pub speculate: bool,
    /// workers emit stats-only manifests (relaxed Chan-merge contract)
    pub stats_only: bool,
    /// directory for worker manifests (created on demand)
    pub out_dir: PathBuf,
    /// straggler simulation (tests/benches)
    pub straggler_sim: Option<StragglerSimCfg>,
    /// fault injection: delay worker w's *first* job by this many ms —
    /// with a delay past `lease_timeout` this simulates a worker that
    /// never heartbeats
    pub fault_delay_ms: Vec<(WorkerId, u64)>,
    /// checkpoint journal path: every collected lease persists here as
    /// it completes, so an interrupted/failed dispatch can be resumed
    /// (see [`journal`]). `None` = no checkpointing
    pub journal: Option<PathBuf>,
    /// replay an existing journal at `journal` before dispatching:
    /// journalled ranges are pre-marked done and only the uncovered
    /// remainder recomputes (fixed-grain carve; `adaptive_grain` does
    /// not apply to the resumed remainder)
    pub resume: bool,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        Self {
            grain: 0,
            adaptive_grain: false,
            min_grain: 0,
            threads_per_worker: 1,
            lease_timeout: Duration::from_secs(300),
            max_retries: 3,
            poll_interval: Duration::from_millis(10),
            speculate: true,
            stats_only: false,
            out_dir: std::env::temp_dir().join(format!("gcod_dispatch_{}", std::process::id())),
            straggler_sim: None,
            fault_delay_ms: Vec::new(),
            journal: None,
            resume: false,
        }
    }
}

/// What happened during a dispatch, for operators and tests.
#[derive(Clone, Debug, Default)]
pub struct DispatchReport {
    pub leases_issued: u64,
    pub completed: u64,
    pub speculative_issued: u64,
    /// worker failures that led to a re-enqueue
    pub retried: u64,
    /// leases reaped by the deadline (hung/straggling workers)
    pub timeouts: u64,
    /// speculation losers cancelled after a duplicate finished first
    pub cancelled: u64,
    /// redundant results dropped/trimmed by `dedup_cover`
    pub duplicates_dropped: usize,
    pub per_worker_completed: Vec<u64>,
    pub failure_log: Vec<String>,
    pub elapsed: Duration,
}

impl DispatchReport {
    /// One-paragraph operator summary.
    pub fn summary(&self) -> String {
        format!(
            "dispatched {} lease(s) ({} speculative): {} completed, {} retried, \
             {} timeout(s), {} cancelled, {} duplicate result(s) deduped, {:.2}s \
             [per-worker completions: {}]",
            self.leases_issued,
            self.speculative_issued,
            self.completed,
            self.retried,
            self.timeouts,
            self.cancelled,
            self.duplicates_dropped,
            self.elapsed.as_secs_f64(),
            self.per_worker_completed
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join("/")
        )
    }
}

/// A finished dispatch: the canonical merged sweep plus the scheduling
/// report.
#[derive(Debug)]
pub struct DispatchOutcome {
    pub merged: MergedSweep,
    pub report: DispatchReport,
}

/// Executes one sweep across a worker pool. See the module docs.
pub struct Dispatcher {
    cfg: DispatchConfig,
}

impl Dispatcher {
    pub fn new(cfg: DispatchConfig) -> Self {
        Self { cfg }
    }

    /// Run `sweep` to completion on `transport`'s worker pool and merge
    /// the collected shard results. Full-manifest dispatches are
    /// byte-identical to `shard::run_full` regardless of worker count,
    /// grain, failures, timeouts or speculation.
    pub fn run(
        &self,
        sweep: &SweepConfig,
        transport: &mut dyn WorkerTransport,
    ) -> Result<DispatchOutcome> {
        // the registry, not a kind list, decides dispatchability — a
        // freshly registered kernel is dispatchable with no change here
        if let Some(msg) = sweep.sweep.external_producer() {
            return Err(Error::msg(format!(
                "sweep kind '{}' cannot be dispatched: {msg}",
                sweep.sweep.as_str()
            )));
        }
        // validate params before spawning anything: a bad param would
        // otherwise fail inside every worker and burn the whole retry
        // budget before surfacing as a misleading retry-exhaustion error
        sweep.sweep.kernel().validate(sweep)?;
        if sweep.trials == 0 {
            return Err(Error::msg("nothing to dispatch: sweep has 0 trials"));
        }
        if sweep.chunk == 0 {
            return Err(Error::msg("sweep chunk must be >= 1"));
        }
        let n = transport.n_workers();
        if n == 0 {
            return Err(Error::msg("transport has no workers to dispatch to"));
        }
        let grain = match self.cfg.grain {
            0 => (sweep.trials.div_ceil(4 * n)).max(sweep.chunk),
            g => g,
        };
        // checkpoint journal: open (and on resume, replay) before the
        // queue is built so journalled ranges never re-lease
        let mut journal = None;
        if let Some(path) = &self.cfg.journal {
            journal = Some(Journal::open(path, sweep, self.cfg.stats_only, self.cfg.resume)?);
        }
        let mut results: Vec<ShardResult> =
            journal.as_mut().map(Journal::take_preloaded).unwrap_or_default();
        let done_ranges: Vec<(usize, usize)> = results.iter().map(|r| (r.lo, r.hi)).collect();
        let mut queue = if !done_ranges.is_empty() {
            WorkQueue::resume(sweep.trials, grain, sweep.chunk, self.cfg.max_retries, &done_ranges)?
        } else if self.cfg.adaptive_grain {
            let min = match self.cfg.min_grain {
                0 => sweep.chunk,
                m => m,
            };
            WorkQueue::new_adaptive(sweep.trials, grain, min, sweep.chunk, self.cfg.max_retries)?
        } else {
            WorkQueue::new(sweep.trials, grain, sweep.chunk, self.cfg.max_retries)?
        };
        std::fs::create_dir_all(&self.cfg.out_dir)
            .map_err(|e| Error::msg(format!("create {}: {e}", self.cfg.out_dir.display())))?;

        let mut sim = self
            .cfg
            .straggler_sim
            .as_ref()
            .map(|s| DelaySampler::new(BernoulliStragglers::new(s.p, s.seed), s.delay));
        let mut fault_delay: BTreeMap<WorkerId, u64> =
            self.cfg.fault_delay_ms.iter().copied().collect();

        let mut busy: Vec<Option<LeaseId>> = vec![None; n];
        let mut report =
            DispatchReport { per_worker_completed: vec![0; n], ..DispatchReport::default() };
        if let Some(j) = &mut journal {
            // dropped/stale entries recompute; say so in the report
            report.failure_log.append(&mut j.notes);
        }
        let started = Instant::now();

        // wraps a queue error (retry budget blown) with the failure log
        // so the loud failure explains itself
        let with_log = |e: Error, log: &[String]| {
            Error::msg(if log.is_empty() {
                e.to_string()
            } else {
                format!("{e}\nworker failure log:\n  {}", log.join("\n  "))
            })
        };

        loop {
            // 1. poll busy workers (redundancy computed once per tick —
            // a lease turning redundant mid-sweep is caught next tick)
            let redundant = queue.redundant();
            for w in 0..n {
                let Some(id) = busy[w] else { continue };
                match transport.poll(w) {
                    WorkerPoll::Running => {
                        // speculation loser: a duplicate already
                        // finished this range
                        if redundant.contains(&id) {
                            transport.kill(w);
                            queue.cancel(id);
                            busy[w] = None;
                            report.cancelled += 1;
                        }
                    }
                    WorkerPoll::Done => {
                        busy[w] = None;
                        let lease = queue.get(id).cloned().expect("busy lease is active");
                        match transport.collect(w).and_then(|r| {
                            validate_result(r, sweep, &lease, self.cfg.stats_only)
                        }) {
                            Ok(res) => {
                                queue.complete(id)?;
                                if let Some(j) = &mut journal {
                                    // checkpoint loss is not worth
                                    // failing a healthy dispatch over
                                    if let Err(e) = j.record(&res) {
                                        report.failure_log.push(format!(
                                            "checkpoint of lease [{}, {}) failed: {e}",
                                            res.lo, res.hi
                                        ));
                                    }
                                }
                                results.push(res);
                                report.completed += 1;
                                report.per_worker_completed[w] += 1;
                            }
                            Err(e) => {
                                report.failure_log.push(format!(
                                    "worker {w} lease [{}, {}): bad result: {e}",
                                    lease.lo, lease.hi
                                ));
                                let (_, requeued) = queue
                                    .fail(id)
                                    .map_err(|e| with_log(e, &report.failure_log))?;
                                report.retried += u64::from(requeued);
                            }
                        }
                    }
                    WorkerPoll::Failed(msg) => {
                        busy[w] = None;
                        report.failure_log.push(msg);
                        let (_, requeued) =
                            queue.fail(id).map_err(|e| with_log(e, &report.failure_log))?;
                        report.retried += u64::from(requeued);
                    }
                    WorkerPoll::Idle => {
                        busy[w] = None;
                        report.failure_log.push(format!(
                            "worker {w} lost its job for lease {id} (transport reported idle)"
                        ));
                        let (_, requeued) =
                            queue.fail(id).map_err(|e| with_log(e, &report.failure_log))?;
                        report.retried += u64::from(requeued);
                    }
                }
            }

            // 2. reap leases past their deadline (dead-but-undetected or
            // hung workers — the "never heartbeats" case)
            for id in queue.expired(self.cfg.lease_timeout) {
                let lease = queue.get(id).cloned().expect("expired lease is active");
                transport.kill(lease.worker);
                busy[lease.worker] = None;
                report.timeouts += 1;
                report.failure_log.push(format!(
                    "worker {} lease [{}, {}): deadline {:?} exceeded, re-enqueueing",
                    lease.worker, lease.lo, lease.hi, self.cfg.lease_timeout
                ));
                let (_, requeued) =
                    queue.fail(id).map_err(|e| with_log(e, &report.failure_log))?;
                report.retried += u64::from(requeued);
            }

            // 3. hand ranges to idle workers
            let delays: Option<Vec<Duration>> = if busy.iter().any(Option::is_none) {
                sim.as_mut().map(|s| s.sample_delays(n))
            } else {
                None
            };
            for w in 0..n {
                if busy[w].is_some() {
                    continue;
                }
                let lease = match queue.lease(w) {
                    Some(l) => l,
                    None if self.cfg.speculate => match queue.speculative_lease(w) {
                        Some(l) => l,
                        None => continue,
                    },
                    None => continue,
                };
                let mut delay_ms = delays.as_ref().map(|d| d[w].as_millis() as u64).unwrap_or(0);
                if let Some(ms) = fault_delay.remove(&w) {
                    delay_ms = ms;
                }
                let job = WorkerJob {
                    config: sweep.clone(),
                    lo: lease.lo,
                    hi: lease.hi,
                    threads: self.cfg.threads_per_worker.max(1),
                    stats_only: self.cfg.stats_only,
                    out_path: self
                        .cfg
                        .out_dir
                        .join(format!("lease_{}_{}_{}.json", lease.id, lease.lo, lease.hi)),
                    delay_ms,
                };
                report.leases_issued += 1;
                report.speculative_issued += u64::from(lease.speculative);
                match transport.start(w, &job) {
                    Ok(()) => busy[w] = Some(lease.id),
                    Err(e) => {
                        report.failure_log.push(format!(
                            "worker {w} lease [{}, {}): start failed: {e}",
                            lease.lo, lease.hi
                        ));
                        let (_, requeued) = queue
                            .fail(lease.id)
                            .map_err(|e| with_log(e, &report.failure_log))?;
                        report.retried += u64::from(requeued);
                    }
                }
            }

            // 4. termination
            let all_idle = busy.iter().all(Option::is_none);
            if queue.is_complete() && all_idle {
                break;
            }
            if all_idle && queue.active_leases() == 0 && queue.pending_ranges() == 0 {
                // unreachable by construction (fail() either requeues or
                // errors), but never spin silently
                return Err(with_log(
                    Error::msg("dispatcher stalled: no pending work, no active leases, sweep \
                                incomplete"),
                    &report.failure_log,
                ));
            }
            std::thread::sleep(self.cfg.poll_interval);
        }

        let (cover, deduped) =
            shard::dedup_cover(results).map_err(|e| with_log(e, &report.failure_log))?;
        report.duplicates_dropped = deduped;
        let merged = shard::merge(cover).map_err(|e| with_log(e, &report.failure_log))?;
        // the sweep merged: the checkpoint has served its purpose (on
        // any earlier error return the journal stays behind for --resume)
        if let Some(j) = journal {
            j.finish();
        }
        report.elapsed = started.elapsed();
        Ok(DispatchOutcome { merged, report })
    }
}

/// A collected result must be exactly the leased range of the requested
/// sweep — anything else is treated as a worker failure (and the range
/// re-leased), never silently merged.
fn validate_result(
    res: ShardResult,
    sweep: &SweepConfig,
    lease: &Lease,
    stats_only: bool,
) -> Result<ShardResult> {
    if res.config != *sweep {
        return Err(Error::msg("worker manifest config differs from the dispatched sweep"));
    }
    if (res.lo, res.hi) != (lease.lo, lease.hi) {
        return Err(Error::msg(format!(
            "worker manifest covers [{}, {}), lease was [{}, {})",
            res.lo, res.hi, lease.lo, lease.hi
        )));
    }
    if res.stats_only != stats_only {
        return Err(Error::msg("worker manifest stats-only mode differs from the dispatch"));
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::shard::SweepKind;
    use std::collections::BTreeMap;

    /// Per-worker behavior script for the in-process mock transport.
    #[derive(Clone, Default)]
    struct WorkerScript {
        /// report Failed for this many jobs before behaving
        fail_first: usize,
        /// hang (Running forever, until killed) for this many jobs
        hang_first: usize,
        /// healthy jobs stay Running for this many polls before Done
        done_after_polls: usize,
    }

    enum SlotState {
        Failing,
        Hung,
        Working { polls_left: usize, result: ShardResult },
        Done { result: ShardResult },
    }

    /// In-process transport: computes leased ranges via
    /// `shard::run_range` but exposes them through the same poll-based
    /// interface as a real process pool, with scripted faults.
    struct Scripted {
        scripts: Vec<WorkerScript>,
        slots: Vec<Option<SlotState>>,
    }

    impl Scripted {
        fn new(scripts: Vec<WorkerScript>) -> Self {
            let slots = scripts.iter().map(|_| None).collect();
            Self { scripts, slots }
        }
    }

    impl WorkerTransport for Scripted {
        fn n_workers(&self) -> usize {
            self.scripts.len()
        }

        fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()> {
            assert!(self.slots[worker].is_none(), "worker {worker} double-started");
            let script = &mut self.scripts[worker];
            let state = if script.fail_first > 0 {
                script.fail_first -= 1;
                SlotState::Failing
            } else if script.hang_first > 0 {
                script.hang_first -= 1;
                SlotState::Hung
            } else {
                let mut result = shard::run_range(&job.config, job.threads, job.lo, job.hi)?;
                if job.stats_only {
                    result = result.into_stats_only();
                }
                SlotState::Working { polls_left: script.done_after_polls, result }
            };
            self.slots[worker] = Some(state);
            Ok(())
        }

        fn poll(&mut self, worker: WorkerId) -> WorkerPoll {
            match self.slots[worker].take() {
                None => WorkerPoll::Idle,
                Some(SlotState::Failing) => {
                    WorkerPoll::Failed(format!("worker {worker}: scripted death"))
                }
                Some(SlotState::Hung) => {
                    self.slots[worker] = Some(SlotState::Hung);
                    WorkerPoll::Running
                }
                Some(SlotState::Working { polls_left, result }) => {
                    if polls_left == 0 {
                        self.slots[worker] = Some(SlotState::Done { result });
                        WorkerPoll::Done
                    } else {
                        self.slots[worker] =
                            Some(SlotState::Working { polls_left: polls_left - 1, result });
                        WorkerPoll::Running
                    }
                }
                Some(SlotState::Done { result }) => {
                    self.slots[worker] = Some(SlotState::Done { result });
                    WorkerPoll::Done
                }
            }
        }

        fn kill(&mut self, worker: WorkerId) {
            self.slots[worker] = None;
        }

        fn collect(&mut self, worker: WorkerId) -> Result<ShardResult> {
            match self.slots[worker].take() {
                Some(SlotState::Done { result }) => Ok(result),
                _ => Err(Error::msg(format!("worker {worker}: nothing to collect"))),
            }
        }
    }

    fn sweep_cfg(trials: usize) -> SweepConfig {
        SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:12,3".into(),
            decoder: "optimal".into(),
            p: 0.25,
            seed: 11,
            trials,
            chunk: 8,
            params: BTreeMap::new(),
        }
    }

    fn fast_dispatch() -> DispatchConfig {
        DispatchConfig {
            grain: 8,
            poll_interval: Duration::from_millis(1),
            lease_timeout: Duration::from_secs(30),
            out_dir: std::env::temp_dir()
                .join(format!("gcod_dispatch_test_{}", std::process::id())),
            ..DispatchConfig::default()
        }
    }

    #[test]
    fn healthy_pool_matches_single_process_bits() {
        let c = sweep_cfg(60);
        let single = shard::run_full(&c, 2).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 3]);
        let out = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "merged JSON bytes");
        assert!(out.report.leases_issued >= 3, "{}", out.report.summary());
        // at least one completion per range (speculation may add more)
        assert!(out.report.completed as usize >= out.merged.config.trials.div_ceil(8));
    }

    #[test]
    fn worker_deaths_requeue_and_stay_bit_exact() {
        let c = sweep_cfg(48);
        let single = shard::run_full(&c, 1).unwrap();
        let scripts = vec![
            WorkerScript { fail_first: 2, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let out = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(out.report.retried >= 2, "{}", out.report.summary());
        assert!(!out.report.failure_log.is_empty());
    }

    #[test]
    fn hung_worker_hits_deadline_and_range_redispatches() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        let scripts = vec![
            WorkerScript { hang_first: 1, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig {
            lease_timeout: Duration::from_millis(40),
            speculate: false, // force the timeout path to do the rescue
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(out.report.timeouts >= 1, "{}", out.report.summary());
    }

    #[test]
    fn speculative_duplicates_dedup_before_merge() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        // worker 0 is slow (extra poll) so its first range drains the
        // queue while still running; idle worker 1 speculates on it and
        // both results arrive — a genuine duplicate cover
        let scripts = vec![
            WorkerScript { done_after_polls: 1, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig { grain: 16, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(
            out.report.speculative_issued >= 1,
            "expected speculation: {}",
            out.report.summary()
        );
        assert!(
            out.report.duplicates_dropped >= 1 || out.report.cancelled >= 1,
            "expected a deduped duplicate or a cancelled loser: {}",
            out.report.summary()
        );
    }

    /// Adaptive grain is pure scheduling: shrinking tail leases must
    /// leave the merged JSON byte-identical to the single-process run,
    /// with or without worker faults in the mix.
    #[test]
    fn adaptive_grain_matches_single_process_bits() {
        let c = sweep_cfg(96);
        let single = shard::run_full(&c, 2).unwrap();
        // healthy pool
        let mut t = Scripted::new(vec![WorkerScript::default(); 3]);
        let dcfg = DispatchConfig {
            grain: 32,
            adaptive_grain: true,
            min_grain: 8,
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg.clone()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "adaptive healthy merged JSON bytes");
        // adaptive carving hands out more, smaller leases than the
        // fixed 96/32 = 3-range split would
        assert!(out.report.leases_issued > 3, "{}", out.report.summary());
        // with a faulty worker: failed ranges re-lease whole and the
        // bits still match
        let scripts = vec![
            WorkerScript { fail_first: 2, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "adaptive faulted merged JSON bytes");
        assert!(out.report.retried >= 2, "{}", out.report.summary());
    }

    /// Checkpoint/resume on the deterministic scripted transport: a
    /// first dispatch dies of retry exhaustion after banking some
    /// leases in its journal; the resumed dispatch recomputes only the
    /// uncovered remainder and the merged JSON is byte-identical to an
    /// uninterrupted single-process run.
    #[test]
    fn journaled_dispatch_resumes_bit_exact_after_failure() {
        let c = sweep_cfg(64);
        let single = shard::run_full(&c, 1).unwrap();
        let jdir = std::env::temp_dir()
            .join(format!("gcod_journal_test_{}", std::process::id()));
        std::fs::create_dir_all(&jdir).unwrap();
        let jpath = jdir.join("sweep.journal");

        // phase 1: worker 0 is healthy, worker 1 fails forever — with a
        // tiny retry budget the dispatch dies, but worker 0's completed
        // leases are checkpointed
        let scripts = vec![
            WorkerScript { done_after_polls: 1, ..WorkerScript::default() },
            WorkerScript { fail_first: usize::MAX, ..WorkerScript::default() },
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig {
            max_retries: 1,
            speculate: false,
            journal: Some(jpath.clone()),
            ..fast_dispatch()
        };
        let err = Dispatcher::new(dcfg.clone()).run(&c, &mut t).unwrap_err();
        assert!(format!("{err}").contains("giving up"), "{err}");
        assert!(jpath.is_file(), "failed dispatch must leave its journal behind");
        let text = std::fs::read_to_string(&jpath).unwrap();
        assert!(text.starts_with(journal::JOURNAL_HEADER), "{text}");
        let banked = text.lines().filter(|l| l.starts_with("done ")).count();
        assert!(banked >= 1, "no leases were checkpointed:\n{text}");

        // phase 2: resume with a healthy pool; only the gaps recompute
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig { resume: true, max_retries: 3, ..dcfg };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "resumed merged JSON bytes");
        // the resumed run dispatched fewer leases than full coverage
        // would need (8 ranges of grain 8): the banked ones were free
        assert!(
            (out.report.completed as usize) + banked >= 8,
            "coverage accounting: completed={} banked={banked}",
            out.report.completed
        );
        assert!(
            (out.report.completed as usize) <= 8 - banked + 1,
            "resume recomputed banked ranges: completed={} banked={banked} ({})",
            out.report.completed,
            out.report.summary()
        );
        // success removed the journal + sidecar manifests
        assert!(!jpath.is_file(), "journal must be cleaned up after a successful merge");
        assert!(!Journal::sidecar_dir(&jpath).exists());
        let _ = std::fs::remove_dir_all(&jdir);
    }

    /// Resuming a journal against a different sweep is refused, and a
    /// journal whose sidecar manifests were corrupted degrades to
    /// recomputation rather than bad merges.
    #[test]
    fn journal_rejects_mismatched_sweep_and_survives_corruption() {
        let c = sweep_cfg(32);
        let jdir = std::env::temp_dir()
            .join(format!("gcod_journal_guard_{}", std::process::id()));
        std::fs::create_dir_all(&jdir).unwrap();
        let jpath = jdir.join("guard.journal");

        // healthy journaled run that we interrupt artificially: run to
        // completion but keep the journal by copying it mid-flight is
        // racy — instead, synthesize the journal from a real partial run
        let mut j = Journal::open(&jpath, &c, false, false).unwrap();
        let part = shard::run_range(&c, 1, 0, 16).unwrap();
        j.record(&part).unwrap();
        drop(j);
        assert!(jpath.is_file());

        // different seed = different sweep: hard refusal
        let mut other = sweep_cfg(32);
        other.seed = 999;
        let err = Journal::open(&jpath, &other, false, true).unwrap_err();
        assert!(format!("{err}").contains("different sweep"), "{err}");

        // corrupt the banked manifest: the entry is dropped with a note
        // and the range recomputes
        let manifest = Journal::sidecar_dir(&jpath).join("done_0_16.json");
        std::fs::write(&manifest, "not json").unwrap();
        let mut j = Journal::open(&jpath, &c, false, true).unwrap();
        assert!(j.take_preloaded().is_empty());
        assert_eq!(j.notes.len(), 1, "{:?}", j.notes);
        drop(j);

        // resuming a journal that does not exist is a hard error (a
        // typo'd path must not silently recompute everything) ...
        let err = Journal::open(&jdir.join("nope.journal"), &c, false, true).unwrap_err();
        assert!(format!("{err}").contains("not found"), "{err}");
        // ... and a fresh (non-resume) open refuses to clobber an
        // existing checkpoint
        let err = Journal::open(&jpath, &c, false, false).unwrap_err();
        assert!(format!("{err}").contains("already exists"), "{err}");

        // and a full resumed dispatch over the corrupted journal still
        // produces the exact single-process bytes
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig {
            journal: Some(jpath.clone()),
            resume: true,
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        let _ = std::fs::remove_dir_all(&jdir);
    }

    /// Bad kernel params die in the dispatcher, immediately — never by
    /// burning the retry budget on workers that can only fail.
    #[test]
    fn dispatch_rejects_invalid_params_before_spawning() {
        let mut c = sweep_cfg(16);
        c.params.insert("precond".into(), "maybe".into());
        let mut t = Scripted::new(vec![WorkerScript::default()]);
        let err = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("precond"), "{msg}");
        assert!(!msg.contains("giving up"), "param error burned the retry budget: {msg}");
    }

    #[test]
    fn retry_budget_exhaustion_fails_loudly() {
        let c = sweep_cfg(16);
        let scripts = vec![WorkerScript { fail_first: usize::MAX, ..WorkerScript::default() }];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig { max_retries: 1, ..fast_dispatch() };
        let err = Dispatcher::new(dcfg).run(&c, &mut t).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("giving up"), "{msg}");
        assert!(msg.contains("failure log"), "{msg}");
    }

    #[test]
    fn stats_only_dispatch_uses_chan_contract() {
        let c = sweep_cfg(40);
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig { stats_only: true, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert!(out.merged.stats_only && out.merged.values.is_empty());
        assert_eq!(out.merged.stats.count(), 40);
        assert_eq!(out.merged.stats.min().to_bits(), single.stats.min().to_bits());
        assert_eq!(out.merged.stats.max().to_bits(), single.stats.max().to_bits());
        assert!((out.merged.stats.mean() - single.stats.mean()).abs() < 1e-12);
    }

    #[test]
    fn rejects_undispatchable_sweeps() {
        let mut t = Scripted::new(vec![WorkerScript::default()]);
        let mut c = sweep_cfg(0);
        let d = Dispatcher::new(fast_dispatch());
        assert!(d.run(&c, &mut t).is_err());
        c.trials = 8;
        c.sweep = SweepKind::Fig4Cluster;
        assert!(d.run(&c, &mut t).is_err());
        // a worker-less transport must error, not spin or divide by zero
        let mut empty = Scripted::new(vec![]);
        let err = d.run(&sweep_cfg(8), &mut empty).unwrap_err();
        assert!(format!("{err}").contains("no workers"), "{err}");
    }
}
