//! Elastic, fault-tolerant dispatch of sharded sweeps.
//!
//! The paper's premise is a computation that survives straggling and
//! adversarial machines; this module applies the same idea to the
//! repo's own Monte-Carlo sweep infrastructure. A [`Dispatcher`]
//! executes any standard [`SweepConfig`] across a pool of workers and
//! returns a merged result **byte-identical to a single-process run**:
//!
//! * a [`queue::WorkQueue`] partitions `[0, N)` into contiguous
//!   lease-able ranges (initial size from the `grain` knob, aligned to
//!   the engine's chunk grid so `run_range_map` warm-replay stays
//!   exact) and tracks leases with deadlines;
//! * a [`transport::WorkerTransport`] executes leased ranges —
//!   [`transport::LocalProcess`] spawns `gcod sweep-shard --range a..b`
//!   subprocesses; ssh/k8s transports slot in behind the same trait;
//! * the [`Dispatcher`] event loop polls workers, re-enqueues ranges
//!   from dead or deadline-blown workers (bounded retries, failure
//!   log), speculatively re-executes the slowest ranges on idle
//!   workers, and finally feeds the collected shard results through
//!   [`shard::dedup_cover`] (duplicate covers from speculation are
//!   dropped or trimmed — bit-neutral, because per-trial values are
//!   split-invariant) into [`shard::merge`], which still fails loudly
//!   on any coverage gap.
//!
//! Lost *worker* work is cheap by construction: any contiguous
//! re-cover of a lost range merges cleanly, so fault tolerance is pure
//! scheduling — no coordination with the surviving workers. Losing the
//! *dispatcher* itself is covered by the optional checkpoint
//! [`journal`]: completed leases persist as they arrive, and a resumed
//! launch recomputes only the uncovered remainder (byte-identity
//! preserved, since per-trial values are split-invariant).
//!
//! Crash-class faults (the above) are only half the paper's threat
//! model; the **adversarial** half is covered by three more layers:
//!
//! * [`chaos`] — a deterministic fault-injection wrapper
//!   ([`chaos::ChaosTransport`]) that turns any transport into a
//!   seeded adversary for tests and soaks (kills, hangs, delays,
//!   truncated manifests, flipped bits, wrong ranges, stale replays),
//!   replayable exactly from `--chaos-seed`;
//! * **result audit** — every collected manifest is structurally
//!   validated (range, config, stats-refold integrity), and with
//!   [`DispatchConfig::audit_fraction`] `> 0` a sampled sub-range of a
//!   completed lease is re-executed on a *different* worker and
//!   byte-compared ([`ShardResult::slice`] is bit-neutral, so honest
//!   workers always agree). A mismatch is arbitrated by a third worker
//!   (tiebreak); the condemned side has **all** of its banked
//!   contributions invalidated and re-queued (without charging the
//!   retry budget) and is flagged in [`health`];
//! * [`health`] — per-worker scorecards, exponential backoff with
//!   deterministic jitter on respawn, and quarantine: a worker
//!   condemned by the audit [`health::HealthConfig::quarantine_after`]
//!   times is never scheduled again. If quarantine shrinks the pool to
//!   nothing with work remaining, the dispatch fails loudly with a
//!   per-worker post-mortem instead of burning the retry budget.
//!
//! The invariant throughout is unchanged: under any replayed fault
//! plan that leaves enough honest workers, the merged output is
//! byte-identical to a single-process run.
//!
//! Dispatch crosses machine boundaries through the same seam:
//! [`tcp::TcpTransport`] serves leases to remote `gcod worker`
//! processes over a length-prefixed JSON [`protocol`], and the
//! persistent [`server`] (`gcod serve`) holds a machine registry with
//! capability classes plus a job queue that clients (`gcod submit` /
//! `gcod status`) talk to — all of the machinery above (leases,
//! journal, chaos, audits, quarantine) composes with TCP workers
//! unchanged, and the byte-identity invariant holds per job.

pub mod chaos;
pub mod health;
pub mod journal;
pub mod protocol;
pub mod queue;
pub mod server;
pub mod store;
pub mod sys;
pub mod tcp;
pub mod transport;

use crate::error::{Error, Result};
use crate::metrics::Stats;
use crate::obs::{Event, Obs};
use crate::prng;
use crate::straggler::{BernoulliStragglers, DelaySampler};
use crate::sweep::shard::{self, MergedSweep, ShardResult, SweepConfig};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use chaos::{ChaosProfile, ChaosTransport, Fault, FaultPlan};
pub use health::{HealthConfig, HealthTracker, QuarantineReason, WorkerHealth};
pub use journal::Journal;
pub use protocol::{JobSpec, LeaseSpec, Msg};
pub use queue::{Lease, LeaseId, WorkQueue, WorkerId};
pub use server::{
    fetch_job, query_status, serve, serve_on, submit_job, submit_job_nowait, ServeConfig,
    SubmitOutcome,
};
pub use store::{JobState, StateStore};
pub use tcp::{worker_loop, RegisteredWorker, TcpTransport, WorkerOpts};
pub use transport::{LocalProcess, WorkerJob, WorkerPoll, WorkerTransport};

/// Simulate straggling workers: each assignment wave samples a
/// Bernoulli(p) mask over the pool and delays the chosen workers' jobs
/// by `delay` (via the transport's startup-delay hook). Reuses the
/// paper's random-straggler model for the dispatcher's own test bench.
#[derive(Clone, Debug)]
pub struct StragglerSimCfg {
    pub p: f64,
    pub delay: Duration,
    pub seed: u64,
}

/// Dispatcher tuning knobs.
#[derive(Clone, Debug)]
pub struct DispatchConfig {
    /// initial lease size in trials (0 = auto: `trials / (4 * workers)`,
    /// clamped to the chunk grid)
    pub grain: usize,
    /// shrink lease sizes geometrically as the frontier drains (tail
    /// latency: the last leases are small, so the sweep never waits on
    /// one straggler holding a full-grain range). `grain` stays the
    /// cap, `min_grain` the floor. Bit-neutral: lease boundaries stay
    /// chunk-aligned and per-trial values are split-invariant.
    pub adaptive_grain: bool,
    /// floor for adaptive carves (0 = one engine chunk)
    pub min_grain: usize,
    /// engine threads inside each worker
    pub threads_per_worker: usize,
    /// base lease deadline: a lease older than `lease_timeout +
    /// lease_timeout_per_trial * range_len` is presumed lost — its
    /// worker is killed and the range re-enqueued (catches hung workers
    /// that never complete — for a local transport, "never heartbeats")
    pub lease_timeout: Duration,
    /// per-trial deadline scaling, so a flat base tuned for small tail
    /// leases doesn't reap healthy workers holding large adaptive-grain
    /// head leases (ZERO = flat deadline)
    pub lease_timeout_per_trial: Duration,
    /// re-enqueues allowed per range before the dispatch fails loudly
    pub max_retries: usize,
    /// event-loop pause between polls
    pub poll_interval: Duration,
    /// duplicate the slowest running ranges onto idle workers once the
    /// queue drains (duplicates are deduplicated before the merge)
    pub speculate: bool,
    /// workers emit stats-only manifests (relaxed Chan-merge contract)
    pub stats_only: bool,
    /// directory for worker manifests (created on demand)
    pub out_dir: PathBuf,
    /// straggler simulation (tests/benches)
    pub straggler_sim: Option<StragglerSimCfg>,
    /// fraction of completed leases whose result is audited: a sampled
    /// chunk-aligned sub-range is re-executed on a different worker and
    /// byte-compared. 0 disables auditing; 1 audits every lease. Full
    /// manifests only — stats-only results have no per-trial vector to
    /// slice and compare
    pub audit_fraction: f64,
    /// seed for the deterministic audit sampling (which leases, which
    /// sub-range)
    pub audit_seed: u64,
    /// per-worker health policy: backoff on failure, quarantine
    /// thresholds (see [`health::HealthConfig`])
    pub health: HealthConfig,
    /// checkpoint journal path: every collected lease persists here as
    /// it completes, so an interrupted/failed dispatch can be resumed
    /// (see [`journal`]). `None` = no checkpointing
    pub journal: Option<PathBuf>,
    /// replay an existing journal at `journal` before dispatching:
    /// journalled ranges are pre-marked done and only the uncovered
    /// remainder recomputes (fixed-grain carve; `adaptive_grain` does
    /// not apply to the resumed remainder)
    pub resume: bool,
    /// observability handle: every scheduling decision (lease issue,
    /// completion, reap, retry, audit verdict, quarantine, …) is
    /// emitted as a structured [`crate::obs::Event`] through this
    /// handle's sinks. The default disabled handle makes every emit a
    /// no-op. Bit-neutral by contract: events never touch shard
    /// results, manifests or the merge
    pub obs: Obs,
    /// half-open-peer reap window for TCP transports: a registered
    /// worker silent for longer than this while holding a job is
    /// presumed dead (see [`tcp::DEAD_AFTER`], the default). Local
    /// process transports ignore it
    pub peer_silence_timeout: Duration,
    /// cooperative drain flag: when it flips true mid-run the
    /// dispatcher stops issuing leases, lets in-flight leases land (or
    /// be reaped), and unwinds with an error beginning
    /// `dispatch drained` — leaving the journal behind so a resumed
    /// run completes from the banked ranges. `None` = never drains
    pub stop: Option<Arc<AtomicBool>>,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        Self {
            grain: 0,
            adaptive_grain: false,
            min_grain: 0,
            threads_per_worker: 1,
            lease_timeout: Duration::from_secs(300),
            lease_timeout_per_trial: Duration::ZERO,
            max_retries: 3,
            poll_interval: Duration::from_millis(10),
            speculate: true,
            stats_only: false,
            out_dir: std::env::temp_dir().join(format!("gcod_dispatch_{}", std::process::id())),
            straggler_sim: None,
            audit_fraction: 0.0,
            audit_seed: 0xA0D1_75EE_D001,
            health: HealthConfig::default(),
            journal: None,
            resume: false,
            obs: Obs::default(),
            peer_silence_timeout: tcp::DEAD_AFTER,
            stop: None,
        }
    }
}

/// What happened during a dispatch, for operators and tests.
#[derive(Clone, Debug, Default)]
pub struct DispatchReport {
    pub leases_issued: u64,
    pub completed: u64,
    pub speculative_issued: u64,
    /// worker failures that led to a re-enqueue
    pub retried: u64,
    /// leases reaped by the deadline (hung/straggling workers)
    pub timeouts: u64,
    /// speculation losers cancelled after a duplicate finished first
    pub cancelled: u64,
    /// redundant results dropped/trimmed by `dedup_cover`
    pub duplicates_dropped: usize,
    /// audit jobs dispatched (probes, tiebreaks and retries)
    pub audits_issued: u64,
    /// probe audits whose re-execution byte-matched the banked slice
    pub audits_passed: u64,
    /// probe audits that disagreed with the banked slice
    pub audit_mismatches: u64,
    /// banked ranges invalidated because their worker was condemned
    pub invalidated_ranges: u64,
    /// workers removed from scheduling, with the reason
    pub quarantined: Vec<(WorkerId, String)>,
    /// final per-worker scorecards
    pub worker_health: Vec<WorkerHealth>,
    pub per_worker_completed: Vec<u64>,
    pub failure_log: Vec<String>,
    pub elapsed: Duration,
}

impl DispatchReport {
    /// One-paragraph operator summary.
    pub fn summary(&self) -> String {
        let audit = if self.audits_issued > 0 || self.audit_mismatches > 0 {
            format!(
                ", {} audit(s) ({} passed, {} mismatch(es), {} range(s) invalidated)",
                self.audits_issued, self.audits_passed, self.audit_mismatches,
                self.invalidated_ranges
            )
        } else {
            String::new()
        };
        let quarantine = if self.quarantined.is_empty() {
            String::new()
        } else {
            format!(
                ", quarantined: {}",
                self.quarantined
                    .iter()
                    .map(|(w, why)| format!("worker {w} ({why})"))
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        };
        format!(
            "dispatched {} lease(s) ({} speculative): {} completed, {} retried, \
             {} timeout(s), {} cancelled, {} duplicate result(s) deduped{audit}{quarantine}, \
             {:.2}s [per-worker completions: {}]",
            self.leases_issued,
            self.speculative_issued,
            self.completed,
            self.retried,
            self.timeouts,
            self.cancelled,
            self.duplicates_dropped,
            self.elapsed.as_secs_f64(),
            self.per_worker_completed
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join("/")
        )
    }
}

/// A finished dispatch: the canonical merged sweep plus the scheduling
/// report.
#[derive(Debug)]
pub struct DispatchOutcome {
    pub merged: MergedSweep,
    pub report: DispatchReport,
}

/// Executes one sweep across a worker pool. See the module docs.
pub struct Dispatcher {
    cfg: DispatchConfig,
}

impl Dispatcher {
    pub fn new(cfg: DispatchConfig) -> Self {
        Self { cfg }
    }

    /// Run `sweep` to completion on `transport`'s worker pool and merge
    /// the collected shard results. Full-manifest dispatches are
    /// byte-identical to `shard::run_full` regardless of worker count,
    /// grain, failures, timeouts or speculation.
    pub fn run(
        &self,
        sweep: &SweepConfig,
        transport: &mut dyn WorkerTransport,
    ) -> Result<DispatchOutcome> {
        // the registry, not a kind list, decides dispatchability — a
        // freshly registered kernel is dispatchable with no change here
        if let Some(msg) = sweep.sweep.external_producer() {
            return Err(Error::msg(format!(
                "sweep kind '{}' cannot be dispatched: {msg}",
                sweep.sweep.as_str()
            )));
        }
        // validate params before spawning anything: a bad param would
        // otherwise fail inside every worker and burn the whole retry
        // budget before surfacing as a misleading retry-exhaustion error
        sweep.sweep.kernel().validate(sweep)?;
        if sweep.trials == 0 {
            return Err(Error::msg("nothing to dispatch: sweep has 0 trials"));
        }
        if sweep.chunk == 0 {
            return Err(Error::msg("sweep chunk must be >= 1"));
        }
        let n = transport.n_workers();
        if n == 0 {
            return Err(Error::msg("transport has no workers to dispatch to"));
        }
        let grain = match self.cfg.grain {
            0 => (sweep.trials.div_ceil(4 * n)).max(sweep.chunk),
            g => g,
        };
        // checkpoint journal: open (and on resume, replay) before the
        // queue is built so journalled ranges never re-lease
        let mut journal = None;
        if let Some(path) = &self.cfg.journal {
            journal = Some(Journal::open(path, sweep, self.cfg.stats_only, self.cfg.resume)?);
        }
        let results: Vec<ShardResult> =
            journal.as_mut().map(Journal::take_preloaded).unwrap_or_default();
        let done_ranges: Vec<(usize, usize)> = results.iter().map(|r| (r.lo, r.hi)).collect();
        let queue = if !done_ranges.is_empty() {
            WorkQueue::resume(sweep.trials, grain, sweep.chunk, self.cfg.max_retries, &done_ranges)?
        } else if self.cfg.adaptive_grain {
            let min = match self.cfg.min_grain {
                0 => sweep.chunk,
                m => m,
            };
            WorkQueue::new_adaptive(sweep.trials, grain, min, sweep.chunk, self.cfg.max_retries)?
        } else {
            WorkQueue::new(sweep.trials, grain, sweep.chunk, self.cfg.max_retries)?
        };
        std::fs::create_dir_all(&self.cfg.out_dir)
            .map_err(|e| Error::msg(format!("create {}: {e}", self.cfg.out_dir.display())))?;

        let mut sim = self
            .cfg
            .straggler_sim
            .as_ref()
            .map(|s| DelaySampler::new(BernoulliStragglers::new(s.p, s.seed), s.delay));

        let mut state = RunState {
            cfg: &self.cfg,
            sweep,
            n,
            queue,
            health: HealthTracker::new(n, self.cfg.health.clone()),
            report: DispatchReport {
                per_worker_completed: vec![0; n],
                ..DispatchReport::default()
            },
            banked: results.into_iter().map(|res| Banked { worker: None, res }).collect(),
            audits: BTreeMap::new(),
            next_audit_id: 0,
            bank_counts: BTreeMap::new(),
            journal,
            busy: vec![None; n],
        };
        if let Some(j) = &mut state.journal {
            // dropped/stale entries recompute; say so in the report
            state.report.failure_log.append(&mut j.notes);
        }
        let started = Instant::now();
        self.cfg.obs.emit(Event::DispatchStarted {
            trials: sweep.trials,
            workers: n,
            grain,
            linalg: sweep.linalg_label().to_string(),
        });

        loop {
            let now = Instant::now();
            // 1. poll busy workers (leases and audit jobs)
            state.poll_workers(transport)?;
            // 2. reap leases and audit jobs past their (length-scaled)
            // deadline — dead-but-undetected or hung workers
            state.reap_expired(transport, now)?;
            // 3. audits nobody eligible can ever run must not deadlock
            // termination
            state.drop_unassignable_audits();
            // 4. hand audits, then ranges, to idle available workers —
            // unless a drain was requested, in which case stop leasing
            // and let the in-flight work land
            let draining =
                self.cfg.stop.as_ref().is_some_and(|s| s.load(Ordering::Relaxed));
            if !draining {
                state.assign(transport, &mut sim, now)?;
            }

            // 5. termination
            let all_idle = state.busy.iter().all(Option::is_none);
            if state.queue.is_complete() && all_idle && state.audits.is_empty() {
                break;
            }
            if draining && all_idle {
                // every in-flight lease has landed in the bank (and the
                // journal, when one is open) or been reaped; unwind with
                // the journal left behind so a resumed run completes
                // from the checkpoint instead of restarting
                state.emit_post_mortem(false, started);
                return Err(state.err_with_log(Error::msg(format!(
                    "dispatch drained: {}/{} trials banked, journal retained for resume",
                    state.queue.done_trials(),
                    sweep.trials
                ))));
            }
            if state.health.all_quarantined() {
                // graceful degradation has run out of pool: explain
                // per-worker instead of burning the retry budget
                state.emit_post_mortem(false, started);
                return Err(state.err_with_log(Error::msg(format!(
                    "dispatch halted: every worker is quarantined with work remaining\n\
                     per-worker post-mortem:\n{}",
                    state.health.post_mortem()
                ))));
            }
            if all_idle
                && state.queue.active_leases() == 0
                && state.queue.pending_ranges() == 0
                && state.audits.is_empty()
            {
                // unreachable by construction (fail() either requeues or
                // errors), but never spin silently
                state.emit_post_mortem(false, started);
                return Err(state.err_with_log(Error::msg(
                    "dispatcher stalled: no pending work, no active leases, sweep incomplete",
                )));
            }
            crate::metrics::gauge("queue_done_trials").set(state.queue.done_trials() as f64);
            std::thread::sleep(self.cfg.poll_interval);
        }
        state.emit_post_mortem(true, started);

        let RunState { mut report, banked, health, journal, .. } = state;
        let results: Vec<ShardResult> = banked.into_iter().map(|b| b.res).collect();
        let (cover, deduped) =
            shard::dedup_cover(results).map_err(|e| with_log(e, &report.failure_log))?;
        report.duplicates_dropped = deduped;
        let merged = shard::merge(cover).map_err(|e| with_log(e, &report.failure_log))?;
        // the sweep merged: the checkpoint has served its purpose (on
        // any earlier error return the journal stays behind for --resume)
        if let Some(j) = journal {
            j.finish();
        }
        report.worker_health = health.into_workers();
        report.elapsed = started.elapsed();
        Ok(DispatchOutcome { merged, report })
    }
}

/// Re-dispatch attempts for one audit job before the audit is abandoned
/// and the banked result gets the benefit of the doubt — an audit must
/// never be able to stall an otherwise healthy dispatch.
const AUDIT_MAX_ATTEMPTS: usize = 3;

/// What a busy worker slot is running.
#[derive(Clone, Copy)]
enum SlotJob {
    Lease(LeaseId),
    Audit(u64),
}

/// Where an in-flight audit stands.
enum AuditPhase {
    /// first re-execution of the sampled slice on a non-source worker
    Probe,
    /// the probe disagreed with the bank: a third worker arbitrates
    Tiebreak { challenger: WorkerId, challenger_bytes: String },
}

/// One audit of a banked result: re-execute `[lo, hi)` (a sampled
/// sub-range of `src_range`) on a worker other than `src_worker` and
/// byte-compare against `expected` (the banked slice's manifest).
struct AuditTask {
    src_worker: WorkerId,
    /// full banked range — the invalidation granularity on condemnation
    src_range: (usize, usize),
    lo: usize,
    hi: usize,
    expected: String,
    phase: AuditPhase,
    /// dispatch attempts burned (worker deaths/timeouts, not verdicts)
    attempts: usize,
    running_on: Option<WorkerId>,
    issued: Instant,
}

/// A collected shard result plus who produced it (`None` = journal
/// preload — no live worker to attribute or condemn).
struct Banked {
    worker: Option<WorkerId>,
    res: ShardResult,
}

fn with_log(e: Error, log: &[String]) -> Error {
    Error::msg(if log.is_empty() {
        e.to_string()
    } else {
        format!("{e}\nworker failure log:\n  {}", log.join("\n  "))
    })
}

/// One worker's final scorecard as a structured event.
fn post_mortem_event(w: WorkerId, h: &WorkerHealth) -> Event {
    Event::WorkerPostMortem {
        worker: w,
        state: h.quarantined.map_or("active", QuarantineReason::as_str).to_string(),
        completions: h.completions,
        failures: h.failures,
        timeouts: h.timeouts,
        audit_passes: h.audit_passes,
        audit_failures: h.audit_failures,
        mean_lease_secs: if h.completions == 0 { 0.0 } else { h.lease_secs.mean() },
        last_error: h.last_error.clone().unwrap_or_default(),
    }
}

/// Deterministic per-(range, occurrence) audit substream key — the same
/// mixing idea as [`chaos`]'s fault keying, in the opposite role: this
/// stream decides *checks*, not faults, and is worker/timing-independent
/// so a given banked range draws the same audit verdict under any
/// scheduling interleaving.
fn audit_key(lo: usize, hi: usize, occurrence: u64) -> u64 {
    let mut x = (lo as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (hi as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ occurrence.wrapping_mul(0x1656_67B1_9E37_79F9);
    x ^= x >> 31;
    x
}

/// May worker `x` run this audit job? Never the audited source, and in
/// the tiebreak phase never the original challenger either.
fn audit_allows(t: &AuditTask, x: WorkerId) -> bool {
    match &t.phase {
        AuditPhase::Probe => x != t.src_worker,
        AuditPhase::Tiebreak { challenger, .. } => x != t.src_worker && x != *challenger,
    }
}

/// The dispatcher event loop's mutable state, factored out so the
/// poll/reap/audit/assign stages can be separate methods instead of one
/// monolithic loop body.
struct RunState<'a> {
    cfg: &'a DispatchConfig,
    sweep: &'a SweepConfig,
    n: usize,
    queue: WorkQueue,
    health: HealthTracker,
    report: DispatchReport,
    banked: Vec<Banked>,
    audits: BTreeMap<u64, AuditTask>,
    next_audit_id: u64,
    /// completions banked per range — the occurrence index keys the
    /// audit-sampling substream so duplicate covers draw independently
    bank_counts: BTreeMap<(usize, usize), u64>,
    journal: Option<Journal>,
    busy: Vec<Option<SlotJob>>,
}

impl RunState<'_> {
    fn err_with_log(&self, e: Error) -> Error {
        with_log(e, &self.report.failure_log)
    }

    /// `queue.fail` plus retry bookkeeping.
    fn fail_lease(&mut self, id: LeaseId) -> Result<()> {
        let (lease, requeued) =
            self.queue.fail(id).map_err(|e| with_log(e, &self.report.failure_log))?;
        self.report.retried += u64::from(requeued);
        if requeued {
            self.cfg.obs.emit(Event::LeaseRetried {
                lo: lease.lo,
                hi: lease.hi,
                attempt: self.queue.retry_count(lease.lo, lease.hi),
            });
        }
        Ok(())
    }

    fn note_quarantine(&mut self, w: WorkerId, q: Option<QuarantineReason>) {
        if let Some(reason) = q {
            self.report.quarantined.push((w, reason.as_str().to_string()));
            // the triggering failure was logged just before the health
            // layer tripped, so the last log entry is the detail
            self.cfg.obs.emit(Event::WorkerQuarantined {
                worker: w,
                reason: reason.as_str().to_string(),
                detail: self.report.failure_log.last().cloned().unwrap_or_default(),
            });
            self.report
                .failure_log
                .push(format!("worker {w} quarantined ({})", reason.as_str()));
        }
    }

    /// The per-worker post-mortem table as structured events (the
    /// machine-readable twin of [`HealthTracker::post_mortem`] — with a
    /// JSON sink configured, `--log-format json` turns the health table
    /// into parseable records), followed by the dispatch-done marker.
    /// Emitted on success and on both loud-failure paths, then flushed
    /// so a JSONL trace is complete even when the process aborts next.
    fn emit_post_mortem(&self, ok: bool, started: Instant) {
        crate::metrics::gauge("queue_done_trials").set(self.queue.done_trials() as f64);
        if !self.cfg.obs.enabled() {
            return;
        }
        for w in 0..self.n {
            self.cfg.obs.emit(post_mortem_event(w, self.health.worker(w)));
        }
        self.cfg.obs.emit(Event::DispatchDone {
            completed: self.report.completed,
            retried: self.report.retried,
            elapsed_secs: started.elapsed().as_secs_f64(),
            ok,
        });
        self.cfg.obs.flush();
    }

    /// Stage 1: poll every busy slot (lease and audit jobs alike).
    fn poll_workers(&mut self, transport: &mut dyn WorkerTransport) -> Result<()> {
        // redundancy computed once per tick — a lease turning redundant
        // mid-sweep is caught next tick
        let redundant = self.queue.redundant();
        for w in 0..self.n {
            match self.busy[w] {
                None => {}
                Some(SlotJob::Lease(id)) => self.poll_lease(transport, w, id, &redundant)?,
                Some(SlotJob::Audit(aid)) => self.poll_audit(transport, w, aid),
            }
        }
        Ok(())
    }

    fn poll_lease(
        &mut self,
        transport: &mut dyn WorkerTransport,
        w: WorkerId,
        id: LeaseId,
        redundant: &[LeaseId],
    ) -> Result<()> {
        match transport.poll(w) {
            WorkerPoll::Running => {
                // speculation loser: a duplicate already finished this
                // range
                if redundant.contains(&id) {
                    transport.kill(w);
                    self.queue.cancel(id);
                    self.busy[w] = None;
                    self.report.cancelled += 1;
                    self.cfg.obs.emit(Event::LeaseCancelled { lease: id, worker: w });
                }
            }
            WorkerPoll::Done => {
                self.busy[w] = None;
                let lease = self.queue.get(id).cloned().expect("busy lease is active");
                match transport.collect(w).and_then(|r| {
                    validate_result(r, self.sweep, lease.lo, lease.hi, self.cfg.stats_only)
                }) {
                    Ok(res) => {
                        self.queue.complete(id)?;
                        self.health.record_completion(w, lease.issued.elapsed());
                        self.report.completed += 1;
                        self.report.per_worker_completed[w] += 1;
                        self.cfg.obs.emit(Event::LeaseCompleted {
                            lease: id,
                            worker: w,
                            lo: lease.lo,
                            hi: lease.hi,
                            secs: lease.issued.elapsed().as_secs_f64(),
                            duplicate: lease.speculative,
                        });
                        self.bank(res, w);
                    }
                    Err(e) => {
                        let msg = format!(
                            "worker {w} lease [{}, {}): bad result: {e}",
                            lease.lo, lease.hi
                        );
                        self.report.failure_log.push(msg.clone());
                        self.cfg.obs.emit(Event::LeaseFailed {
                            lease: id,
                            worker: w,
                            lo: lease.lo,
                            hi: lease.hi,
                            error: msg.clone(),
                        });
                        let q = self.health.record_failure(w, Instant::now(), &msg);
                        self.note_quarantine(w, q);
                        self.fail_lease(id)?;
                    }
                }
            }
            WorkerPoll::Failed(msg) => {
                self.busy[w] = None;
                let (lo, hi) = self.queue.get(id).map(|l| (l.lo, l.hi)).unwrap_or((0, 0));
                self.report.failure_log.push(msg.clone());
                self.cfg.obs.emit(Event::LeaseFailed {
                    lease: id,
                    worker: w,
                    lo,
                    hi,
                    error: msg.clone(),
                });
                let q = self.health.record_failure(w, Instant::now(), &msg);
                self.note_quarantine(w, q);
                self.fail_lease(id)?;
            }
            WorkerPoll::Idle => {
                self.busy[w] = None;
                let (lo, hi) = self.queue.get(id).map(|l| (l.lo, l.hi)).unwrap_or((0, 0));
                let msg = format!(
                    "worker {w} lost its job for lease {id} (transport reported idle)"
                );
                self.report.failure_log.push(msg.clone());
                self.cfg.obs.emit(Event::LeaseFailed {
                    lease: id,
                    worker: w,
                    lo,
                    hi,
                    error: msg.clone(),
                });
                let q = self.health.record_failure(w, Instant::now(), &msg);
                self.note_quarantine(w, q);
                self.fail_lease(id)?;
            }
        }
        Ok(())
    }

    /// A validated lease result enters the bank: checkpoint it, maybe
    /// sample an audit of it, then hold it for the merge.
    fn bank(&mut self, res: ShardResult, worker: WorkerId) {
        if let Some(j) = &mut self.journal {
            // checkpoint loss is not worth failing a healthy dispatch
            // over
            if let Err(e) = j.record(&res) {
                self.report.failure_log.push(format!(
                    "checkpoint of lease [{}, {}) failed: {e}",
                    res.lo, res.hi
                ));
            }
        }
        self.maybe_audit(&res, worker);
        self.banked.push(Banked { worker: Some(worker), res });
    }

    /// Deterministically decide whether (and on which sub-range) to
    /// audit this freshly banked result.
    fn maybe_audit(&mut self, res: &ShardResult, worker: WorkerId) {
        // stats-only manifests have no per-trial vector to slice and
        // byte-compare
        if self.cfg.audit_fraction <= 0.0 || res.stats_only {
            return;
        }
        // an audit needs a worker other than the source to ever run it
        if !(0..self.n).any(|x| x != worker && self.health.eligible(x)) {
            return;
        }
        let occurrence = {
            let c = self.bank_counts.entry((res.lo, res.hi)).or_insert(0);
            let occ = *c;
            *c += 1;
            occ
        };
        let mut rng =
            prng::substream(self.cfg.audit_seed, audit_key(res.lo, res.hi, occurrence));
        if rng.f64() >= self.cfg.audit_fraction {
            return;
        }
        // one chunk-aligned window of the banked range: cheap relative
        // to the lease, and a forger can't predict which window
        let chunk = self.sweep.chunk.max(1);
        let windows = (res.hi - res.lo).div_ceil(chunk);
        let pick = if windows > 1 { rng.below(windows) } else { 0 };
        let s_lo = res.lo + pick * chunk;
        let s_hi = (s_lo + chunk).min(res.hi);
        let expected = match res.slice(s_lo, s_hi) {
            Ok(s) => s.render(),
            Err(e) => {
                self.report.failure_log.push(format!(
                    "audit of [{}, {}) skipped: slice failed: {e}",
                    res.lo, res.hi
                ));
                return;
            }
        };
        let aid = self.next_audit_id;
        self.next_audit_id += 1;
        self.audits.insert(
            aid,
            AuditTask {
                src_worker: worker,
                src_range: (res.lo, res.hi),
                lo: s_lo,
                hi: s_hi,
                expected,
                phase: AuditPhase::Probe,
                attempts: 0,
                running_on: None,
                issued: Instant::now(),
            },
        );
    }

    fn poll_audit(&mut self, transport: &mut dyn WorkerTransport, w: WorkerId, aid: u64) {
        match transport.poll(w) {
            WorkerPoll::Running => {}
            WorkerPoll::Done => {
                self.busy[w] = None;
                let collected = transport.collect(w);
                self.resolve_audit(transport, aid, w, collected);
            }
            WorkerPoll::Failed(msg) => {
                self.busy[w] = None;
                self.report.failure_log.push(msg.clone());
                let q = self.health.record_failure(w, Instant::now(), &msg);
                self.note_quarantine(w, q);
                self.audit_attempt_failed(aid, &format!("auditor worker {w} died: {msg}"));
            }
            WorkerPoll::Idle => {
                self.busy[w] = None;
                let msg =
                    format!("worker {w} lost its audit job {aid} (transport reported idle)");
                self.report.failure_log.push(msg.clone());
                let q = self.health.record_failure(w, Instant::now(), &msg);
                self.note_quarantine(w, q);
                self.audit_attempt_failed(aid, &msg);
            }
        }
    }

    /// An audit job's dispatch attempt failed with no verdict (worker
    /// death, timeout, start failure). Bounded retries; on exhaustion
    /// the audit is dropped and the banked result stands.
    fn audit_attempt_failed(&mut self, aid: u64, why: &str) {
        let Some(task) = self.audits.get_mut(&aid) else { return };
        task.running_on = None;
        task.attempts += 1;
        if task.attempts >= AUDIT_MAX_ATTEMPTS {
            let (lo, hi) = task.src_range;
            let (s_lo, s_hi) = (task.lo, task.hi);
            self.audits.remove(&aid);
            self.cfg.obs.emit(Event::AuditDropped {
                lo: s_lo,
                hi: s_hi,
                reason: format!("abandoned after {AUDIT_MAX_ATTEMPTS} attempts: {why}"),
            });
            self.report.failure_log.push(format!(
                "audit of [{lo}, {hi}) abandoned after {AUDIT_MAX_ATTEMPTS} attempts ({why}) \
                 — giving the banked result the benefit of the doubt"
            ));
        }
    }

    /// An auditor delivered a manifest: compare bytes and judge.
    fn resolve_audit(
        &mut self,
        transport: &mut dyn WorkerTransport,
        aid: u64,
        auditor: WorkerId,
        collected: Result<ShardResult>,
    ) {
        let Some(mut task) = self.audits.remove(&aid) else { return };
        task.running_on = None;
        let bytes = match collected
            .and_then(|r| validate_result(r, self.sweep, task.lo, task.hi, false))
        {
            Ok(r) => r.render(),
            Err(e) => {
                // the audit *job* failed structurally — that's on the
                // auditor, not on the audited result
                let msg = format!(
                    "worker {auditor} audit of [{}, {}): bad result: {e}",
                    task.lo, task.hi
                );
                self.report.failure_log.push(msg.clone());
                let q = self.health.record_failure(auditor, Instant::now(), &msg);
                self.note_quarantine(auditor, q);
                self.audits.insert(aid, task);
                self.audit_attempt_failed(aid, &msg);
                return;
            }
        };
        match std::mem::replace(&mut task.phase, AuditPhase::Probe) {
            AuditPhase::Probe => {
                if bytes == task.expected {
                    self.health.record_audit_pass(task.src_worker);
                    self.report.audits_passed += 1;
                    self.cfg.obs.emit(Event::AuditPassed {
                        auditor,
                        lo: task.lo,
                        hi: task.hi,
                    });
                    return;
                }
                self.report.audit_mismatches += 1;
                self.cfg.obs.emit(Event::AuditFailed {
                    lo: task.lo,
                    hi: task.hi,
                    detail: format!(
                        "worker {} (banked) vs worker {auditor} (probe re-run)",
                        task.src_worker
                    ),
                });
                self.report.failure_log.push(format!(
                    "audit mismatch on [{}, {}): worker {} (banked) vs worker {auditor} \
                     (probe re-run)",
                    task.lo, task.hi, task.src_worker
                ));
                // someone forged bits — but which side? a third worker
                // arbitrates when one exists
                let src = task.src_worker;
                let has_third =
                    (0..self.n).any(|x| x != src && x != auditor && self.health.eligible(x));
                if has_third {
                    task.phase =
                        AuditPhase::Tiebreak { challenger: auditor, challenger_bytes: bytes };
                    task.attempts = 0;
                    self.audits.insert(aid, task);
                } else {
                    // degenerate pool: condemn both sides — bit-exactness
                    // beats progress when the forger can't be identified
                    self.condemn(transport, src, "audit mismatch with no tiebreaker available");
                    self.condemn(
                        transport,
                        auditor,
                        "audit mismatch with no tiebreaker available",
                    );
                }
            }
            AuditPhase::Tiebreak { challenger, challenger_bytes } => {
                if bytes == task.expected {
                    // the arbiter sides with the bank: the challenger
                    // forged its probe
                    self.health.record_audit_pass(task.src_worker);
                    self.report.audits_passed += 1;
                    self.cfg.obs.emit(Event::AuditPassed {
                        auditor,
                        lo: task.lo,
                        hi: task.hi,
                    });
                    self.condemn(transport, challenger, "tiebreak contradicted its probe re-run");
                } else if bytes == challenger_bytes {
                    self.condemn(
                        transport,
                        task.src_worker,
                        "tiebreak confirmed the probe mismatch: banked result is forged",
                    );
                } else {
                    self.condemn(transport, task.src_worker, "three-way audit disagreement");
                    self.condemn(transport, challenger, "three-way audit disagreement");
                }
            }
        }
    }

    /// The audit found `w` guilty: strike everything it banked, count
    /// the offense, and past the quarantine threshold remove it from
    /// the pool — killing and re-routing whatever it was running.
    fn condemn(&mut self, transport: &mut dyn WorkerTransport, w: WorkerId, why: &str) {
        self.report
            .failure_log
            .push(format!("worker {w} condemned by result audit: {why}"));
        self.cfg.obs.emit(Event::Note {
            text: format!("worker {w} condemned by result audit: {why}"),
        });
        let q = self.health.record_audit_failure(w, why);
        self.invalidate_banked(transport, w);
        if q.is_some() {
            self.note_quarantine(w, q);
            if let Some(job) = self.busy[w].take() {
                transport.kill(w);
                match job {
                    SlotJob::Lease(id) => {
                        // reopen, not fail: quarantine shouldn't charge
                        // the range's retry budget
                        if let Some(lease) = self.queue.cancel(id) {
                            self.queue.reopen(lease.lo, lease.hi);
                        }
                    }
                    SlotJob::Audit(aid) => {
                        self.audit_attempt_failed(
                            aid,
                            &format!("auditor worker {w} was quarantined mid-run"),
                        );
                    }
                }
            }
        }
    }

    /// Remove every banked contribution of `w` from the merge set and
    /// re-queue the ranges — without charging the per-range retry
    /// budget, because honest progress shouldn't pay for an adversary's
    /// forgeries. Journal entries are retracted so an interrupted
    /// launch cannot resume from a forged manifest, and in-flight
    /// audits *of* `w`'s results become moot.
    fn invalidate_banked(&mut self, transport: &mut dyn WorkerTransport, w: WorkerId) {
        let banked = std::mem::take(&mut self.banked);
        for b in banked {
            if b.worker != Some(w) {
                self.banked.push(b);
                continue;
            }
            let (lo, hi) = (b.res.lo, b.res.hi);
            self.report.invalidated_ranges += 1;
            self.cfg.obs.emit(Event::RangeInvalidated { worker: w, lo, hi });
            self.queue.reopen(lo, hi);
            if let Some(j) = &mut self.journal {
                if let Err(e) = j.invalidate(lo, hi) {
                    self.report
                        .failure_log
                        .push(format!("journal retraction of [{lo}, {hi}) failed: {e}"));
                }
            }
            self.report.failure_log.push(format!(
                "invalidated banked range [{lo}, {hi}) from worker {w} — re-queued for \
                 recomputation"
            ));
        }
        let moot: Vec<u64> = self
            .audits
            .iter()
            .filter(|(_, t)| t.src_worker == w)
            .map(|(aid, _)| *aid)
            .collect();
        for aid in moot {
            let task = self.audits.remove(&aid).expect("listed audit exists");
            if let Some(x) = task.running_on {
                transport.kill(x);
                self.busy[x] = None;
            }
        }
    }

    /// Stage 2: reap lease and audit jobs past their length-scaled
    /// deadline (`base + per_trial * range_len`).
    fn reap_expired(&mut self, transport: &mut dyn WorkerTransport, now: Instant) -> Result<()> {
        let base = self.cfg.lease_timeout;
        let per = self.cfg.lease_timeout_per_trial;
        for id in self.queue.expired(base, per) {
            let lease = self.queue.get(id).cloned().expect("expired lease is active");
            transport.kill(lease.worker);
            self.busy[lease.worker] = None;
            self.report.timeouts += 1;
            self.cfg.obs.emit(Event::LeaseReaped {
                lease: id,
                worker: lease.worker,
                lo: lease.lo,
                hi: lease.hi,
                secs: lease.issued.elapsed().as_secs_f64(),
                cause: "deadline".to_string(),
            });
            let msg = format!(
                "worker {} lease [{}, {}): deadline exceeded, re-enqueueing",
                lease.worker, lease.lo, lease.hi
            );
            self.report.failure_log.push(msg.clone());
            let q = self.health.record_timeout(lease.worker, now, &msg);
            self.note_quarantine(lease.worker, q);
            self.fail_lease(id)?;
        }
        let overdue: Vec<(u64, WorkerId)> = self
            .audits
            .iter()
            .filter_map(|(aid, t)| {
                let len = u32::try_from(t.hi - t.lo).unwrap_or(u32::MAX);
                match t.running_on {
                    Some(x) if t.issued.elapsed() > base + per.saturating_mul(len) => {
                        Some((*aid, x))
                    }
                    _ => None,
                }
            })
            .collect();
        for (aid, x) in overdue {
            transport.kill(x);
            self.busy[x] = None;
            self.report.timeouts += 1;
            self.cfg.obs.emit(Event::Note {
                text: format!("worker {x} audit job {aid}: deadline exceeded"),
            });
            let msg = format!("worker {x} audit job {aid}: deadline exceeded");
            self.report.failure_log.push(msg.clone());
            let q = self.health.record_timeout(x, now, &msg);
            self.note_quarantine(x, q);
            self.audit_attempt_failed(aid, &msg);
        }
        Ok(())
    }

    /// Stage 3: an audit whose remaining eligible pool can never run it
    /// (all allowed workers quarantined) must not deadlock termination.
    fn drop_unassignable_audits(&mut self) {
        let doomed: Vec<u64> = self
            .audits
            .iter()
            .filter(|(_, t)| {
                t.running_on.is_none()
                    && !(0..self.n).any(|x| audit_allows(t, x) && self.health.eligible(x))
            })
            .map(|(aid, _)| *aid)
            .collect();
        for aid in doomed {
            let t = self.audits.remove(&aid).expect("listed audit exists");
            self.cfg.obs.emit(Event::AuditDropped {
                lo: t.lo,
                hi: t.hi,
                reason: "no eligible worker left to run it".to_string(),
            });
            self.report.failure_log.push(format!(
                "audit of [{}, {}) dropped: no eligible worker left to run it",
                t.lo, t.hi
            ));
        }
    }

    /// Hand the oldest assignable audit job to idle worker `w`. Returns
    /// whether `w` was consumed by an audit this round.
    fn try_assign_audit(
        &mut self,
        transport: &mut dyn WorkerTransport,
        w: WorkerId,
        now: Instant,
    ) -> bool {
        let Some(aid) = self
            .audits
            .iter()
            .find(|(_, t)| t.running_on.is_none() && audit_allows(t, w))
            .map(|(aid, _)| *aid)
        else {
            return false;
        };
        let task = self.audits.get_mut(&aid).expect("audit id just found");
        let job = WorkerJob {
            config: self.sweep.clone(),
            lo: task.lo,
            hi: task.hi,
            threads: self.cfg.threads_per_worker.max(1),
            stats_only: false,
            out_path: self
                .cfg
                .out_dir
                .join(format!("audit_{aid}_{}_{}.json", task.lo, task.hi)),
            delay_ms: 0,
        };
        self.report.audits_issued += 1;
        self.cfg.obs.emit(Event::AuditIssued {
            auditor: w,
            lo: task.lo,
            hi: task.hi,
            original: task.src_worker,
        });
        match transport.start(w, &job) {
            Ok(()) => {
                task.running_on = Some(w);
                task.issued = now;
                self.busy[w] = Some(SlotJob::Audit(aid));
            }
            Err(e) => {
                let msg = format!("worker {w} audit job {aid}: start failed: {e}");
                self.report.failure_log.push(msg.clone());
                let q = self.health.record_failure(w, now, &msg);
                self.note_quarantine(w, q);
                self.audit_attempt_failed(aid, &msg);
            }
        }
        true
    }

    /// Stage 4: hand audits, then leases, to idle available workers
    /// (quarantined and backing-off workers are skipped).
    fn assign(
        &mut self,
        transport: &mut dyn WorkerTransport,
        sim: &mut Option<DelaySampler<BernoulliStragglers>>,
        now: Instant,
    ) -> Result<()> {
        let delays: Option<Vec<Duration>> = if self.busy.iter().any(Option::is_none) {
            sim.as_mut().map(|s| s.sample_delays(self.n))
        } else {
            None
        };
        for w in 0..self.n {
            if self.busy[w].is_some() || !self.health.available(w, now) {
                continue;
            }
            // audits first: a pending verdict gates trust in banked work
            if self.try_assign_audit(transport, w, now) {
                continue;
            }
            let lease = match self.queue.lease(w) {
                Some(l) => l,
                None if self.cfg.speculate => match self.queue.speculative_lease(w) {
                    Some(l) => l,
                    None => continue,
                },
                None => continue,
            };
            let delay_ms = delays.as_ref().map(|d| d[w].as_millis() as u64).unwrap_or(0);
            let job = WorkerJob {
                config: self.sweep.clone(),
                lo: lease.lo,
                hi: lease.hi,
                threads: self.cfg.threads_per_worker.max(1),
                stats_only: self.cfg.stats_only,
                out_path: self
                    .cfg
                    .out_dir
                    .join(format!("lease_{}_{}_{}.json", lease.id, lease.lo, lease.hi)),
                delay_ms,
            };
            self.report.leases_issued += 1;
            self.report.speculative_issued += u64::from(lease.speculative);
            self.cfg.obs.emit(Event::LeaseIssued {
                lease: lease.id,
                worker: w,
                lo: lease.lo,
                hi: lease.hi,
                speculative: lease.speculative,
            });
            match transport.start(w, &job) {
                Ok(()) => self.busy[w] = Some(SlotJob::Lease(lease.id)),
                Err(e) => {
                    let msg = format!(
                        "worker {w} lease [{}, {}): start failed: {e}",
                        lease.lo, lease.hi
                    );
                    self.report.failure_log.push(msg.clone());
                    self.cfg.obs.emit(Event::LeaseFailed {
                        lease: lease.id,
                        worker: w,
                        lo: lease.lo,
                        hi: lease.hi,
                        error: msg.clone(),
                    });
                    let q = self.health.record_failure(w, now, &msg);
                    self.note_quarantine(w, q);
                    self.fail_lease(lease.id)?;
                }
            }
        }
        Ok(())
    }
}

/// A collected manifest must be exactly the requested range of the
/// requested sweep, and its summary stats must refold bit-for-bit from
/// its per-trial values — anything else is treated as a worker failure
/// (and the range re-leased), never silently merged.
fn validate_result(
    res: ShardResult,
    sweep: &SweepConfig,
    lo: usize,
    hi: usize,
    stats_only: bool,
) -> Result<ShardResult> {
    if res.config != *sweep {
        return Err(Error::msg("worker manifest config differs from the dispatched sweep"));
    }
    if (res.lo, res.hi) != (lo, hi) {
        return Err(Error::msg(format!(
            "worker manifest covers [{}, {}), lease was [{lo}, {hi})",
            res.lo, res.hi
        )));
    }
    if res.stats_only != stats_only {
        return Err(Error::msg("worker manifest stats-only mode differs from the dispatch"));
    }
    if !stats_only {
        if res.values.len() != hi - lo {
            return Err(Error::msg(format!(
                "worker manifest carries {} value(s) for a {}-trial range",
                res.values.len(),
                hi - lo
            )));
        }
        // a manifest whose summary disagrees with its own per-trial
        // vector is corrupt (truncated, spliced or hand-edited) even
        // when each half looks sane on its own
        let refold = Stats::from_values(&res.values);
        let same = refold.count() == res.stats.count()
            && refold.mean().to_bits() == res.stats.mean().to_bits()
            && refold.m2().to_bits() == res.stats.m2().to_bits()
            && refold.min().to_bits() == res.stats.min().to_bits()
            && refold.max().to_bits() == res.stats.max().to_bits();
        if !same {
            return Err(Error::msg(
                "worker manifest stats do not refold from its per-trial values",
            ));
        }
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::shard::SweepKind;
    use std::collections::BTreeMap;

    /// Per-worker behavior script for the in-process mock transport.
    #[derive(Clone, Default)]
    struct WorkerScript {
        /// report Failed for this many jobs before behaving
        fail_first: usize,
        /// hang (Running forever, until killed) for this many jobs
        hang_first: usize,
        /// healthy jobs stay Running for this many polls before Done
        done_after_polls: usize,
    }

    enum SlotState {
        Failing,
        Hung,
        Working { polls_left: usize, result: ShardResult },
        Done { result: ShardResult },
    }

    /// In-process transport: computes leased ranges via
    /// `shard::run_range` but exposes them through the same poll-based
    /// interface as a real process pool, with scripted faults.
    struct Scripted {
        scripts: Vec<WorkerScript>,
        slots: Vec<Option<SlotState>>,
    }

    impl Scripted {
        fn new(scripts: Vec<WorkerScript>) -> Self {
            let slots = scripts.iter().map(|_| None).collect();
            Self { scripts, slots }
        }
    }

    impl WorkerTransport for Scripted {
        fn n_workers(&self) -> usize {
            self.scripts.len()
        }

        fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()> {
            assert!(self.slots[worker].is_none(), "worker {worker} double-started");
            let script = &mut self.scripts[worker];
            let state = if script.fail_first > 0 {
                script.fail_first -= 1;
                SlotState::Failing
            } else if script.hang_first > 0 {
                script.hang_first -= 1;
                SlotState::Hung
            } else {
                let mut result = shard::run_range(&job.config, job.threads, job.lo, job.hi)?;
                if job.stats_only {
                    result = result.into_stats_only();
                }
                SlotState::Working { polls_left: script.done_after_polls, result }
            };
            self.slots[worker] = Some(state);
            Ok(())
        }

        fn poll(&mut self, worker: WorkerId) -> WorkerPoll {
            match self.slots[worker].take() {
                None => WorkerPoll::Idle,
                Some(SlotState::Failing) => {
                    WorkerPoll::Failed(format!("worker {worker}: scripted death"))
                }
                Some(SlotState::Hung) => {
                    self.slots[worker] = Some(SlotState::Hung);
                    WorkerPoll::Running
                }
                Some(SlotState::Working { polls_left, result }) => {
                    if polls_left == 0 {
                        self.slots[worker] = Some(SlotState::Done { result });
                        WorkerPoll::Done
                    } else {
                        self.slots[worker] =
                            Some(SlotState::Working { polls_left: polls_left - 1, result });
                        WorkerPoll::Running
                    }
                }
                Some(SlotState::Done { result }) => {
                    self.slots[worker] = Some(SlotState::Done { result });
                    WorkerPoll::Done
                }
            }
        }

        fn kill(&mut self, worker: WorkerId) {
            self.slots[worker] = None;
        }

        fn collect(&mut self, worker: WorkerId) -> Result<ShardResult> {
            match self.slots[worker].take() {
                Some(SlotState::Done { result }) => Ok(result),
                _ => Err(Error::msg(format!("worker {worker}: nothing to collect"))),
            }
        }
    }

    fn sweep_cfg(trials: usize) -> SweepConfig {
        SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:12,3".into(),
            decoder: "optimal".into(),
            p: 0.25,
            seed: 11,
            trials,
            chunk: 8,
            params: BTreeMap::new(),
        }
    }

    fn fast_dispatch() -> DispatchConfig {
        DispatchConfig {
            grain: 8,
            poll_interval: Duration::from_millis(1),
            lease_timeout: Duration::from_secs(30),
            out_dir: std::env::temp_dir()
                .join(format!("gcod_dispatch_test_{}", std::process::id())),
            ..DispatchConfig::default()
        }
    }

    #[test]
    fn healthy_pool_matches_single_process_bits() {
        let c = sweep_cfg(60);
        let single = shard::run_full(&c, 2).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 3]);
        let out = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "merged JSON bytes");
        assert!(out.report.leases_issued >= 3, "{}", out.report.summary());
        // at least one completion per range (speculation may add more)
        assert!(out.report.completed as usize >= out.merged.config.trials.div_ceil(8));
    }

    #[test]
    fn worker_deaths_requeue_and_stay_bit_exact() {
        let c = sweep_cfg(48);
        let single = shard::run_full(&c, 1).unwrap();
        let scripts = vec![
            WorkerScript { fail_first: 2, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let out = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(out.report.retried >= 2, "{}", out.report.summary());
        assert!(!out.report.failure_log.is_empty());
    }

    #[test]
    fn hung_worker_hits_deadline_and_range_redispatches() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        let scripts = vec![
            WorkerScript { hang_first: 1, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig {
            lease_timeout: Duration::from_millis(40),
            speculate: false, // force the timeout path to do the rescue
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(out.report.timeouts >= 1, "{}", out.report.summary());
    }

    #[test]
    fn speculative_duplicates_dedup_before_merge() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        // worker 0 is slow (extra poll) so its first range drains the
        // queue while still running; idle worker 1 speculates on it and
        // both results arrive — a genuine duplicate cover
        let scripts = vec![
            WorkerScript { done_after_polls: 1, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig { grain: 16, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        assert!(
            out.report.speculative_issued >= 1,
            "expected speculation: {}",
            out.report.summary()
        );
        assert!(
            out.report.duplicates_dropped >= 1 || out.report.cancelled >= 1,
            "expected a deduped duplicate or a cancelled loser: {}",
            out.report.summary()
        );
    }

    /// Adaptive grain is pure scheduling: shrinking tail leases must
    /// leave the merged JSON byte-identical to the single-process run,
    /// with or without worker faults in the mix.
    #[test]
    fn adaptive_grain_matches_single_process_bits() {
        let c = sweep_cfg(96);
        let single = shard::run_full(&c, 2).unwrap();
        // healthy pool
        let mut t = Scripted::new(vec![WorkerScript::default(); 3]);
        let dcfg = DispatchConfig {
            grain: 32,
            adaptive_grain: true,
            min_grain: 8,
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg.clone()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "adaptive healthy merged JSON bytes");
        // adaptive carving hands out more, smaller leases than the
        // fixed 96/32 = 3-range split would
        assert!(out.report.leases_issued > 3, "{}", out.report.summary());
        // with a faulty worker: failed ranges re-lease whole and the
        // bits still match
        let scripts = vec![
            WorkerScript { fail_first: 2, ..WorkerScript::default() },
            WorkerScript::default(),
        ];
        let mut t = Scripted::new(scripts);
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "adaptive faulted merged JSON bytes");
        assert!(out.report.retried >= 2, "{}", out.report.summary());
    }

    /// Checkpoint/resume on the deterministic scripted transport: a
    /// first dispatch dies of retry exhaustion after banking some
    /// leases in its journal; the resumed dispatch recomputes only the
    /// uncovered remainder and the merged JSON is byte-identical to an
    /// uninterrupted single-process run.
    #[test]
    fn journaled_dispatch_resumes_bit_exact_after_failure() {
        let c = sweep_cfg(64);
        let single = shard::run_full(&c, 1).unwrap();
        let jdir = std::env::temp_dir()
            .join(format!("gcod_journal_test_{}", std::process::id()));
        std::fs::create_dir_all(&jdir).unwrap();
        let jpath = jdir.join("sweep.journal");

        // phase 1: worker 0 is healthy, worker 1 fails forever — with a
        // tiny retry budget the dispatch dies, but worker 0's completed
        // leases are checkpointed
        let scripts = vec![
            WorkerScript { done_after_polls: 1, ..WorkerScript::default() },
            WorkerScript { fail_first: usize::MAX, ..WorkerScript::default() },
        ];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig {
            max_retries: 1,
            speculate: false,
            journal: Some(jpath.clone()),
            ..fast_dispatch()
        };
        let err = Dispatcher::new(dcfg.clone()).run(&c, &mut t).unwrap_err();
        assert!(format!("{err}").contains("giving up"), "{err}");
        assert!(jpath.is_file(), "failed dispatch must leave its journal behind");
        let text = std::fs::read_to_string(&jpath).unwrap();
        assert!(text.starts_with(journal::JOURNAL_HEADER), "{text}");
        let banked = text.lines().filter(|l| l.starts_with("done ")).count();
        assert!(banked >= 1, "no leases were checkpointed:\n{text}");

        // phase 2: resume with a healthy pool; only the gaps recompute
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig { resume: true, max_retries: 3, ..dcfg };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "resumed merged JSON bytes");
        // the resumed run dispatched fewer leases than full coverage
        // would need (8 ranges of grain 8): the banked ones were free
        assert!(
            (out.report.completed as usize) + banked >= 8,
            "coverage accounting: completed={} banked={banked}",
            out.report.completed
        );
        assert!(
            (out.report.completed as usize) <= 8 - banked + 1,
            "resume recomputed banked ranges: completed={} banked={banked} ({})",
            out.report.completed,
            out.report.summary()
        );
        // success removed the journal + sidecar manifests
        assert!(!jpath.is_file(), "journal must be cleaned up after a successful merge");
        assert!(!Journal::sidecar_dir(&jpath).exists());
        let _ = std::fs::remove_dir_all(&jdir);
    }

    /// Resuming a journal against a different sweep is refused, and a
    /// journal whose sidecar manifests were corrupted degrades to
    /// recomputation rather than bad merges.
    #[test]
    fn journal_rejects_mismatched_sweep_and_survives_corruption() {
        let c = sweep_cfg(32);
        let jdir = std::env::temp_dir()
            .join(format!("gcod_journal_guard_{}", std::process::id()));
        std::fs::create_dir_all(&jdir).unwrap();
        let jpath = jdir.join("guard.journal");

        // healthy journaled run that we interrupt artificially: run to
        // completion but keep the journal by copying it mid-flight is
        // racy — instead, synthesize the journal from a real partial run
        let mut j = Journal::open(&jpath, &c, false, false).unwrap();
        let part = shard::run_range(&c, 1, 0, 16).unwrap();
        j.record(&part).unwrap();
        drop(j);
        assert!(jpath.is_file());

        // different seed = different sweep: hard refusal
        let mut other = sweep_cfg(32);
        other.seed = 999;
        let err = Journal::open(&jpath, &other, false, true).unwrap_err();
        assert!(format!("{err}").contains("different sweep"), "{err}");

        // corrupt the banked manifest: the entry is dropped with a note
        // and the range recomputes
        let manifest = Journal::sidecar_dir(&jpath).join("done_0_16.json");
        std::fs::write(&manifest, "not json").unwrap();
        let mut j = Journal::open(&jpath, &c, false, true).unwrap();
        assert!(j.take_preloaded().is_empty());
        assert_eq!(j.notes.len(), 1, "{:?}", j.notes);
        drop(j);

        // resuming a journal that does not exist is a hard error (a
        // typo'd path must not silently recompute everything) ...
        let err = Journal::open(&jdir.join("nope.journal"), &c, false, true).unwrap_err();
        assert!(format!("{err}").contains("not found"), "{err}");
        // ... and a fresh (non-resume) open refuses to clobber an
        // existing checkpoint
        let err = Journal::open(&jpath, &c, false, false).unwrap_err();
        assert!(format!("{err}").contains("already exists"), "{err}");

        // and a full resumed dispatch over the corrupted journal still
        // produces the exact single-process bytes
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig {
            journal: Some(jpath.clone()),
            resume: true,
            ..fast_dispatch()
        };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render());
        let _ = std::fs::remove_dir_all(&jdir);
    }

    /// Bad kernel params die in the dispatcher, immediately — never by
    /// burning the retry budget on workers that can only fail.
    #[test]
    fn dispatch_rejects_invalid_params_before_spawning() {
        let mut c = sweep_cfg(16);
        c.params.insert("precond".into(), "maybe".into());
        let mut t = Scripted::new(vec![WorkerScript::default()]);
        let err = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("precond"), "{msg}");
        assert!(!msg.contains("giving up"), "param error burned the retry budget: {msg}");
    }

    #[test]
    fn retry_budget_exhaustion_fails_loudly() {
        let c = sweep_cfg(16);
        let scripts = vec![WorkerScript { fail_first: usize::MAX, ..WorkerScript::default() }];
        let mut t = Scripted::new(scripts);
        let dcfg = DispatchConfig { max_retries: 1, ..fast_dispatch() };
        let err = Dispatcher::new(dcfg).run(&c, &mut t).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("giving up"), "{msg}");
        assert!(msg.contains("failure log"), "{msg}");
    }

    #[test]
    fn stats_only_dispatch_uses_chan_contract() {
        let c = sweep_cfg(40);
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig { stats_only: true, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert!(out.merged.stats_only && out.merged.values.is_empty());
        assert_eq!(out.merged.stats.count(), 40);
        assert_eq!(out.merged.stats.min().to_bits(), single.stats.min().to_bits());
        assert_eq!(out.merged.stats.max().to_bits(), single.stats.max().to_bits());
        assert!((out.merged.stats.mean() - single.stats.mean()).abs() < 1e-12);
    }

    #[test]
    fn rejects_undispatchable_sweeps() {
        let mut t = Scripted::new(vec![WorkerScript::default()]);
        let mut c = sweep_cfg(0);
        let d = Dispatcher::new(fast_dispatch());
        assert!(d.run(&c, &mut t).is_err());
        c.trials = 8;
        c.sweep = SweepKind::Fig4Cluster;
        assert!(d.run(&c, &mut t).is_err());
        // a worker-less transport must error, not spin or divide by zero
        let mut empty = Scripted::new(vec![]);
        let err = d.run(&sweep_cfg(8), &mut empty).unwrap_err();
        assert!(format!("{err}").contains("no workers"), "{err}");
    }

    // -----------------------------------------------------------------
    // result audit + chaos + quarantine
    // -----------------------------------------------------------------

    /// Structural validation: a manifest whose stats don't refold from
    /// its per-trial values, or whose vector length disagrees with the
    /// range, is rejected before it can reach the bank.
    #[test]
    fn validate_result_rejects_inconsistent_manifests() {
        let c = sweep_cfg(16);
        let honest = shard::run_range(&c, 1, 0, 16).unwrap();
        assert!(validate_result(honest.clone(), &c, 0, 16, false).is_ok());

        // naive corruption: a value changed without refolding the stats
        let mut forged = honest.clone();
        forged.values[3] += 1.0;
        let err = validate_result(forged, &c, 0, 16, false).unwrap_err();
        assert!(format!("{err}").contains("refold"), "{err}");

        // short vector
        let mut short = honest.clone();
        short.values.pop();
        let err = validate_result(short, &c, 0, 16, false).unwrap_err();
        assert!(format!("{err}").contains("value(s)"), "{err}");

        // wrong range
        let err = validate_result(honest, &c, 0, 8, false).unwrap_err();
        assert!(format!("{err}").contains("lease was"), "{err}");
    }

    /// The flagship byzantine contract end-to-end: a pinned adversary
    /// whose forgeries are structurally self-consistent (refolded
    /// stats) is caught by the re-execution audit, condemned by
    /// tiebreak, quarantined, its banked work invalidated and
    /// recomputed — and the merged bytes still exactly match the
    /// single-process run.
    #[test]
    fn byzantine_worker_is_audited_quarantined_and_bits_stay_exact() {
        let c = sweep_cfg(48);
        let single = shard::run_full(&c, 1).unwrap();
        let profile = ChaosProfile { byzantine_worker: Some(1), ..ChaosProfile::none() };
        let mut t =
            ChaosTransport::new(Scripted::new(vec![WorkerScript::default(); 3]), 5, profile);
        let dcfg = DispatchConfig { audit_fraction: 1.0, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "byzantine merged JSON bytes");
        assert!(
            out.report.quarantined.iter().any(|(w, why)| *w == 1 && why == "byzantine"),
            "adversary not quarantined: {}",
            out.report.summary()
        );
        assert!(out.report.audit_mismatches >= 1, "{}", out.report.summary());
        assert!(out.report.invalidated_ranges >= 1, "{}", out.report.summary());
        assert!(
            out.report.worker_health[1].audit_failures >= 2,
            "scorecard missed the condemnations: {:?}",
            out.report.worker_health[1]
        );
        // the forgeries are in the failure log for the operator
        assert!(
            out.report.failure_log.iter().any(|l| l.contains("condemned by result audit")),
            "{:?}",
            out.report.failure_log
        );
    }

    /// Byzantine faults that are *not* self-consistent — wrong-range
    /// manifests, stale replays, truncated text — die in structural
    /// validation (no audit configured at all) and the range re-leases.
    #[test]
    fn structural_validation_catches_wrong_range_stale_and_truncated() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = ChaosTransport::new(
            Scripted::new(vec![WorkerScript::default(); 2]),
            0,
            ChaosProfile::none(),
        );
        t.preset(0, Fault::Truncate);
        t.preset(0, Fault::WrongRange);
        t.preset(0, Fault::StaleReplay);
        let out = Dispatcher::new(fast_dispatch()).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "merged JSON bytes");
        assert!(out.report.retried >= 2, "{}", out.report.summary());
        assert!(!out.report.failure_log.is_empty());
        assert!(out.report.quarantined.is_empty(), "{}", out.report.summary());
    }

    /// Honest pool under a 100% audit regime: every audit passes, no
    /// mismatch, no quarantine, bytes exact — auditing is pure overhead,
    /// never a behavior change.
    #[test]
    fn honest_pool_passes_full_audit_bit_exact() {
        let c = sweep_cfg(32);
        let single = shard::run_full(&c, 1).unwrap();
        let mut t = Scripted::new(vec![WorkerScript::default(); 2]);
        let dcfg = DispatchConfig { audit_fraction: 1.0, ..fast_dispatch() };
        let out = Dispatcher::new(dcfg).run(&c, &mut t).unwrap();
        assert_eq!(out.merged.render(), single.render(), "audited merged JSON bytes");
        assert!(out.report.audits_issued >= 1, "{}", out.report.summary());
        assert!(out.report.audits_passed >= 1, "{}", out.report.summary());
        assert_eq!(out.report.audit_mismatches, 0, "{}", out.report.summary());
        assert!(out.report.quarantined.is_empty());
        assert!(out.report.worker_health.iter().any(|h| h.audit_passes >= 1));
    }

    /// Degenerate pool: with 2 workers and one pinned adversary there is
    /// no tiebreaker, so a mismatch condemns both sides — and once the
    /// whole pool is quarantined the dispatch fails loudly with the
    /// per-worker post-mortem instead of spinning or merging bad bits.
    #[test]
    fn all_quarantined_pool_fails_with_post_mortem() {
        let c = sweep_cfg(32);
        let profile = ChaosProfile { byzantine_worker: Some(1), ..ChaosProfile::none() };
        let mut t =
            ChaosTransport::new(Scripted::new(vec![WorkerScript::default(); 2]), 3, profile);
        let dcfg = DispatchConfig {
            audit_fraction: 1.0,
            health: HealthConfig { quarantine_after: 1, ..HealthConfig::default() },
            ..fast_dispatch()
        };
        let err = Dispatcher::new(dcfg).run(&c, &mut t).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("every worker is quarantined"), "{msg}");
        assert!(msg.contains("post-mortem"), "{msg}");
        assert!(msg.contains("byzantine"), "{msg}");
    }

    /// Journal hardening: an `undo` retracts its `done` entry and a torn
    /// final line (append interrupted mid-write) is dropped with a note,
    /// never a parse error.
    #[test]
    fn journal_undo_and_torn_tail_recovery() {
        let c = sweep_cfg(32);
        let jdir = std::env::temp_dir()
            .join(format!("gcod_journal_torn_{}", std::process::id()));
        std::fs::create_dir_all(&jdir).unwrap();
        let jpath = jdir.join("torn.journal");

        let mut j = Journal::open(&jpath, &c, false, false).unwrap();
        j.record(&shard::run_range(&c, 1, 0, 16).unwrap()).unwrap();
        j.record(&shard::run_range(&c, 1, 16, 32).unwrap()).unwrap();
        j.invalidate(0, 16).unwrap();
        drop(j);
        // simulate a crash mid-append: a final line with no newline
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(&jpath)
                .unwrap();
            write!(f, "done 16 32 torn_garbage").unwrap();
        }

        let mut j = Journal::open(&jpath, &c, false, true).unwrap();
        let pre = j.take_preloaded();
        assert_eq!(
            pre.iter().map(|r| (r.lo, r.hi)).collect::<Vec<_>>(),
            vec![(16, 32)],
            "undo must retract [0, 16) and the torn tail must not resurrect anything"
        );
        assert!(j.notes.iter().any(|n| n.contains("torn")), "{:?}", j.notes);
        drop(j);
        let _ = std::fs::remove_dir_all(&jdir);
    }
}
