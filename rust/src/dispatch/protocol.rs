//! Wire protocol for multi-host dispatch: length-prefixed JSON frames.
//!
//! One frame = a 4-byte big-endian length followed by that many bytes
//! of UTF-8 JSON — a single [`Msg`]. The protocol is deliberately tiny
//! and debuggable (`nc` + eyeballs suffice): workers **register** with
//! a capability class, the coordinator **leases** trial ranges to them,
//! workers **heartbeat** while computing and return the finished shard
//! **manifest** verbatim (the same bytes `sweep-shard --out` would have
//! written, so the bit-exact merge contract crosses the wire
//! untouched), and either side says **goodbye**. Clients speak the same
//! framing: **submit** a [`JobSpec`], receive **submitted** /
//! **job-done** / **job-error**, or ask for **status**.
//!
//! Numbers that must round-trip exactly ride the same encodings as the
//! shard manifests: `u64` seeds as decimal strings (JSON numbers are
//! f64), floats as hex bit patterns (see [`crate::bench_util`]). The
//! manifest payload itself is embedded as an escaped JSON string and
//! re-parsed with the full structural validation in
//! [`ShardResult::parse`](crate::sweep::shard::ShardResult::parse) —
//! a byzantine worker gains nothing from the transport layer.

use crate::bench_util::{f64_from_hex_bits, f64_to_hex_bits, json_escape, json_f64_display};
use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::metrics::{self, Counter};
use crate::sweep::shard::{SweepConfig, SweepKind};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Bumped on any wire-incompatible change; registration carries it so
/// a version skew fails with a message instead of a parse error.
pub const PROTO_VERSION: u64 = 1;

/// Upper bound on one frame body. Shard manifests dominate frame size
/// (~21 bytes/trial full-fidelity); 1 GiB of manifest is far past the
/// point where `--stats-only` should be in use.
pub const MAX_FRAME: usize = 1 << 30;

// ---------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------

/// A leased trial range as it travels to a remote worker: everything in
/// [`WorkerJob`](super::transport::WorkerJob) except the coordinator's
/// local `out_path` (the worker picks its own scratch path and returns
/// the manifest *text*, never a filename).
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseSpec {
    pub config: SweepConfig,
    pub lo: usize,
    pub hi: usize,
    pub threads: usize,
    pub stats_only: bool,
    pub delay_ms: u64,
}

/// One sweep job as submitted by a client: the sweep identity plus the
/// dispatch knobs the coordinator should run it with (mirrors the
/// `sweep-launch` flag set; chaos fields drive the coordinator-side
/// [`ChaosTransport`](super::chaos::ChaosTransport) wrap).
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub config: SweepConfig,
    /// capability class this job may run on ("" = any registered worker)
    pub class: String,
    pub grain: usize,
    pub adaptive_grain: bool,
    pub min_grain: usize,
    pub threads_per_worker: usize,
    pub lease_timeout_ms: u64,
    pub lease_timeout_per_trial_ms: u64,
    pub max_retries: usize,
    pub stats_only: bool,
    pub audit_fraction: f64,
    pub chaos_seed: u64,
    /// [`ChaosProfile::parse`](super::chaos::ChaosProfile::parse) spec
    pub chaos_profile: String,
    /// chaos preset: kill this worker slot mid-lease (fault-drill jobs)
    pub kill_worker: Option<usize>,
    pub kill_after_ms: u64,
    /// client-chosen dedup token ("" = none): a resubmission carrying
    /// the same key returns the original job id instead of re-running
    pub idempotency_key: String,
}

impl JobSpec {
    /// `sweep-launch`'s defaults around a sweep identity.
    pub fn new(config: SweepConfig) -> Self {
        Self {
            config,
            class: String::new(),
            grain: 0,
            adaptive_grain: false,
            min_grain: 0,
            threads_per_worker: 1,
            lease_timeout_ms: 30_000,
            lease_timeout_per_trial_ms: 5,
            max_retries: 3,
            stats_only: false,
            audit_fraction: 0.0,
            chaos_seed: 0,
            chaos_profile: "none".into(),
            kill_worker: None,
            kill_after_ms: 50,
            idempotency_key: String::new(),
        }
    }
}

/// Everything that crosses a dispatch socket, worker side and client
/// side alike (the first frame a connection sends identifies its role).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker → coordinator, once, immediately after connect
    Register { class: String, threads: usize },
    /// worker → coordinator, periodic liveness while connected
    Heartbeat,
    /// coordinator → worker: run this range; `job` tags the reply
    Lease { job: u64, spec: LeaseSpec },
    /// coordinator → worker: abandon job `job` (lease reaped, chaos
    /// drill, speculation loser); the worker tears its subprocess down
    Kill { job: u64 },
    /// worker → coordinator: job finished; `text` is the shard manifest
    /// verbatim
    Manifest { job: u64, text: String },
    /// worker → coordinator: job died without a manifest
    JobFailed { job: u64, error: String },
    /// either direction: orderly shutdown of this connection
    Goodbye,
    /// client → coordinator: enqueue a sweep
    Submit { spec: Box<JobSpec> },
    /// coordinator → client: job accepted under this id
    Submitted { job: u64 },
    /// coordinator → client: merged result (byte-identical to a
    /// single-process run) plus the dispatch report summary
    JobDone { job: u64, summary: String, manifest: String },
    /// coordinator → client: the job failed after retries
    JobError { job: u64, error: String },
    /// client → coordinator: registry / queue / metrics snapshot
    Status,
    /// coordinator → client: rendered status tables
    StatusReport { text: String },
    /// client → coordinator: (re)attach to job `job` — a finished job
    /// streams its banked manifest, a live one replies when it lands
    Fetch { job: u64 },
}

impl Msg {
    pub fn render(&self) -> String {
        match self {
            Msg::Register { class, threads } => format!(
                "{{\"msg\": \"register\", \"proto\": {PROTO_VERSION}, \"class\": \"{}\", \
                 \"threads\": {threads}}}",
                json_escape(class)
            ),
            Msg::Heartbeat => "{\"msg\": \"heartbeat\"}".into(),
            Msg::Lease { job, spec } => format!(
                "{{\"msg\": \"lease\", \"job\": {job}, \"lo\": {}, \"hi\": {}, \
                 \"threads\": {}, \"stats_only\": {}, \"delay_ms\": {}, \"config\": {}}}",
                spec.lo,
                spec.hi,
                spec.threads,
                spec.stats_only,
                spec.delay_ms,
                render_config(&spec.config)
            ),
            Msg::Kill { job } => format!("{{\"msg\": \"kill\", \"job\": {job}}}"),
            Msg::Manifest { job, text } => format!(
                "{{\"msg\": \"manifest\", \"job\": {job}, \"text\": \"{}\"}}",
                json_escape(text)
            ),
            Msg::JobFailed { job, error } => format!(
                "{{\"msg\": \"job-failed\", \"job\": {job}, \"error\": \"{}\"}}",
                json_escape(error)
            ),
            Msg::Goodbye => "{\"msg\": \"goodbye\"}".into(),
            Msg::Submit { spec } => {
                format!("{{\"msg\": \"submit\", \"spec\": {}}}", render_job_spec(spec))
            }
            Msg::Submitted { job } => format!("{{\"msg\": \"submitted\", \"job\": {job}}}"),
            Msg::JobDone { job, summary, manifest } => format!(
                "{{\"msg\": \"job-done\", \"job\": {job}, \"summary\": \"{}\", \
                 \"manifest\": \"{}\"}}",
                json_escape(summary),
                json_escape(manifest)
            ),
            Msg::JobError { job, error } => format!(
                "{{\"msg\": \"job-error\", \"job\": {job}, \"error\": \"{}\"}}",
                json_escape(error)
            ),
            Msg::Status => "{\"msg\": \"status\"}".into(),
            Msg::StatusReport { text } => {
                format!("{{\"msg\": \"status-report\", \"text\": \"{}\"}}", json_escape(text))
            }
            Msg::Fetch { job } => format!("{{\"msg\": \"fetch\", \"job\": {job}}}"),
        }
    }

    pub fn parse(text: &str) -> Result<Msg> {
        let doc = Json::parse(text).map_err(|e| Error::msg(format!("protocol frame: {e}")))?;
        let kind = get_str(&doc, "msg")?;
        match kind.as_str() {
            "register" => {
                let proto = get_u64(&doc, "proto")?;
                if proto != PROTO_VERSION {
                    return Err(Error::msg(format!(
                        "protocol version skew: peer speaks v{proto}, this binary v{PROTO_VERSION}"
                    )));
                }
                Ok(Msg::Register {
                    class: get_str(&doc, "class")?,
                    threads: get_usize(&doc, "threads")?,
                })
            }
            "heartbeat" => Ok(Msg::Heartbeat),
            "lease" => Ok(Msg::Lease {
                job: get_u64(&doc, "job")?,
                spec: LeaseSpec {
                    config: parse_config(
                        doc.get("config").ok_or_else(|| Error::msg("lease: missing 'config'"))?,
                    )?,
                    lo: get_usize(&doc, "lo")?,
                    hi: get_usize(&doc, "hi")?,
                    threads: get_usize(&doc, "threads")?,
                    stats_only: get_bool(&doc, "stats_only")?,
                    delay_ms: get_u64(&doc, "delay_ms")?,
                },
            }),
            "kill" => Ok(Msg::Kill { job: get_u64(&doc, "job")? }),
            "manifest" => {
                Ok(Msg::Manifest { job: get_u64(&doc, "job")?, text: get_str(&doc, "text")? })
            }
            "job-failed" => {
                Ok(Msg::JobFailed { job: get_u64(&doc, "job")?, error: get_str(&doc, "error")? })
            }
            "goodbye" => Ok(Msg::Goodbye),
            "submit" => Ok(Msg::Submit {
                spec: Box::new(parse_job_spec(
                    doc.get("spec").ok_or_else(|| Error::msg("submit: missing 'spec'"))?,
                )?),
            }),
            "submitted" => Ok(Msg::Submitted { job: get_u64(&doc, "job")? }),
            "job-done" => Ok(Msg::JobDone {
                job: get_u64(&doc, "job")?,
                summary: get_str(&doc, "summary")?,
                manifest: get_str(&doc, "manifest")?,
            }),
            "job-error" => {
                Ok(Msg::JobError { job: get_u64(&doc, "job")?, error: get_str(&doc, "error")? })
            }
            "status" => Ok(Msg::Status),
            "status-report" => Ok(Msg::StatusReport { text: get_str(&doc, "text")? }),
            "fetch" => Ok(Msg::Fetch { job: get_u64(&doc, "job")? }),
            other => Err(Error::msg(format!("unknown protocol message '{other}'"))),
        }
    }
}

// ---------------------------------------------------------------------
// SweepConfig / JobSpec wire encodings
// ---------------------------------------------------------------------

fn render_config(c: &SweepConfig) -> String {
    let mut params = String::from("{");
    for (i, (k, v)) in c.params.iter().enumerate() {
        if i > 0 {
            params.push_str(", ");
        }
        params.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
    }
    params.push('}');
    format!(
        "{{\"chunk\": {}, \"decoder\": \"{}\", \"p\": \"{}\", \"p_bits\": \"{}\", \
         \"params\": {params}, \"scheme\": \"{}\", \"seed\": \"{}\", \"sweep\": \"{}\", \
         \"trials\": {}}}",
        c.chunk,
        json_escape(&c.decoder),
        json_f64_display(c.p),
        f64_to_hex_bits(c.p),
        json_escape(&c.scheme),
        c.seed,
        json_escape(c.sweep.as_str()),
        c.trials
    )
}

fn parse_config(j: &Json) -> Result<SweepConfig> {
    let mut params = BTreeMap::new();
    if let Some(Json::Obj(m)) = j.get("params") {
        for (k, v) in m {
            params.insert(
                k.clone(),
                v.as_str()
                    .ok_or_else(|| Error::msg(format!("param '{k}' is not a string")))?
                    .to_string(),
            );
        }
    }
    Ok(SweepConfig {
        sweep: SweepKind::parse(&get_str(j, "sweep")?)?,
        scheme: get_str(j, "scheme")?,
        decoder: get_str(j, "decoder")?,
        p: get_f64_bits(j, "p_bits")?,
        seed: get_u64_str(j, "seed")?,
        trials: get_usize(j, "trials")?,
        chunk: get_usize(j, "chunk")?,
        params,
    })
}

/// Single-line JSON encoding of a [`JobSpec`] — shared by the `submit`
/// frame and the coordinator's durable state journal, so a replayed
/// spec is bitwise what the client sent (floats ride hex bit patterns).
pub(crate) fn render_job_spec(s: &JobSpec) -> String {
    format!(
        "{{\"adaptive_grain\": {}, \"audit_fraction_bits\": \"{}\", \"chaos_profile\": \"{}\", \
         \"chaos_seed\": \"{}\", \"class\": \"{}\", \"config\": {}, \"grain\": {}, \
         \"idempotency_key\": \"{}\", \"kill_after_ms\": {}, \"kill_worker\": {}, \
         \"lease_timeout_ms\": {}, \"lease_timeout_per_trial_ms\": {}, \"max_retries\": {}, \
         \"min_grain\": {}, \"stats_only\": {}, \"threads_per_worker\": {}}}",
        s.adaptive_grain,
        f64_to_hex_bits(s.audit_fraction),
        json_escape(&s.chaos_profile),
        s.chaos_seed,
        json_escape(&s.class),
        render_config(&s.config),
        s.grain,
        json_escape(&s.idempotency_key),
        s.kill_after_ms,
        s.kill_worker.map_or("null".to_string(), |w| w.to_string()),
        s.lease_timeout_ms,
        s.lease_timeout_per_trial_ms,
        s.max_retries,
        s.min_grain,
        s.stats_only,
        s.threads_per_worker
    )
}

pub(crate) fn parse_job_spec(j: &Json) -> Result<JobSpec> {
    Ok(JobSpec {
        config: parse_config(
            j.get("config").ok_or_else(|| Error::msg("job spec: missing 'config'"))?,
        )?,
        class: get_str(j, "class")?,
        grain: get_usize(j, "grain")?,
        adaptive_grain: get_bool(j, "adaptive_grain")?,
        min_grain: get_usize(j, "min_grain")?,
        threads_per_worker: get_usize(j, "threads_per_worker")?,
        lease_timeout_ms: get_u64(j, "lease_timeout_ms")?,
        lease_timeout_per_trial_ms: get_u64(j, "lease_timeout_per_trial_ms")?,
        max_retries: get_usize(j, "max_retries")?,
        stats_only: get_bool(j, "stats_only")?,
        audit_fraction: get_f64_bits(j, "audit_fraction_bits")?,
        chaos_seed: get_u64_str(j, "chaos_seed")?,
        chaos_profile: get_str(j, "chaos_profile")?,
        kill_worker: match j.get("kill_worker") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_usize().ok_or_else(|| Error::msg("job spec: bad 'kill_worker'"))?,
            ),
        },
        kill_after_ms: get_u64(j, "kill_after_ms")?,
        // absent on pre-durability senders: treat as "no key"
        idempotency_key: j
            .get("idempotency_key")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
    })
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| Error::msg(format!("missing or non-string '{key}'")))
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::msg(format!("missing or non-integer '{key}'")))
}

/// Small u64s (job ids, timeouts) travel as JSON numbers — fine below
/// 2^53, which a per-connection job counter never approaches.
fn get_u64(j: &Json, key: &str) -> Result<u64> {
    get_usize(j, key).map(|x| x as u64)
}

/// Full-width u64s (seeds) travel as decimal strings.
fn get_u64_str(j: &Json, key: &str) -> Result<u64> {
    get_str(j, key)?
        .parse()
        .map_err(|e| Error::msg(format!("bad u64 '{key}': {e}")))
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| Error::msg(format!("missing or non-bool '{key}'")))
}

fn get_f64_bits(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_str)
        .and_then(f64_from_hex_bits)
        .ok_or_else(|| Error::msg(format!("missing or invalid hex-bits '{key}'")))
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// Total protocol bytes moved (both directions, length prefixes
/// included). Cached handle: one registry lookup per process, then a
/// plain relaxed atomic add per frame.
fn bytes_framed() -> &'static Counter {
    static C: OnceLock<Counter> = OnceLock::new();
    C.get_or_init(|| metrics::counter("bytes_framed_total"))
}

/// Write one frame: 4-byte big-endian length + UTF-8 JSON body.
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> Result<()> {
    let body = msg.render();
    let bytes = body.as_bytes();
    if bytes.len() > MAX_FRAME {
        return Err(Error::msg(format!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte protocol cap",
            bytes.len()
        )));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())
        .and_then(|()| w.write_all(bytes))
        .and_then(|()| w.flush())
        .map_err(|e| Error::msg(format!("send frame: {e}")))?;
    bytes_framed().add(4 + bytes.len() as u64);
    Ok(())
}

/// Incremental frame reassembly over a byte stream that arrives in
/// arbitrary pieces (non-blocking sockets). Feed bytes in, pop complete
/// messages out.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Raw buffered bytes, unparsed. The server peeks this to tell a
    /// framed peer from a stray HTTP client: "GET " read as a big-endian
    /// frame length is ~1.2 GB — past [`MAX_FRAME`] — so an HTTP request
    /// surfaces as a poisoned stream unless sniffed first.
    pub fn raw(&self) -> &[u8] {
        &self.buf
    }

    /// The next complete frame, parsed, or `None` if more bytes are
    /// needed. Call in a loop to drain back-to-back frames.
    pub fn next_msg(&mut self) -> Result<Option<Msg>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_be_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > MAX_FRAME {
            return Err(Error::msg(format!(
                "peer announced a {len}-byte frame (cap {MAX_FRAME}) — corrupt or hostile stream"
            )));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let body = String::from_utf8(self.buf[4..4 + len].to_vec())
            .map_err(|e| Error::msg(format!("frame is not UTF-8: {e}")))?;
        self.buf.drain(..4 + len);
        Msg::parse(&body).map(Some)
    }
}

/// One framed, non-blocking protocol connection: a [`TcpStream`] plus
/// reassembly state. Reads never block ([`Conn::poll_msgs`] drains what
/// the kernel has); writes spin on `WouldBlock` until the frame is out
/// (frames are small except manifests, and a manifest sender has
/// nothing better to do than finish sending it).
pub struct Conn {
    stream: TcpStream,
    frames: FrameBuf,
    peer: String,
    eof: bool,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Result<Self> {
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        stream.set_nodelay(true).map_err(|e| Error::msg(format!("set_nodelay: {e}")))?;
        stream
            .set_nonblocking(true)
            .map_err(|e| Error::msg(format!("set_nonblocking: {e}")))?;
        Ok(Self { stream, frames: FrameBuf::default(), peer, eof: false })
    }

    /// Peer address for log lines.
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// Whether the peer has closed its half of the connection (any
    /// already-buffered frames stay poppable).
    pub fn is_eof(&self) -> bool {
        self.eof
    }

    pub fn send(&mut self, msg: &Msg) -> Result<()> {
        let body = msg.render();
        let bytes = body.as_bytes();
        if bytes.len() > MAX_FRAME {
            return Err(Error::msg(format!(
                "frame of {} bytes exceeds the {MAX_FRAME}-byte protocol cap",
                bytes.len()
            )));
        }
        let mut framed = Vec::with_capacity(4 + bytes.len());
        framed.extend_from_slice(&(bytes.len() as u32).to_be_bytes());
        framed.extend_from_slice(bytes);
        self.send_raw(&framed)?;
        bytes_framed().add(framed.len() as u64);
        Ok(())
    }

    /// Write raw bytes, spinning on `WouldBlock` like [`Conn::send`].
    /// Used for the non-frame HTTP response on the `/metrics` path.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        let mut off = 0;
        while off < bytes.len() {
            match self.stream.write(&bytes[off..]) {
                Ok(0) => return Err(Error::msg(format!("{}: connection closed", self.peer))),
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(Error::msg(format!("{}: send: {e}", self.peer))),
            }
        }
        Ok(())
    }

    /// Does the buffered prefix look like an HTTP request rather than a
    /// frame? Checked by the server before treating a poisoned stream as
    /// hostile, so `curl http://coordinator/metrics` works on the same
    /// listener the framed protocol uses.
    pub fn looks_like_http(&self) -> bool {
        let raw = self.frames.raw();
        [b"GET " as &[u8], b"HEAD", b"POST"].iter().any(|m| raw.starts_with(m))
    }

    /// The HTTP request path, once the request line is fully buffered
    /// (`None` until then). Only meaningful after `looks_like_http`.
    pub fn http_request_path(&self) -> Option<String> {
        let raw = self.frames.raw();
        let line_end = raw.iter().position(|&b| b == b'\n')?;
        let line = String::from_utf8_lossy(&raw[..line_end]);
        let mut parts = line.split_whitespace();
        let _method = parts.next()?;
        parts.next().map(|p| p.to_string())
    }

    /// Drain every byte the kernel has buffered and return the complete
    /// messages in arrival order. Never blocks. A closed peer sets
    /// [`Conn::is_eof`] rather than erroring — whether that is a fault
    /// depends on whether work was outstanding, which is the caller's
    /// call.
    pub fn poll_msgs(&mut self) -> Result<Vec<Msg>> {
        let mut tmp = [0u8; 16 * 1024];
        while !self.eof {
            match self.stream.read(&mut tmp) {
                Ok(0) => self.eof = true,
                Ok(n) => {
                    bytes_framed().add(n as u64);
                    self.frames.feed(&tmp[..n]);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    self.eof = true;
                    return Err(Error::msg(format!("{}: recv: {e}", self.peer)));
                }
            }
        }
        let mut out = Vec::new();
        while let Some(m) = self.frames.next_msg()? {
            out.push(m);
        }
        Ok(out)
    }

    /// Block (politely, 1 ms naps) until one message arrives or the
    /// deadline passes. Handshakes and thin clients use this; the
    /// coordinator's hot path never does.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        let deadline = Instant::now() + timeout;
        loop {
            let mut msgs = self.poll_msgs()?;
            if !msgs.is_empty() {
                // frames after the first stay buffered for the next poll
                let first = msgs.remove(0);
                for m in msgs.into_iter().rev() {
                    self.requeue(m);
                }
                return Ok(Some(first));
            }
            if self.eof {
                return Err(Error::msg(format!("{}: connection closed", self.peer)));
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Push an already-popped message back to the front of the queue.
    fn requeue(&mut self, msg: Msg) {
        let body = msg.render();
        let mut framed = Vec::with_capacity(4 + body.len());
        framed.extend_from_slice(&(body.len() as u32).to_be_bytes());
        framed.extend_from_slice(body.as_bytes());
        framed.extend_from_slice(&self.frames.buf);
        self.frames.buf = framed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SweepConfig {
        let mut params = BTreeMap::new();
        params.insert("budget".into(), "3".into());
        SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:16,3".into(),
            decoder: "optimal".into(),
            p: 0.2,
            seed: u64::MAX - 7, // exercises the string encoding
            trials: 1000,
            chunk: 32,
            params,
        }
    }

    fn roundtrip(m: Msg) {
        let text = m.render();
        assert_eq!(Msg::parse(&text).unwrap(), m, "wire text: {text}");
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Msg::Register { class: "cpu-fast".into(), threads: 8 });
        roundtrip(Msg::Heartbeat);
        roundtrip(Msg::Lease {
            job: 42,
            spec: LeaseSpec {
                config: cfg(),
                lo: 96,
                hi: 128,
                threads: 2,
                stats_only: true,
                delay_ms: 7,
            },
        });
        roundtrip(Msg::Kill { job: 42 });
        roundtrip(Msg::Manifest { job: 42, text: "{\"kind\": \"x\"}\nline2 \\ \"q\"".into() });
        roundtrip(Msg::JobFailed { job: 3, error: "exit status 137".into() });
        roundtrip(Msg::Goodbye);
        let mut spec = JobSpec::new(cfg());
        spec.class = "any".into();
        spec.audit_fraction = 0.1; // not exactly representable: bits must survive
        spec.chaos_seed = 0xDEAD_BEEF_DEAD_BEEF;
        spec.kill_worker = Some(1);
        spec.idempotency_key = "client-42/retry \"x\"".into();
        roundtrip(Msg::Submit { spec: Box::new(spec) });
        roundtrip(Msg::Submitted { job: 9 });
        roundtrip(Msg::JobDone { job: 9, summary: "ok".into(), manifest: "{}".into() });
        roundtrip(Msg::JobError { job: 9, error: "every worker quarantined".into() });
        roundtrip(Msg::Status);
        roundtrip(Msg::StatusReport { text: "jobs: 0".into() });
        roundtrip(Msg::Fetch { job: 17 });
    }

    #[test]
    fn job_spec_without_idempotency_key_parses_as_no_key() {
        // a pre-durability sender omits the field entirely
        let spec = JobSpec::new(cfg());
        let rendered = render_job_spec(&spec);
        let stripped = rendered.replace("\"idempotency_key\": \"\", ", "");
        assert_ne!(rendered, stripped, "field not found to strip");
        let parsed = parse_job_spec(&Json::parse(&stripped).unwrap()).unwrap();
        assert_eq!(parsed, spec);
    }

    #[test]
    fn config_floats_roundtrip_bitwise() {
        let mut c = cfg();
        c.p = 0.1 + 0.2; // 0.30000000000000004
        let m = Msg::Lease {
            job: 1,
            spec: LeaseSpec {
                config: c.clone(),
                lo: 0,
                hi: 1,
                threads: 1,
                stats_only: false,
                delay_ms: 0,
            },
        };
        match Msg::parse(&m.render()).unwrap() {
            Msg::Lease { spec, .. } => assert_eq!(spec.config.p.to_bits(), c.p.to_bits()),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn framebuf_reassembles_split_and_coalesced_frames() {
        let a = Msg::Heartbeat;
        let b = Msg::Kill { job: 7 };
        let mut wire = Vec::new();
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();
        // feed byte-by-byte: every split point must work
        let mut fb = FrameBuf::default();
        let mut got = Vec::new();
        for byte in &wire {
            fb.feed(std::slice::from_ref(byte));
            while let Some(m) = fb.next_msg().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, vec![a.clone(), b.clone()]);
        // and coalesced in one read
        let mut fb = FrameBuf::default();
        fb.feed(&wire);
        assert_eq!(fb.next_msg().unwrap(), Some(a));
        assert_eq!(fb.next_msg().unwrap(), Some(b));
        assert_eq!(fb.next_msg().unwrap(), None);
    }

    #[test]
    fn framebuf_rejects_oversized_and_non_utf8_frames() {
        let mut fb = FrameBuf::default();
        fb.feed(&(MAX_FRAME as u32 + 1).to_be_bytes());
        assert!(fb.next_msg().is_err());
        let mut fb = FrameBuf::default();
        fb.feed(&2u32.to_be_bytes());
        fb.feed(&[0xFF, 0xFE]);
        assert!(fb.next_msg().is_err());
    }

    #[test]
    fn register_rejects_version_skew() {
        let text = "{\"msg\": \"register\", \"proto\": 999, \"class\": \"x\", \"threads\": 1}";
        let err = Msg::parse(text).unwrap_err().to_string();
        assert!(err.contains("version skew"), "{err}");
    }

    #[test]
    fn unknown_message_is_a_clear_error() {
        let err = Msg::parse("{\"msg\": \"warp-core\"}").unwrap_err().to_string();
        assert!(err.contains("warp-core"), "{err}");
    }

    #[test]
    fn http_prefix_is_sniffed_instead_of_framed() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Conn::new(server).unwrap();
        client.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        client.flush().unwrap();
        // "GET " as a big-endian frame length (~1.2 GB) exceeds
        // MAX_FRAME, so the framed path must error — and the sniffer
        // must still see the intact HTTP prefix afterwards.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match conn.poll_msgs() {
                Err(_) => break,
                Ok(msgs) => assert!(msgs.is_empty(), "HTTP bytes parsed as frames?"),
            }
            assert!(Instant::now() < deadline, "HTTP bytes never arrived");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(conn.looks_like_http());
        assert_eq!(conn.http_request_path().as_deref(), Some("/metrics"));
        conn.send_raw(b"HTTP/1.0 200 OK\r\n\r\nok").unwrap();
    }
}
