//! `gcod serve`: a persistent TCP job coordinator.
//!
//! One daemon, one port, three kinds of peer (the first frame a
//! connection sends picks its role — see [`super::protocol`]):
//!
//! * **Workers** (`gcod worker`) register with a capability class and
//!   wait in the machine registry. They survive across jobs: the server
//!   lends their connections to a [`TcpTransport`] for the duration of
//!   a job and reclaims the survivors afterwards.
//! * **Submitters** (`gcod submit`) enqueue a [`JobSpec`] — a sweep
//!   identity plus dispatch knobs, including an optional chaos plan for
//!   fault drills — and stream back the merged manifest, which is
//!   byte-identical to a single-process run of the same sweep.
//! * **Status clients** (`gcod status`) get a registry/queue/metrics
//!   snapshot and disconnect; `gcod fetch` clients (re)attach to a job
//!   by id and receive its result when (or as soon as) it exists.
//!
//! Jobs run one at a time through the existing [`Dispatcher`] — leases,
//! deadlines, retries, speculation, journals, audits, health tracking
//! and quarantine all apply to TCP workers exactly as to local
//! subprocesses, because the server composes the same pieces:
//! `Dispatcher` → [`ChaosTransport`] → [`TcpTransport`].
//!
//! With `--state-dir` the coordinator itself stops being a single point
//! of total loss: every admitted job is fsynced into the
//! [`StateStore`](super::store::StateStore) journal *before* the
//! `submitted` ack leaves the socket, every state transition and banked
//! manifest follows it, and a restarted coordinator replays the journal
//! — re-queueing unfinished jobs (which resume mid-sweep through their
//! per-job dispatch journals, keyed by id **and** sweep fingerprint so
//! an id collision can never resume someone else's checkpoint) and
//! answering `fetch`/idempotent re-submits for finished ones from the
//! manifest bank. `kill -9` at any point costs at most the leases in
//! flight; the merged manifest stays byte-identical to a single-process
//! run. A drain request (SIGTERM under `gcod serve`, or a test's drain
//! handle) stops leasing, lets in-flight leases land in the journal,
//! says goodbye to the fleet, and returns cleanly.

use super::chaos::{ChaosProfile, ChaosTransport};
use super::protocol::{Conn, JobSpec, Msg};
use super::store::{self, JobState, Recovery, StateStore};
use super::tcp::{RegisteredWorker, TcpTransport, DEAD_AFTER, REGISTER_TIMEOUT};
use super::{DispatchConfig, Dispatcher, HealthConfig, WorkerTransport};
use crate::error::{Error, Result};
use crate::metrics::{self, LatencyHistogram, Stopwatch, Table};
use crate::obs::{Event, Obs};
use std::collections::{BTreeMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator daemon configuration.
pub struct ServeConfig {
    /// listen address, `host:port` (port 0 = ephemeral)
    pub bind: String,
    /// hold queued jobs until this many workers are registered
    pub min_workers: usize,
    /// event-loop tick
    pub poll: Duration,
    /// exit after the first job finishes (CI smokes and tests; a real
    /// deployment serves forever)
    pub once: bool,
    /// checkpoint each job to `<dir>/job_<id>_<fp>.journal`; a
    /// re-submitted identical job resumes from it (superseded by
    /// `state_dir`, which journals into `<state-dir>/jobs/`)
    pub journal_dir: Option<PathBuf>,
    /// durable coordinator state: admitted specs, job states, the id
    /// counter and finished manifests survive a coordinator crash and
    /// replay on the next start with the same dir
    pub state_dir: Option<PathBuf>,
    /// cooperative shutdown flag: when it flips true (SIGTERM handler,
    /// test harness), the server drains — stops leasing, lets the
    /// running job unwind into its journal, goodbyes the fleet, exits Ok
    pub drain: Option<Arc<AtomicBool>>,
    /// drain as soon as the queue is empty instead of serving forever
    /// (`gcod serve --drain`: "work off the journaled backlog, exit 0")
    pub drain_when_idle: bool,
    /// observability handle shared with every dispatched job: job
    /// lifecycle, lease scheduling, chaos faults and peer reaps all
    /// stream through its sinks, and the event→metrics bridge feeds the
    /// `/metrics` endpoint (scrapeable on the serve port with plain
    /// `GET /metrics`)
    pub obs: Obs,
    /// half-open-peer reap window handed to each job's [`TcpTransport`]
    /// (`--peer-silence-timeout-ms`; default [`DEAD_AFTER`])
    pub peer_silence: Duration,
}

impl ServeConfig {
    pub fn new(bind: impl Into<String>) -> Self {
        Self {
            bind: bind.into(),
            min_workers: 1,
            poll: Duration::from_millis(10),
            once: false,
            journal_dir: None,
            state_dir: None,
            drain: None,
            drain_when_idle: false,
            obs: Obs::default(),
            peer_silence: DEAD_AFTER,
        }
    }
}

/// Bind and serve. Blocks for the life of the daemon (forever, unless
/// [`ServeConfig::once`] or a drain). `TcpListener::bind` sets
/// `SO_REUSEADDR` on unix, so a restarted coordinator rebinds its port
/// immediately even with the crashed process's sockets in TIME_WAIT.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.bind)
        .map_err(|e| Error::msg(format!("bind {}: {e}", cfg.bind)))?;
    println!(
        "gcod serve: listening on {} (min {} worker(s))",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| cfg.bind.clone()),
        cfg.min_workers
    );
    serve_on(listener, cfg)
}

/// Serve on an already-bound listener (tests bind to port 0 themselves
/// to learn the address before spawning workers and clients).
pub fn serve_on(listener: TcpListener, cfg: &ServeConfig) -> Result<()> {
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::msg(format!("listener set_nonblocking: {e}")))?;
    let mut srv = Server {
        cfg,
        store: None,
        workers: Vec::new(),
        handshakes: Vec::new(),
        queue: VecDeque::new(),
        keys: BTreeMap::new(),
        terminal: BTreeMap::new(),
        next_job: 0,
        recovered: 0,
        jobs_done: 0,
        jobs_failed: 0,
        leases_issued: 0,
        retried: 0,
        job_latency: LatencyHistogram::new(0.05, 24),
        up: Stopwatch::new(),
    };
    if let Some(dir) = &cfg.state_dir {
        let (store, recovery) = StateStore::open(dir)?;
        srv.store = Some(store);
        srv.recover(recovery);
    }
    loop {
        if srv.drain_requested() {
            return srv.drain_exit("drain flag raised");
        }
        srv.accept_pending(&listener);
        srv.advance_handshakes();
        srv.pump_idle_workers();
        if let Some(done) = srv.maybe_run_job()? {
            if cfg.once && done {
                srv.goodbye_all();
                return Ok(());
            }
        }
        if cfg.drain_when_idle && srv.queue.is_empty() {
            return srv.drain_exit("queue empty with --drain");
        }
        std::thread::sleep(cfg.poll);
    }
}

/// Where a finished job's manifest lives.
enum Banked {
    /// in memory (no state dir)
    Text(String),
    /// file name under `<state-dir>/manifests/`, fsynced before the
    /// journal admitted the job was done
    File(String),
}

/// A finished job, kept for `fetch` and idempotent re-submits.
enum Terminal {
    Done { summary: String, manifest: Banked },
    Failed(String),
}

struct PendingJob {
    id: u64,
    spec: Box<JobSpec>,
    /// every connection waiting on this job's result: the original
    /// submitter plus any `fetch`/duplicate-submit attachments
    /// (a journal-recovered job starts with none)
    clients: Vec<Conn>,
}

struct Server<'a> {
    cfg: &'a ServeConfig,
    store: Option<StateStore>,
    workers: Vec<RegisteredWorker>,
    /// accepted connections whose first (role-declaring) frame hasn't
    /// arrived yet, with their handshake deadline
    handshakes: Vec<(Conn, Instant)>,
    queue: VecDeque<PendingJob>,
    /// idempotency key → job id (replayed from the store on recovery)
    keys: BTreeMap<String, u64>,
    /// finished jobs by id, for fetch / dedup replies
    terminal: BTreeMap<u64, Terminal>,
    next_job: u64,
    recovered: u64,
    jobs_done: u64,
    jobs_failed: u64,
    leases_issued: u64,
    retried: u64,
    job_latency: LatencyHistogram,
    up: Stopwatch,
}

impl Server<'_> {
    /// Rebuild in-memory state from a replayed coordinator journal:
    /// terminal jobs go to the bank, unfinished ones back on the queue
    /// (their per-job sweep journals pick up mid-sweep), and the id
    /// counter continues where it stopped.
    fn recover(&mut self, rec: Recovery) {
        for note in &rec.notes {
            eprintln!("gcod serve: state journal: {note}");
        }
        self.next_job = rec.next_job;
        let total = rec.jobs.len() as u64;
        for job in rec.jobs {
            if !job.key.is_empty() {
                self.keys.insert(job.key.clone(), job.id);
            }
            match job.state {
                JobState::Done { file, summary } => {
                    self.terminal
                        .insert(job.id, Terminal::Done { summary, manifest: Banked::File(file) });
                }
                JobState::Failed { error } => {
                    self.terminal.insert(job.id, Terminal::Failed(error));
                }
                state @ (JobState::Queued | JobState::Running) => {
                    let mid_sweep = self
                        .store
                        .as_ref()
                        .is_some_and(|s| s.job_journal_path(job.id, &job.spec).is_file());
                    let detail = format!(
                        "was {}; {}",
                        if state == JobState::Running { "running" } else { "queued" },
                        if mid_sweep {
                            "resuming from its sweep journal"
                        } else {
                            "restarting from scratch"
                        }
                    );
                    println!("gcod serve: job {} re-queued after restart ({detail})", job.id);
                    self.cfg.obs.emit(Event::JobResumed { job: job.id, detail });
                    if state == JobState::Running {
                        if let Some(store) = &mut self.store {
                            if let Err(e) = store.record_state(job.id, &JobState::Queued) {
                                eprintln!("gcod serve: job {}: state record failed: {e}", job.id);
                            }
                        }
                    }
                    self.recovered += 1;
                    self.queue.push_back(PendingJob {
                        id: job.id,
                        spec: job.spec,
                        clients: Vec::new(),
                    });
                }
            }
        }
        if total > 0 {
            println!(
                "gcod serve: recovered {total} job(s) from the state journal \
                 ({} re-queued, next id {})",
                self.recovered, self.next_job
            );
            self.cfg.obs.emit(Event::CoordinatorRecovered {
                jobs: total,
                requeued: self.recovered,
            });
            self.cfg.obs.flush();
        }
    }

    fn drain_requested(&self) -> bool {
        self.cfg.drain.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }

    /// Orderly exit: nothing is lost — queued jobs are journaled (when
    /// a store exists), workers and waiting clients get goodbyes so
    /// they fail over to reconnect/fetch, and the caller returns Ok.
    fn drain_exit(&mut self, why: &str) -> Result<()> {
        println!(
            "gcod serve: draining ({why}) — {} queued job(s) retained, {} worker(s) released",
            self.queue.len(),
            self.workers.len()
        );
        self.cfg.obs.emit(Event::DrainStarted {
            detail: format!(
                "{why}; {} queued job(s) retained, {} worker(s) released",
                self.queue.len(),
                self.workers.len()
            ),
        });
        self.goodbye_all();
        for job in &mut self.queue {
            for client in &mut job.clients {
                let _ = client.send(&Msg::Goodbye);
            }
        }
        self.cfg.obs.flush();
        Ok(())
    }

    fn accept_pending(&mut self, listener: &TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => match Conn::new(stream) {
                    Ok(conn) => {
                        self.handshakes.push((conn, Instant::now() + REGISTER_TIMEOUT));
                    }
                    Err(e) => eprintln!("gcod serve: rejected connection: {e}"),
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    eprintln!("gcod serve: accept failed: {e}");
                    break;
                }
            }
        }
    }

    /// Poll handshaking connections for their first frame and route
    /// them to a role. Never blocks the loop on a silent peer.
    fn advance_handshakes(&mut self) {
        let mut still = Vec::new();
        for (mut conn, deadline) in std::mem::take(&mut self.handshakes) {
            let msgs = match conn.poll_msgs() {
                Ok(m) => m,
                Err(e) => {
                    // "GET " read as a frame length exceeds MAX_FRAME, so
                    // a plain HTTP request lands here with its bytes
                    // still buffered — answer it instead of dropping it.
                    // The request line can straddle a segment boundary:
                    // keep the conn in the handshake set until the line
                    // is complete or its deadline lapses.
                    if conn.looks_like_http() {
                        if conn.http_request_path().is_some() {
                            self.respond_http(&mut conn);
                        } else if Instant::now() < deadline {
                            still.push((conn, deadline));
                        }
                    } else {
                        eprintln!("gcod serve: {}: handshake failed: {e}", conn.peer());
                    }
                    continue;
                }
            };
            match msgs.into_iter().next() {
                Some(Msg::Register { class, threads }) => {
                    println!(
                        "gcod serve: worker registered from {} (class '{}', {} thread(s))",
                        conn.peer(),
                        class,
                        threads
                    );
                    self.workers.push(RegisteredWorker { conn, class, threads });
                }
                Some(Msg::Submit { spec }) => self.handle_submit(conn, spec),
                Some(Msg::Fetch { job }) => self.attach_client(conn, job),
                Some(Msg::Status) => {
                    let report = self.status_text();
                    if let Err(e) = conn.send(&Msg::StatusReport { text: report }) {
                        eprintln!("gcod serve: {}: status reply failed: {e}", conn.peer());
                    }
                }
                Some(Msg::Goodbye) => {}
                Some(other) => {
                    eprintln!(
                        "gcod serve: {}: unexpected first frame {other:?} — dropping",
                        conn.peer()
                    );
                }
                None if conn.is_eof() => {}
                None => {
                    if Instant::now() >= deadline {
                        eprintln!(
                            "gcod serve: {}: no role frame within {REGISTER_TIMEOUT:?} — dropping",
                            conn.peer()
                        );
                    } else {
                        still.push((conn, deadline));
                    }
                }
            }
        }
        self.handshakes = still;
    }

    /// Admit a submitted job: dedup by idempotency key, persist the
    /// spec *before* acking (once the client hears `submitted`, the job
    /// must survive any crash), then queue it.
    fn handle_submit(&mut self, mut conn: Conn, spec: Box<JobSpec>) {
        let key = spec.idempotency_key.clone();
        if let Err(e) = store::validate_idempotency_key(&key) {
            let _ = conn.send(&Msg::JobError { job: u64::MAX, error: e.to_string() });
            return;
        }
        if let Some(&id) = self.keys.get(&key).filter(|_| !key.is_empty()) {
            println!(
                "gcod serve: duplicate submit (idempotency key '{key}') → existing job {id}"
            );
            self.cfg.obs.emit(Event::ServeJob {
                job: id,
                state: "deduplicated".to_string(),
                detail: format!("idempotency key '{key}' from {}", conn.peer()),
            });
            if conn.send(&Msg::Submitted { job: id }).is_ok() {
                self.attach_client(conn, id);
            }
            return;
        }
        let id = self.next_job;
        if let Some(store) = &mut self.store {
            if let Err(e) = store.record_job(id, &key, &spec) {
                eprintln!("gcod serve: job {id}: persist failed: {e}");
                let _ = conn.send(&Msg::JobError {
                    job: u64::MAX,
                    error: format!("coordinator could not persist the job: {e}"),
                });
                return;
            }
        }
        self.next_job += 1;
        if !key.is_empty() {
            self.keys.insert(key, id);
        }
        if let Err(e) = conn.send(&Msg::Submitted { job: id }) {
            // the job is admitted (and journaled) regardless; the
            // client can still recover the result via fetch
            eprintln!("gcod serve: {}: submit ack failed: {e}", conn.peer());
        }
        println!(
            "gcod serve: job {id} queued from {}: sweep '{}' ({} trials)",
            conn.peer(),
            spec.config.sweep.as_str(),
            spec.config.trials
        );
        self.cfg.obs.emit(Event::ServeJob {
            job: id,
            state: "queued".to_string(),
            detail: format!(
                "sweep '{}' ({} trials) from {}",
                spec.config.sweep.as_str(),
                spec.config.trials,
                conn.peer()
            ),
        });
        self.queue.push_back(PendingJob { id, spec, clients: vec![conn] });
    }

    /// Attach a connection to job `id`: a finished job answers
    /// immediately from the bank, a pending one adds the connection to
    /// its reply list, an unknown id gets a loud error.
    fn attach_client(&mut self, mut conn: Conn, id: u64) {
        let reply = match self.terminal.get(&id) {
            Some(Terminal::Failed(error)) => {
                Some(Msg::JobError { job: id, error: error.clone() })
            }
            Some(Terminal::Done { summary, manifest }) => {
                let text = match manifest {
                    Banked::Text(t) => Ok(t.clone()),
                    Banked::File(f) => self
                        .store
                        .as_ref()
                        .ok_or_else(|| Error::msg("manifest banked on disk but no store open"))
                        .and_then(|s| s.load_manifest(f)),
                };
                Some(match text {
                    Ok(manifest) => {
                        Msg::JobDone { job: id, summary: summary.clone(), manifest }
                    }
                    Err(e) => Msg::JobError {
                        job: id,
                        error: format!("job {id} finished but its banked manifest failed: {e}"),
                    },
                })
            }
            None => None,
        };
        if let Some(reply) = reply {
            if let Err(e) = conn.send(&reply) {
                eprintln!("gcod serve: {}: banked reply failed: {e}", conn.peer());
            }
            return;
        }
        if let Some(job) = self.queue.iter_mut().find(|j| j.id == id) {
            job.clients.push(conn);
        } else {
            let _ = conn.send(&Msg::JobError {
                job: id,
                error: format!("unknown job id {id} (never submitted, or state not durable)"),
            });
        }
    }

    /// Answer a plain-HTTP peer on the frame port: `GET /metrics`
    /// serves the Prometheus-style registry (refreshing the server
    /// gauges first), anything else 404s. One response, then the
    /// connection drops (HTTP/1.0 close semantics).
    fn respond_http(&mut self, conn: &mut Conn) {
        let path = conn.http_request_path().unwrap_or_default();
        let (status, body) = if path == "/metrics" {
            self.refresh_gauges();
            ("200 OK", metrics::registry().render_prometheus())
        } else {
            ("404 Not Found", format!("no such endpoint '{path}' (try /metrics)\n"))
        };
        let resp = format!(
            "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        if let Err(e) = conn.send_raw(resp.as_bytes()) {
            eprintln!("gcod serve: {}: http reply failed: {e}", conn.peer());
        }
    }

    /// Registry gauges that describe current server state are refreshed
    /// at scrape time rather than maintained incrementally.
    fn refresh_gauges(&self) {
        // touch the families CI and dashboards assert zero on, so they
        // exist from the very first scrape (creation registers at 0;
        // values already bridged by events are left alone)
        let _ = metrics::counter("leases_reaped_total");
        let _ = metrics::gauge("workers_quarantined");
        metrics::gauge("serve_uptime_seconds").set(self.up.elapsed_secs());
        metrics::gauge("workers_registered").set(self.workers.len() as f64);
        metrics::gauge("serve_jobs_queued").set(self.queue.len() as f64);
        metrics::gauge("serve_jobs_done").set(self.jobs_done as f64);
        metrics::gauge("serve_jobs_failed").set(self.jobs_failed as f64);
        metrics::gauge("serve_jobs_recovered").set(self.recovered as f64);
        if self.job_latency.stats().count() > 0 {
            metrics::gauge("serve_job_latency_p50_seconds").set(self.job_latency.quantile(0.5));
            metrics::gauge("serve_job_latency_p95_seconds").set(self.job_latency.quantile(0.95));
        }
    }

    /// Keep idle registry connections honest: consume heartbeats, drop
    /// the dead.
    fn pump_idle_workers(&mut self) {
        self.workers.retain_mut(|w| {
            let alive = match w.conn.poll_msgs() {
                Ok(msgs) => !msgs.iter().any(|m| matches!(m, Msg::Goodbye)) && !w.conn.is_eof(),
                Err(_) => false,
            };
            if !alive {
                println!("gcod serve: worker {} left the registry", w.conn.peer());
            }
            alive
        });
    }

    /// Workers eligible for a job's capability class ("" accepts any).
    fn eligible(&self, class: &str) -> usize {
        self.workers.iter().filter(|w| class.is_empty() || w.class == class).count()
    }

    /// Run the frontmost runnable job to completion. `Ok(Some(true))` =
    /// a job finished this tick.
    fn maybe_run_job(&mut self) -> Result<Option<bool>> {
        if self.workers.len() < self.cfg.min_workers.max(1) {
            return Ok(None);
        }
        let Some(pos) = self.queue.iter().position(|j| self.eligible(&j.spec.class) > 0)
        else {
            return Ok(None);
        };
        let mut job = self.queue.remove(pos).expect("position came from this queue");
        let class = job.spec.class.clone();
        let lent: Vec<RegisteredWorker> = {
            let mut lent = Vec::new();
            let mut kept = Vec::new();
            for w in self.workers.drain(..) {
                if class.is_empty() || w.class == class {
                    lent.push(w);
                } else {
                    kept.push(w);
                }
            }
            self.workers = kept;
            lent
        };
        println!(
            "gcod serve: job {} starting on {} worker(s) (class '{}')",
            job.id,
            lent.len(),
            class
        );
        self.cfg.obs.emit(Event::ServeJob {
            job: job.id,
            state: "started".to_string(),
            detail: format!("{} worker(s), class '{class}'", lent.len()),
        });
        if let Some(store) = &mut self.store {
            if let Err(e) = store.record_state(job.id, &JobState::Running) {
                eprintln!("gcod serve: job {}: state record failed: {e}", job.id);
            }
        }
        let watch = Stopwatch::new();
        let outcome = self.execute(job.id, &job.spec, lent);
        self.job_latency.record(watch.elapsed_secs());
        // a drain unwind is not a failure: the dispatcher stopped on
        // purpose with its progress journaled — the job goes back on
        // the queue (and in the store) for the next coordinator
        if let Err(e) = &outcome {
            if e.to_string().starts_with("dispatch drained") {
                println!("gcod serve: job {} drained mid-run — re-queued", job.id);
                self.cfg.obs.emit(Event::ServeJob {
                    job: job.id,
                    state: "drained".to_string(),
                    detail: e.to_string(),
                });
                if let Some(store) = &mut self.store {
                    if let Err(e) = store.record_state(job.id, &JobState::Queued) {
                        eprintln!("gcod serve: job {}: state record failed: {e}", job.id);
                    }
                }
                self.queue.push_front(job);
                self.cfg.obs.flush();
                return Ok(Some(false));
            }
        }
        let reply = match outcome {
            Ok((merged, summary)) => {
                self.jobs_done += 1;
                println!("gcod serve: job {} done ({summary})", job.id);
                self.cfg.obs.emit(Event::ServeJob {
                    job: job.id,
                    state: "done".to_string(),
                    detail: summary.clone(),
                });
                let banked = match &mut self.store {
                    Some(store) => match store.record_done(job.id, &summary, &merged) {
                        Ok(file) => Banked::File(file),
                        Err(e) => {
                            eprintln!("gcod serve: job {}: bank failed: {e}", job.id);
                            Banked::Text(merged.clone())
                        }
                    },
                    None => Banked::Text(merged.clone()),
                };
                self.terminal
                    .insert(job.id, Terminal::Done { summary: summary.clone(), manifest: banked });
                Msg::JobDone { job: job.id, summary, manifest: merged }
            }
            Err(e) => {
                self.jobs_failed += 1;
                println!("gcod serve: job {} failed: {e}", job.id);
                self.cfg.obs.emit(Event::ServeJob {
                    job: job.id,
                    state: "failed".to_string(),
                    detail: e.to_string(),
                });
                if let Some(store) = &mut self.store {
                    let failed = JobState::Failed { error: e.to_string() };
                    if let Err(e) = store.record_state(job.id, &failed) {
                        eprintln!("gcod serve: job {}: state record failed: {e}", job.id);
                    }
                }
                self.terminal.insert(job.id, Terminal::Failed(e.to_string()));
                Msg::JobError { job: job.id, error: e.to_string() }
            }
        };
        self.cfg.obs.flush();
        for mut client in job.clients {
            if let Err(e) = client.send(&reply) {
                eprintln!(
                    "gcod serve: job {}: client {} unreachable for the result: {e}",
                    job.id,
                    client.peer()
                );
            }
        }
        Ok(Some(true))
    }

    /// Dispatch one job over the lent workers; returns the merged
    /// manifest text and report summary. Surviving workers go back to
    /// the registry whatever happens.
    fn execute(
        &mut self,
        id: u64,
        spec: &JobSpec,
        lent: Vec<RegisteredWorker>,
    ) -> Result<(String, String)> {
        let out_dir =
            std::env::temp_dir().join(format!("gcod_serve_{}_job_{id}", std::process::id()));
        // per-job sweep journal, keyed by id + sweep fingerprint so no
        // job can ever resume another's checkpoint (Journal::open
        // re-verifies the full fingerprint line inside the file)
        let journal = match (&self.store, &self.cfg.journal_dir) {
            (Some(store), _) => Some(store.job_journal_path(id, spec)),
            (None, Some(d)) => Some(d.join(store::job_journal_name(id, spec))),
            (None, None) => None,
        };
        let resume = journal.as_ref().is_some_and(|j| j.is_file());
        let dcfg = DispatchConfig {
            grain: spec.grain,
            adaptive_grain: spec.adaptive_grain,
            min_grain: spec.min_grain,
            threads_per_worker: spec.threads_per_worker,
            lease_timeout: Duration::from_millis(spec.lease_timeout_ms),
            lease_timeout_per_trial: Duration::from_millis(spec.lease_timeout_per_trial_ms),
            max_retries: spec.max_retries,
            poll_interval: self.cfg.poll,
            speculate: true,
            stats_only: spec.stats_only,
            out_dir: out_dir.clone(),
            straggler_sim: None,
            audit_fraction: spec.audit_fraction,
            // same derivation as sweep-launch: a resubmitted job audits
            // the same leases on the same sub-ranges
            audit_seed: spec.config.seed ^ 0xA0D1_75EE,
            health: HealthConfig {
                quarantine_after: 2,
                // sockets do die; a worker that keeps crashing leases
                // must leave the pool instead of burning the retry
                // budget
                quarantine_after_failures: 3,
                backoff_base: Duration::from_millis(100),
                ..HealthConfig::default()
            },
            journal,
            resume,
            stop: self.cfg.drain.clone(),
            obs: self.cfg.obs.clone(),
            peer_silence_timeout: self.cfg.peer_silence,
        };
        let profile = ChaosProfile::parse(&spec.chaos_profile)?;
        let mut tcp = TcpTransport::new(lent).with_peer_silence(self.cfg.peer_silence);
        tcp.set_obs(self.cfg.obs.clone());
        let mut transport = ChaosTransport::new(tcp, spec.chaos_seed, profile);
        transport.set_obs(self.cfg.obs.clone());
        if let Some(w) = spec.kill_worker {
            if w >= transport.n_workers() {
                transport.inner().reclaim().into_iter().for_each(|w| self.workers.push(w));
                return Err(Error::msg(format!(
                    "kill_worker {w} out of range for {} lent worker(s)",
                    transport.n_workers()
                )));
            }
            transport.preset_kill(w, Duration::from_millis(spec.kill_after_ms));
        }
        let result = Dispatcher::new(dcfg).run(&spec.config, &mut transport);
        let _ = std::fs::remove_dir_all(&out_dir);
        // with observability enabled the fault decisions streamed out
        // live as chaos-fault events; the println fallback keeps fault
        // drills legible for a bare default config
        if !self.cfg.obs.enabled() {
            for line in &transport.plan.log {
                println!("gcod serve: job {id} [chaos] {line}");
            }
        }
        let survivors = transport.inner().reclaim();
        println!(
            "gcod serve: job {id} returned {} worker(s) to the registry",
            survivors.len()
        );
        self.workers.extend(survivors);
        let outcome = result?;
        self.leases_issued += outcome.report.leases_issued;
        self.retried += outcome.report.retried;
        Ok((outcome.merged.render(), outcome.report.summary()))
    }

    fn status_text(&self) -> String {
        let mut classes: Vec<String> = self
            .workers
            .iter()
            .map(|w| if w.class.is_empty() { "(any)".to_string() } else { w.class.clone() })
            .collect();
        classes.sort();
        classes.dedup();
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["uptime (s)".into(), format!("{:.1}", self.up.elapsed_secs())]);
        t.row(vec![
            "durable state".into(),
            self.cfg
                .state_dir
                .as_ref()
                .map_or("(memory only)".into(), |d| d.display().to_string()),
        ]);
        t.row(vec!["workers registered".into(), self.workers.len().to_string()]);
        t.row(vec!["capability classes".into(), classes.join(",")]);
        t.row(vec!["jobs queued".into(), self.queue.len().to_string()]);
        t.row(vec!["jobs recovered".into(), self.recovered.to_string()]);
        t.row(vec!["jobs done".into(), self.jobs_done.to_string()]);
        t.row(vec!["jobs failed".into(), self.jobs_failed.to_string()]);
        t.row(vec!["leases issued".into(), self.leases_issued.to_string()]);
        t.row(vec!["leases retried".into(), self.retried.to_string()]);
        if self.job_latency.stats().count() > 0 {
            t.row(vec![
                "job latency p50 (s)".into(),
                format!("{:.3}", self.job_latency.quantile(0.5)),
            ]);
            t.row(vec![
                "job latency p95 (s)".into(),
                format!("{:.3}", self.job_latency.quantile(0.95)),
            ]);
        }
        t.render()
    }

    fn goodbye_all(&mut self) {
        for w in &mut self.workers {
            let _ = w.conn.send(&Msg::Goodbye);
        }
        self.workers.clear();
    }
}

// ---------------------------------------------------------------------
// Clients
// ---------------------------------------------------------------------

/// A finished job as seen by the submitting client.
pub struct SubmitOutcome {
    pub job: u64,
    pub summary: String,
    /// merged-manifest text, byte-identical to a single-process run
    pub manifest: String,
}

/// Submit a job and block until the coordinator streams the merged
/// result back (or `timeout` passes). Outlives a coordinator restart:
/// once the job id is known, a dropped connection fails over to
/// [`fetch_job`]; before the ack, a spec with an idempotency key is
/// safely re-submitted (the key dedups server-side).
pub fn submit_job(addr: &str, spec: JobSpec, timeout: Duration) -> Result<SubmitOutcome> {
    let deadline = Instant::now() + timeout;
    let resubmittable = !spec.idempotency_key.is_empty();
    let mut conn = connect(addr)?;
    conn.send(&Msg::Submit { spec: Box::new(spec.clone()) })?;
    let mut id = None;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(Error::msg(match id {
                Some(id) => format!("job {id} accepted but no result within {timeout:?}"),
                None => format!("no submit ack from {addr} within {timeout:?}"),
            }));
        }
        match conn.recv_timeout(left) {
            Ok(Some(Msg::Submitted { job })) => id = Some(job),
            Ok(Some(Msg::JobDone { job, summary, manifest })) => {
                return Ok(SubmitOutcome { job, summary, manifest });
            }
            Ok(Some(Msg::JobError { job, error })) => {
                return Err(Error::msg(format!("job {job} failed: {error}")));
            }
            Ok(Some(Msg::Goodbye)) | Err(_) => {
                // coordinator went away (crash or drain): fail over
                let left = deadline.saturating_duration_since(Instant::now());
                if let Some(id) = id {
                    return fetch_job(addr, id, left);
                }
                if !resubmittable {
                    return Err(Error::msg(format!(
                        "lost {addr} before the submit ack; re-submit with an \
                         idempotency key to make this safe to retry"
                    )));
                }
                conn = reconnect_with_backoff(addr, deadline)?;
                // a failed re-send leaves the conn EOF; the next
                // recv_timeout error loops back here
                let _ = conn.send(&Msg::Submit { spec: Box::new(spec.clone()) });
            }
            Ok(Some(_)) | Ok(None) => {}
        }
    }
}

/// Fire-and-forget submission: returns the accepted job id without
/// waiting for the sweep to run.
pub fn submit_job_nowait(addr: &str, spec: JobSpec, timeout: Duration) -> Result<u64> {
    let mut conn = connect(addr)?;
    conn.send(&Msg::Submit { spec: Box::new(spec) })?;
    match conn.recv_timeout(timeout)? {
        Some(Msg::Submitted { job }) => Ok(job),
        Some(other) => Err(Error::msg(format!("expected submit ack, got {other:?}"))),
        None => Err(Error::msg(format!("no submit ack from {addr} within {timeout:?}"))),
    }
}

/// Retrieve job `job`'s result by id, surviving coordinator restarts:
/// connection loss (or an unreachable coordinator) retries with backoff
/// until the result arrives or `timeout` passes. A finished job answers
/// from the manifest bank; a queued one answers when it lands.
pub fn fetch_job(addr: &str, job: u64, timeout: Duration) -> Result<SubmitOutcome> {
    let deadline = Instant::now() + timeout;
    loop {
        let mut conn = reconnect_with_backoff(addr, deadline)
            .map_err(|e| Error::msg(format!("fetch job {job}: {e}")))?;
        if conn.send(&Msg::Fetch { job }).is_err() {
            std::thread::sleep(Duration::from_millis(100));
            continue;
        }
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::msg(format!("job {job}: no result within {timeout:?}")));
            }
            match conn.recv_timeout(left) {
                Ok(Some(Msg::JobDone { job, summary, manifest })) => {
                    return Ok(SubmitOutcome { job, summary, manifest });
                }
                Ok(Some(Msg::JobError { job, error })) => {
                    return Err(Error::msg(format!("job {job} failed: {error}")));
                }
                Ok(Some(Msg::Goodbye)) | Err(_) => break, // reconnect and re-fetch
                Ok(Some(_)) | Ok(None) => {}
            }
        }
    }
}

/// Fetch the coordinator's status snapshot.
pub fn query_status(addr: &str, timeout: Duration) -> Result<String> {
    let mut conn = connect(addr)?;
    conn.send(&Msg::Status)?;
    match conn.recv_timeout(timeout)? {
        Some(Msg::StatusReport { text }) => Ok(text),
        Some(other) => Err(Error::msg(format!("expected status report, got {other:?}"))),
        None => Err(Error::msg(format!("no status report from {addr} within {timeout:?}"))),
    }
}

fn connect(addr: &str) -> Result<Conn> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::msg(format!("connect {addr}: {e}")))?;
    Conn::new(stream)
}

/// Keep dialing `addr` (100 ms → 2 s exponential backoff) until a
/// connection lands or `deadline` passes — the coordinator may be
/// mid-restart.
fn reconnect_with_backoff(addr: &str, deadline: Instant) -> Result<Conn> {
    let mut delay = Duration::from_millis(100);
    loop {
        match connect(addr) {
            Ok(conn) => return Ok(conn),
            Err(e) => {
                if Instant::now() + delay >= deadline {
                    return Err(Error::msg(format!("{addr} unreachable before deadline: {e}")));
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
        }
    }
}
