//! Durable coordinator state: the `gcod serve --state-dir` journal.
//!
//! The dispatch layer already makes *workers* expendable (leases are
//! reaped and retried) and makes a single *job* resumable (the per-job
//! sweep journal in [`super::journal`]). This module closes the last
//! gap: the coordinator itself. Everything the serve loop would lose in
//! a crash — submitted specs, the job-id counter, job states, finished
//! manifests — is recorded in one append-only, fsynced journal and
//! replayed on restart, so `kill -9` on the coordinator costs at most
//! the leases in flight (which the per-job journal re-covers).
//!
//! Layout under `--state-dir`:
//!
//! ```text
//! coordinator.journal      append-only record of jobs + transitions
//! manifests/job_<id>.json  banked merged manifests (fsynced before
//!                          the `done` record that points at them)
//! jobs/                    per-job sweep journals + sidecars, keyed
//!                          job_<id>_<fp>.journal (fp = fingerprint
//!                          hash, so an id collision can never resume
//!                          another sweep's journal)
//! ```
//!
//! Journal grammar (line-oriented, like the sweep journal):
//!
//! ```text
//! gcod-serve-state v1
//! job <id> <key|-> <spec-json>      admission (spec bitwise, one line)
//! counter <next>                    persisted job-id counter
//! state <id> queued|running
//! state <id> failed <escaped error>
//! done <id> <file> <escaped summary>
//! ```
//!
//! Write ordering is strict: the `job` line is fsynced **before** the
//! `submitted` ack leaves the socket, and a manifest file is fsynced
//! **before** the `done` line that references it — so every state the
//! journal admits to is really on disk. A torn final line (torn by the
//! very crash this exists for) is dropped with a note; a malformed line
//! anywhere else is a hard error, because it means corruption rather
//! than a crash.

use super::journal;
use super::protocol::{parse_job_spec, render_job_spec, JobSpec};
use crate::config::json::Json;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// First line of every coordinator journal; bumped on format change.
pub const STATE_HEADER: &str = "gcod-serve-state v1";

/// Longest accepted idempotency key (the key rides a journal line and
/// a status table; unbounded client input stays out of both).
pub const MAX_IDEMPOTENCY_KEY: usize = 128;

/// Where a job stands after replay (or at runtime).
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    /// was executing when the journal last heard of it; resumes through
    /// its per-job sweep journal exactly like a queued job
    Running,
    Done {
        /// manifest file name under `manifests/`
        file: String,
        summary: String,
    },
    Failed {
        error: String,
    },
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed { .. })
    }
}

/// One job as reconstructed from the journal.
#[derive(Debug)]
pub struct JobRecord {
    pub id: u64,
    /// idempotency key, "" if the client sent none
    pub key: String,
    pub spec: Box<JobSpec>,
    pub state: JobState,
}

/// Everything `open` learned from an existing journal.
#[derive(Debug, Default)]
pub struct Recovery {
    /// all recorded jobs, id-ascending
    pub jobs: Vec<JobRecord>,
    /// first unissued job id (max recorded + 1, or the persisted
    /// counter if that is larger)
    pub next_job: u64,
    /// non-fatal oddities (torn tail), for the serve log
    pub notes: Vec<String>,
}

/// Append handle on the coordinator journal plus the dir layout.
pub struct StateStore {
    dir: PathBuf,
    file: File,
}

impl StateStore {
    /// Open (or create) the state dir, replaying any existing journal.
    pub fn open(dir: &Path) -> Result<(StateStore, Recovery)> {
        fs::create_dir_all(dir.join("manifests"))
            .and_then(|()| fs::create_dir_all(dir.join("jobs")))
            .map_err(|e| Error::msg(format!("state dir {}: {e}", dir.display())))?;
        let path = dir.join("coordinator.journal");
        let existing = if path.is_file() {
            fs::read_to_string(&path)
                .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?
        } else {
            String::new()
        };
        // No complete line on disk means a crash interrupted journal
        // creation before the (fsynced) header landed: start fresh.
        // Anything with at least one full line must replay cleanly.
        let recovery = if existing.contains('\n') {
            replay(&path)?
        } else {
            let mut f = File::create(&path)
                .map_err(|e| Error::msg(format!("create {}: {e}", path.display())))?;
            f.write_all(format!("{STATE_HEADER}\n").as_bytes())
                .and_then(|()| f.sync_all())
                .map_err(|e| Error::msg(format!("write {}: {e}", path.display())))?;
            let mut rec = Recovery::default();
            if !existing.is_empty() {
                rec.notes.push("journal header was torn by a crash; starting fresh".into());
            }
            rec
        };
        let file = OpenOptions::new()
            .append(true)
            .open(&path)
            .map_err(|e| Error::msg(format!("append {}: {e}", path.display())))?;
        Ok((StateStore { dir: dir.to_path_buf(), file }, recovery))
    }

    /// A job was admitted under `id`: persist the spec (bitwise) and
    /// the advanced id counter. Fsynced before returning, so the
    /// `submitted` ack may only be sent after this succeeds.
    pub fn record_job(&mut self, id: u64, key: &str, spec: &JobSpec) -> Result<()> {
        validate_idempotency_key(key)?;
        let key_tok = if key.is_empty() { "-" } else { key };
        self.append(&format!(
            "job {id} {key_tok} {}\ncounter {}\nstate {id} queued",
            render_job_spec(spec),
            id + 1
        ))
    }

    /// A queued job started executing (or drained back to queued).
    pub fn record_state(&mut self, id: u64, state: &JobState) -> Result<()> {
        match state {
            JobState::Queued => self.append(&format!("state {id} queued")),
            JobState::Running => self.append(&format!("state {id} running")),
            JobState::Failed { error } => {
                self.append(&format!("state {id} failed {}", escape(error)))
            }
            JobState::Done { .. } => Err(Error::msg(
                "state store: use record_done for terminal success (manifest must land first)",
            )),
        }
    }

    /// A job finished: bank the manifest (fsynced), then commit the
    /// `done` record pointing at it. Returns the banked file name.
    pub fn record_done(&mut self, id: u64, summary: &str, manifest: &str) -> Result<String> {
        let file = format!("job_{id}.json");
        let path = self.dir.join("manifests").join(&file);
        let mut f = File::create(&path)
            .map_err(|e| Error::msg(format!("bank manifest {}: {e}", path.display())))?;
        f.write_all(manifest.as_bytes())
            .and_then(|()| f.sync_all())
            .map_err(|e| Error::msg(format!("bank manifest {}: {e}", path.display())))?;
        self.append(&format!("done {id} {file} {}", escape(summary)))?;
        Ok(file)
    }

    /// Re-read a banked manifest, verbatim.
    pub fn load_manifest(&self, file: &str) -> Result<String> {
        let path = self.dir.join("manifests").join(file);
        fs::read_to_string(&path)
            .map_err(|e| Error::msg(format!("banked manifest {}: {e}", path.display())))
    }

    /// Per-job sweep journal path: keyed by id **and** the sweep's
    /// identity fingerprint, so a journal can only ever be resumed by
    /// the job it belongs to ([`journal::Journal::open`] additionally
    /// verifies the full fingerprint line inside the file).
    pub fn job_journal_path(&self, id: u64, spec: &JobSpec) -> PathBuf {
        self.dir.join("jobs").join(job_journal_name(id, spec))
    }

    fn append(&mut self, lines: &str) -> Result<()> {
        self.file
            .write_all(format!("{lines}\n").as_bytes())
            .and_then(|()| self.file.sync_data())
            .map_err(|e| Error::msg(format!("coordinator journal append: {e}")))
    }
}

/// `job_<id>_<fp>.journal` — `fp` is a 64-bit FNV-1a of the sweep
/// identity fingerprint, hex. Distinct sweeps can never share a file
/// name even if a counter ever regressed.
pub fn job_journal_name(id: u64, spec: &JobSpec) -> String {
    let fp = journal::fingerprint(&spec.config, spec.stats_only);
    format!("job_{id}_{:016x}.journal", fnv1a(fp.as_bytes()))
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An idempotency key must tokenize safely on a journal line and print
/// safely in a status table: short, non-empty only if used, and drawn
/// from `[A-Za-z0-9._-]` (in particular no whitespace, no `/`).
pub fn validate_idempotency_key(key: &str) -> Result<()> {
    if key.is_empty() {
        return Ok(());
    }
    if key.len() > MAX_IDEMPOTENCY_KEY {
        return Err(Error::msg(format!(
            "idempotency key is {} bytes (cap {MAX_IDEMPOTENCY_KEY})",
            key.len()
        )));
    }
    if !key.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-')) {
        return Err(Error::msg(format!(
            "idempotency key '{key}' has characters outside [A-Za-z0-9._-]"
        )));
    }
    Ok(())
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn replay(path: &Path) -> Result<Recovery> {
    let text = fs::read_to_string(path)
        .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?;
    let complete = text.ends_with('\n');
    let lines: Vec<&str> = text.lines().collect();
    if lines.first().copied() != Some(STATE_HEADER) {
        return Err(Error::msg(format!(
            "{}: not a coordinator journal (bad header)",
            path.display()
        )));
    }
    let mut jobs: BTreeMap<u64, JobRecord> = BTreeMap::new();
    let mut counter: u64 = 0;
    let mut notes = Vec::new();
    for (i, line) in lines.iter().enumerate().skip(1) {
        let torn_ok = !complete && i == lines.len() - 1;
        match replay_line(line, &mut jobs, &mut counter) {
            Ok(()) => {}
            Err(e) if torn_ok => {
                notes.push(format!(
                    "dropped torn final journal line (crash mid-append): {e}"
                ));
            }
            Err(e) => {
                return Err(Error::msg(format!(
                    "{} line {}: {e}",
                    path.display(),
                    i + 1
                )));
            }
        }
    }
    let next_job = jobs.keys().next_back().map_or(0, |id| id + 1).max(counter);
    Ok(Recovery { jobs: jobs.into_values().collect(), next_job, notes })
}

fn replay_line(
    line: &str,
    jobs: &mut BTreeMap<u64, JobRecord>,
    counter: &mut u64,
) -> Result<()> {
    let (verb, rest) = line.split_once(' ').ok_or_else(|| Error::msg("missing verb"))?;
    match verb {
        "job" => {
            let (id_tok, rest) =
                rest.split_once(' ').ok_or_else(|| Error::msg("job: missing key"))?;
            let (key_tok, spec_json) =
                rest.split_once(' ').ok_or_else(|| Error::msg("job: missing spec"))?;
            let id: u64 =
                id_tok.parse().map_err(|e| Error::msg(format!("job: bad id: {e}")))?;
            let doc = Json::parse(spec_json)
                .map_err(|e| Error::msg(format!("job {id}: bad spec json: {e}")))?;
            let spec = parse_job_spec(&doc)?;
            let key = if key_tok == "-" { String::new() } else { key_tok.to_string() };
            validate_idempotency_key(&key)?;
            jobs.insert(id, JobRecord { id, key, spec: Box::new(spec), state: JobState::Queued });
            Ok(())
        }
        "counter" => {
            *counter =
                rest.parse().map_err(|e| Error::msg(format!("counter: bad value: {e}")))?;
            Ok(())
        }
        "state" => {
            let (id_tok, rest) =
                rest.split_once(' ').ok_or_else(|| Error::msg("state: missing state"))?;
            let id: u64 =
                id_tok.parse().map_err(|e| Error::msg(format!("state: bad id: {e}")))?;
            let job = jobs
                .get_mut(&id)
                .ok_or_else(|| Error::msg(format!("state for unknown job {id}")))?;
            job.state = match rest.split_once(' ') {
                None if rest == "queued" => JobState::Queued,
                None if rest == "running" => JobState::Running,
                Some(("failed", err)) => JobState::Failed { error: unescape(err) },
                _ => return Err(Error::msg(format!("job {id}: bad state '{rest}'"))),
            };
            Ok(())
        }
        "done" => {
            let (id_tok, rest) =
                rest.split_once(' ').ok_or_else(|| Error::msg("done: missing file"))?;
            let id: u64 =
                id_tok.parse().map_err(|e| Error::msg(format!("done: bad id: {e}")))?;
            let (file, summary) = rest.split_once(' ').unwrap_or((rest, ""));
            let job = jobs
                .get_mut(&id)
                .ok_or_else(|| Error::msg(format!("done for unknown job {id}")))?;
            job.state =
                JobState::Done { file: file.to_string(), summary: unescape(summary) };
            Ok(())
        }
        other => Err(Error::msg(format!("unknown journal verb '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::shard::{SweepConfig, SweepKind};

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("gcod_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn spec(seed: u64) -> JobSpec {
        JobSpec::new(SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:16,3".into(),
            decoder: "optimal".into(),
            p: 0.1 + 0.2, // non-representable: must survive bitwise
            seed,
            trials: 100,
            chunk: 8,
            params: BTreeMap::new(),
        })
    }
    use std::collections::BTreeMap;

    #[test]
    fn journal_roundtrips_jobs_states_and_manifests() {
        let dir = scratch("roundtrip");
        {
            let (mut store, rec) = StateStore::open(&dir).unwrap();
            assert!(rec.jobs.is_empty());
            assert_eq!(rec.next_job, 0);
            store.record_job(0, "key-a", &spec(7)).unwrap();
            store.record_job(1, "", &spec(u64::MAX - 3)).unwrap();
            store.record_state(0, &JobState::Running).unwrap();
            store.record_done(0, "summary line\nsecond", "{\"manifest\": true}").unwrap();
            store
                .record_state(1, &JobState::Failed { error: "boom \\ bust".into() })
                .unwrap();
        }
        let (store, rec) = StateStore::open(&dir).unwrap();
        assert_eq!(rec.next_job, 2);
        assert_eq!(rec.jobs.len(), 2);
        assert!(rec.notes.is_empty());
        let j0 = &rec.jobs[0];
        assert_eq!((j0.id, j0.key.as_str()), (0, "key-a"));
        assert_eq!(j0.spec.config.p.to_bits(), (0.1f64 + 0.2).to_bits());
        match &j0.state {
            JobState::Done { file, summary } => {
                assert_eq!(summary, "summary line\nsecond");
                assert_eq!(store.load_manifest(file).unwrap(), "{\"manifest\": true}");
            }
            other => panic!("job 0 state: {other:?}"),
        }
        let j1 = &rec.jobs[1];
        assert_eq!(j1.spec.config.seed, u64::MAX - 3);
        assert_eq!(j1.state, JobState::Failed { error: "boom \\ bust".into() });
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn running_job_replays_as_resumable_and_counter_never_regresses() {
        let dir = scratch("resume");
        {
            let (mut store, _) = StateStore::open(&dir).unwrap();
            store.record_job(0, "bad key", &spec(1)).unwrap_err(); // rejected before write
            store.record_job(0, "", &spec(1)).unwrap();
            store.record_state(0, &JobState::Running).unwrap();
        }
        let (_store, rec) = StateStore::open(&dir).unwrap();
        assert_eq!(rec.jobs.len(), 1);
        assert_eq!(rec.jobs[0].state, JobState::Running);
        assert_eq!(rec.next_job, 1, "counter must survive even with the job unfinished");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_final_line_is_dropped_with_a_note() {
        let dir = scratch("torn");
        {
            let (mut store, _) = StateStore::open(&dir).unwrap();
            store.record_job(0, "k1", &spec(3)).unwrap();
        }
        // simulate a crash mid-append: partial line, no trailing newline
        let path = dir.join("coordinator.journal");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"state 0 runn").unwrap();
        f.sync_all().unwrap();
        let (_store, rec) = StateStore::open(&dir).unwrap();
        assert_eq!(rec.jobs.len(), 1);
        assert_eq!(rec.jobs[0].state, JobState::Queued, "torn transition must not apply");
        assert_eq!(rec.notes.len(), 1, "torn tail must be noted: {:?}", rec.notes);
        // ...and the journal keeps accepting appends afterwards
        let (mut store, _) = StateStore::open(&dir).unwrap();
        store.record_state(0, &JobState::Running).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn malformed_middle_line_is_a_hard_error() {
        let dir = scratch("corrupt");
        {
            let (mut store, _) = StateStore::open(&dir).unwrap();
            store.record_job(0, "", &spec(3)).unwrap();
        }
        let path = dir.join("coordinator.journal");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"gibberish line\nstate 0 running\n").unwrap();
        f.sync_all().unwrap();
        let err = StateStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("unknown journal verb"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_names_differ_for_same_id_different_sweeps() {
        let a = job_journal_name(3, &spec(7));
        let b = job_journal_name(3, &spec(8)); // different seed = different sweep
        assert_ne!(a, b);
        assert!(a.starts_with("job_3_"), "{a}");
        // and identical sweeps agree (the restart path depends on it)
        assert_eq!(a, job_journal_name(3, &spec(7)));
    }

    #[test]
    fn idempotency_keys_are_validated() {
        validate_idempotency_key("").unwrap();
        validate_idempotency_key("run-42_rev.7").unwrap();
        validate_idempotency_key("has space").unwrap_err();
        validate_idempotency_key("new\nline").unwrap_err();
        validate_idempotency_key("sl/ash").unwrap_err();
        validate_idempotency_key(&"x".repeat(MAX_IDEMPOTENCY_KEY + 1)).unwrap_err();
    }
}
