//! Worker transports: how the dispatcher runs a leased range somewhere.
//!
//! [`WorkerTransport`] is the seam between the [`super::Dispatcher`]'s
//! scheduling logic and the mechanics of executing `gcod sweep-shard
//! --range lo..hi` on a machine: [`LocalProcess`] spawns subprocesses
//! of the `gcod` binary on this host and collects their JSON manifests;
//! an ssh or k8s transport slots in behind the same trait later (the
//! dispatcher never touches a process handle or a path directly).
//!
//! The trait is deliberately poll-based and non-blocking: the
//! dispatcher owns the event loop and calls [`WorkerTransport::poll`]
//! on its own cadence, so a transport never needs threads of its own.
//! For a local process, "heartbeat" degenerates to "the process is
//! still alive"; a *hung* worker stays `Running` forever and is caught
//! by the dispatcher's lease deadline instead.
//!
//! Fault injection does **not** live here: wrap any transport in
//! [`super::chaos::ChaosTransport`] to inject seeded kills, hangs,
//! delays and byzantine corruption (one-shot presets included — see
//! [`super::chaos::ChaosTransport::preset_kill`]). The only simulation
//! hook a transport itself carries is [`WorkerJob::delay_ms`],
//! forwarded to the subprocess via the `GCOD_SWEEP_TEST_DELAY_MS`
//! startup-delay env var so straggling workers can be driven by the
//! crate's own straggler models.

use crate::error::{Error, Result};
use crate::sweep::shard::{ShardResult, SweepConfig};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

pub use super::queue::WorkerId;

/// Environment variable read by `gcod sweep-shard` at startup: sleep
/// this many milliseconds before doing any work. Test/simulation hook
/// for slow and hung workers.
pub const DELAY_ENV: &str = "GCOD_SWEEP_TEST_DELAY_MS";

/// One leased range, fully specified for remote execution.
#[derive(Clone, Debug)]
pub struct WorkerJob {
    pub config: SweepConfig,
    pub lo: usize,
    pub hi: usize,
    /// engine threads inside the worker
    pub threads: usize,
    pub stats_only: bool,
    /// where the worker must write its shard manifest
    pub out_path: PathBuf,
    /// injected startup delay (0 = none) — straggler simulation
    pub delay_ms: u64,
}

/// Non-blocking status of a worker slot.
#[derive(Debug)]
pub enum WorkerPoll {
    /// no job running (nothing started, or the last job was collected)
    Idle,
    Running,
    /// job finished; [`WorkerTransport::collect`] will yield its result
    Done,
    /// the worker died or exited without producing a manifest
    Failed(String),
}

/// Execution backend for dispatcher workers.
pub trait WorkerTransport {
    /// Number of worker slots in the pool.
    fn n_workers(&self) -> usize;

    /// Begin executing `job` on an idle worker slot.
    fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()>;

    /// Current status of the slot. Must not block.
    fn poll(&mut self, worker: WorkerId) -> WorkerPoll;

    /// Tear down whatever runs on the slot (lease timeout, speculation
    /// loser). The slot is idle afterwards.
    fn kill(&mut self, worker: WorkerId);

    /// Retrieve the result of a slot whose last [`WorkerTransport::poll`]
    /// returned [`WorkerPoll::Done`]. The slot becomes idle.
    fn collect(&mut self, worker: WorkerId) -> Result<ShardResult>;
}

// ---------------------------------------------------------------------
// Local subprocess transport
// ---------------------------------------------------------------------

struct Slot {
    child: Option<Child>,
    out_path: PathBuf,
    /// worker stderr sidecar file — a file, not a pipe, so a chatty or
    /// panicking worker can never block on a full pipe buffer
    err_path: PathBuf,
}

/// Runs each leased range as a `gcod sweep-shard --range lo..hi`
/// subprocess on this host. The process boundary is real — workers
/// share nothing with the dispatcher but the manifest files — so this
/// transport exercises exactly the contract a multi-host transport
/// needs.
pub struct LocalProcess {
    gcod_bin: PathBuf,
    slots: Vec<Slot>,
}

impl LocalProcess {
    /// `gcod_bin` is the `gcod` binary to spawn (the dispatcher CLI
    /// passes its own `std::env::current_exe()`; tests pass
    /// `env!("CARGO_BIN_EXE_gcod")`).
    pub fn new(gcod_bin: impl Into<PathBuf>, workers: usize) -> Self {
        let gcod_bin = gcod_bin.into();
        let slots = (0..workers.max(1))
            .map(|_| Slot {
                child: None,
                out_path: PathBuf::new(),
                err_path: PathBuf::new(),
            })
            .collect();
        Self { gcod_bin, slots }
    }

}

/// The `gcod sweep-shard` argument vector executing `job`. Shared by
/// every transport that runs leases as subprocesses ([`LocalProcess`]
/// here, the remote side of [`super::tcp::worker_loop`]), so local and
/// remote leases are the same invocation by construction.
pub fn shard_args(job: &WorkerJob) -> Vec<String> {
    let c = &job.config;
    let mut args = vec![
        "sweep-shard".into(),
        "--sweep".into(),
        c.sweep.as_str().into(),
        "--scheme".into(),
        c.scheme.clone(),
        "--decoder".into(),
        c.decoder.clone(),
        // shortest round-trip Display: the worker re-parses the
        // exact same f64 bits
        "--p".into(),
        format!("{}", c.p),
        "--trials".into(),
        c.trials.to_string(),
        "--seed".into(),
        c.seed.to_string(),
        "--chunk".into(),
        c.chunk.to_string(),
        "--threads".into(),
        job.threads.to_string(),
        "--range".into(),
        format!("{}..{}", job.lo, job.hi),
        "--out".into(),
        job.out_path.display().to_string(),
    ];
    if job.stats_only {
        args.push("--stats-only".into());
    }
    for (k, v) in &c.params {
        args.push("--set".into());
        args.push(format!("{k}={v}"));
    }
    args
}

impl WorkerTransport for LocalProcess {
    fn n_workers(&self) -> usize {
        self.slots.len()
    }

    fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()> {
        let slot = &mut self.slots[worker];
        if slot.child.is_some() {
            return Err(Error::msg(format!("worker {worker} is already running a job")));
        }
        let err_path = job.out_path.with_extension("stderr.log");
        let err_file = std::fs::File::create(&err_path)
            .map_err(|e| Error::msg(format!("create {}: {e}", err_path.display())))?;
        let mut cmd = Command::new(&self.gcod_bin);
        cmd.args(shard_args(job)).stdout(Stdio::null()).stderr(Stdio::from(err_file));
        if job.delay_ms > 0 {
            cmd.env(DELAY_ENV, job.delay_ms.to_string());
        }
        let child = cmd.spawn().map_err(|e| {
            Error::msg(format!("spawn {} for worker {worker}: {e}", self.gcod_bin.display()))
        })?;
        slot.child = Some(child);
        slot.out_path = job.out_path.clone();
        slot.err_path = err_path;
        Ok(())
    }

    fn poll(&mut self, worker: WorkerId) -> WorkerPoll {
        let slot = &mut self.slots[worker];
        let Some(child) = slot.child.as_mut() else { return WorkerPoll::Idle };
        match child.try_wait() {
            Ok(None) => WorkerPoll::Running,
            Ok(Some(status)) => {
                slot.child = None;
                let stderr = read_tail(&slot.err_path, 4096);
                let _ = std::fs::remove_file(&slot.err_path);
                if status.success() && slot.out_path.is_file() {
                    WorkerPoll::Done
                } else {
                    WorkerPoll::Failed(format!(
                        "worker {worker} process exited ({status}) without a manifest{}{}",
                        if stderr.is_empty() { "" } else { ": " },
                        stderr
                    ))
                }
            }
            Err(e) => {
                slot.child = None;
                WorkerPoll::Failed(format!("worker {worker} wait failed: {e}"))
            }
        }
    }

    fn kill(&mut self, worker: WorkerId) {
        let slot = &mut self.slots[worker];
        if let Some(mut child) = slot.child.take() {
            let _ = child.kill();
            let _ = child.wait(); // reap
            let _ = std::fs::remove_file(&slot.err_path);
            // the job's manifest will never be collected — don't let a
            // just-finished-then-killed worker leave a stale file
            let _ = std::fs::remove_file(&slot.out_path);
        }
    }

    fn collect(&mut self, worker: WorkerId) -> Result<ShardResult> {
        let path = self.slots[worker].out_path.clone();
        let res = ShardResult::read(&path);
        // the manifest was parsed (or is corrupt) — either way the file
        // has served its purpose
        let _ = std::fs::remove_file(&path);
        res
    }
}

impl Drop for LocalProcess {
    fn drop(&mut self) {
        for w in 0..self.slots.len() {
            self.kill(w);
        }
    }
}

/// Last `max` bytes of a worker's stderr sidecar file, lossy-decoded
/// and trimmed — enough context for the failure log without ever
/// holding a pipe the worker could block on.
pub(crate) fn read_tail(path: &Path, max: usize) -> String {
    let Ok(bytes) = std::fs::read(path) else { return String::new() };
    let start = bytes.len().saturating_sub(max);
    String::from_utf8_lossy(&bytes[start..]).trim().to_string()
}
