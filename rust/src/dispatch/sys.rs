//! Minimal, dependency-free OS hooks for graceful shutdown.
//!
//! The crate deliberately carries no `libc`-style dependency, so the
//! one platform facility the coordinator needs — noticing SIGTERM so
//! `gcod serve` can drain instead of dying mid-lease — is declared here
//! as a single `extern "C"` binding to the C `signal(2)` entry point.
//! The handler does the only thing that is async-signal-safe and the
//! only thing required: set one atomic flag. The serve loop polls the
//! flag at its tick cadence via
//! [`ServeConfig::drain`](super::server::ServeConfig::drain); nothing
//! else happens in signal context.
//!
//! On non-unix targets the install function is a no-op returning
//! `false`; tests never rely on real signals either way — they flip the
//! same drain flag directly.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::sync::OnceLock;

/// The drain flag the SIGTERM handler flips. Handed out as an `Arc` so
/// the serve config and the handler observe the same bool.
static DRAIN_FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();

/// `SIGTERM` on every unix this builds on.
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" {
    /// C `signal(2)`. Using the historical `signal` (not `sigaction`)
    /// keeps the FFI surface to one symbol; its semantics (handler
    /// stays installed, BSD restart behavior) are fine for a polled
    /// flag. The return is the previous handler's address, pointer-
    /// sized — declared `usize` since it is only compared to SIG_ERR.
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

#[cfg(unix)]
extern "C" fn on_sigterm(_signum: i32) {
    // async-signal-safe: one relaxed atomic store, nothing else
    if let Some(flag) = DRAIN_FLAG.get() {
        flag.store(true, Ordering::Relaxed);
    }
}

/// Install a SIGTERM → drain-flag handler and return the flag (wire it
/// into [`ServeConfig::drain`](super::server::ServeConfig::drain)).
/// Returns `None` on platforms without signals or if installation
/// fails; the caller serves without signal-triggered drain then.
pub fn install_sigterm_drain() -> Option<Arc<AtomicBool>> {
    let flag = DRAIN_FLAG.get_or_init(|| Arc::new(AtomicBool::new(false))).clone();
    install(&flag).then_some(flag)
}

#[cfg(unix)]
fn install(_flag: &Arc<AtomicBool>) -> bool {
    // SIG_ERR is -1 as a function address
    const SIG_ERR: usize = usize::MAX;
    // SAFETY: `on_sigterm` is an `extern "C" fn(i32)` matching the
    // sighandler signature, and it only performs an atomic store.
    unsafe { signal(SIGTERM, on_sigterm) != SIG_ERR }
}

#[cfg(not(unix))]
fn install(_flag: &Arc<AtomicBool>) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(unix)]
    fn sigterm_flips_the_drain_flag() {
        let flag = install_sigterm_drain().expect("handler install");
        assert!(!flag.load(Ordering::Relaxed));
        // raise SIGTERM in-process: the handler must set the flag and
        // the process must survive (default disposition would kill it)
        extern "C" {
            fn raise(signum: i32) -> i32;
        }
        // SAFETY: raising a signal whose handler we just installed.
        unsafe {
            assert_eq!(raise(SIGTERM), 0);
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !flag.load(Ordering::Relaxed) {
            assert!(std::time::Instant::now() < deadline, "flag never set");
            std::thread::yield_now();
        }
        flag.store(false, Ordering::Relaxed); // leave no state for other tests
    }
}
