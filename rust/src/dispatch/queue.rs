//! Lease-based work queue over a trial range.
//!
//! [`WorkQueue`] carves `[0, N)` into contiguous ranges of up to
//! `grain` trials, aligned to the engine's chunk grid (split points are
//! multiples of `chunk`, so `TrialEngine::run_range_map` never has to
//! warm-replay a partial leading chunk). Ranges are carved lazily from
//! a frontier as workers ask for work; with
//! [`WorkQueue::new_adaptive`], the carve size **shrinks as the
//! frontier drains** (geometrically, down to `min_grain`), so the last
//! leases are small and the sweep's tail is spread across workers
//! instead of waiting on one straggler holding a full-grain lease.
//! Ranges are handed out as [`Lease`]s with issue timestamps; the
//! dispatcher re-enqueues the range of a lease whose worker died or
//! exceeded its deadline, with a bounded per-range retry budget
//! (failed ranges are re-leased whole, never re-carved, so the
//! per-range retry key stays stable). Completion is tracked as a set
//! of coalesced done-intervals, which makes duplicate covers
//! (speculative re-execution) harmless bookkeeping: a range can
//! complete twice, and leases whose range is already fully covered are
//! reported by [`WorkQueue::redundant`] so the dispatcher can cancel
//! them.

use crate::error::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Index of a worker slot in the transport's pool.
pub type WorkerId = usize;

/// Monotonic identifier of one issued lease.
pub type LeaseId = u64;

/// One outstanding claim on a trial range.
#[derive(Clone, Debug)]
pub struct Lease {
    pub id: LeaseId,
    pub lo: usize,
    pub hi: usize,
    pub worker: WorkerId,
    pub issued: Instant,
    /// duplicate cover of a range some other lease is still running
    pub speculative: bool,
}

/// How lease sizes are carved from the frontier. Fresh leases shrink
/// toward the tail under `Adaptive`; re-enqueued (failed) ranges are
/// always handed out whole regardless of policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum GrainPolicy {
    /// every carve is exactly `grain` (the last is ragged)
    Fixed,
    /// carve `clamp(remaining / ADAPTIVE_SHRINK, min_grain, grain)`,
    /// chunk-rounded: full-grain leases while the frontier is deep,
    /// geometrically shrinking ones (tail-latency) as it drains
    Adaptive { min_grain: usize },
}

/// Adaptive carves target this many remaining leases' worth of frontier
/// (~4 outstanding tails keeps every worker busy through the drain
/// without collapsing to per-chunk dispatch overhead too early).
const ADAPTIVE_SHRINK: usize = 4;

/// Elastic range queue: an un-leased frontier (carved on demand),
/// failed ranges awaiting re-lease, outstanding leases, coalesced
/// done-intervals and per-range retry counts.
#[derive(Debug)]
pub struct WorkQueue {
    trials: usize,
    chunk: usize,
    /// max carve size, rounded up to the chunk grid
    grain: usize,
    policy: GrainPolicy,
    /// first never-leased trial: fresh leases carve `[frontier, ...)`
    frontier: usize,
    /// failed ranges awaiting re-lease (whole, so the retry key below
    /// stays stable)
    requeued: VecDeque<(usize, usize)>,
    active: BTreeMap<LeaseId, Lease>,
    /// sorted, disjoint, coalesced completed intervals
    done: Vec<(usize, usize)>,
    /// re-enqueue count per original range (keyed by bounds — ranges
    /// are never re-split, so the key is stable)
    retries: BTreeMap<(usize, usize), usize>,
    max_retries: usize,
    next_id: LeaseId,
}

impl WorkQueue {
    /// Fixed-grain queue: `[0, trials)` is carved into ranges of
    /// `grain` trials rounded up to a multiple of `chunk` (the last
    /// range is ragged).
    pub fn new(trials: usize, grain: usize, chunk: usize, max_retries: usize) -> Result<Self> {
        Self::with_policy(trials, grain, chunk, max_retries, GrainPolicy::Fixed)
    }

    /// Adaptive-grain queue: carve sizes start at `grain` and shrink
    /// geometrically toward `min_grain` (chunk-rounded) as the frontier
    /// drains, cutting the tail latency of the final leases. The merged
    /// sweep bits are unaffected — lease boundaries stay on the chunk
    /// grid, and per-trial values are split-invariant.
    pub fn new_adaptive(
        trials: usize,
        grain: usize,
        min_grain: usize,
        chunk: usize,
        max_retries: usize,
    ) -> Result<Self> {
        if min_grain == 0 {
            return Err(Error::msg("work queue min grain must be >= 1"));
        }
        let min_grain = min_grain.min(trials.max(1)).div_ceil(chunk.max(1)) * chunk.max(1);
        Self::with_policy(trials, grain, chunk, max_retries, GrainPolicy::Adaptive { min_grain })
    }

    /// Fixed-grain queue resuming from a checkpoint: `completed` ranges
    /// (from a dispatch journal) are pre-marked done and never
    /// re-leased; the uncovered gaps are carved into grain-sized,
    /// chunk-aligned ranges up front and handed out like re-enqueued
    /// work. A queue resumed with full coverage reports
    /// [`WorkQueue::is_complete`] immediately.
    pub fn resume(
        trials: usize,
        grain: usize,
        chunk: usize,
        max_retries: usize,
        completed: &[(usize, usize)],
    ) -> Result<Self> {
        let mut q = Self::new(trials, grain, chunk, max_retries)?;
        for &(lo, hi) in completed {
            if lo > hi || hi > trials {
                return Err(Error::msg(format!(
                    "journalled range [{lo}, {hi}) outside sweep of {trials} trials"
                )));
            }
            q.mark_done(lo, hi);
        }
        // carve the complement of the (coalesced) done set; nothing is
        // left on the frontier
        let done = q.done.clone();
        let mut cursor = 0usize;
        for &(dlo, dhi) in done.iter().chain(std::iter::once(&(trials, trials))) {
            let mut lo = cursor;
            while lo < dlo {
                let hi = (lo + q.grain).min(dlo);
                q.requeued.push_back((lo, hi));
                lo = hi;
            }
            cursor = cursor.max(dhi);
        }
        q.frontier = trials;
        Ok(q)
    }

    fn with_policy(
        trials: usize,
        grain: usize,
        chunk: usize,
        max_retries: usize,
        policy: GrainPolicy,
    ) -> Result<Self> {
        if trials == 0 {
            return Err(Error::msg("work queue needs at least one trial"));
        }
        if grain == 0 || chunk == 0 {
            return Err(Error::msg("work queue grain and chunk must be >= 1"));
        }
        // clamp before rounding up to the chunk grid: a grain beyond
        // the sweep is just "one lease", and the clamp keeps the
        // round-up multiply from overflowing on absurd inputs
        let grain = grain.min(trials).div_ceil(chunk) * chunk;
        let policy = match policy {
            GrainPolicy::Adaptive { min_grain } => {
                GrainPolicy::Adaptive { min_grain: min_grain.min(grain) }
            }
            GrainPolicy::Fixed => GrainPolicy::Fixed,
        };
        Ok(Self {
            trials,
            chunk,
            grain,
            policy,
            frontier: 0,
            requeued: VecDeque::new(),
            active: BTreeMap::new(),
            done: Vec::new(),
            retries: BTreeMap::new(),
            max_retries,
            next_id: 0,
        })
    }

    pub fn trials(&self) -> usize {
        self.trials
    }

    /// Lease-able ranges left: re-enqueued failures plus the frontier
    /// at the current carve size (an estimate under the adaptive
    /// policy, where later carves may be smaller).
    pub fn pending_ranges(&self) -> usize {
        let rem = self.trials - self.frontier;
        self.requeued.len() + if rem == 0 { 0 } else { rem.div_ceil(self.next_carve().max(1)) }
    }

    pub fn active_leases(&self) -> usize {
        self.active.len()
    }

    /// Size of the next fresh carve from the frontier.
    fn next_carve(&self) -> usize {
        let remaining = self.trials - self.frontier;
        let size = match self.policy {
            GrainPolicy::Fixed => self.grain,
            GrainPolicy::Adaptive { min_grain } => {
                let target = remaining.div_ceil(ADAPTIVE_SHRINK).div_ceil(self.chunk) * self.chunk;
                target.clamp(min_grain, self.grain)
            }
        };
        size.min(remaining)
    }

    /// Claim the next pending range for `worker`: a failed range
    /// awaiting re-lease first (whole, retry-key stability), else a
    /// fresh carve from the frontier.
    pub fn lease(&mut self, worker: WorkerId) -> Option<Lease> {
        if let Some((lo, hi)) = self.requeued.pop_front() {
            return Some(self.issue(lo, hi, worker, false));
        }
        if self.frontier >= self.trials {
            return None;
        }
        let lo = self.frontier;
        let hi = lo + self.next_carve();
        self.frontier = hi;
        Some(self.issue(lo, hi, worker, false))
    }

    /// With nothing pending, duplicate the oldest still-running range
    /// onto an idle worker (speculative re-execution of the slowest
    /// ranges — safe because [`crate::sweep::shard::dedup_cover`] drops
    /// duplicate covers before the merge). At most one duplicate per
    /// range is issued.
    pub fn speculative_lease(&mut self, worker: WorkerId) -> Option<Lease> {
        if !self.requeued.is_empty() || self.frontier < self.trials {
            return None;
        }
        let candidate = self
            .active
            .values()
            .filter(|l| {
                !l.speculative
                    && !self.range_done(l.lo, l.hi)
                    && !self
                        .active
                        .values()
                        .any(|o| o.speculative && (o.lo, o.hi) == (l.lo, l.hi))
            })
            .min_by_key(|l| l.issued)
            .map(|l| (l.lo, l.hi))?;
        Some(self.issue(candidate.0, candidate.1, worker, true))
    }

    fn issue(&mut self, lo: usize, hi: usize, worker: WorkerId, speculative: bool) -> Lease {
        let lease = Lease { id: self.next_id, lo, hi, worker, issued: Instant::now(), speculative };
        self.next_id += 1;
        self.active.insert(lease.id, lease.clone());
        lease
    }

    pub fn get(&self, id: LeaseId) -> Option<&Lease> {
        self.active.get(&id)
    }

    /// The lease's range finished successfully: retire the lease and
    /// mark the interval covered.
    pub fn complete(&mut self, id: LeaseId) -> Result<Lease> {
        let lease =
            self.active.remove(&id).ok_or_else(|| Error::msg(format!("unknown lease {id}")))?;
        self.mark_done(lease.lo, lease.hi);
        Ok(lease)
    }

    /// The lease's worker died, timed out or returned garbage: retire
    /// the lease and re-enqueue its range unless a duplicate cover
    /// already completed it — or is still running it (a failed
    /// speculative duplicate must neither resurrect the range nor
    /// charge its retry budget while the healthy original is mid-run,
    /// and vice versa). Errors once a range exhausts its retry budget —
    /// the dispatcher fails loudly rather than spinning. Returns the
    /// lease and whether the range was re-enqueued.
    pub fn fail(&mut self, id: LeaseId) -> Result<(Lease, bool)> {
        let lease =
            self.active.remove(&id).ok_or_else(|| Error::msg(format!("unknown lease {id}")))?;
        if self.range_done(lease.lo, lease.hi) {
            return Ok((lease, false));
        }
        if self.active.values().any(|o| (o.lo, o.hi) == (lease.lo, lease.hi)) {
            return Ok((lease, false));
        }
        let tries = self.retries.entry((lease.lo, lease.hi)).or_insert(0);
        *tries += 1;
        if *tries > self.max_retries {
            return Err(Error::msg(format!(
                "trial range [{}, {}) failed {} times (max {} retries) — giving up",
                lease.lo, lease.hi, *tries, self.max_retries
            )));
        }
        self.requeued.push_back((lease.lo, lease.hi));
        Ok((lease, true))
    }

    /// Retire a lease without re-enqueueing (its range was finished by
    /// a duplicate cover).
    pub fn cancel(&mut self, id: LeaseId) -> Option<Lease> {
        self.active.remove(&id)
    }

    /// Active leases past their deadline. The effective deadline scales
    /// with lease length — `base + per_trial * (hi - lo)` — because a
    /// flat timeout tuned for tail leases wrongly reaps healthy workers
    /// holding full-grain head leases under the adaptive policy. Pass
    /// `per_trial = ZERO` for the old flat behaviour.
    pub fn expired(&self, base: Duration, per_trial: Duration) -> Vec<LeaseId> {
        self.active
            .values()
            .filter(|l| {
                let len = u32::try_from(l.hi - l.lo).unwrap_or(u32::MAX);
                l.issued.elapsed() > base + per_trial.saturating_mul(len)
            })
            .map(|l| l.id)
            .collect()
    }

    /// Invalidate a previously-completed cover of `[lo, hi)` (the
    /// result audit condemned the worker that banked it): carve the
    /// interval back out of the done set and re-enqueue it — *without*
    /// charging the per-range retry budget, because honest progress
    /// shouldn't pay for an adversary's forgeries. The bounds are
    /// always original lease bounds, so the retry-key stability
    /// contract holds. If an active lease or pending requeue already
    /// covers the range, it is only uncovered, not double-enqueued.
    /// Returns whether the range was re-enqueued here.
    pub fn reopen(&mut self, lo: usize, hi: usize) -> bool {
        if lo >= hi || hi > self.trials {
            return false;
        }
        let mut next = Vec::with_capacity(self.done.len() + 1);
        for &(a, b) in &self.done {
            if b <= lo || a >= hi {
                next.push((a, b));
                continue;
            }
            if a < lo {
                next.push((a, lo));
            }
            if hi < b {
                next.push((hi, b));
            }
        }
        self.done = next;
        if self.active.values().any(|l| l.lo <= lo && hi <= l.hi) {
            return false;
        }
        if self.requeued.iter().any(|&(a, b)| a <= lo && hi <= b) {
            return false;
        }
        self.requeued.push_back((lo, hi));
        true
    }

    /// Active leases whose whole range is already covered by completed
    /// duplicates — speculation losers the dispatcher should cancel.
    pub fn redundant(&self) -> Vec<LeaseId> {
        self.active
            .values()
            .filter(|l| self.range_done(l.lo, l.hi))
            .map(|l| l.id)
            .collect()
    }

    /// Every trial in `[0, trials)` has a completed cover.
    pub fn is_complete(&self) -> bool {
        self.done == [(0, self.trials)]
    }

    /// Trials with a completed cover — the dispatcher's progress gauge
    /// (`queue_done_trials` in the metrics registry).
    pub fn done_trials(&self) -> usize {
        self.done.iter().map(|&(a, b)| b - a).sum()
    }

    /// Retry attempts charged to range `[lo, hi)` so far (observability:
    /// the `lease-retried` event reports the attempt number).
    pub fn retry_count(&self, lo: usize, hi: usize) -> usize {
        self.retries.get(&(lo, hi)).copied().unwrap_or(0)
    }

    fn range_done(&self, lo: usize, hi: usize) -> bool {
        lo == hi || self.done.iter().any(|&(a, b)| a <= lo && hi <= b)
    }

    fn mark_done(&mut self, lo: usize, hi: usize) {
        if lo == hi {
            return;
        }
        self.done.push((lo, hi));
        self.done.sort_unstable();
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.done.len());
        for &(lo, hi) in &self.done {
            match merged.last_mut() {
                Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
                _ => merged.push((lo, hi)),
            }
        }
        self.done = merged;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_aligns_to_chunk_grid() {
        // grain 10 rounds up to 16; ranges are [0,16), [16,32), ... [96,100)
        let mut q = WorkQueue::new(100, 10, 8, 3).unwrap();
        let mut lo = 0;
        while let Some(l) = q.lease(0) {
            assert_eq!(l.lo, lo);
            assert!(l.lo % 16 == 0);
            assert!(l.hi - l.lo <= 16);
            lo = l.hi;
        }
        assert_eq!(lo, 100);
        // rejects degenerate inputs
        assert!(WorkQueue::new(0, 4, 4, 3).is_err());
        assert!(WorkQueue::new(10, 0, 4, 3).is_err());
        assert!(WorkQueue::new(10, 4, 0, 3).is_err());
        // absurd grain clamps to one whole-sweep lease, no overflow
        let mut q = WorkQueue::new(10, usize::MAX, 32, 3).unwrap();
        let l = q.lease(0).unwrap();
        assert_eq!((l.lo, l.hi), (0, 10));
        assert_eq!(q.pending_ranges(), 0);
    }

    #[test]
    fn complete_all_leases_completes_queue() {
        let mut q = WorkQueue::new(40, 16, 8, 3).unwrap();
        assert!(!q.is_complete());
        let mut ids = Vec::new();
        while let Some(l) = q.lease(ids.len() % 3) {
            ids.push(l.id);
        }
        assert_eq!(q.pending_ranges(), 0);
        for id in ids {
            q.complete(id).unwrap();
        }
        assert!(q.is_complete());
        assert_eq!(q.active_leases(), 0);
    }

    #[test]
    fn fail_requeues_until_retry_budget_exhausted() {
        let mut q = WorkQueue::new(16, 16, 8, 2).unwrap();
        for round in 0..2 {
            let l = q.lease(0).unwrap();
            let (lease, requeued) = q.fail(l.id).unwrap();
            assert_eq!((lease.lo, lease.hi), (0, 16), "round {round}");
            assert!(requeued);
        }
        let l = q.lease(0).unwrap();
        let err = q.fail(l.id).unwrap_err();
        assert!(format!("{err}").contains("giving up"), "{err}");
    }

    #[test]
    fn fail_after_duplicate_completion_does_not_requeue() {
        let mut q = WorkQueue::new(16, 16, 8, 1).unwrap();
        let a = q.lease(0).unwrap();
        // speculation: nothing pending, duplicate the running range
        let b = q.speculative_lease(1).unwrap();
        assert!(b.speculative);
        assert_eq!((b.lo, b.hi), (a.lo, a.hi));
        // only one duplicate per range
        assert!(q.speculative_lease(2).is_none());
        q.complete(b.id).unwrap();
        assert!(q.is_complete());
        // the original lease is now redundant; failing it must not
        // resurrect the range
        assert_eq!(q.redundant(), vec![a.id]);
        let (_, requeued) = q.fail(a.id).unwrap();
        assert!(!requeued);
        assert_eq!(q.pending_ranges(), 0);
    }

    #[test]
    fn failed_duplicate_is_free_while_a_live_lease_covers_the_range() {
        let mut q = WorkQueue::new(16, 16, 8, 1).unwrap();
        let a = q.lease(0).unwrap();
        let b = q.speculative_lease(1).unwrap();
        // the duplicate dies while the original is mid-run: no requeue,
        // no retry charge
        let (_, requeued) = q.fail(b.id).unwrap();
        assert!(!requeued);
        assert_eq!(q.pending_ranges(), 0);
        // the original dies too: now the range really is lost -> requeue
        let (_, requeued) = q.fail(a.id).unwrap();
        assert!(requeued);
        // and the budget only counts real losses: one retry left burns
        // on the next failure
        let c = q.lease(0).unwrap();
        let err = q.fail(c.id).unwrap_err();
        assert!(format!("{err}").contains("giving up"), "{err}");
    }

    #[test]
    fn expiry_is_time_based() {
        let mut q = WorkQueue::new(16, 16, 8, 3).unwrap();
        let l = q.lease(0).unwrap();
        assert!(q.expired(Duration::from_secs(60), Duration::ZERO).is_empty());
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(q.expired(Duration::ZERO, Duration::ZERO), vec![l.id]);
    }

    #[test]
    fn expiry_deadline_scales_with_lease_length() {
        // two leases: [0,64) and [64,80) — after a beat, a zero base
        // with a generous per-trial rate reaps only the short one
        let mut q = WorkQueue::new(80, 64, 8, 3).unwrap();
        let big = q.lease(0).unwrap();
        assert_eq!((big.lo, big.hi), (0, 64));
        let small = q.lease(1).unwrap();
        assert_eq!((small.lo, small.hi), (64, 80));
        std::thread::sleep(Duration::from_millis(20));
        let per_trial = Duration::from_millis(1); // big: 64ms, small: 16ms
        assert_eq!(q.expired(Duration::ZERO, per_trial), vec![small.id]);
        // a long enough base keeps both alive regardless of length
        assert!(q.expired(Duration::from_secs(60), Duration::ZERO).is_empty());
    }

    #[test]
    fn reopen_uncovers_and_requeues_without_retry_charge() {
        let mut q = WorkQueue::new(32, 16, 8, 0).unwrap(); // zero retries!
        let a = q.lease(0).unwrap(); // [0,16)
        let b = q.lease(1).unwrap(); // [16,32)
        q.complete(a.id).unwrap();
        q.complete(b.id).unwrap();
        assert!(q.is_complete());
        // audit condemns the worker that banked [0,16)
        assert!(q.reopen(0, 16));
        assert!(!q.is_complete());
        // double-reopen is idempotent: requeue already covers it
        assert!(!q.reopen(0, 16));
        let r = q.lease(2).unwrap();
        assert_eq!((r.lo, r.hi), (0, 16));
        // even with max_retries = 0 the reopened range carried no
        // retry charge; its first real failure still gets a requeue
        // denied only by the budget (0 here -> error), proving reopen
        // itself never touched the counter
        q.complete(r.id).unwrap();
        assert!(q.is_complete());
    }

    #[test]
    fn reopen_with_live_cover_only_uncovers() {
        let mut q = WorkQueue::new(16, 16, 8, 3).unwrap();
        let a = q.lease(0).unwrap();
        q.complete(a.id).unwrap();
        // a speculative duplicate issued before the audit verdict is
        // still running: reopen must not double-enqueue the range
        let mut q2 = WorkQueue::new(16, 16, 8, 3).unwrap();
        let x = q2.lease(0).unwrap();
        let s = q2.speculative_lease(1).unwrap();
        q2.complete(x.id).unwrap();
        assert!(q2.is_complete());
        assert!(!q2.reopen(0, 16), "live lease covers the range");
        assert!(!q2.is_complete());
        q2.complete(s.id).unwrap();
        assert!(q2.is_complete(), "the live cover re-banks the range");
        // out-of-range / empty reopens are rejected
        assert!(!q.reopen(8, 8));
        assert!(!q.reopen(0, 999));
    }

    #[test]
    fn reopen_splits_coalesced_done_intervals() {
        let mut q = WorkQueue::new(48, 16, 16, 3).unwrap();
        let ids: Vec<_> = std::iter::from_fn(|| q.lease(0)).map(|l| l.id).collect();
        for id in ids {
            q.complete(id).unwrap();
        }
        assert!(q.is_complete());
        // reopening the middle lease splits [0,48) into [0,16)+[32,48)
        assert!(q.reopen(16, 32));
        assert!(!q.is_complete());
        let r = q.lease(1).unwrap();
        assert_eq!((r.lo, r.hi), (16, 32));
        q.complete(r.id).unwrap();
        assert!(q.is_complete());
    }

    #[test]
    fn adaptive_grain_shrinks_toward_the_tail() {
        // 256 trials, grain 64, min 8, chunk 8: early carves are
        // full-grain, later ones shrink geometrically to the floor
        let mut q = WorkQueue::new_adaptive(256, 64, 8, 8, 3).unwrap();
        let mut sizes = Vec::new();
        let mut lo = 0usize;
        while let Some(l) = q.lease(0) {
            assert_eq!(l.lo, lo, "carves stay contiguous");
            assert!(l.lo % 8 == 0, "chunk-aligned start");
            assert!(l.hi == 256 || l.hi % 8 == 0, "chunk-aligned end");
            sizes.push(l.hi - l.lo);
            lo = l.hi;
        }
        assert_eq!(lo, 256, "carves cover the sweep");
        assert_eq!(sizes[0], 64, "deep frontier carves at full grain");
        assert!(sizes.last().unwrap() <= &8, "tail carve at the floor: {sizes:?}");
        // monotone non-increasing carve sizes
        assert!(sizes.windows(2).all(|w| w[0] >= w[1]), "{sizes:?}");
        // strictly more ranges than fixed-grain would produce
        assert!(sizes.len() > 256 / 64, "{sizes:?}");
    }

    #[test]
    fn adaptive_failed_ranges_release_whole() {
        let mut q = WorkQueue::new_adaptive(256, 32, 8, 8, 2).unwrap();
        let a = q.lease(0).unwrap(); // [0, 32): deep frontier, full grain
        let (lease, requeued) = q.fail(a.id).unwrap();
        assert!(requeued);
        // the re-lease hands back the exact failed bounds even though a
        // fresh carve at this frontier depth would be smaller — the
        // retry budget stays keyed to stable bounds
        let b = q.lease(1).unwrap();
        assert_eq!((b.lo, b.hi), (lease.lo, lease.hi));
        let (_, requeued) = q.fail(b.id).unwrap();
        assert!(requeued);
        let c = q.lease(0).unwrap();
        let err = q.fail(c.id).unwrap_err();
        assert!(format!("{err}").contains("giving up"), "{err}");
    }

    #[test]
    fn adaptive_validates_min_grain() {
        assert!(WorkQueue::new_adaptive(64, 32, 0, 8, 3).is_err());
        // min above grain clamps rather than erroring
        let mut q = WorkQueue::new_adaptive(64, 16, 1000, 8, 3).unwrap();
        let l = q.lease(0).unwrap();
        assert!(l.hi - l.lo <= 16);
    }

    #[test]
    fn resume_releases_only_uncovered_gaps() {
        // 80 trials, chunk 8, grain 16; [16,32) and [48,64) already done
        let mut q = WorkQueue::resume(80, 16, 8, 3, &[(16, 32), (48, 64)]).unwrap();
        assert!(!q.is_complete());
        let mut got = Vec::new();
        let mut ids = Vec::new();
        while let Some(l) = q.lease(0) {
            got.push((l.lo, l.hi));
            ids.push(l.id);
        }
        assert_eq!(got, vec![(0, 16), (32, 48), (64, 80)]);
        for id in ids {
            q.complete(id).unwrap();
        }
        assert!(q.is_complete());
        // overlapping/adjacent journal entries coalesce; failed resumed
        // ranges still charge the retry budget normally
        let mut q = WorkQueue::resume(32, 32, 8, 1, &[(0, 8), (8, 16), (4, 12)]).unwrap();
        let l = q.lease(0).unwrap();
        assert_eq!((l.lo, l.hi), (16, 32));
        let (_, requeued) = q.fail(l.id).unwrap();
        assert!(requeued);
        let l = q.lease(0).unwrap();
        assert_eq!((l.lo, l.hi), (16, 32));
        assert!(q.fail(l.id).is_err());
    }

    #[test]
    fn resume_with_full_coverage_is_immediately_complete() {
        let q = WorkQueue::resume(40, 16, 8, 3, &[(0, 24), (24, 40)]).unwrap();
        assert!(q.is_complete());
        assert_eq!(q.pending_ranges(), 0);
        // ranges outside the sweep are rejected
        assert!(WorkQueue::resume(40, 16, 8, 3, &[(0, 48)]).is_err());
        assert!(WorkQueue::resume(40, 16, 8, 3, &[(8, 4)]).is_err());
        // an empty journal degenerates to... everything requeued
        let mut q = WorkQueue::resume(40, 16, 8, 3, &[]).unwrap();
        let mut covered = 0;
        while let Some(l) = q.lease(0) {
            assert_eq!(l.lo, covered);
            covered = l.hi;
        }
        assert_eq!(covered, 40);
    }

    #[test]
    fn done_intervals_coalesce_across_duplicates() {
        let mut q = WorkQueue::new(48, 16, 16, 3).unwrap();
        let a = q.lease(0).unwrap(); // [0,16)
        let b = q.lease(1).unwrap(); // [16,32)
        let c = q.lease(2).unwrap(); // [32,48)
        q.complete(c.id).unwrap();
        q.complete(a.id).unwrap();
        assert!(!q.is_complete());
        q.complete(b.id).unwrap();
        assert!(q.is_complete());
    }
}
