//! TCP transport: leases served to remote `gcod worker` processes.
//!
//! Two halves of one socket protocol (see [`super::protocol`]):
//!
//! * **Coordinator side** — [`TcpTransport`] implements
//!   [`WorkerTransport`] over a pool of registered worker connections,
//!   so the [`Dispatcher`](super::Dispatcher) (and therefore leases,
//!   deadlines, retries, speculation, journaling, audits, quarantine
//!   and [`ChaosTransport`](super::chaos::ChaosTransport) wrapping)
//!   works across hosts unchanged. `kill` really kills: it sends a
//!   kill frame and the remote worker tears down its shard subprocess,
//!   which is what makes chaos drills meaningful over TCP.
//! * **Worker side** — [`worker_loop`] connects out to a coordinator,
//!   registers with a capability class, and serves leases by spawning
//!   `gcod sweep-shard --range lo..hi` subprocesses (the same process
//!   boundary [`LocalProcess`](super::transport::LocalProcess) uses, so
//!   a remote lease computes byte-identical manifests by construction)
//!   and returning the manifest text verbatim.
//!
//! Stale replies cannot corrupt a sweep: every lease carries a
//! coordinator-assigned job id, replies tagged with any other id are
//! dropped on the floor, and every returned manifest still passes the
//! full structural validation + optional byte-audit pipeline that local
//! results do.

use super::protocol::{Conn, LeaseSpec, Msg};
use super::queue::WorkerId;
use super::transport::{read_tail, shard_args, WorkerJob, WorkerPoll, WorkerTransport, DELAY_ENV};
use crate::error::{Error, Result};
use crate::obs::{Event, Obs};
use crate::sweep::shard::ShardResult;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Worker → coordinator liveness cadence.
pub const HEARTBEAT_INTERVAL: Duration = Duration::from_millis(500);

/// Default half-open-peer reap window: a busy worker silent this long
/// is presumed dead even if the kernel still thinks the connection is
/// up (half-open TCP). Generous relative to [`HEARTBEAT_INTERVAL`]: the
/// lease deadline, not this timer, is the scheduling backstop.
/// Overridable per run via
/// [`DispatchConfig::peer_silence_timeout`](super::DispatchConfig) /
/// `--peer-silence-timeout-ms`.
pub const DEAD_AFTER: Duration = Duration::from_secs(10);

/// How long a freshly accepted connection gets to say `register`.
pub const REGISTER_TIMEOUT: Duration = Duration::from_secs(5);

/// Worker main-loop tick (poll sockets + child process this often).
const TICK: Duration = Duration::from_millis(10);

/// A worker connection that has completed the `register` handshake.
pub struct RegisteredWorker {
    pub conn: Conn,
    /// capability class the worker volunteered ("" = generic)
    pub class: String,
    /// engine threads the worker offers per lease
    pub threads: usize,
}

/// Accept-side half of the handshake: the first frame must be a
/// `register` within `timeout`.
pub fn accept_registration(stream: TcpStream, timeout: Duration) -> Result<RegisteredWorker> {
    let mut conn = Conn::new(stream)?;
    match conn.recv_timeout(timeout)? {
        Some(Msg::Register { class, threads }) => Ok(RegisteredWorker { conn, class, threads }),
        Some(other) => Err(Error::msg(format!(
            "{}: expected register, got {other:?}",
            conn.peer()
        ))),
        None => Err(Error::msg(format!(
            "{}: no register frame within {timeout:?}",
            conn.peer()
        ))),
    }
}

// ---------------------------------------------------------------------
// Coordinator side: TcpTransport
// ---------------------------------------------------------------------

enum SlotState {
    Idle,
    Running,
    Done(Box<ShardResult>),
    Failed(String),
}

struct TcpSlot {
    worker: RegisteredWorker,
    state: SlotState,
    /// job id the slot is waiting on (`None` = no reply expected; any
    /// manifest/failure tagged otherwise is a stale reply and dropped)
    expect: Option<u64>,
    next_job: u64,
    last_seen: Instant,
    /// socket gone (EOF, error or goodbye) — the slot can only fail
    dead: bool,
}

/// [`WorkerTransport`] over registered TCP worker connections.
pub struct TcpTransport {
    slots: Vec<TcpSlot>,
    /// half-open-peer reap window (default [`DEAD_AFTER`])
    peer_silence: Duration,
    obs: Obs,
}

impl TcpTransport {
    pub fn new(workers: Vec<RegisteredWorker>) -> Self {
        let now = Instant::now();
        let slots = workers
            .into_iter()
            .map(|worker| TcpSlot {
                worker,
                state: SlotState::Idle,
                expect: None,
                next_job: 0,
                last_seen: now,
                dead: false,
            })
            .collect();
        Self { slots, peer_silence: DEAD_AFTER, obs: Obs::default() }
    }

    /// Override the half-open-peer reap window (`--peer-silence-timeout-ms`).
    pub fn with_peer_silence(mut self, window: Duration) -> Self {
        self.peer_silence = window;
        self
    }

    /// The active half-open-peer reap window.
    pub fn peer_silence(&self) -> Duration {
        self.peer_silence
    }

    /// Attach an observability handle: peer reaps emit
    /// [`Event::PeerReaped`] through it.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    /// Accept and register exactly `n` workers from `listener`, failing
    /// if they don't all show up within `timeout`. The listener is left
    /// in non-blocking mode.
    pub fn accept(listener: &TcpListener, n: usize, timeout: Duration) -> Result<Self> {
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::msg(format!("listener set_nonblocking: {e}")))?;
        let deadline = Instant::now() + timeout;
        let mut workers = Vec::with_capacity(n);
        while workers.len() < n {
            match listener.accept() {
                Ok((stream, _)) => workers.push(accept_registration(stream, REGISTER_TIMEOUT)?),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(Error::msg(format!(
                            "only {} of {n} workers registered within {timeout:?}",
                            workers.len()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(Error::msg(format!("accept: {e}"))),
            }
        }
        Ok(Self::new(workers))
    }

    /// Capability class of each slot (status displays).
    pub fn classes(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.worker.class.clone()).collect()
    }

    /// Live (non-dead) worker count.
    pub fn alive(&self) -> usize {
        self.slots.iter().filter(|s| !s.dead).count()
    }

    /// Send `goodbye` to every live worker (orderly shutdown — workers
    /// exit cleanly instead of seeing an EOF mid-session).
    pub fn shutdown(&mut self) {
        for slot in &mut self.slots {
            if !slot.dead {
                let _ = slot.worker.conn.send(&Msg::Goodbye);
                slot.dead = true;
            }
        }
    }

    /// Drain the still-alive connections back out (the job server
    /// returns them to its registry between jobs). Dead slots are
    /// dropped; no goodbye is sent.
    pub fn reclaim(&mut self) -> Vec<RegisteredWorker> {
        std::mem::take(&mut self.slots)
            .into_iter()
            .filter(|s| !s.dead && !s.worker.conn.is_eof())
            .map(|s| s.worker)
            .collect()
    }

    /// Drain the socket and fold whatever arrived into the slot state.
    fn pump(&mut self, w: WorkerId) {
        let slot = &mut self.slots[w];
        if slot.dead {
            Self::fail_if_expecting(slot, format!("worker {w}: connection is gone"));
            return;
        }
        let peer = slot.worker.conn.peer().to_string();
        match slot.worker.conn.poll_msgs() {
            Ok(msgs) => {
                for msg in msgs {
                    slot.last_seen = Instant::now();
                    match msg {
                        Msg::Heartbeat => {}
                        Msg::Manifest { job, text } if slot.expect == Some(job) => {
                            slot.expect = None;
                            slot.state = match ShardResult::parse(&text) {
                                Ok(res) => SlotState::Done(Box::new(res)),
                                Err(e) => SlotState::Failed(format!(
                                    "worker {w} ({peer}): manifest rejected: {e}"
                                )),
                            };
                        }
                        Msg::JobFailed { job, error } if slot.expect == Some(job) => {
                            slot.expect = None;
                            slot.state =
                                SlotState::Failed(format!("worker {w} ({peer}): {error}"));
                        }
                        // stale reply for a killed/expired lease
                        Msg::Manifest { .. } | Msg::JobFailed { .. } => {}
                        Msg::Goodbye => slot.dead = true,
                        // anything else is a protocol violation from a
                        // worker; ignoring it is the byzantine-safe move
                        // (validation + audits judge results, not chatter)
                        _ => {}
                    }
                }
            }
            Err(e) => {
                slot.dead = true;
                Self::fail_if_expecting(slot, format!("worker {w} ({peer}): {e}"));
                return;
            }
        }
        if slot.worker.conn.is_eof() {
            slot.dead = true;
        }
        if slot.dead {
            Self::fail_if_expecting(
                slot,
                format!("worker {w} ({peer}): disconnected mid-lease"),
            );
        } else if slot.expect.is_some() && slot.last_seen.elapsed() > self.peer_silence {
            let window = self.peer_silence;
            slot.dead = true;
            self.obs
                .emit(Event::PeerReaped { worker: w, silence_ms: window.as_millis() as u64 });
            Self::fail_if_expecting(
                slot,
                format!("worker {w} ({peer}): no heartbeat for {window:?} — presumed dead"),
            );
        }
    }

    fn fail_if_expecting(slot: &mut TcpSlot, msg: String) {
        if slot.expect.take().is_some() {
            slot.state = SlotState::Failed(msg);
        }
    }
}

impl WorkerTransport for TcpTransport {
    fn n_workers(&self) -> usize {
        self.slots.len()
    }

    fn start(&mut self, worker: WorkerId, job: &WorkerJob) -> Result<()> {
        self.pump(worker);
        let slot = &mut self.slots[worker];
        if slot.dead {
            return Err(Error::msg(format!(
                "worker {worker} ({}) is disconnected",
                slot.worker.conn.peer()
            )));
        }
        if slot.expect.is_some() {
            return Err(Error::msg(format!("worker {worker} is already running a job")));
        }
        let id = slot.next_job;
        slot.next_job += 1;
        let lease = Msg::Lease {
            job: id,
            spec: LeaseSpec {
                config: job.config.clone(),
                lo: job.lo,
                hi: job.hi,
                threads: job.threads,
                stats_only: job.stats_only,
                delay_ms: job.delay_ms,
            },
        };
        if let Err(e) = slot.worker.conn.send(&lease) {
            slot.dead = true;
            return Err(Error::msg(format!(
                "worker {worker} ({}): lease send failed: {e}",
                slot.worker.conn.peer()
            )));
        }
        slot.expect = Some(id);
        slot.state = SlotState::Running;
        slot.last_seen = Instant::now();
        Ok(())
    }

    fn poll(&mut self, worker: WorkerId) -> WorkerPoll {
        self.pump(worker);
        let slot = &mut self.slots[worker];
        match &slot.state {
            SlotState::Idle => WorkerPoll::Idle,
            SlotState::Running => WorkerPoll::Running,
            SlotState::Done(_) => WorkerPoll::Done,
            SlotState::Failed(_) => {
                // one-shot, like a reaped subprocess: report the failure
                // and the slot is idle again
                let SlotState::Failed(msg) = std::mem::replace(&mut slot.state, SlotState::Idle)
                else {
                    unreachable!()
                };
                WorkerPoll::Failed(msg)
            }
        }
    }

    fn kill(&mut self, worker: WorkerId) {
        let slot = &mut self.slots[worker];
        if let Some(id) = slot.expect.take() {
            if !slot.dead && slot.worker.conn.send(&Msg::Kill { job: id }).is_err() {
                slot.dead = true;
            }
        }
        slot.state = SlotState::Idle;
    }

    fn collect(&mut self, worker: WorkerId) -> Result<ShardResult> {
        let slot = &mut self.slots[worker];
        match std::mem::replace(&mut slot.state, SlotState::Idle) {
            SlotState::Done(res) => Ok(*res),
            other => {
                slot.state = other;
                Err(Error::msg(format!("worker {worker} has no finished result to collect")))
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Worker side: gcod worker
// ---------------------------------------------------------------------

/// `gcod worker` configuration.
pub struct WorkerOpts {
    /// coordinator address, `host:port`
    pub coordinator: String,
    /// capability class to register with
    pub class: String,
    /// engine threads offered per lease (0 = all cores)
    pub threads: usize,
    /// the `gcod` binary to spawn for `sweep-shard` leases
    pub gcod_bin: PathBuf,
    /// connect attempts before giving up (the server may still be
    /// starting); also bounds each reconnect round after a session is
    /// lost mid-flight
    pub connect_retries: usize,
    /// delay between initial connect attempts, and the starting delay
    /// of the exponential reconnect backoff (doubles per attempt, caps
    /// at [`RECONNECT_DELAY_CAP`])
    pub retry_delay: Duration,
    /// observability handle: reconnects emit
    /// [`Event::WorkerReconnected`] through it
    pub obs: Obs,
}

impl WorkerOpts {
    pub fn new(coordinator: impl Into<String>, gcod_bin: impl Into<PathBuf>) -> Self {
        Self {
            coordinator: coordinator.into(),
            class: String::new(),
            threads: 1,
            gcod_bin: gcod_bin.into(),
            connect_retries: 50,
            retry_delay: Duration::from_millis(100),
            obs: Obs::default(),
        }
    }
}

/// Ceiling for the doubling reconnect delay after a lost session.
pub const RECONNECT_DELAY_CAP: Duration = Duration::from_secs(5);

/// Distinguishes scratch dirs when several worker loops share a process
/// (tests run them on threads).
static WORKER_SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

struct RunningLease {
    id: u64,
    child: Child,
    out_path: PathBuf,
    err_path: PathBuf,
}

impl RunningLease {
    fn abandon(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_file(&self.out_path);
        let _ = std::fs::remove_file(&self.err_path);
    }
}

/// How one worker↔coordinator session ended.
enum SessionEnd {
    /// orderly `goodbye` frame — the worker's job is done
    Goodbye,
    /// the socket died mid-session (EOF, send/recv error); the worker
    /// should abandon any running lease and reconnect
    ConnectionLost(String),
}

/// Serve leases from a coordinator until it says goodbye. Each lease
/// runs as a `gcod sweep-shard --range lo..hi` subprocess — the same
/// arguments and process boundary as local dispatch — and its manifest
/// text is returned over the socket verbatim.
///
/// A vanished coordinator (EOF or socket error mid-session) is NOT
/// fatal: the worker abandons its running lease (the restarted
/// coordinator will re-lease that range from its journal) and re-enters
/// the connect loop with exponential backoff starting at
/// `opts.retry_delay` and capped at [`RECONNECT_DELAY_CAP`], bounded by
/// `opts.connect_retries` attempts per round. Each successful reconnect
/// emits [`Event::WorkerReconnected`] through `opts.obs`.
///
/// Returns `Ok(jobs_completed)` (summed across sessions) on an orderly
/// goodbye; errors only when a reconnect round is exhausted. Either way
/// the scratch dir and any running subprocess are torn down.
pub fn worker_loop(opts: &WorkerOpts) -> Result<u64> {
    let scratch = std::env::temp_dir().join(format!(
        "gcod_worker_{}_{}",
        std::process::id(),
        WORKER_SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    if let Err(e) = std::fs::create_dir_all(&scratch) {
        return Err(Error::msg(format!("create scratch {}: {e}", scratch.display())));
    }
    let mut completed = 0u64;
    let mut next_stream = match connect_with_retry(opts) {
        Ok(s) => Some(s),
        Err(e) => {
            let _ = std::fs::remove_dir_all(&scratch);
            return Err(e);
        }
    };
    let result = loop {
        let stream = next_stream.take().expect("stream is set before every session");
        let mut running: Option<RunningLease> = None;
        let end = run_session(opts, stream, &scratch, &mut running, &mut completed);
        if let Some(lease) = running.take() {
            // the coordinator that leased this range is gone (or said
            // goodbye); its successor re-leases from the journal
            lease.abandon();
        }
        match end {
            Ok(SessionEnd::Goodbye) => break Ok(completed),
            Ok(SessionEnd::ConnectionLost(why)) => match reconnect_with_backoff(opts) {
                Ok((s, attempts)) => {
                    opts.obs.emit(Event::WorkerReconnected { attempts, detail: why });
                    next_stream = Some(s);
                }
                Err(e) => break Err(e),
            },
            Err(e) => break Err(e),
        }
    };
    let _ = std::fs::remove_dir_all(&scratch);
    result
}

/// One connected session: register, then serve leases until goodbye or
/// socket loss. Socket trouble during registration counts as a lost
/// session (the coordinator may be mid-restart), not a hard error.
fn run_session(
    opts: &WorkerOpts,
    stream: TcpStream,
    scratch: &std::path::Path,
    running: &mut Option<RunningLease>,
    completed: &mut u64,
) -> Result<SessionEnd> {
    let mut conn = Conn::new(stream)?;
    let register = Msg::Register { class: opts.class.clone(), threads: opts.threads };
    if let Err(e) = conn.send(&register) {
        return Ok(SessionEnd::ConnectionLost(format!("register failed: {e}")));
    }
    serve_leases(opts, &mut conn, scratch, running, completed)
}

fn connect_with_retry(opts: &WorkerOpts) -> Result<TcpStream> {
    let mut last_err = String::new();
    for _ in 0..opts.connect_retries.max(1) {
        match TcpStream::connect(&opts.coordinator) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = e.to_string(),
        }
        std::thread::sleep(opts.retry_delay);
    }
    Err(Error::msg(format!(
        "could not reach coordinator {} after {} attempts: {last_err}",
        opts.coordinator,
        opts.connect_retries.max(1)
    )))
}

/// Like [`connect_with_retry`] but with a doubling delay (capped at
/// [`RECONNECT_DELAY_CAP`]) — used after a session is lost, where the
/// coordinator restart may take a while. Returns the stream and the
/// number of attempts it took.
fn reconnect_with_backoff(opts: &WorkerOpts) -> Result<(TcpStream, u64)> {
    let mut delay = opts.retry_delay.max(Duration::from_millis(1));
    let mut last_err = String::new();
    let rounds = opts.connect_retries.max(1);
    for attempt in 1..=rounds {
        match TcpStream::connect(&opts.coordinator) {
            Ok(s) => return Ok((s, attempt as u64)),
            Err(e) => last_err = e.to_string(),
        }
        std::thread::sleep(delay);
        delay = (delay * 2).min(RECONNECT_DELAY_CAP);
    }
    Err(Error::msg(format!(
        "lost coordinator {} and could not reconnect after {rounds} attempts: {last_err}",
        opts.coordinator
    )))
}

fn serve_leases(
    opts: &WorkerOpts,
    conn: &mut Conn,
    scratch: &std::path::Path,
    running: &mut Option<RunningLease>,
    completed: &mut u64,
) -> Result<SessionEnd> {
    let mut last_beat = Instant::now();
    loop {
        let msgs = match conn.poll_msgs() {
            Ok(msgs) => msgs,
            Err(e) => return Ok(SessionEnd::ConnectionLost(format!("recv failed: {e}"))),
        };
        for msg in msgs {
            match msg {
                Msg::Lease { job, spec } => {
                    if let Some(old) = running.take() {
                        // a lease while busy means the coordinator gave
                        // up on the old job (kill frame raced or lost)
                        old.abandon();
                    }
                    match spawn_lease(opts, scratch, job, &spec) {
                        Ok(lease) => *running = Some(lease),
                        Err(e) => {
                            let fail = Msg::JobFailed { job, error: e.to_string() };
                            if let Err(e) = conn.send(&fail) {
                                return Ok(SessionEnd::ConnectionLost(format!(
                                    "send failed: {e}"
                                )));
                            }
                        }
                    }
                }
                Msg::Kill { job } => {
                    if running.as_ref().is_some_and(|r| r.id == job) {
                        running.take().expect("matched above").abandon();
                    }
                }
                Msg::Goodbye => return Ok(SessionEnd::Goodbye),
                // coordinators don't send anything else to workers
                _ => {}
            }
        }
        if conn.is_eof() {
            return Ok(SessionEnd::ConnectionLost(
                "coordinator closed the connection without goodbye".into(),
            ));
        }
        if let Some(lease) = running.take() {
            match reap_lease(lease) {
                LeaseTick::StillRunning(lease) => *running = Some(lease),
                LeaseTick::Finished(job, outcome) => {
                    let msg = match outcome {
                        Ok(text) => {
                            *completed += 1;
                            Msg::Manifest { job, text }
                        }
                        Err(e) => Msg::JobFailed { job, error: e.to_string() },
                    };
                    if let Err(e) = conn.send(&msg) {
                        return Ok(SessionEnd::ConnectionLost(format!("send failed: {e}")));
                    }
                }
            }
        }
        if last_beat.elapsed() >= HEARTBEAT_INTERVAL {
            if let Err(e) = conn.send(&Msg::Heartbeat) {
                return Ok(SessionEnd::ConnectionLost(format!("heartbeat failed: {e}")));
            }
            last_beat = Instant::now();
        }
        std::thread::sleep(TICK);
    }
}

fn spawn_lease(
    opts: &WorkerOpts,
    scratch: &std::path::Path,
    job: u64,
    spec: &LeaseSpec,
) -> Result<RunningLease> {
    let out_path = scratch.join(format!("lease_{job}_{}_{}.json", spec.lo, spec.hi));
    let err_path = out_path.with_extension("stderr.log");
    let wjob = WorkerJob {
        config: spec.config.clone(),
        lo: spec.lo,
        hi: spec.hi,
        threads: spec.threads,
        stats_only: spec.stats_only,
        out_path: out_path.clone(),
        delay_ms: spec.delay_ms,
    };
    let err_file = std::fs::File::create(&err_path)
        .map_err(|e| Error::msg(format!("create {}: {e}", err_path.display())))?;
    let mut cmd = Command::new(&opts.gcod_bin);
    cmd.args(shard_args(&wjob)).stdout(Stdio::null()).stderr(Stdio::from(err_file));
    if wjob.delay_ms > 0 {
        cmd.env(DELAY_ENV, wjob.delay_ms.to_string());
    }
    let child = cmd
        .spawn()
        .map_err(|e| Error::msg(format!("spawn {}: {e}", opts.gcod_bin.display())))?;
    Ok(RunningLease { id: job, child, out_path, err_path })
}

enum LeaseTick {
    StillRunning(RunningLease),
    Finished(u64, Result<String>),
}

fn reap_lease(mut lease: RunningLease) -> LeaseTick {
    match lease.child.try_wait() {
        Ok(None) => LeaseTick::StillRunning(lease),
        Ok(Some(status)) => {
            let stderr = read_tail(&lease.err_path, 4096);
            let _ = std::fs::remove_file(&lease.err_path);
            let outcome = if status.success() && lease.out_path.is_file() {
                std::fs::read_to_string(&lease.out_path)
                    .map_err(|e| Error::msg(format!("read {}: {e}", lease.out_path.display())))
            } else {
                Err(Error::msg(format!(
                    "shard process exited ({status}) without a manifest{}{}",
                    if stderr.is_empty() { "" } else { ": " },
                    stderr
                )))
            };
            let _ = std::fs::remove_file(&lease.out_path);
            LeaseTick::Finished(lease.id, outcome)
        }
        Err(e) => {
            let _ = lease.child.kill();
            let _ = lease.child.wait();
            LeaseTick::Finished(lease.id, Err(Error::msg(format!("wait failed: {e}"))))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::shard::{SweepConfig, SweepKind};
    use std::collections::BTreeMap;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            sweep: SweepKind::DecodeError,
            scheme: "graph-rr:16,3".into(),
            decoder: "optimal".into(),
            p: 0.2,
            seed: 11,
            trials: 8,
            chunk: 8,
            params: BTreeMap::new(),
        }
    }

    #[test]
    fn peer_silence_defaults_to_dead_after_and_overrides() {
        let t = TcpTransport::new(Vec::new());
        assert_eq!(t.peer_silence(), DEAD_AFTER);
        let t = t.with_peer_silence(Duration::from_millis(1234));
        assert_eq!(t.peer_silence(), Duration::from_millis(1234));
    }

    #[test]
    fn silent_peer_is_reaped_after_the_configured_window() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // the "worker": registers, then goes silent (no heartbeats) —
        // kept in scope so the socket stays open (half-open simulation)
        let mut client = TcpStream::connect(addr).unwrap();
        super::super::protocol::write_frame(
            &mut client,
            &Msg::Register { class: String::new(), threads: 1 },
        )
        .unwrap();
        let (stream, _) = listener.accept().unwrap();
        let rw = accept_registration(stream, Duration::from_secs(5)).unwrap();
        let mut t = TcpTransport::new(vec![rw]).with_peer_silence(Duration::from_millis(60));
        let obs = Obs::new();
        t.set_obs(obs.clone());
        let job = WorkerJob {
            config: tiny_cfg(),
            lo: 0,
            hi: 8,
            threads: 1,
            stats_only: false,
            out_path: std::env::temp_dir().join("gcod_tcp_silence_test.json"),
            delay_ms: 0,
        };
        t.start(0, &job).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let failure = loop {
            match t.poll(0) {
                WorkerPoll::Failed(msg) => break msg,
                _ => {
                    assert!(Instant::now() < deadline, "silent peer was never reaped");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        assert!(failure.contains("no heartbeat"), "unexpected failure: {failure}");
        let reaps: Vec<_> = obs
            .flight_log()
            .into_iter()
            .filter(|(_, e)| matches!(e, Event::PeerReaped { worker: 0, .. }))
            .collect();
        assert_eq!(reaps.len(), 1, "exactly one structured peer-reap event");
        drop(client);
    }

    #[test]
    fn worker_reconnects_after_coordinator_socket_loss() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let obs = Obs::new();
        let mut opts = WorkerOpts::new(addr.to_string(), "/bin/true");
        opts.retry_delay = Duration::from_millis(10);
        opts.obs = obs.clone();
        let handle = std::thread::spawn(move || worker_loop(&opts));
        // session 1: accept the registration, then drop the socket
        // without a goodbye — simulates a crashed coordinator
        let (s1, _) = listener.accept().unwrap();
        let rw1 = accept_registration(s1, Duration::from_secs(5)).unwrap();
        drop(rw1);
        // session 2: the worker must come back and re-register; an
        // orderly goodbye then ends the loop cleanly
        let (s2, _) = listener.accept().unwrap();
        let mut rw2 = accept_registration(s2, Duration::from_secs(5)).unwrap();
        rw2.conn.send(&Msg::Goodbye).unwrap();
        let completed = handle.join().unwrap().unwrap();
        assert_eq!(completed, 0, "no leases were served");
        let reconnects: Vec<_> = obs
            .flight_log()
            .into_iter()
            .filter(|(_, e)| matches!(e, Event::WorkerReconnected { .. }))
            .collect();
        assert_eq!(reconnects.len(), 1, "exactly one worker-reconnected event");
    }
}
